"""Single-token GQA decode attention Pallas-TPU kernel.

Serving hot spot: one query per sequence against a long KV cache.  On TPU the
decode step is HBM-bandwidth-bound (the whole cache streams through VMEM
once), so the kernel:

  * batches all ``rep = H // KV`` query heads of a KV group into ONE MXU
    matmul per cache block — (rep × hd) @ (hd × block_k) — instead of rep
    vector-matrix products;
  * streams the cache in (block_k, hd) VMEM tiles along the innermost
    sequential grid axis with f32 online-softmax scratch carried across
    blocks;
  * consumes a per-token validity mask (ring-buffer caches pass their
    occupancy/window mask) as a (1, block_k) SMEM-friendly tile.

Layouts: q (B, KV, rep, hd); k/v (B, KV, T, hd); valid (B, T) bool.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, n_k: int, block_k: int, seq_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (rep, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    valid = valid_ref[0]                           # (bk,) bool
    # guard the ragged tail: padded block positions are never valid, and the
    # padded k/v payload must be zeroed (garbage * 0 would still poison acc)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (valid.shape[0],), 0)
    inb = cols < seq_k
    valid = valid & inb
    k = jnp.where(inb[:, None], k, 0.0)
    v = jnp.where(inb[:, None], v, 0.0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (rep, bk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]                            # (rep,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(valid[None, :], p, 0.0)          # kill exp(NEG-NEG)=1 artifacts
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, valid, *, block_k: int = 512, interpret: bool = False):
    """q: (B, KV, rep, hd); k/v: (B, KV, T, hd); valid: (B, T) -> (B, KV, rep, hd)."""
    B, KV, rep, hd = q.shape
    T = k.shape[2]
    block_k = max(min(block_k, T), 8)
    n_k = pl.cdiv(T, block_k)
    # pad T to a block multiple via the validity mask semantics: BlockSpec
    # handles the ragged tail (Pallas pads; the mask must cover it)
    grid = (B, KV, n_k)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), n_k=n_k, block_k=block_k, seq_k=T
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, ik: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, g, ik: (b, g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, g, ik: (b, g, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, g, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, ik: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
