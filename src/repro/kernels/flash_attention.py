"""Flash-attention forward Pallas-TPU kernel (causal / sliding-window, GQA).

TPU adaptation of the blocked online-softmax algorithm:
  * grid (B, H, n_q_blocks, n_k_blocks) — the k-block axis is innermost and
    sequential on a TensorCore, so the f32 (m, l, acc) running statistics
    live in VMEM scratch and persist across k-steps;
  * BlockSpecs tile q/k/v/out into (block_q|block_k, head_dim) VMEM tiles;
    block sizes default to 128 to keep MXU matmul dims hardware-aligned;
  * GQA is handled in the k/v index_map (query head h reads kv head
    h // (H // KV)) — no materialized repeat;
  * masking (causal and/or sliding window) is applied inside the kernel from
    global row/col indices.

VMEM working set per program:
  q (bq·hd) + k,v (2·bk·hd) + acc (bq·hd f32) + out ≈ 260 KiB at 128×128,
well within v5e VMEM (~16 MiB), leaving room for the compiler's double
buffering of the k/v streams.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, n_k: int, seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    # zero the ragged-tail padding (garbage would poison acc via 0*NaN)
    kcols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (k.shape[0],), 0)
    inb = (kcols < seq_k)[:, None]
    k = jnp.where(inb, k, 0.0)
    v = jnp.where(inb, v, 0.0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (bq, bk)

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (rows < seq_q) & (cols < seq_k)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])               # (bq, bk)
    p = jnp.where(mask, p, 0.0)                   # kill exp(NEG-NEG)=1 artifacts
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _finish():
        # fully-masked rows (e.g. padding) have l == 0; emit zeros not NaNs
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: (B, H, S, hd); k/v: (B, KV, T, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    rep = H // KV
    block_q = max(min(block_q, S), 8)
    block_k = max(min(block_k, T), 8)
    n_q = pl.cdiv(S, block_q)
    n_k = pl.cdiv(T, block_k)
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(
        _kernel,
        scale=1.0 / math.sqrt(hd),
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        seq_q=S,
        seq_k=T,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
