"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Shapes use the *kernel* layouts (ops.py adapts from model layouts):
  flash_attention_ref : q (B,H,S,hd),  k/v (B,KV,T,hd)
  decode_attention_ref: q (B,H,hd),    k/v (B,KV,T,hd), valid (B,T)
  ssd_ref             : x (B,H,S,P), dt (B,H,S), A (H,), Bm/Cm (B,H,S,N)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "decode_attention_ref", "ssd_ref"]


def flash_attention_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None):
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, kr).astype(jnp.float32) / math.sqrt(hd)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", w, vr)


def decode_attention_ref(q, k, v, valid):
    """q: (B,H,hd) one query; k/v: (B,KV,T,hd); valid: (B,T) bool."""
    B, H, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q, kr).astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bht,bhtd->bhd", w, vr)


def ssd_ref(x, dt, A, Bm, Cm, chunk: int):
    """Head-major SSD oracle.  x: (B,H,S,P), dt: (B,H,S), A: (H,),
    Bm/Cm: (B,H,S,N) (groups already broadcast to heads)."""
    from ..models.ssm import ssd_reference

    xs = x.transpose(0, 2, 1, 3)          # (B,S,H,P)
    dts = dt.transpose(0, 2, 1)           # (B,S,H)
    Bs = Bm.transpose(0, 2, 1, 3)         # (B,S,H,N) == groups-as-heads
    Cs = Cm.transpose(0, 2, 1, 3)
    y = ssd_reference(xs, dts, A, Bs, Cs, chunk)
    return y.transpose(0, 2, 1, 3)        # (B,H,S,P)
