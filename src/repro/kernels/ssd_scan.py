"""Mamba-2 SSD chunked-scan Pallas-TPU kernel.

TPU adaptation of the state-space-duality algorithm (arXiv:2405.21060):
the sequence is processed in chunks of Q tokens; within a chunk the quadratic
(C·Bᵀ ⊙ decay) form runs on the MXU as (Q×N)@(N×Q) and (Q×Q)@(Q×P) matmuls;
across chunks the (N×P) recurrent state is carried in VMEM scratch along the
innermost sequential grid axis — the classic scan-as-grid-walk pattern.

Grid: (B, H, n_chunks).  Per-program VMEM working set at Q=128, N=128, P=64:
x (Q·P) + B,C (2·Q·N) + decay (Q·Q) + state (N·P f32) ≈ 200 KiB.

Layouts (head-major; ops.py adapts): x (B,H,S,P), dt (B,H,S), A (H,),
Bm/Cm (B,H,S,N) with SSM groups pre-broadcast to heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q,)
    A = a_ref[0].astype(jnp.float32)               # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (Q, N)

    dA = dt * A                                    # (Q,) negative
    cs = jnp.cumsum(dA)                            # (Q,)

    # ---- intra-chunk: y_intra[i] = sum_{j<=i} exp(cs_i - cs_j) dt_j (C_i·B_j) x_j
    seg = cs[:, None] - cs[None, :]                # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(cols <= rows, jnp.exp(seg), 0.0)  # causal decay matrix
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (Q, Q)
    w = scores * L * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (Q, P)

    # ---- inter-chunk: y += (C_i exp(cs_i)) @ state_prev
    carry_in = state_ref[...]                      # (N, P) f32
    y = y + jax.lax.dot_general(
        Cm * jnp.exp(cs)[:, None], carry_in,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    # ---- state update: state = exp(sum dA) * state + sum_j exp(cs_Q - cs_j) dt_j B_j ⊗ x_j
    total = cs[-1]
    decay_to_end = jnp.exp(total - cs)             # (Q,)
    wB = Bm * (decay_to_end * dt)[:, None]         # (Q, N)
    new_state = jax.lax.dot_general(
        wB, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (N, P)
    state_ref[...] = jnp.exp(total) * carry_in + new_state

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B,H,S,P), dt: (B,H,S), A: (H,), Bm/Cm: (B,H,S,N) -> y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
