"""Fused Pallas kernel for the hierarchical analytic allocator.

One grid program allocates one frame's class queue: the padded (C, M, L)
class tensors are loaded into VMEM once, and the whole class walk —
masked argmax over the (M, L) slab, analytic chunk sizing by f32 floor
division, budget depletion — runs fused on chip without round-tripping
the shrinking ``gamma``/``eta`` vectors to HBM between classes.  Output
is the fixed-shape ``(take, start)`` cell pair (see
``repro.core.aggregation``): ``take[c, j, l]`` members of class ``c`` go
to cell ``(j, l)`` starting at member offset ``start[c, j, l]``.

Grid decision: the grid is ``(B,)`` — one program per frame in the batch,
like the dense GUS kernel — **not** ``(B, class-chunks)``.  The budget
vectors are a sequential carry across the entire class axis, so a
class-chunked grid would need cross-program carry through scratch or
revisited output blocks; both break under ``vmap`` batching (vmap
prepends a grid axis and shifts ``pl.program_id`` semantics), and the
fleet runner vmaps this kernel over replications inside ``lax.scan``.
The class axis is walked in-kernel with ``fori_loop`` instead; classes
are already the compressed representation, so ``C`` is small (padded to
a power-of-two bucket) and the sequential walk is the algorithm, not a
layout artifact.

Layout per program (all VMEM):

  us/v/u       : (1, C, M, L)  class candidate tensors, f32
  feas         : (1, C, M, L)  feasibility mask, f32 0/1 (uniform tiling
                               with the candidate tensors, as in the
                               dense kernel)
  cover/count  : (1, C)        class cover server / member count, int32
  gamma/eta    : (1, M)        per-server budgets (loop carry)
  out take     : (1, C, M, L)  int32 members allocated per cell
  out start    : (1, C, M, L)  int32 first member offset per cell

Bit-parity contract: the chunk-sizing arithmetic is op-for-op the f32
sequence of ``repro.core.aggregation.hier_cells_np`` and its jitted XLA
twin — ``floor(budget / cost)``, ``min`` against the remainder in f32
*before* the int32 cast (overflow guard for tiny costs), commit via
``budget + (-(f32(take) * cost))``.  Integer outputs must equal both
exactly (``tests/test_hier_parity.py`` is the three-way harness).

This module depends only on jax — never on ``repro.core`` (the core's
aggregation module imports *us*, and a reverse import would cycle).
``interpret=True`` runs the kernel body as plain jax ops (CPU CI); on a
TPU backend the default is the compiled Mosaic path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hier_cells_pallas"]

#: matches ``repro.core.aggregation._NEG`` — the masked-out cell score.
NEG = -1e30


def _hier_kernel(
    us_ref, feas_ref, v_ref, u_ref, cover_ref, count_ref,
    gamma_ref, eta_ref,
    take_ref, start_ref,
    *, n_classes: int,
):
    us = us_ref[0]
    feas = feas_ref[0] != 0.0
    v = v_ref[0]
    u = u_ref[0]
    cover = cover_ref[0]
    count = count_ref[0]
    M, L = us.shape[1], us.shape[2]

    def cls_body(c, state):
        gamma, eta, take_all, start_all = state
        s = jax.lax.dynamic_index_in_dim(cover, c, keepdims=False)
        cnt = jax.lax.dynamic_index_in_dim(count, c, keepdims=False)
        us_c = jax.lax.dynamic_index_in_dim(us, c, keepdims=False)
        feas_c = jax.lax.dynamic_index_in_dim(feas, c, keepdims=False)
        v_c = jax.lax.dynamic_index_in_dim(v, c, keepdims=False)
        u_c = jax.lax.dynamic_index_in_dim(u, c, keepdims=False)
        is_local = jnp.arange(M, dtype=jnp.int32) == s

        def cond(st):
            return st[-1]

        def chunk(st):
            rem, gamma, eta, take, start, used, _ = st
            eta_s = jax.lax.dynamic_index_in_dim(eta, s, keepdims=False)
            ok = (
                feas_c
                & (v_c <= gamma[:, None])
                & (is_local[:, None] | (u_c <= eta_s))
            )
            score = jnp.where(ok, us_c, NEG).reshape(-1)
            flat = jnp.argmax(score)
            any_ok = score[flat] > NEG
            j = (flat // L).astype(jnp.int32)
            l = (flat % L).astype(jnp.int32)
            vv = v_c[j, l]
            uv = u_c[j, l]
            offl = j != s
            rem_f = rem.astype(jnp.float32)
            cap_g = jnp.where(
                vv > 0, jnp.floor(gamma[j] / jnp.where(vv > 0, vv, 1.0)), rem_f
            )
            cap_e = jnp.where(
                offl & (uv > 0),
                jnp.floor(eta_s / jnp.where(uv > 0, uv, 1.0)),
                rem_f,
            )
            t_f = jnp.minimum(rem_f, jnp.minimum(cap_g, cap_e))
            t = t_f.astype(jnp.int32)
            do = any_ok & (t >= 1)
            tf32 = jnp.where(do, t, 0).astype(jnp.float32)
            gamma = gamma.at[j].add(-(tf32 * vv))
            eta = eta.at[s].add(jnp.where(offl, -(tf32 * uv), 0.0))
            first = take[j, l] == 0
            start = start.at[j, l].set(
                jnp.where(do & first, used, start[j, l])
            )
            take = take.at[j, l].add(jnp.where(do, t, 0))
            used = used + jnp.where(do, t, 0)
            rem = rem - jnp.where(do, t, 0)
            return rem, gamma, eta, take, start, used, do & (rem > 0)

        st0 = (
            cnt,
            gamma,
            eta,
            jnp.zeros((M, L), jnp.int32),
            jnp.zeros((M, L), jnp.int32),
            jnp.int32(0),
            feas_c.any() & (cnt > 0),
        )
        _, gamma, eta, take, start, _, _ = jax.lax.while_loop(
            cond, chunk, st0
        )
        take_all = jax.lax.dynamic_update_index_in_dim(take_all, take, c, 0)
        start_all = jax.lax.dynamic_update_index_in_dim(start_all, start, c, 0)
        return gamma, eta, take_all, start_all

    init = (
        gamma_ref[0],
        eta_ref[0],
        jnp.zeros((n_classes, M, L), jnp.int32),
        jnp.zeros((n_classes, M, L), jnp.int32),
    )
    _, _, take, start = jax.lax.fori_loop(0, n_classes, cls_body, init)
    take_ref[0] = take
    start_ref[0] = start


def hier_cells_pallas(
    us, feas, v, u, cover, count, gamma, eta, *, interpret=None,
):
    """Run the fused hierarchical allocator on a batch of frames.

    Shapes (leading batch axis ``B`` required; ``repro.core.aggregation``
    adds it for single frames): ``us/feas/v/u`` ``(B, C, M, L)``;
    ``cover/count`` ``(B, C)``; ``gamma/eta`` ``(B, M)``.  Returns
    ``(take, start)`` int32 ``(B, C, M, L)``.  ``interpret=None`` resolves
    via :func:`repro.kernels.gus_pallas.gus_pallas_interpret_default`.
    """
    if interpret is None:
        from repro.kernels.gus_pallas import gus_pallas_interpret_default

        interpret = gus_pallas_interpret_default()
    B, C, M, L = us.shape
    if C == 0:
        empty = jnp.zeros((B, 0, M, L), jnp.int32)
        return empty, empty

    cls = pl.BlockSpec((1, C), lambda b: (b, 0))
    cand = pl.BlockSpec((1, C, M, L), lambda b: (b, 0, 0, 0))
    srv = pl.BlockSpec((1, M), lambda b: (b, 0))
    take, start = pl.pallas_call(
        functools.partial(_hier_kernel, n_classes=C),
        grid=(B,),
        in_specs=[cand, cand, cand, cand, cls, cls, srv, srv],
        out_specs=[cand, cand],
        out_shape=[jax.ShapeDtypeStruct((B, C, M, L), jnp.int32)] * 2,
        interpret=interpret,
    )(
        us.astype(jnp.float32),
        feas.astype(jnp.float32),
        v.astype(jnp.float32),
        u.astype(jnp.float32),
        cover.astype(jnp.int32),
        count.astype(jnp.int32),
        gamma.astype(jnp.float32),
        eta.astype(jnp.float32),
    )
    return take, start
