"""Pallas-TPU kernels for the serving hot spots (validated interpret=True on
CPU against the pure-jnp oracles in ref.py):

  flash_attention  — blocked online-softmax prefill attention (causal/window)
  decode_attention — single-token GQA attention over a long KV cache
  ssd_scan         — Mamba-2 chunked SSD scan with VMEM state carry
  gus_pallas       — fused GUS greedy-assignment kernel (utility + feasibility
                     + capacity-aware argmax loop), bit-parity-tested against
                     the NumPy and XLA schedulers in repro.core.gus
"""
from . import ops, ref
from .flash_attention import flash_attention as flash_attention_kernel
from .decode_attention import decode_attention as decode_attention_kernel
from .gus_pallas import gus_assign_pallas
from .ssd_scan import ssd_scan as ssd_scan_kernel

__all__ = [
    "ops",
    "ref",
    "flash_attention_kernel",
    "decode_attention_kernel",
    "gus_assign_pallas",
    "ssd_scan_kernel",
]
