"""jit'd public wrappers for the Pallas kernels.

These adapt *model* layouts to *kernel* layouts, pick interpret mode
automatically off-TPU (the kernel body then runs in Python on CPU — exactly
how the test-suite validates TPU-targeted kernels in this container), and fall
back to the pure-jnp oracle for shapes a kernel does not support."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_kernel
from .flash_attention import flash_attention as _flash_kernel
from .ssd_scan import ssd_scan as _ssd_kernel

__all__ = ["flash_attention", "decode_attention", "ssd", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    """Model layout: q (B,S,H,hd); k/v (B,T,KV,hd) -> (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_kernel(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, valid, *, block_k: int = 512):
    """Model layout: q (B,H,hd) one token; k/v cache (B,T,KV,hd); valid (B,T)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, KV, rep, hd)
    kt = k.transpose(0, 2, 1, 3)   # (B,KV,T,hd)
    vt = v.transpose(0, 2, 1, 3)
    out = _decode_kernel(qg, kt, vt, valid, block_k=block_k, interpret=not on_tpu())
    return out.reshape(B, H, hd)


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Model layout: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,G,N)."""
    B, S, H, P = x.shape
    G = Bm.shape[2]
    rep = H // G
    xt = x.transpose(0, 2, 1, 3)                      # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)                       # (B,H,S)
    Bh = jnp.repeat(Bm.transpose(0, 2, 1, 3), rep, 1)  # (B,H,S,N)
    Ch = jnp.repeat(Cm.transpose(0, 2, 1, 3), rep, 1)
    if S % chunk:
        return ref.ssd_ref(xt, dtt, A, Bh, Ch, chunk).transpose(0, 2, 1, 3)
    y = _ssd_kernel(xt, dtt, A, Bh, Ch, chunk=chunk, interpret=not on_tpu())
    return y.transpose(0, 2, 1, 3)
