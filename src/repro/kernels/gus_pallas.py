"""Fused Pallas kernel for the GUS greedy assignment core.

One grid program schedules one frame: the per-candidate utility tensor
(Eq. 1), hard feasibility, and the capacity-aware greedy argmax loop of
Algorithm 1 all run fused in on-chip memory — the (N, M, L) candidate
tensors are loaded into VMEM once and never round-trip to HBM between the
utility computation and the N sequential greedy steps.  The grid is the
frame batch, so a fleet's ``R`` replications (or a Monte-Carlo sweep's
stacked instances) become ``R`` independent grid programs.

Layout per program (all VMEM):

  cover/A/C/w_a/w_c : (1, N)        request rows
  acc/ctime/v/u     : (1, N, M, L)  candidate tensors, f32
  avail             : (1, N, M, L)  placement mask, f32 0/1 (f32 keeps the
                                    VMEM tiling uniform with the candidate
                                    tensors; bool/i8 loads buy nothing here)
  gamma/eta         : (1, M)        per-server budgets (greedy loop state)
  scal              : (1, 2)        [max_as, max_cs] normalizers
  out j/l           : (1, N)        int32 assignment (-1 = dropped)

The greedy loop is a ``fori_loop`` whose carry holds the depleting budgets
and the assignment vectors; each step is a masked argmax over the (M, L)
candidate slab.  Bit-parity contract: the utility expression below is
op-for-op the one in :func:`repro.core.satisfaction.us_tensor`, the
feasibility mask matches :func:`~repro.core.satisfaction.hard_feasible`,
and the loop body mirrors ``repro.core.gus._gus_body`` — integer
assignments from this kernel must equal the jitted XLA path and the NumPy
oracle *exactly* (``tests/test_gus_parity.py`` is the three-way harness).

This module depends only on jax — never on ``repro.core`` (the core's GUS
module imports *us*, and a reverse import would cycle).  ``interpret=True``
runs the kernel body as plain jax ops, which is how the CPU CI validates
it; on a TPU backend the default is the compiled Mosaic path.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gus_assign_pallas", "gus_pallas_interpret_default"]

#: matches ``repro.core.gus.NEG`` — the masked-out candidate score.  The
#: parity bar requires the identical sentinel: a served/dropped decision is
#: ``score > NEG`` in both implementations.
NEG = -1e30


def gus_pallas_interpret_default() -> bool:
    """Interpret off (compiled Mosaic) on TPU, on everywhere else.

    ``REPRO_PALLAS_INTERPRET=0|1`` overrides — e.g. force interpret on a TPU
    host to debug, or assert the compiled path in an accelerator CI job.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _gus_kernel(
    cover_ref, A_ref, C_ref, wa_ref, wc_ref,
    acc_ref, ctime_ref, v_ref, u_ref, avail_ref,
    gamma_ref, eta_ref, scal_ref,
    j_ref, l_ref,
    *, n_requests: int,
):
    cover = cover_ref[0]
    A = A_ref[0]
    C = C_ref[0]
    w_a = wa_ref[0]
    w_c = wc_ref[0]
    acc = acc_ref[0]
    ctime = ctime_ref[0]
    v = v_ref[0]
    u = u_ref[0]
    avail = avail_ref[0] != 0.0
    max_as = scal_ref[0, 0]
    max_cs = scal_ref[0, 1]
    M, L = acc.shape[1], acc.shape[2]

    # --- fused utility + feasibility (us_tensor / hard_feasible, op-for-op)
    acc_term = (acc - A[:, None, None]) / max_as
    time_term = (C[:, None, None] - ctime) / max_cs
    us = w_a[:, None, None] * acc_term + w_c[:, None, None] * time_term
    feas = avail & (acc >= A[:, None, None]) & (ctime <= C[:, None, None])

    # --- Algorithm 1's greedy loop (mirrors repro.core.gus._gus_body) ------
    def body(i, state):
        gamma, eta, out_j, out_l = state
        s_i = jax.lax.dynamic_index_in_dim(cover, i, keepdims=False)
        row_us = jax.lax.dynamic_index_in_dim(us, i, keepdims=False)
        row_v = jax.lax.dynamic_index_in_dim(v, i, keepdims=False)
        row_u = jax.lax.dynamic_index_in_dim(u, i, keepdims=False)
        row_ok = jax.lax.dynamic_index_in_dim(feas, i, keepdims=False)
        is_local = jnp.arange(M) == s_i
        eta_s = jax.lax.dynamic_index_in_dim(eta, s_i, keepdims=False)

        ok = row_ok & (row_v <= gamma[:, None]) & (is_local[:, None] | (row_u <= eta_s))
        score = jnp.where(ok, row_us, NEG)
        flat = jnp.argmax(score.reshape(-1))
        any_ok = score.reshape(-1)[flat] > NEG
        j = (flat // L).astype(jnp.int32)
        l = (flat % L).astype(jnp.int32)

        served = any_ok
        offload = served & (j != s_i)
        gamma = gamma.at[j].add(jnp.where(served, -row_v[j, l], 0.0))
        eta = eta.at[s_i].add(jnp.where(offload, -row_u[j, l], 0.0))
        out_j = out_j.at[i].set(jnp.where(served, j, -1))
        out_l = out_l.at[i].set(jnp.where(served, l, -1))
        return gamma, eta, out_j, out_l

    init = (
        gamma_ref[0],
        eta_ref[0],
        jnp.full((n_requests,), -1, jnp.int32),
        jnp.full((n_requests,), -1, jnp.int32),
    )
    _, _, out_j, out_l = jax.lax.fori_loop(0, n_requests, body, init)
    j_ref[0] = out_j
    l_ref[0] = out_l


def gus_assign_pallas(
    cover, A, C, w_a, w_c, acc, ctime, v, u, avail, gamma, eta,
    max_as, max_cs, *, interpret=None,
):
    """Run the fused GUS kernel on a batch of frames.

    Shapes (leading batch axis ``B`` required; ``repro.core.gus`` adds it
    for single frames): ``cover/A/C/w_a/w_c`` ``(B, N)``;
    ``acc/ctime/v/u/avail`` ``(B, N, M, L)``; ``gamma/eta`` ``(B, M)``;
    ``max_as/max_cs`` ``(B,)``.  Returns ``(j, l)`` int32 ``(B, N)`` arrays
    with ``-1`` encoding *drop*.  ``interpret=None`` resolves via
    :func:`gus_pallas_interpret_default`.
    """
    if interpret is None:
        interpret = gus_pallas_interpret_default()
    B, N, M, L = acc.shape
    if N == 0:
        empty = jnp.full((B, 0), -1, jnp.int32)
        return empty, empty
    scal = jnp.stack(
        [jnp.broadcast_to(max_as, (B,)), jnp.broadcast_to(max_cs, (B,))], axis=-1
    ).astype(jnp.float32)

    row = pl.BlockSpec((1, N), lambda b: (b, 0))
    cand = pl.BlockSpec((1, N, M, L), lambda b: (b, 0, 0, 0))
    srv = pl.BlockSpec((1, M), lambda b: (b, 0))
    out_j, out_l = pl.pallas_call(
        functools.partial(_gus_kernel, n_requests=N),
        grid=(B,),
        in_specs=[row, row, row, row, row, cand, cand, cand, cand, cand,
                  srv, srv, pl.BlockSpec((1, 2), lambda b: (b, 0))],
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((B, N), jnp.int32)] * 2,
        interpret=interpret,
    )(
        cover.astype(jnp.int32),
        A.astype(jnp.float32),
        C.astype(jnp.float32),
        w_a.astype(jnp.float32),
        w_c.astype(jnp.float32),
        acc.astype(jnp.float32),
        ctime.astype(jnp.float32),
        v.astype(jnp.float32),
        u.astype(jnp.float32),
        avail.astype(jnp.float32),
        gamma.astype(jnp.float32),
        eta.astype(jnp.float32),
        scal,
    )
    return out_j, out_l
