"""Host span tracing — a thread-aware recorder emitting Chrome trace JSON.

The recorder is process-wide and explicitly installed
(:func:`start_trace` / :func:`recording`); until then every
:class:`span` is inert: ``__enter__``/``__exit__`` cost two
``time.perf_counter()`` calls and one ``None`` check, nothing is
allocated, and no lock is taken (``benchmarks/telemetry_overhead.py``
gates that cost at < 1% of the 64-replication fleet bench point).  The
two timestamps are kept even when disabled because the simulators derive
their timing fields (``FleetResult.gen_s`` / ``dispatch_s``,
``SimResult.timings``) from the very same spans via :class:`Stopwatch`
— one instrument, two consumers.

Events carry the recording thread's id and name, so spans from
``simulate_fleet``'s producer thread ("fleet-window-producer") and the
async JSONL exporter land on their own tracks in ``chrome://tracing`` /
Perfetto.  The emitted JSON object format is::

    {"traceEvents": [
        {"name": ..., "cat": ..., "ph": "X", "ts": us, "dur": us,
         "pid": <pid>, "tid": <tid>, "args": {...}},
        {"ph": "M", "name": "thread_name", ...},           # metadata
        {"ph": "i", "name": ..., "ts": us, "s": "t", ...}, # instants
     ],
     "displayTimeUnit": "ms"}

:func:`validate_chrome_trace` checks that shape (the telemetry test
suite and the CI artifact smoke both run it).
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

__all__ = [
    "CAT_GEN",
    "CAT_BUILD",
    "CAT_SCHED",
    "CAT_DISPATCH",
    "CAT_METRICS",
    "CAT_IO",
    "CAT_COMPILE",
    "TraceRecorder",
    "span",
    "instant",
    "Stopwatch",
    "start_trace",
    "stop_trace",
    "recording",
    "active_recorder",
    "save_chrome_trace",
    "validate_chrome_trace",
]

#: span categories used across the pipeline — a stable vocabulary so the
#: CI artifact diff can see a category disappear
CAT_GEN = "gen"            # arrival-trace generation / stream pulls
CAT_BUILD = "build"        # frame-grid / instance building (host)
CAT_SCHED = "sched"        # scheduler calls (host-dispatched)
CAT_DISPATCH = "dispatch"  # jitted fleet-program dispatch + materialization
CAT_METRICS = "metrics"    # window metrics drain / satisfaction reductions
CAT_IO = "io"              # telemetry export (JSONL writer thread)
CAT_COMPILE = "compile"    # compile-cache misses (runner/policy binding)


class TraceRecorder:
    """Thread-safe in-memory event sink for one recording session.

    Timestamps are ``perf_counter`` microseconds relative to the
    recorder's creation, which is what Chrome's trace viewer expects of a
    single-process capture.
    """

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._thread_names: Dict[int, str] = {}

    # -- recording --------------------------------------------------------
    def _note_thread(self, tid: int) -> None:
        if tid not in self._thread_names:
            self._thread_names[tid] = threading.current_thread().name

    def add_complete(
        self, name: str, cat: str, t_start: float, t_end: float,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """One complete ("X") event from a pair of ``perf_counter`` readings."""
        tid = threading.get_ident()
        ev = {
            "name": name,
            "cat": cat,
            "ph": "X",
            "ts": (t_start - self._t0) * 1e6,
            "dur": max(t_end - t_start, 0.0) * 1e6,
            "pid": os.getpid(),
            "tid": tid,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._note_thread(tid)
            self._events.append(ev)

    def add_instant(
        self, name: str, cat: str, args: Optional[Dict[str, Any]] = None
    ) -> None:
        """One instant ("i") event at the current time (thread-scoped)."""
        tid = threading.get_ident()
        ev = {
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter() - self._t0) * 1e6,
            "pid": os.getpid(),
            "tid": tid,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._note_thread(tid)
            self._events.append(ev)

    # -- introspection ----------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def categories(self) -> set:
        return {e["cat"] for e in self.events() if e["ph"] != "M"}

    def thread_ids(self) -> set:
        return {e["tid"] for e in self.events()}

    def span_names(self) -> set:
        return {e["name"] for e in self.events() if e["ph"] == "X"}

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # -- export -----------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (events + thread metadata)."""
        with self._lock:
            events = list(self._events)
            names = dict(self._thread_names)
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": os.getpid(),
                "tid": tid,
                "args": {"name": tname},
            }
            for tid, tname in sorted(names.items())
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(str(path))), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


#: the process-wide recorder; ``None`` means tracing is off (the default)
_RECORDER: Optional[TraceRecorder] = None
_INSTALL_LOCK = threading.Lock()


def active_recorder() -> Optional[TraceRecorder]:
    return _RECORDER


def start_trace() -> TraceRecorder:
    """Install a fresh process-wide recorder (replacing any active one)."""
    global _RECORDER
    with _INSTALL_LOCK:
        _RECORDER = TraceRecorder()
        return _RECORDER


def stop_trace() -> Optional[TraceRecorder]:
    """Uninstall and return the active recorder (``None`` if none)."""
    global _RECORDER
    with _INSTALL_LOCK:
        rec, _RECORDER = _RECORDER, None
        return rec


@contextmanager
def recording():
    """``with recording() as rec: ...`` — record for the block's duration."""
    rec = start_trace()
    try:
        yield rec
    finally:
        with _INSTALL_LOCK:
            global _RECORDER
            if _RECORDER is rec:
                _RECORDER = None


class span:
    """Timed block: ``with span("fleet/dispatch", CAT_DISPATCH) as s: ...``.

    Always measures (``s.elapsed_s`` is valid after exit — the simulators'
    timing fields are built from it); records a trace event only when a
    process-wide recorder is active at ``__enter__``.  An exception inside
    the block still closes and records the span.
    """

    __slots__ = ("name", "cat", "args", "acc", "_t0", "_rec", "elapsed_s")

    def __init__(
        self,
        name: str,
        cat: str = CAT_SCHED,
        acc: Optional["Stopwatch"] = None,
        **args: Any,
    ) -> None:
        self.name = name
        self.cat = cat
        self.args = args or None
        self.acc = acc
        self.elapsed_s = 0.0

    def __enter__(self) -> "span":
        self._rec = _RECORDER  # snapshot: recorder swaps mid-span stay sane
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        self.elapsed_s = t1 - self._t0
        if self.acc is not None:
            self.acc._add(self.name, self.elapsed_s)
        rec = self._rec
        if rec is not None:
            rec.add_complete(self.name, self.cat, self._t0, t1, self.args)


def instant(name: str, cat: str = CAT_COMPILE, **args: Any) -> None:
    """Record an instant event (no-op when tracing is off)."""
    rec = _RECORDER
    if rec is not None:
        rec.add_instant(name, cat, args or None)


class Stopwatch:
    """Per-run accumulator of span durations, keyed by span name.

    ``simulate`` / ``simulate_fleet`` each create one and wire their spans
    through it (``sw.span(...)``), then read totals to fill their timing
    fields — the trace recorder and the result fields see the *same*
    ``perf_counter`` pairs, so enabling tracing cannot skew the numbers.
    """

    __slots__ = ("totals",)

    def __init__(self) -> None:
        self.totals: Dict[str, float] = {}

    def _add(self, name: str, elapsed_s: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + elapsed_s

    def span(self, name: str, cat: str = CAT_SCHED, **args: Any) -> span:
        return span(name, cat, acc=self, **args)

    def total(self, *names: str) -> float:
        return sum(self.totals.get(n, 0.0) for n in names)

    def as_dict(self) -> Dict[str, float]:
        return dict(self.totals)


def save_chrome_trace(recorder: TraceRecorder, path) -> None:
    recorder.save(path)


_VALID_PH = {"X", "i", "M", "B", "E", "C"}


def validate_chrome_trace(obj: Any) -> List[str]:
    """Schema check of a Chrome trace-event JSON object; returns the list
    of violations (empty == valid).  Accepts the object-form trace this
    module emits (and the bare event-array form, for robustness)."""
    errors: List[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level 'traceEvents' missing or not a list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return ["trace is neither an object with 'traceEvents' nor an array"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errors.append(f"event {i}: bad or missing ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"event {i}: missing name")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            errors.append(f"event {i}: missing pid/tid")
        if ph in ("X", "i"):
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"event {i}: missing ts")
            if not isinstance(ev.get("cat"), str):
                errors.append(f"event {i}: missing cat")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: X event needs dur >= 0")
    return errors
