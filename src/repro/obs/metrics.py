"""Per-frame metric streams: the ``MetricsFrame`` pytree and its rollups.

The simulators' opt-in ``metrics=True`` path emits one
:class:`MetricsFrame` per scheduling decision — per-server utilization
and carried backlog, admission-shed / queue-cap-refusal counts,
per-QoS-class satisfaction, and the local/edge-offload/cloud assignment
histogram.  Inside ``simulate_fleet`` the frame is an extra ``lax.scan``
output, so metrics are *stacked on device* across every frame of a
window and drained once per window with the scan's other outputs — there
is no per-frame host sync, which is what keeps the enabled path cheap
and the disabled path untouched (the scan is traced without the metrics
leaves entirely).  ``simulate``'s host frame loop emits the same rows
from its own counters, so single-run and fleet streams are directly
comparable.

This module deliberately imports nothing from :mod:`repro.core` (the
core imports *it*); the device-side row computation lives in
:func:`repro.core.queueing.frame_metrics`.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

__all__ = ["QOS_ACC_EDGES", "MetricsFrame", "MetricsResult"]

#: accuracy-requirement thresholds defining the QoS classes of the
#: per-class satisfaction stream: class q holds requests with
#: ``edges[q-1] <= A_i < edges[q]`` (the paper's testbed pins A_i = 50,
#: i.e. class 1; spread-QoS scenarios populate all four)
QOS_ACC_EDGES: Tuple[float, ...] = (45.0, 55.0, 65.0)


class MetricsFrame(NamedTuple):
    """One decision's metrics — a pytree of scalars and small vectors.

    ``M`` = number of servers, ``Q`` = ``len(QOS_ACC_EDGES) + 1`` QoS
    classes.  As a ``NamedTuple`` it is automatically a jax pytree, so
    ``lax.scan`` stacks a leading frame axis onto every leaf (and
    ``vmap`` a replication axis in front of that).
    """

    n_arrivals: Any    # ()  int32 — real (non-padded) requests decided
    n_served: Any      # ()  int32 — assigned a (server, variant)
    n_satisfied: Any   # ()  int32 — served and QoS met
    n_shed: Any        # ()  int32 — dropped by deadline shedding (admission)
    n_refused: Any     # ()  int32 — refused by the backlog queue cap
    tier_hist: Any     # (3,) int32 — [local, edge-offload, cloud] assignments
    qos_sat: Any       # (Q,) int32 — satisfied per QoS class
    qos_count: Any     # (Q,) int32 — decided per QoS class
    util_gamma: Any    # (M,) float32 — committed compute / frame budget
    util_eta: Any      # (M,) float32 — committed comm / frame budget
    backlog_gamma: Any  # (M,) float32 — carried compute backlog after the frame
    backlog_eta: Any   # (M,) float32 — carried comm backlog after the frame
    us_sum: Any        # ()  float32 — summed US of this decision's requests


_SCALAR_FIELDS = ("n_arrivals", "n_served", "n_satisfied", "n_shed", "n_refused",
                  "us_sum")
_SERVER_FIELDS = ("util_gamma", "util_eta", "backlog_gamma", "backlog_eta")
TIER_NAMES = ("local", "edge_offload", "cloud")


@dataclasses.dataclass
class MetricsResult:
    """Stacked per-frame metrics plus the aggregation/export API.

    ``data`` maps each :class:`MetricsFrame` field to a numpy array whose
    leading axes are ``(T, ...)`` for a single run or ``(R, T, ...)`` for
    a fleet.  ``t_ms`` holds each frame's decision time (single run: the
    actual decision instants, early closes included; fleet: frame
    boundaries).
    """

    data: Dict[str, np.ndarray]
    t_ms: np.ndarray
    n_edge: int
    frame_ms: float
    qos_edges: Tuple[float, ...] = QOS_ACC_EDGES

    # -- shape ------------------------------------------------------------
    @property
    def fleet(self) -> bool:
        return self.data["n_arrivals"].ndim == 2

    @property
    def n_rep(self) -> int:
        return self.data["n_arrivals"].shape[0] if self.fleet else 1

    @property
    def n_frames(self) -> int:
        return self.data["n_arrivals"].shape[-1]

    @property
    def n_servers(self) -> int:
        return self.data["util_gamma"].shape[-1]

    def series(self, field: str, rep: Optional[int] = None) -> np.ndarray:
        """The per-frame series of one field, ``(T, ...)``; ``rep`` picks
        a fleet replication (default 0 when the result is a fleet)."""
        x = self.data[field]
        if self.fleet:
            return x[0 if rep is None else rep]
        return x

    # -- aggregation ------------------------------------------------------
    def total(self, field: str) -> float:
        return float(np.sum(self.data[field]))

    def percentiles(
        self, field: str, qs: Sequence[float] = (50.0, 90.0, 99.0)
    ) -> Dict[str, float]:
        """Percentiles of a per-frame series across every (rep, frame)
        cell; vector fields are reduced to their per-frame server mean."""
        x = np.asarray(self.data[field], np.float64)
        if field in _SERVER_FIELDS:
            x = x.mean(-1)
        return {f"p{g:g}": float(np.percentile(x, g)) for g in qs}

    def per_edge_rollup(self) -> Dict[str, List[float]]:
        """Time-mean utilization/backlog per edge server (the cloud tiers
        sit past ``n_edge`` in the same vectors)."""
        out: Dict[str, List[float]] = {}
        for f in _SERVER_FIELDS:
            x = np.asarray(self.data[f], np.float64)
            mean = x.reshape(-1, x.shape[-1]).mean(0)
            out[f] = [round(float(v), 6) for v in mean[: self.n_edge]]
            out[f + "_cloud"] = [round(float(v), 6) for v in mean[self.n_edge:]]
        return out

    def aggregate(self) -> Dict[str, float]:
        """Run totals and rates — the cross-check against ``SimResult`` /
        ``FleetResult`` (satisfaction counts match those exactly)."""
        n_arr = self.total("n_arrivals")
        tier = np.asarray(self.data["tier_hist"], np.int64).reshape(-1, 3).sum(0)
        qos_sat = np.asarray(self.data["qos_sat"], np.int64)
        qos_cnt = np.asarray(self.data["qos_count"], np.int64)
        q_axis = tuple(range(qos_sat.ndim - 1))
        out = {
            "n_frames": self.n_frames,
            "n_rep": self.n_rep,
            "n_arrivals": int(n_arr),
            "n_served": int(self.total("n_served")),
            "n_satisfied": int(self.total("n_satisfied")),
            "n_shed": int(self.total("n_shed")),
            "n_refused": int(self.total("n_refused")),
            "satisfied_pct": 100.0 * self.total("n_satisfied") / max(n_arr, 1),
            "us_sum": self.total("us_sum"),
        }
        for t, name in enumerate(TIER_NAMES):
            out[f"n_{name}"] = int(tier[t])
        out["qos_sat"] = [int(v) for v in qos_sat.sum(q_axis)]
        out["qos_count"] = [int(v) for v in qos_cnt.sum(q_axis)]
        return out

    # -- export -----------------------------------------------------------
    def iter_rows(self) -> Iterable[Dict[str, Any]]:
        """One JSON-ready dict per (rep, frame) — the JSONL row stream."""
        reps = range(self.n_rep) if self.fleet else (None,)
        for rep in reps:
            for t in range(self.n_frames):
                row: Dict[str, Any] = {"frame": t, "t_ms": float(self.t_ms[t])}
                if rep is not None:
                    row["rep"] = rep
                pick = (lambda f: self.data[f][rep, t]) if self.fleet else (
                    lambda f: self.data[f][t])
                for f in ("n_arrivals", "n_served", "n_satisfied", "n_shed",
                          "n_refused"):
                    row[f] = int(pick(f))
                row["us_sum"] = float(pick("us_sum"))
                th = np.asarray(pick("tier_hist"))
                row["tier"] = {n: int(th[i]) for i, n in enumerate(TIER_NAMES)}
                row["qos_sat"] = [int(v) for v in np.asarray(pick("qos_sat"))]
                row["qos_count"] = [int(v) for v in np.asarray(pick("qos_count"))]
                for f in _SERVER_FIELDS:
                    row[f] = [round(float(v), 6) for v in np.asarray(pick(f))]
                yield row

    def to_jsonl(self, path, writer=None) -> int:
        """Write the per-frame stream as JSONL; returns the row count.

        ``writer`` may be an :class:`repro.obs.export.AsyncJsonlWriter`
        (rows are handed to its queue and flushed off-thread); default is
        a plain synchronous write.
        """
        n = 0
        if writer is not None:
            for row in self.iter_rows():
                writer.write(row)
                n += 1
            return n
        os.makedirs(os.path.dirname(os.path.abspath(str(path))), exist_ok=True)
        with open(path, "w") as f:
            for row in self.iter_rows():
                f.write(json.dumps(row) + "\n")
                n += 1
        return n

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_stacked(
        stacked: "MetricsFrame", t_ms, n_edge: int, frame_ms: float,
        qos_edges: Tuple[float, ...] = QOS_ACC_EDGES,
    ) -> "MetricsResult":
        """From a scan/vmap-stacked :class:`MetricsFrame` (leaves already
        carrying ``(T, ...)`` or ``(R, T, ...)`` axes, numpy or jax)."""
        data = {f: np.asarray(getattr(stacked, f)) for f in MetricsFrame._fields}
        return MetricsResult(
            data=data, t_ms=np.asarray(t_ms, np.float64), n_edge=n_edge,
            frame_ms=frame_ms, qos_edges=qos_edges,
        )

    @staticmethod
    def from_rows(
        rows: Sequence["MetricsFrame"], t_ms, n_edge: int, frame_ms: float,
        qos_edges: Tuple[float, ...] = QOS_ACC_EDGES,
    ) -> "MetricsResult":
        """From a host-side list of per-decision frames (``simulate``)."""
        data = {
            f: np.stack([np.asarray(getattr(r, f)) for r in rows])
            if rows else np.zeros((0,), np.int32)
            for f in MetricsFrame._fields
        }
        return MetricsResult(
            data=data, t_ms=np.asarray(t_ms, np.float64), n_edge=n_edge,
            frame_ms=frame_ms, qos_edges=qos_edges,
        )
