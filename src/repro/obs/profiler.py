"""``jax.profiler`` hooks — device-side profiling of the fleet pipeline.

:func:`profile_trace` wraps a run in ``jax.profiler.trace`` (TensorBoard
/ Perfetto-loadable device profile); inside it, :func:`annotate` marks
host-dispatched regions (per-group fleet dispatch, the Pallas-vs-XLA
scheduler call) with ``jax.profiler.TraceAnnotation`` and
:func:`step_annotation` marks scan windows with ``StepTraceAnnotation``.

When no profile is active — the default — both helpers return one shared
``nullcontext`` instance, so instrumented call sites cost a function
call and a flag check.  A host platform without profiler support (or a
jax build that cannot start one) degrades to a warning, never an error:
profiling is observability, not a dependency.
"""
from __future__ import annotations

import warnings
from contextlib import contextmanager, nullcontext

import jax

__all__ = ["profile_trace", "annotate", "step_annotation", "profiling_active"]

_ACTIVE = False
_NOOP = nullcontext()


def profiling_active() -> bool:
    return _ACTIVE


@contextmanager
def profile_trace(log_dir):
    """Capture a ``jax.profiler`` trace of the block into ``log_dir``.

    ``log_dir`` of ``None``/empty yields without starting anything, so
    callers can thread an optional ``--profile DIR`` flag straight
    through.
    """
    global _ACTIVE
    if not log_dir:
        yield
        return
    try:
        jax.profiler.start_trace(str(log_dir))
    except Exception as e:  # no profiler backend on this host
        warnings.warn(f"jax profiler unavailable ({e}); running unprofiled")
        yield
        return
    _ACTIVE = True
    try:
        yield
    finally:
        _ACTIVE = False
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"jax profiler stop failed ({e})")


def annotate(name: str, **kwargs):
    """``TraceAnnotation(name)`` under an active profile, else a no-op."""
    if not _ACTIVE:
        return _NOOP
    return jax.profiler.TraceAnnotation(name, **kwargs)


def step_annotation(name: str, step: int):
    """``StepTraceAnnotation`` (profiler step marker) under an active
    profile, else a no-op — one per fleet scan window."""
    if not _ACTIVE:
        return _NOOP
    return jax.profiler.StepTraceAnnotation(name, step_num=step)
