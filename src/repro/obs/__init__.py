"""Telemetry subsystem: span tracing, metric streams, profiler hooks.

Three layers, all bitwise-inert when disabled (the same discipline as the
congestion and impairment engines — see ``docs/architecture.md`` §10):

* :mod:`repro.obs.trace` — host-side span tracing.  ``span("name")``
  context managers feed a process-wide :class:`TraceRecorder` that emits
  Chrome trace-event JSON (loadable in ``chrome://tracing`` / Perfetto),
  with correct thread attribution for the fleet's producer thread and the
  async JSONL exporter.  With no recorder installed a span is two
  ``perf_counter`` calls and nothing else.
* :mod:`repro.obs.metrics` — per-frame metric streams.  The simulators'
  opt-in ``metrics=True`` path emits one :class:`MetricsFrame` per frame
  (per-server utilization/backlog, admission sheds, per-QoS-class
  satisfaction, assignment-tier histogram); the fleet stacks them across
  its ``lax.scan`` so there is **no host sync per frame** — frames drain
  once per window with the other scan outputs.  :class:`MetricsResult`
  aggregates (totals, percentiles, per-edge rollups) and exports JSONL.
* :mod:`repro.obs.profiler` — ``jax.profiler`` hooks.
  :func:`profile_trace` captures a device profile for a whole run;
  :func:`annotate` / :func:`step_annotation` mark dispatch groups and
  scan windows inside it, and degrade to shared no-op context managers
  when no profile is active.
"""
from .trace import (
    CAT_BUILD,
    CAT_COMPILE,
    CAT_DISPATCH,
    CAT_GEN,
    CAT_IO,
    CAT_METRICS,
    CAT_SCHED,
    Stopwatch,
    TraceRecorder,
    active_recorder,
    instant,
    recording,
    save_chrome_trace,
    span,
    start_trace,
    stop_trace,
    validate_chrome_trace,
)
from .metrics import (
    QOS_ACC_EDGES,
    MetricsFrame,
    MetricsResult,
)
from .export import AsyncJsonlWriter
from .profiler import annotate, profile_trace, profiling_active, step_annotation

__all__ = [
    "CAT_BUILD",
    "CAT_COMPILE",
    "CAT_DISPATCH",
    "CAT_GEN",
    "CAT_IO",
    "CAT_METRICS",
    "CAT_SCHED",
    "Stopwatch",
    "TraceRecorder",
    "active_recorder",
    "instant",
    "recording",
    "save_chrome_trace",
    "span",
    "start_trace",
    "stop_trace",
    "validate_chrome_trace",
    "QOS_ACC_EDGES",
    "MetricsFrame",
    "MetricsResult",
    "AsyncJsonlWriter",
    "annotate",
    "profile_trace",
    "profiling_active",
    "step_annotation",
]
