"""Async JSONL export — telemetry writes off the simulation's critical path.

:class:`AsyncJsonlWriter` drains a bounded queue on its own thread
("telemetry-writer") and serializes rows in batches, emitting
``CAT_IO`` spans for each flush — so in a recorded run the export work
is visible on its own track instead of silently inflating the frame
loop.  ``close()`` drains the queue, joins the thread, and re-raises any
writer-side exception, so a full trace always contains every row that
was handed over (and the recorder sees every io span before the trace is
saved).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Optional

from .trace import CAT_IO, span

__all__ = ["AsyncJsonlWriter"]

_STOP = object()


class AsyncJsonlWriter:
    """Background JSONL writer: ``write(obj)`` enqueues, a daemon thread
    serializes and appends.  Use as a context manager or call ``close()``."""

    def __init__(self, path, maxsize: int = 1024, batch: int = 64) -> None:
        self.path = str(path)
        self.n_written = 0
        self._batch = max(1, int(batch))
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=maxsize)
        self._error: Optional[BaseException] = None
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        self._thread = threading.Thread(
            target=self._run, name="telemetry-writer", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        try:
            with open(self.path, "w") as f:
                done = False
                while not done:
                    items = [self._q.get()]
                    while len(items) < self._batch:
                        try:
                            items.append(self._q.get_nowait())
                        except queue.Empty:
                            break
                    if items[-1] is _STOP:
                        done = True
                        items.pop()
                    if not items:
                        continue
                    with span("telemetry/jsonl_flush", CAT_IO, rows=len(items)):
                        f.write("".join(json.dumps(o) + "\n" for o in items))
                        self.n_written += len(items)
        except BaseException as e:  # surfaced by close()
            self._error = e
            # keep draining so producers blocked on a full queue unwind
            while True:
                if self._q.get() is _STOP:
                    return

    def write(self, obj: Any) -> None:
        if self._error is not None:
            raise self._error
        self._q.put(obj)

    def close(self) -> None:
        self._q.put(_STOP)
        self._thread.join()
        if self._error is not None:
            raise self._error

    def __enter__(self) -> "AsyncJsonlWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
