"""Serving engine: batched prefill + decode with greedy/temperature sampling.

``ServingEngine`` drives a real model (the CPU testbed example serves the
paper-zoo variants through it and *measures* latencies for the scheduler);
``make_serve_step`` / ``make_prefill_step`` build the jit-able step functions
the multi-pod dry-run lowers for the decode shapes."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import DecodeCache, Model

__all__ = ["ServingEngine", "make_serve_step", "make_prefill_step", "GenerationResult"]


# cast logits to bf16 before the argmax/any cross-shard exchange — halves the
# bytes of a sharded-vocab logits gather (perf variant; greedy argmax is
# unchanged for all but exact ties)
LOCAL_ARGMAX = False


def make_serve_step(model: Model):
    """serve_step(params, tokens (B,1), cache) -> (next_tokens (B,1), cache).

    This is the function the decode-shape dry-runs lower: ONE new token
    against a KV cache of the configured length."""

    def serve_step(params, tokens, cache: DecodeCache):
        logits, cache = model.decode_step(params, tokens, cache)
        lg = logits[:, -1, :]
        if LOCAL_ARGMAX:
            lg = lg.astype(jnp.bfloat16)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache: DecodeCache):
        logits, cache = model.prefill(params, batch, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return prefill_step


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (B, gen)
    prefill_ms: float
    decode_ms_per_token: float
    total_ms: float


class ServingEngine:
    """Batched generation for one model; jits prefill/decode once per shape."""

    def __init__(self, model: Model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(make_serve_step(model))

    def generate(
        self,
        batch: Dict[str, jnp.ndarray],
        max_new_tokens: int = 16,
        max_len: Optional[int] = None,
    ) -> GenerationResult:
        B, S = batch["tokens"].shape
        max_len = max_len or (S + max_new_tokens)
        cache = self.model.init_cache(B, max_len)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        logits.block_until_ready()
        t1 = time.perf_counter()

        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            tok, cache = self._decode(self.params, tok, cache)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t2 = time.perf_counter()

        return GenerationResult(
            tokens=np.concatenate(out, axis=1),
            prefill_ms=1000 * (t1 - t0),
            decode_ms_per_token=1000 * (t2 - t1) / max(max_new_tokens - 1, 1),
            total_ms=1000 * (t2 - t0),
        )

    def eval_next_token_accuracy(self, batch: Dict[str, jnp.ndarray]) -> float:
        """Teacher-forcing next-token top-1 accuracy — the 'accuracy' that the
        scheduler trades against latency for the zoo variants."""
        logits, _ = jax.jit(self.model.forward)(self.params, batch)
        pred = jnp.argmax(logits, axis=-1)
        return float((pred == batch["labels"]).mean())
