"""Analytic performance profiles: ModelConfig -> (FLOPs, bytes) -> latency.

This is the bridge between the JAX substrate and the paper's scheduler: the
processing-delay table T^proc_{jkl} that GUS consumes is *derived from the
models themselves* — either analytically (this module), from the compiled
dry-run cost analysis (``repro.roofline``), or measured live (the serve_edge
example).  Hardware classes model the paper's heterogeneous edge/cloud tiers
with TPU-v5e-like constants."""
from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs.base import ModelConfig

__all__ = ["HardwareClass", "HW_CLASSES", "step_costs", "request_latency_ms", "accuracy_proxy"]


@dataclasses.dataclass(frozen=True)
class HardwareClass:
    name: str
    chips: int
    peak_flops: float = 197e12     # bf16 FLOP/s per chip (TPU v5e)
    hbm_bw: float = 819e9          # bytes/s per chip
    link_bw: float = 50e9          # ICI bytes/s per link


# The paper's three edge classes + a cloud tier, in chip counts.
HW_CLASSES: Dict[str, HardwareClass] = {
    "edge-1": HardwareClass("edge-1", 1),
    "edge-4": HardwareClass("edge-4", 4),
    "edge-8": HardwareClass("edge-8", 8),
    "cloud-256": HardwareClass("cloud-256", 256),
}


def step_costs(cfg: ModelConfig, batch: int, seq: int, mode: str) -> Dict[str, float]:
    """Approximate FLOPs and HBM bytes for one step.

    mode: 'prefill' (process `seq` tokens) or 'decode' (1 token, cache len=seq).
    Uses the 6·N (train) / 2·N (inference) rules on *active* params plus
    attention terms; bytes = params + KV-cache traffic."""
    n_act = cfg.n_active_params()
    p_bytes = n_act * 2  # bf16
    hd, H, KV = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    L = cfg.num_layers
    if mode == "prefill":
        toks = batch * seq
        flops = 2.0 * n_act * toks
        if not cfg.is_attention_free:
            flops += 2.0 * 2.0 * L * H * hd * batch * seq * seq / 2  # causal attn
        bytes_ = p_bytes + toks * cfg.d_model * 2 * L
    else:  # decode
        toks = batch
        flops = 2.0 * n_act * toks
        cache_tokens = min(seq, cfg.sliding_window or seq)
        if cfg.family in ("ssm", "hybrid"):
            state = cfg.num_layers * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim
            cache_bytes = batch * state * 2
            flops += 4.0 * batch * state
        else:
            cache_bytes = batch * cache_tokens * KV * hd * 2 * L * 2
            flops += 2.0 * 2.0 * L * H * hd * batch * cache_tokens
        bytes_ = p_bytes + cache_bytes
    return {"flops": flops, "bytes": bytes_}


def request_latency_ms(
    cfg: ModelConfig,
    hw: HardwareClass,
    prompt_tokens: int = 128,
    gen_tokens: int = 32,
    batch: int = 1,
    efficiency: float = 0.5,
) -> float:
    """Roofline latency of one request = prefill + gen_tokens decode steps."""
    pf = step_costs(cfg, batch, prompt_tokens, "prefill")
    t_pf = max(
        pf["flops"] / (hw.chips * hw.peak_flops),
        pf["bytes"] / (hw.chips * hw.hbm_bw),
    )
    t_dec = 0.0
    dc = step_costs(cfg, batch, prompt_tokens + gen_tokens, "decode")
    t_dec = gen_tokens * max(
        dc["flops"] / (hw.chips * hw.peak_flops),
        dc["bytes"] / (hw.chips * hw.hbm_bw),
    )
    return 1000.0 * (t_pf + t_dec) / efficiency


def accuracy_proxy(n_params: int, a_max: float = 95.0, a_min: float = 35.0) -> float:
    """Scaling-law accuracy proxy, calibrated so the SqueezeNet/GoogleNet gap
    of the paper's testbed is reproduced by the small/large zoo variants:
    ~1M params -> ~a_min, ~100B -> ~a_max (monotone, diminishing returns)."""
    import math

    decades = max(math.log10(max(n_params, 1) / 1e6), 0.0)
    return a_max - (a_max - a_min) * math.exp(-0.9 * decades)
