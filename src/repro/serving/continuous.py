"""Continuous batching — the production serving pattern the paper's
per-frame scheduler feeds into.

A fixed pool of ``n_slots`` decode slots runs in lock-step; new requests are
prefilled individually and *admitted* into free slots without stopping the
running batch; finished sequences vacate their slot.  Per-slot positions are
handled by ``vmap``-ing the (already-validated) single-sequence decode step
over a slot-major cache pytree, so every slot carries its own cache index —
no change to the core model decode path.

This composes with GUS exactly as the paper intends: the scheduler assigns
(request -> server, variant); each server runs one ContinuousBatcher per
hosted variant and admits its assigned requests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import DecodeCache, Model

__all__ = ["ContinuousBatcher", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _slotify(cache: DecodeCache) -> DecodeCache:
    """Prepend a slot axis to every leaf (the inner batch=1 axis is kept —
    the vmapped decode sees exactly the cache a batch-1 model expects)."""
    return jax.tree.map(lambda x: x[None], cache)


class ContinuousBatcher:
    """Fixed-slot continuous batching around a Model.

    Slot-major cache layout: every leaf is (n_slots, ...) where the inner
    model sees batch=1.  ``step()`` vmaps decode over slots; ``admit()``
    prefills one request (batch=1) and writes its cache into a free slot.
    """

    def __init__(self, model: Model, params, n_slots: int = 4, max_len: int = 256):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.requests: List[Optional[Request]] = [None] * n_slots
        self._last_tok = jnp.zeros((n_slots, 1, 1), jnp.int32)

        # slot-major empty cache: build a batch=1 cache and stack n_slots copies
        c1 = _slotify(model.init_cache(1, max_len))
        self._cache = jax.tree.map(
            lambda x: jnp.concatenate([x] * n_slots, axis=0), c1
        )

        def single_decode(params, tok, cache):
            # cache leaves carry inner batch=1; index is per-slot scalar
            logits, new_cache = model.decode_step(params, tok, cache)
            nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            return nxt, new_cache

        self._vstep = jax.jit(
            jax.vmap(single_decode, in_axes=(None, 0, 0), out_axes=(0, 0))
        )
        self._prefill = jax.jit(model.prefill)

    def reset(self):
        """Clear all slots (keeps compiled step functions — cheap reuse)."""
        self.requests = [None] * self.n_slots
        self._last_tok = jnp.zeros((self.n_slots, 1, 1), jnp.int32)
        self._cache = jax.tree.map(jnp.zeros_like, self._cache)
        self._cache = dataclasses.replace(
            self._cache, index=jnp.zeros((self.n_slots,), jnp.int32)
        )

    # ------------------------------------------------------------------ admin
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    def active(self) -> List[Request]:
        return [r for r in self.requests if r is not None]

    def admit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        cache1 = self.model.init_cache(1, self.max_len)
        logits, cache1 = self._prefill(self.params, batch, cache1)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        req.generated.append(int(tok[0, 0]))

        slot_cache = _slotify(cache1)
        self._cache = jax.tree.map(
            lambda full, one: full.at[slot].set(one[0]), self._cache, slot_cache
        )
        self._last_tok = self._last_tok.at[slot].set(tok)
        self.requests[slot] = req
        return True

    # ------------------------------------------------------------------ step
    def step(self):
        """One lock-step decode across all occupied slots."""
        if not self.active():
            return
        nxt, self._cache = self._vstep(self.params, self._last_tok, self._cache)
        self._last_tok = nxt
        for i, r in enumerate(self.requests):
            if r is None or r.done:
                continue
            r.generated.append(int(nxt[i, 0, 0]))
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self.requests[i] = None  # vacate; cache slot is reusable

    # ------------------------------------------------------------------ drive
    def run(self, incoming: List[Request], max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Serve a queue to completion; admits whenever slots free up."""
        queue = list(incoming)
        out: Dict[int, List[int]] = {}
        steps = 0
        pending = {r.rid: r for r in queue}
        while (queue or self.active()) and steps < max_steps:
            while queue and self.free_slots():
                self.admit(queue.pop(0))
            self.step()
            steps += 1
            for rid, r in list(pending.items()):
                if r.done:
                    out[rid] = r.generated
                    del pending[rid]
        # collect any still-active at step limit
        for r in self.active():
            out.setdefault(r.rid, r.generated)
        return out
