"""Model zoo — the paper's "|L| DL model types per service".

A ``ServiceSpec`` owns a ladder of model variants (ModelConfigs of increasing
size = increasing accuracy = increasing cost); ``build_cluster_spec`` turns a
zoo + a server layout into the ``core.simulator.ClusterSpec`` whose
T^proc/accuracy tables the GUS scheduler consumes.  Variant latency comes from
the analytic roofline profile (or measured values when provided)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..configs.base import ModelConfig
from ..core.simulator import ClusterSpec
from .profiles import HW_CLASSES, HardwareClass, accuracy_proxy, request_latency_ms

__all__ = ["ServiceSpec", "ModelZoo", "variant_ladder", "build_cluster_spec"]


@dataclasses.dataclass
class ServiceSpec:
    """One service (task type) with an accuracy/cost ladder of variants."""

    name: str
    variants: List[ModelConfig]                      # ordered cheap -> costly
    accuracy: Optional[List[float]] = None           # measured; else proxy

    def accuracies(self) -> List[float]:
        if self.accuracy is not None:
            return list(self.accuracy)
        return [accuracy_proxy(v.n_params()) for v in self.variants]


def variant_ladder(base: ModelConfig, n_variants: int, min_scale: float = 0.12) -> List[ModelConfig]:
    """Width/depth ladder of the same family: variant 0 is ~min_scale of the
    base cost, the last variant is the base config itself."""
    out = []
    scales = np.geomspace(min_scale, 1.0, n_variants)
    for i, s in enumerate(scales):
        w = max(int(round(base.d_model * np.sqrt(s) / 64)) * 64, 64)
        l = max(int(round(base.num_layers * np.sqrt(s))), 2)
        heads = max(base.num_heads * w // base.d_model, 1)
        kv = max(min(base.num_kv_heads, heads), 1)
        out.append(
            dataclasses.replace(
                base,
                arch_id=f"{base.arch_id}-v{i}",
                num_layers=l,
                d_model=w,
                num_heads=heads,
                num_kv_heads=kv,
                head_dim=w // heads,
                d_ff=max(base.d_ff * w // base.d_model, 64) if base.d_ff else 0,
            )
        )
    return out


@dataclasses.dataclass
class ModelZoo:
    services: List[ServiceSpec]

    @property
    def n_services(self) -> int:
        return len(self.services)

    @property
    def n_variants(self) -> int:
        return max(len(s.variants) for s in self.services)


def build_cluster_spec(
    zoo: ModelZoo,
    edge_classes: Sequence[str],           # hw-class name per edge server
    cloud_classes: Sequence[str],          # hw-class name per cloud server
    *,
    prompt_tokens: int = 128,
    gen_tokens: int = 32,
    edge_variants: int = 6,                # only the cheapest variants fit on edges
    edge_service_frac: float = 0.6,
    gamma_frame: Optional[np.ndarray] = None,
    eta_frame: Optional[np.ndarray] = None,
    seed: int = 0,
    measured_proc: Optional[Dict] = None,  # {(server, service, variant): ms}
) -> ClusterSpec:
    """Assemble the simulator's cluster description from the zoo.

    T^proc_{jkl} = roofline latency of variant l of service k on server j's
    hardware class (overridable by measurements), exactly the paper's
    "processing delay based on our testbed results"."""
    rng = np.random.default_rng(seed)
    hw: List[HardwareClass] = [HW_CLASSES[c] for c in edge_classes] + [
        HW_CLASSES[c] for c in cloud_classes
    ]
    M = len(hw)
    n_edge = len(edge_classes)
    K = zoo.n_services
    L = zoo.n_variants

    proc = np.full((M, K, L), 1e9, np.float32)
    placed = np.zeros((M, K, L), bool)
    acc = np.zeros((K, L), np.float32)

    for k, svc in enumerate(zoo.services):
        accs = svc.accuracies()
        for l, vcfg in enumerate(svc.variants):
            acc[k, l] = accs[l]
            for j in range(M):
                is_cloud = j >= n_edge
                on_server = is_cloud or (
                    l < edge_variants and rng.random() < edge_service_frac
                )
                if not on_server:
                    continue
                placed[j, k, l] = True
                key = (j, k, l)
                if measured_proc and key in measured_proc:
                    proc[j, k, l] = measured_proc[key]
                else:
                    proc[j, k, l] = request_latency_ms(
                        vcfg, hw[j], prompt_tokens, gen_tokens
                    )

    gamma = (
        gamma_frame
        if gamma_frame is not None
        else np.array([h.chips * 3000.0 for h in hw], np.float32)  # chip-ms/frame
    )
    eta = (
        eta_frame
        if eta_frame is not None
        else np.array(
            [(6000.0 if j >= n_edge else 600.0) for j in range(M)], np.float32
        )
    )
    return ClusterSpec(
        n_edge=n_edge,
        n_cloud=M - n_edge,
        gamma_frame=np.asarray(gamma, np.float32),
        eta_frame=np.asarray(eta, np.float32),
        proc_ms=proc,
        placed=placed,
        acc=acc,
    )
