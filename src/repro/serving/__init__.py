from .profiles import HardwareClass, HW_CLASSES, step_costs, request_latency_ms, accuracy_proxy
from .zoo import ServiceSpec, ModelZoo, variant_ladder, build_cluster_spec
from .engine import ServingEngine, make_serve_step, make_prefill_step, GenerationResult
from .continuous import ContinuousBatcher, Request

__all__ = [
    "HardwareClass", "HW_CLASSES", "step_costs", "request_latency_ms", "accuracy_proxy",
    "ServiceSpec", "ModelZoo", "variant_ladder", "build_cluster_spec",
    "ServingEngine", "make_serve_step", "make_prefill_step", "GenerationResult",
    "ContinuousBatcher", "Request",
]
