from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, cosine_schedule
from .train_loop import TrainState, cross_entropy, make_loss_fn, make_train_step, make_eval_step, init_state
from .data import SyntheticLM, batch_iterator, make_batch, vision_stub_batch, audio_stub_batch
from .checkpoint import save_checkpoint, restore_checkpoint

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
    "TrainState", "cross_entropy", "make_loss_fn", "make_train_step", "make_eval_step",
    "init_state", "SyntheticLM", "batch_iterator", "make_batch",
    "vision_stub_batch", "audio_stub_batch", "save_checkpoint", "restore_checkpoint",
]
