"""Sharding-aware checkpointing (npz-based, offline-friendly).

Saves the flattened param/opt pytree with '/'-joined key paths; restores into
the same tree structure.  On a real multi-host fleet each host would write its
addressable shards — here (single process) we gather to host and write one
file, but the path layout (one array per key) matches what a tensorstore
backend would use, so swapping the IO layer does not touch callers."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "tree_paths"]


def tree_paths(tree) -> Dict[str, Any]:
    flat = {}

    def visit(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                visit(f"{path}/{k}" if path else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(f"{path}/{i}", v)
        elif node is None:
            pass
        else:
            flat[path] = node

    visit("", tree)
    return flat


def save_checkpoint(path: str, tree, step: int = 0) -> str:
    flat = {k: np.asarray(v) for k, v in tree_paths(tree).items()}
    flat["__step__"] = np.int64(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)
    return path


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    flat_like = tree_paths(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    def rebuild(path, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{path}/{k}" if path else str(k), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            vals = [rebuild(f"{path}/{i}", v) for i, v in enumerate(node)]
            return t(vals) if t is not tuple else tuple(vals)
        if node is None:
            return None
        return jax.numpy.asarray(data[path])

    out = rebuild("", like)
    return out, int(data["__step__"]) if "__step__" in data.files else 0
