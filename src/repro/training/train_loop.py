"""Training loop: loss, train_step/eval_step builders (jit/pjit-ready)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "cross_entropy", "make_loss_fn", "make_train_step", "make_eval_step", "init_state"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean next-token CE in nats.  logits: (B, S, V) f32, labels: (B, S)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def make_loss_fn(model: Model):
    cfg = model.cfg

    def loss_fn(params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = model.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        loss = ce + cfg.router_aux_weight * aux.get("router_aux", 0.0)
        return loss, {"ce": ce, **aux}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    loss_fn = make_loss_fn(model)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params, opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, aux = loss_fn(params, batch)
        return {"loss": loss, **aux}

    return eval_step


def init_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, adamw_init(params))
