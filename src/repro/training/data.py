"""Deterministic synthetic data pipeline.

Two sources:

* ``SyntheticLM`` — a seeded Markov-ish token stream with learnable structure
  (n-gram transitions + copy motifs) so tiny models show real loss curves;
  used by the end-to-end training example and the serve-edge accuracy evals.
* ``batch_iterator`` — shardable batches (tokens, labels) with host-side
  prefetch; labels are next-token shifted.

Also provides modality stubs per the assignment carve-out:
``vision_stub_batch`` / ``audio_stub_batch`` hand precomputed patch/frame
embeddings of the right shape (the ViT/conv frontends are NOT implemented).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp

from ..configs.base import ModelConfig

__all__ = ["SyntheticLM", "batch_iterator", "make_batch", "vision_stub_batch", "audio_stub_batch"]


@dataclasses.dataclass
class SyntheticLM:
    """Order-1 Markov chain over a vocab with periodic copy motifs — enough
    structure that cross-entropy falls well below uniform for a trained model.

    ``alpha`` controls difficulty: smaller -> peakier transitions -> higher
    achievable next-token accuracy (the serve_edge example uses an easy task
    so its tiny models separate within a few hundred CPU steps)."""

    vocab_size: int
    seed: int = 0
    motif_period: int = 17
    motif_period2: Optional[int] = None   # second, longer-range copy motif
    alpha: float = 0.05

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = min(self.vocab_size, 512)  # transition table kept small
        self._V = V
        raw = rng.dirichlet(np.full(V, self.alpha), size=V).astype(np.float32)
        self._trans = raw / raw.sum(-1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        V = self._V
        out = np.empty((batch, seq), np.int32)
        state = rng.integers(0, V, size=batch)
        for t in range(seq):
            p2 = self.motif_period2
            if p2 and t % p2 == 0 and t >= p2:
                state = out[:, t - p2]                 # long-range copy motif
            elif t % self.motif_period == 0 and t > 0:
                state = out[:, t - self.motif_period]  # copy motif
            else:
                u = rng.random(batch)
                cdf = np.cumsum(self._trans[state], axis=-1)
                state = (u[:, None] < cdf).argmax(-1)
            out[:, t] = state
        return out % self.vocab_size


def make_batch(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    rng: np.random.Generator,
    source: Optional[SyntheticLM] = None,
) -> Dict[str, jnp.ndarray]:
    """One training batch for any family (adds modality stubs as needed)."""
    src = source or SyntheticLM(cfg.vocab_size)
    toks = src.sample(rng, batch, seq + 1)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.family == "vlm" and cfg.num_patches:
        out.update(vision_stub_batch(cfg, batch, seq, rng))
    if cfg.family == "encdec":
        out.update(audio_stub_batch(cfg, batch, rng))
    return out


def vision_stub_batch(cfg: ModelConfig, batch: int, seq: int, rng) -> Dict[str, jnp.ndarray]:
    """STUB vision frontend: precomputed patch embeddings + their positions
    in the token stream (first num_patches slots by convention)."""
    P = min(cfg.num_patches, seq)
    emb = rng.standard_normal((batch, P, cfg.d_model)).astype(np.float32) * 0.02
    pos = np.broadcast_to(np.arange(P, dtype=np.int32), (batch, P)).copy()
    return {"vision_embeds": jnp.asarray(emb), "vision_positions": jnp.asarray(pos)}


def audio_stub_batch(cfg: ModelConfig, batch: int, rng) -> Dict[str, jnp.ndarray]:
    """STUB audio frontend: precomputed mel/conv frame embeddings."""
    T = cfg.enc_seq_len
    emb = rng.standard_normal((batch, T, cfg.d_model)).astype(np.float32) * 0.02
    return {"enc_embeds": jnp.asarray(emb)}


def batch_iterator(
    cfg: ModelConfig, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict[str, jnp.ndarray]]:
    src = SyntheticLM(cfg.vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        yield make_batch(cfg, batch, seq, rng, src)
