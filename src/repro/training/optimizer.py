"""AdamW + gradient clipping + LR schedules, in pure JAX (no optax dependency).

Optimizer state is a pytree mirroring the params, so it inherits the params'
sharding (m/v get the same PartitionSpec as their weight)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.int32(0), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)

    return sched


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    grads, state: AdamWState, params, cfg: AdamWConfig
) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree.unflatten(tdef, new_p),
        AdamWState(step, jax.tree.unflatten(tdef, new_m), jax.tree.unflatten(tdef, new_v)),
        {"grad_norm": gnorm, "lr": lr},
    )
