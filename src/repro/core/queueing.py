"""Congestion subsystem — load-dependent service times and the policy carry.

The paper's headline result (GUS beats every baseline by >= 50% satisfied
users) only emerges on the *testbed*, where over-committed servers slow
down: the Happy-Computation / Happy-Communication relaxations, which ignore
a capacity constraint, collapse under real congestion.  The numerical model
treats processing delay as load-independent, so those two policies act as
unreachable upper bounds instead.  This module closes that gap with a
capacity-overcommit inflation model shared by both simulators:

* every server *j* carries a **backlog** ``b_j`` of unfinished work
  (chip-ms for compute, KB for communication) across frames;
* a frame that commits work ``w_j`` against budget ``g_j`` runs at
  utilization ``rho_j = (b_j + w_j) / g_j``; realized processing and
  transfer times inflate by ``phi = 1 + slope * max(0, rho - 1) ** power``
  (capped at ``max_inflation``) — at or below budget nothing slows down;
* the backlog then **drains** at the frame budget:
  ``b' = max(0, b + w - g * drain)``;
* the *scheduler* sees the congestion only through a reduced frame budget
  ``max(g - b, 0)`` — capacity-honoring policies adapt, the Happy-*
  relaxations keep over-committing and spiral.

Every function is pure ``jax.numpy`` and shape-polymorphic, so the same
code runs in the sequential testbed's host loop and inside
``simulate_fleet``'s ``lax.scan`` (the backlog is the scan carry).  With
``CongestionConfig(enabled=False)`` (the default) the simulators skip the
model entirely and results are bit-identical to the congestion-free path.

:class:`PolicyCarry` generalizes the simulator's per-frame PRNG-key
threading into an explicit state object threaded through ``simulate``'s
frame loop and ``simulate_fleet``'s scan: the key chain, the per-server
backlogs, an EMA load estimate, and the paper's bandwidth-estimator state.
A :class:`~repro.core.policies.Policy` registered with ``stateful=True``
receives the whole carry and returns an updated one — the hook for
learned/adaptive schedulers (the backlog and bandwidth fields stay
simulator-owned; ``ema_util`` and ``key`` are policy-usable).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import QOS_ACC_EDGES, MetricsFrame

from .instance import FlatInstance
from .satisfaction import mean_us, satisfied_mask

__all__ = [
    "CongestionConfig",
    "PolicyCarry",
    "init_policy_carry",
    "fleet_policy_carry",
    "compute_inflation",
    "comm_inflation",
    "step_backlog",
    "committed_loads",
    "ema_update",
    "effective_capacity",
    "congested_ctime",
    "frame_utilization",
    "frame_metrics",
]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class CongestionConfig:
    """Parameters of the capacity-overcommit inflation model.

    ``enabled=False`` (the default) turns the whole subsystem off: the
    simulators skip every congestion computation and results are
    bit-identical to the pre-congestion code paths.
    """

    enabled: bool = False
    #: inflation slope per unit of compute over-commit (rho - 1)
    compute_slope: float = 4.0
    #: inflation slope per unit of communication over-commit
    comm_slope: float = 4.0
    #: exponent on the over-commit ratio; the default 2 is superlinear, the
    #: M/G/1 flavour — mild over-commit costs little, deep over-commit spirals
    power: float = 2.0
    #: fraction of the frame budget available to drain carried backlog
    drain: float = 1.0
    #: hard cap on the inflation factor (keeps a dead server finite)
    max_inflation: float = 100.0
    #: smoothing of the per-server EMA utilization estimate in the carry
    ema_alpha: float = 0.2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolicyCarry:
    """Explicit per-replication state threaded across frames.

    Fields (``M`` = number of servers):

    * ``key`` — ``jax.random`` key chain.  Simulator-owned for ``needs_key``
      policies (one subkey split per frame decision); a ``stateful`` policy
      owns it and splits for itself.
    * ``backlog_gamma`` — ``(M,)`` carried compute backlog (chip-ms).
    * ``backlog_eta`` — ``(M,)`` carried communication backlog (KB).
    * ``ema_util`` — ``(M,)`` EMA of per-server committed compute
      utilization (policy-readable load estimate).
    * ``bw_prev`` / ``bw_cur`` — the paper's bandwidth-estimator state
      ``B_{t-1}``, ``B_t`` (sequential testbed only; the fleet schedules
      with the true mean bandwidth).
    * ``link_bw`` — ``(M,)`` this frame's per-edge link bandwidth scale
      from the resilience engine (:mod:`repro.core.impairments`); all ones
      when impairments are disabled.  Simulator-owned, policy-readable.
    * ``server_up`` — ``(M,)`` this frame's up/down vector from the outage
      stream (1.0 = up); all ones when disabled.  Simulator-owned,
      policy-readable (the hook ``gus-adaptive`` uses to route around
      down servers).
    """

    key: jnp.ndarray
    backlog_gamma: jnp.ndarray
    backlog_eta: jnp.ndarray
    ema_util: jnp.ndarray
    bw_prev: jnp.ndarray
    bw_cur: jnp.ndarray
    link_bw: jnp.ndarray
    server_up: jnp.ndarray


def init_policy_carry(
    n_servers: int, *, seed: int = 0, bandwidth_init: float = 0.0
) -> PolicyCarry:
    """A fresh carry: empty backlogs, zero EMA, key chain seeded by ``seed``."""
    return PolicyCarry(
        key=jax.random.PRNGKey(seed),
        backlog_gamma=jnp.zeros((n_servers,), jnp.float32),
        backlog_eta=jnp.zeros((n_servers,), jnp.float32),
        ema_util=jnp.zeros((n_servers,), jnp.float32),
        bw_prev=jnp.float32(bandwidth_init),
        bw_cur=jnp.float32(bandwidth_init),
        link_bw=jnp.ones((n_servers,), jnp.float32),
        server_up=jnp.ones((n_servers,), jnp.float32),
    )


def fleet_policy_carry(
    n_rep: int, n_servers: int, *, seed: int = 0, bandwidth_init: float = 0.0
) -> PolicyCarry:
    """A batched carry for ``simulate_fleet``: one :class:`PolicyCarry` per
    replication, stacked on a leading ``(R,)`` axis.

    Replication ``r``'s key chain is ``fold_in(PRNGKey(seed), r)`` — the
    fleet's legacy per-replication chain — and the leading axis is exactly
    the axis the sharded fleet places across its ``("rep",)`` device mesh,
    so the whole carry pytree shards with ``PartitionSpec("rep")``.
    """
    return PolicyCarry(
        key=jax.vmap(lambda r: jax.random.fold_in(jax.random.PRNGKey(seed), r))(
            jnp.arange(n_rep)
        ),
        backlog_gamma=jnp.zeros((n_rep, n_servers), jnp.float32),
        backlog_eta=jnp.zeros((n_rep, n_servers), jnp.float32),
        ema_util=jnp.zeros((n_rep, n_servers), jnp.float32),
        bw_prev=jnp.full((n_rep,), bandwidth_init, jnp.float32),
        bw_cur=jnp.full((n_rep,), bandwidth_init, jnp.float32),
        link_bw=jnp.ones((n_rep, n_servers), jnp.float32),
        server_up=jnp.ones((n_rep, n_servers), jnp.float32),
    )


def _inflation(load, budget, slope, cfg: CongestionConfig):
    """Service-time inflation ``phi``: 1 at or below budget, then
    ``1 + slope * (rho - 1) ** power`` capped at ``max_inflation``."""
    rho = load / jnp.maximum(budget, _EPS)
    over = jnp.maximum(rho - 1.0, 0.0)
    phi = 1.0 + slope * over ** cfg.power
    return jnp.minimum(phi, cfg.max_inflation)


def compute_inflation(load, budget, cfg: CongestionConfig):
    """(M,) processing-time inflation from committed+carried compute load."""
    return _inflation(load, budget, cfg.compute_slope, cfg)


def comm_inflation(load, budget, cfg: CongestionConfig):
    """(M,) transfer-time inflation from committed+carried comm load."""
    return _inflation(load, budget, cfg.comm_slope, cfg)


def step_backlog(backlog, committed, budget, cfg: CongestionConfig):
    """Next frame's carried backlog: ``max(0, b + w - g * drain)``.

    Conservation: ``b + w == drained + b'`` with
    ``drained = min(b + w, g * drain)`` — work is never created or lost,
    only served this frame or carried to the next.
    """
    return jnp.maximum(backlog + committed - budget * cfg.drain, 0.0)


def effective_capacity(budget, backlog):
    """The budget the *scheduler* sees: ``max(budget - backlog, 0)``.

    A server still working off yesterday's queue offers less fresh
    capacity this frame.  With an empty backlog this is ``budget`` exactly
    (bitwise), which is what keeps the disabled path bit-identical.
    """
    return jnp.maximum(budget - backlog, 0.0)


def committed_loads(
    inst: FlatInstance, assign_j, assign_l
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-server work committed by one frame's assignment.

    Returns ``(w, c)``: ``w[j]`` is the compute (chip-ms, from ``inst.v``)
    scheduled on server *j*; ``c[e]`` is the communication (KB, from
    ``inst.u``) charged against covering edge *e* by offloaded requests.
    Dropped rows (``j < 0``) — including padded rows — contribute nothing.
    """
    M = inst.gamma.shape[-1]
    served = assign_j >= 0
    j = jnp.maximum(assign_j, 0)
    l = jnp.maximum(assign_l, 0)
    idx = jnp.arange(assign_j.shape[-1])
    v_picked = inst.v[idx, j, l]
    u_picked = inst.u[idx, j, l]
    offloaded = served & (assign_j != inst.cover)
    w = jnp.zeros((M,), jnp.float32).at[j].add(jnp.where(served, v_picked, 0.0))
    c = jnp.zeros((M,), jnp.float32).at[inst.cover].add(
        jnp.where(offloaded, u_picked, 0.0)
    )
    return w, c


def ema_update(ema, committed, budget, cfg: CongestionConfig):
    """EMA of per-server committed utilization (``committed / budget``)."""
    util = committed / jnp.maximum(budget, _EPS)
    return (1.0 - cfg.ema_alpha) * ema + cfg.ema_alpha * util


def congested_ctime(inst: FlatInstance, tq, phi_c, phi_e) -> jnp.ndarray:
    """Realized completion-time tensor under congestion.

    ``ctime = Tq + proc + comm`` was built load-free; this inflates the
    processing part (``inst.v``) by the serving server's ``phi_c`` and the
    communication part (``ctime - v - Tq``, which includes the cloud
    backhaul constant) by the covering edge's ``phi_e``:

    ``ct' = ctime + v * (phi_c[j] - 1) + comm * (phi_e[cover] - 1)``

    With ``phi == 1`` everywhere this is ``ctime`` bitwise (the additions
    are exact zeros), so one metrics path serves both modes.

    Shapes: ``inst`` leaves ``(N, M, L)``, ``tq`` ``(N,)``, ``phi_c`` /
    ``phi_e`` ``(M,)``; every argument may carry matching leading batch axes.
    """
    comm = inst.ctime - inst.v - tq[..., :, None, None]
    phi_e_cover = jnp.take_along_axis(phi_e, inst.cover, axis=-1)
    return (
        inst.ctime
        + inst.v * (phi_c[..., None, :, None] - 1.0)
        + comm * (phi_e_cover[..., :, None, None] - 1.0)
    )


def frame_utilization(committed, budget) -> jnp.ndarray:
    """Per-server committed-work / frame-budget ratio, 0 where the budget
    is zero (a fully-down server under an outage mask).  Overcommitting
    policies exceed 1 — that *is* the signal the calibration item needs."""
    return jnp.where(budget > 0.0, committed / jnp.maximum(budget, _EPS), 0.0)


def frame_metrics(
    inst: FlatInstance,
    assign_j,
    assign_l,
    tq,
    phi_c,
    phi_e,
    n_real,
    n_edge: int,
    carry: PolicyCarry,
    n_shed,
    n_refused,
    qos_edges: Tuple[float, ...] = QOS_ACC_EDGES,
) -> MetricsFrame:
    """One decision's :class:`~repro.obs.metrics.MetricsFrame`, pure jnp.

    Runs unbatched inside ``simulate_fleet``'s scan step (the scan stacks
    the frame axis, ``vmap`` the replication axis) on the *same* operands
    the result metrics use — ``congested_ctime`` with the step's
    inflation factors (bitwise ``inst.ctime`` when they are all ones), so
    the stream's satisfaction counts match ``FleetResult`` exactly.
    ``n_real`` masks the padded rows; ``carry`` supplies the post-step
    backlogs (the series the Fig. 1(e)-(h) calibration fits against).
    """
    N = assign_j.shape[-1]
    real = jnp.arange(N) < n_real
    served = (assign_j >= 0) & real
    minst = dataclasses.replace(
        inst, ctime=congested_ctime(inst, tq, phi_c, phi_e)
    )
    sat = satisfied_mask(minst, assign_j, assign_l) & real
    local = served & (assign_j == inst.cover)
    cloud = served & (assign_j >= n_edge)
    tier = jnp.stack(
        [local.sum(), (served & ~local & ~cloud).sum(), cloud.sum()]
    ).astype(jnp.int32)
    edges = jnp.asarray(qos_edges, jnp.float32)
    cls = jnp.sum(inst.A[..., :, None] >= edges, axis=-1)
    nq = len(qos_edges) + 1
    qos_count = jnp.zeros((nq,), jnp.int32).at[cls].add(real.astype(jnp.int32))
    qos_sat = jnp.zeros((nq,), jnp.int32).at[cls].add(sat.astype(jnp.int32))
    w, c = committed_loads(inst, assign_j, assign_l)
    return MetricsFrame(
        n_arrivals=jnp.asarray(n_real, jnp.int32),
        n_served=served.sum().astype(jnp.int32),
        n_satisfied=sat.sum().astype(jnp.int32),
        n_shed=jnp.asarray(n_shed, jnp.int32),
        n_refused=jnp.asarray(n_refused, jnp.int32),
        tier_hist=tier,
        qos_sat=qos_sat,
        qos_count=qos_count,
        util_gamma=frame_utilization(w, inst.gamma),
        util_eta=frame_utilization(c, inst.eta),
        backlog_gamma=carry.backlog_gamma,
        backlog_eta=carry.backlog_eta,
        us_sum=(mean_us(minst, assign_j, assign_l) * N).astype(jnp.float32),
    )
