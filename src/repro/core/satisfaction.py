"""User-Satisfaction (US) metric — Eq. (1) of the paper.

US_{ijkl} = w_a * (a_{ijkl} - A_i) / Max_as  +  w_c * (C_i - c_{ijkl}) / Max_cs

A request is *satisfiable* by (j, l) iff the accuracy floor and the deadline
hold AND the variant is placed on j (constraints 2b, 2c and placement).
"""
from __future__ import annotations

import jax.numpy as jnp

from .instance import FlatInstance

__all__ = ["us_tensor", "hard_feasible", "mean_us", "satisfied_mask"]


def us_tensor(inst: FlatInstance) -> jnp.ndarray:
    """(..., N, M, L) user satisfaction for every candidate assignment."""
    max_as = inst.max_as[..., None, None, None]  # broadcast over (N, M, L)
    max_cs = inst.max_cs[..., None, None, None]
    acc_term = (inst.acc - inst.A[..., :, None, None]) / max_as
    time_term = (inst.C[..., :, None, None] - inst.ctime) / max_cs
    return (
        inst.w_a[..., :, None, None] * acc_term
        + inst.w_c[..., :, None, None] * time_term
    )


def hard_feasible(inst: FlatInstance) -> jnp.ndarray:
    """(..., N, M, L) bool: placement + accuracy floor + deadline (2b), (2c)."""
    return (
        inst.avail
        & (inst.acc >= inst.A[..., :, None, None])
        & (inst.ctime <= inst.C[..., :, None, None])
    )


def satisfied_mask(inst: FlatInstance, assign_j, assign_l) -> jnp.ndarray:
    """(..., N) bool: request i assigned (assign_j >= 0) and QoS met."""
    served = assign_j >= 0
    j = jnp.maximum(assign_j, 0)
    l = jnp.maximum(assign_l, 0)
    idx_n = jnp.arange(assign_j.shape[-1])
    acc = jnp.take_along_axis(
        jnp.take_along_axis(inst.acc, j[..., :, None, None], axis=-2)[..., :, 0, :],
        l[..., :, None],
        axis=-1,
    )[..., :, 0]
    ct = jnp.take_along_axis(
        jnp.take_along_axis(inst.ctime, j[..., :, None, None], axis=-2)[..., :, 0, :],
        l[..., :, None],
        axis=-1,
    )[..., :, 0]
    del idx_n
    return served & (acc >= inst.A) & (ct <= inst.C)


def mean_us(inst: FlatInstance, assign_j, assign_l) -> jnp.ndarray:
    """Objective (2): mean US over all |N| requests (dropped contribute 0).

    Gathers the chosen (j, l) cell of ``acc``/``ctime`` first and evaluates
    Eq. (1) only there — the same elementwise operations, in the same order,
    on the same operands as picking out of the full :func:`us_tensor`, so
    the result is bit-identical while doing ~M*L times less arithmetic
    (this sits on the fleet's per-window metrics path).
    """
    served = assign_j >= 0
    j = jnp.maximum(assign_j, 0)
    l = jnp.maximum(assign_l, 0)

    def pick(x):
        return jnp.take_along_axis(
            jnp.take_along_axis(x, j[..., :, None, None], axis=-2)[..., :, 0, :],
            l[..., :, None],
            axis=-1,
        )[..., :, 0]

    acc_term = (pick(inst.acc) - inst.A) / inst.max_as[..., None]
    time_term = (inst.C - pick(inst.ctime)) / inst.max_cs[..., None]
    picked = inst.w_a * acc_term + inst.w_c * time_term
    return jnp.where(served, picked, 0.0).mean(axis=-1)
