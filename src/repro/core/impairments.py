"""Resilience layer — link impairments, stochastic outages, admission control.

The paper's testbed (Fig. 1(e)-(h)) runs over a *real* wireless network:
links drop, hand off and add latency, servers fail, and overload must be
shed before it poisons every later frame.  The numerical model in
:mod:`repro.core.simulator` is a perfect network, so this module adds the
three missing mechanisms behind the same switch discipline as
:class:`~repro.core.queueing.CongestionConfig` — **bit-identical results
when disabled**, deterministic given a seed when enabled:

* **Link-quality traces** — each edge carries a :class:`LinkTrace`: a
  frame-indexed sequence of ``(bandwidth_scale, extra_latency_ms)`` pairs
  drawn from a composable :class:`LinkProfile` (intermittent connectivity,
  bursty loss, 4G/5G handoff gaps, satellite latency).  The trace modulates
  the *scheduler-visible* transfer times (through the frame instance's
  ``ctime``) and the *realized* channel in the sequential testbed, and the
  current per-edge bandwidth scale rides the
  :class:`~repro.core.queueing.PolicyCarry` (``carry.link_bw``) so adaptive
  policies can see it.  Traces are memoized prefix-stable: the value at
  frame ``t`` depends only on ``(profile, seed, t)``, never on how the
  frames were pulled — which is what keeps the windowed / prefetched /
  sharded fleet paths bitwise identical to the serial run.
* **Server outage/recovery events** — a per-server up/down Markov chain
  parameterized by MTBF/MTTR (in frames).  Where the ``outage`` *scenario*
  scripts one fixed window, the :class:`ResilienceEngine` generalizes it to
  a stochastic event stream: the engine's capacity mask multiplies into the
  per-frame budgets exactly like a scenario ``capacity_scale``, and the
  up/down vector rides the carry (``carry.server_up``).
* **Admission control** — :class:`AdmissionConfig` adds per-server queue
  caps (refuse assignments to servers whose carried backlog exceeds
  ``queue_cap_mult`` frame budgets) and deadline-based shedding (mask out
  requests that provably cannot meet their deadline under the *pre-frame*
  congestion estimate).  The shed test uses the backlog-only inflation
  ``phi(backlog)`` — a lower bound on the realized ``phi(backlog +
  committed)`` since inflation is monotone in load — so a shed request
  could never have been satisfied: shedding never drops a feasible
  in-deadline request.

The amplitude blend gives an exact identity at zero: a trace value
``(raw_bw, raw_lat)`` is applied as ``bw = 1 + amplitude * (raw_bw - 1)``
and ``lat = amplitude * raw_lat``, so ``amplitude=0.0`` multiplies by
exactly ``1.0`` and adds exactly ``0.0`` — bitwise inert even with the
subsystem enabled (pinned in ``tests/test_impairments.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .instance import FlatInstance
from .queueing import (
    CongestionConfig,
    comm_inflation,
    compute_inflation,
    congested_ctime,
)

__all__ = [
    "LinkProfile",
    "IdealLink",
    "IntermittentLink",
    "BurstyLossLink",
    "HandoffLink",
    "SatelliteLink",
    "ComposedLink",
    "LinkTrace",
    "OutageTrace",
    "ImpairmentConfig",
    "AdmissionConfig",
    "ResilienceEngine",
    "predicted_inflation",
    "admission_keep",
    "apply_queue_cap",
]

#: hard floor on any profile's bandwidth scale — a "down" link is slow, not
#: a division by zero
MIN_BW_SCALE = 1e-3


# ---------------------------------------------------------------------------
# Link-quality profiles (composable trace generators)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Base profile: the ideal link.  Subclasses override :meth:`init_state`
    and :meth:`sample` to define a per-frame Markov process emitting
    ``(bandwidth_scale, extra_latency_ms)`` — scale in ``(0, 1]``, latency
    ``>= 0``.  Profiles are frozen (hashable) so they can live inside
    :class:`ImpairmentConfig` and cache keys.
    """

    def init_state(self, rng: np.random.Generator):
        return 0

    def sample(self, state, rng: np.random.Generator):
        """One frame: ``(next_state, bandwidth_scale, extra_latency_ms)``.

        Called exactly once per frame in frame order — a profile may draw
        from ``rng`` freely; sequential consumption is what makes traces
        prefix-stable."""
        return state, 1.0, 0.0


@dataclasses.dataclass(frozen=True)
class IdealLink(LinkProfile):
    """No impairment: scale 1, zero extra latency (the explicit default)."""


@dataclasses.dataclass(frozen=True)
class IntermittentLink(LinkProfile):
    """Intermittent connectivity: an up/down Markov chain.  While down the
    link limps at ``down_bw`` of nominal bandwidth plus ``down_lat`` ms of
    retry latency (disconnect/reconnect, not a hard zero)."""

    p_down: float = 0.15   # P(up -> down) per frame
    p_up: float = 0.5      # P(down -> up) per frame
    down_bw: float = 0.05
    down_lat: float = 400.0

    def sample(self, state, rng):
        u = rng.random()
        if state == 0:  # up
            state = 1 if u < self.p_down else 0
        else:
            state = 0 if u < self.p_up else 1
        if state:
            return state, self.down_bw, self.down_lat
        return state, 1.0, 0.0


@dataclasses.dataclass(frozen=True)
class BurstyLossLink(LinkProfile):
    """Gilbert–Elliott bursty loss: a good/bad chain where the bad state
    models retransmission pressure — reduced goodput and added latency."""

    p_enter: float = 0.2   # P(good -> bad)
    p_exit: float = 0.5    # P(bad -> good)
    bad_bw: float = 0.4
    bad_lat: float = 120.0

    def sample(self, state, rng):
        u = rng.random()
        if state == 0:
            state = 1 if u < self.p_enter else 0
        else:
            state = 0 if u < self.p_exit else 1
        if state:
            return state, self.bad_bw, self.bad_lat
        return state, 1.0, 0.0


@dataclasses.dataclass(frozen=True)
class HandoffLink(LinkProfile):
    """4G/5G handoff: roughly every ``period_frames`` (jittered) the link
    stalls for ``gap_frames`` while the user re-attaches — bandwidth
    collapses and control-plane latency spikes.  State is the countdown to
    the next handoff (negative while inside the gap)."""

    period_frames: int = 20
    period_jitter: int = 4
    gap_frames: int = 1
    gap_bw: float = 0.1
    gap_lat: float = 250.0

    def _next_period(self, rng) -> int:
        lo = max(1, self.period_frames - self.period_jitter)
        hi = self.period_frames + self.period_jitter
        return int(rng.integers(lo, hi + 1))

    def init_state(self, rng):
        return self._next_period(rng)

    def sample(self, state, rng):
        if state > 0:  # connected; count down to the handoff
            return state - 1, 1.0, 0.0
        # in the gap: state counts 0, -1, ..., -(gap_frames - 1)
        if state <= -(self.gap_frames - 1):  # last gap frame: re-arm the timer
            return self._next_period(rng), self.gap_bw, self.gap_lat
        return state - 1, self.gap_bw, self.gap_lat


@dataclasses.dataclass(frozen=True)
class SatelliteLink(LinkProfile):
    """Satellite backhaul: a constant high propagation delay with jitter and
    a mildly reduced goodput — impaired every frame, never disconnected."""

    bw: float = 0.8
    lat: float = 550.0
    lat_jitter: float = 40.0

    def sample(self, state, rng):
        lat = self.lat + self.lat_jitter * rng.standard_normal()
        return state, self.bw, max(lat, 0.0)


@dataclasses.dataclass(frozen=True)
class ComposedLink(LinkProfile):
    """Composition of profiles: bandwidth scales multiply, latencies add —
    e.g. a satellite link that also suffers bursty loss."""

    parts: Tuple[LinkProfile, ...] = ()

    def init_state(self, rng):
        return tuple(p.init_state(rng) for p in self.parts)

    def sample(self, state, rng):
        new_states: List = []
        bw, lat = 1.0, 0.0
        for p, s in zip(self.parts, state):
            s2, b, t = p.sample(s, rng)
            new_states.append(s2)
            bw *= b
            lat += t
        return tuple(new_states), bw, lat


class LinkTrace:
    """One edge's frame-indexed link-quality trace, drawn lazily.

    Values are memoized and extended strictly in frame order from a private
    generator, so ``value(t)`` depends only on ``(profile, seed, t)`` — the
    pull pattern (one frame at a time, whole windows, or everything at once)
    never changes the sequence.  ``tests/test_impairments.py`` pins
    chunked == one-shot draining.
    """

    def __init__(self, profile: LinkProfile, seed: int = 0):
        self.profile = profile
        self._rng = np.random.default_rng(seed)
        self._state = profile.init_state(self._rng)
        self._bw: List[float] = []
        self._lat: List[float] = []

    def __len__(self) -> int:
        return len(self._bw)

    def _extend_to(self, t: int) -> None:
        while len(self._bw) <= t:
            self._state, bw, lat = self.profile.sample(self._state, self._rng)
            self._bw.append(min(max(float(bw), MIN_BW_SCALE), 1.0))
            self._lat.append(max(float(lat), 0.0))

    def value(self, t: int) -> Tuple[float, float]:
        """``(bandwidth_scale, extra_latency_ms)`` for frame ``t``."""
        self._extend_to(t)
        return self._bw[t], self._lat[t]

    def values(self, t0: int, t1: int) -> Tuple[np.ndarray, np.ndarray]:
        """Arrays of (scale, latency) for frames ``[t0, t1)``."""
        if t1 > t0:
            self._extend_to(t1 - 1)
        return (
            np.asarray(self._bw[t0:t1], np.float64),
            np.asarray(self._lat[t0:t1], np.float64),
        )


class OutageTrace:
    """One server's up/down Markov chain: per frame,
    ``P(up -> down) = 1/mtbf`` and ``P(down -> up) = 1/mttr`` (frames).
    Memoized prefix-stable like :class:`LinkTrace`; starts up."""

    def __init__(self, mtbf_frames: float, mttr_frames: float, seed: int = 0):
        self.p_fail = 1.0 / max(float(mtbf_frames), 1.0)
        self.p_repair = 1.0 / max(float(mttr_frames), 1.0)
        self._rng = np.random.default_rng(seed)
        self._up: List[bool] = []
        self._state = True

    def up(self, t: int) -> bool:
        while len(self._up) <= t:
            u = self._rng.random()
            if self._state:
                self._state = not (u < self.p_fail)
            else:
                self._state = u < self.p_repair
            self._up.append(self._state)
        return self._up[t]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ImpairmentConfig:
    """Switchboard for the network/server fault injection.

    ``enabled=False`` (the default) skips the whole subsystem — no engine is
    built and every code path is bit-identical to the pre-resilience
    simulator.  With ``enabled=True`` and ``amplitude=0.0`` the subsystem
    *runs* but applies exact-identity values (multiply by 1.0, add 0.0), so
    results are still bitwise unchanged — the identity the tests pin.
    """

    enabled: bool = False
    #: blend factor for link traces: ``bw = 1 + amplitude * (raw - 1)``,
    #: ``lat = amplitude * raw``.  0 is an exact identity, 1 the full trace.
    amplitude: float = 1.0
    #: per-edge link profiles, cycled when shorter than ``n_edge``; empty
    #: means every edge gets :class:`IdealLink`.
    link_profiles: Tuple[LinkProfile, ...] = ()
    #: impairment stream seed — *independent* of the simulation seed and of
    #: the replication index, so every fleet replication faces the same
    #: network weather (what makes the per-frame trace arrays shareable
    #: across the rep axis, and sharded == serial trivially).
    seed: int = 0
    #: mean frames between failures for the stochastic outage stream;
    #: ``0.0`` disables server outages entirely.
    outage_mtbf_frames: float = 0.0
    #: mean frames to repair
    outage_mttr_frames: float = 3.0
    #: servers subject to the outage stream (empty = none)
    outage_servers: Tuple[int, ...] = ()

    @property
    def has_outages(self) -> bool:
        return self.outage_mtbf_frames > 0.0 and len(self.outage_servers) > 0


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control knobs (protection mechanisms).

    ``enabled=False`` skips every admission computation.  With
    ``enabled=True`` the defaults are still inert: ``queue_cap_mult=inf``
    never refuses (``backlog >= inf`` is False, and ``inf * 0`` is NaN whose
    comparisons are False, so even a zero-budget outage server passes), and
    ``shed=False`` keeps every request.  Hashable — part of the fleet
    runner's compile-cache key.
    """

    enabled: bool = False
    #: refuse assignments to a server whose carried backlog exceeds this
    #: many frame budgets (compute side by the serving server, comm side by
    #: the covering edge).  ``inf`` = never refuse; finite values also
    #: refuse dead (zero-budget) servers.
    queue_cap_mult: float = math.inf
    #: deadline-based shedding: drop requests that provably cannot finish
    #: in deadline under the pre-frame congestion estimate (see
    #: :func:`admission_keep`)
    shed: bool = False


# ---------------------------------------------------------------------------
# The engine (host-side, deterministic, frame-indexed)
# ---------------------------------------------------------------------------


class ResilienceEngine:
    """Deterministic fault-injection state for one simulation run.

    A pure function of ``(config, frame_index)``: link values and outage
    states are memoized prefix-stable per trace, so any caller — the
    sequential frame loop, the fleet's windowed grid builder (inline or on
    the prefetch producer thread), the host-side oracle fallback — sees the
    same values for the same frame.  Replication-independent by design (see
    :attr:`ImpairmentConfig.seed`).
    """

    def __init__(self, rcfg: ImpairmentConfig, n_edge: int, n_servers: int):
        self.rcfg = rcfg
        self.n_edge = n_edge
        self.n_servers = n_servers
        profiles = rcfg.link_profiles or (IdealLink(),)
        self._traces = [
            LinkTrace(profiles[e % len(profiles)], seed=rcfg.seed * 1_000_003 + e)
            for e in range(n_edge)
        ]
        self._outages = {
            j: OutageTrace(
                rcfg.outage_mtbf_frames,
                rcfg.outage_mttr_frames,
                seed=rcfg.seed * 2_000_003 + j,
            )
            for j in rcfg.outage_servers
            if 0 <= j < n_servers
        } if rcfg.has_outages else {}

    def link_frame(self, t: int) -> Tuple[np.ndarray, np.ndarray]:
        """Amplitude-blended per-*server* ``(bandwidth_scale, extra_lat_ms)``
        for frame ``t`` — entries beyond ``n_edge`` (the cloud tier, never a
        covering edge) stay at identity."""
        amp = self.rcfg.amplitude
        scale = np.ones(self.n_servers, np.float64)
        lat = np.zeros(self.n_servers, np.float64)
        for e, tr in enumerate(self._traces):
            bw, lt = tr.value(t)
            scale[e] = 1.0 + amp * (bw - 1.0)
            lat[e] = amp * lt
        np.clip(scale, MIN_BW_SCALE, None, out=scale)
        return scale, lat

    def server_up(self, t: int) -> np.ndarray:
        """(M,) float32 up/down vector for frame ``t`` (1.0 = up)."""
        up = np.ones(self.n_servers, np.float32)
        for j, tr in self._outages.items():
            if not tr.up(t):
                up[j] = 0.0
        return up

    def capacity_scale(self, t: int) -> Optional[np.ndarray]:
        """Per-frame budget multiplier from the outage stream, or ``None``
        when no outage process is configured (budgets untouched bitwise)."""
        if not self._outages:
            return None
        return self.server_up(t).astype(np.float64)


# ---------------------------------------------------------------------------
# Admission-control primitives (pure jnp; shared by frame loop, fleet scan
# and the host-side oracle fallback)
# ---------------------------------------------------------------------------


def predicted_inflation(backlog_gamma, backlog_eta, gamma, eta, ccfg: CongestionConfig):
    """Pre-frame inflation estimate ``phi(backlog)`` against the *full*
    frame budgets — a lower bound on the realized ``phi(backlog +
    committed)`` because inflation is monotone in load.  All-ones when the
    congestion model is off (nothing ever inflates)."""
    if not ccfg.enabled:
        return jnp.ones_like(gamma), jnp.ones_like(eta)
    return (
        compute_inflation(backlog_gamma, gamma, ccfg),
        comm_inflation(backlog_eta, eta, ccfg),
    )


def admission_keep(inst: FlatInstance, tq, phi_c, phi_e) -> jnp.ndarray:
    """(N,) bool: request has at least one placed candidate meeting both its
    accuracy floor and its deadline under the inflation estimate.

    With the conservative (under-)estimate from :func:`predicted_inflation`
    this can only be False when *every* candidate also misses under the
    realized inflation — shedding on ``~keep`` never drops a request that
    could have been satisfied."""
    ct = congested_ctime(inst, tq, phi_c, phi_e)
    ok = (
        inst.avail
        & (inst.acc >= inst.A[..., :, None, None])
        & (ct <= inst.C[..., :, None, None])
    )
    return ok.any((-1, -2))


def apply_queue_cap(
    assign_j, inst: FlatInstance, backlog_gamma, backlog_eta, acfg: AdmissionConfig
):
    """Refuse (-> -1) assignments to servers over their backlog cap.

    A server is over-cap when its carried backlog reaches
    ``queue_cap_mult`` times its frame budget — compute side checked for the
    serving server, comm side for the covering edge of offloaded requests.
    ``inst.gamma``/``inst.eta`` must be the *full* frame budgets.  With the
    default ``inf`` cap nothing is ever refused (``>= inf`` and ``>= nan``
    are both False), keeping the call bitwise inert."""
    over_c = backlog_gamma >= acfg.queue_cap_mult * inst.gamma
    over_e = backlog_eta >= acfg.queue_cap_mult * inst.eta
    served = assign_j >= 0
    j = jnp.maximum(assign_j, 0)
    refuse = served & (
        over_c[j] | ((assign_j != inst.cover) & over_e[inst.cover])
    )
    return jnp.where(refuse, -1, assign_j)
