"""Exact MUS solver — depth-first branch & bound (CPLEX stand-in).

The MUS ILP (Eq. 2) is NP-hard (Theorem 1), so exact solving is reserved for
small instances; we use it as the oracle behind the paper's "GUS achieves on
average 90% of the optimal" claim (Sec. IV).

Bounding: at each node the remaining requests contribute at most their best
feasible US *ignoring capacity* (an admissible relaxation of 2d/2e), so
``current + optimistic_suffix <= best`` prunes.  Requests are pre-sorted by
their optimistic US descending, which tightens the bound early.

``solve_exhaustive`` enumerates every assignment vector — used in tests to
verify the B&B on tiny instances.

``lagrangian_dual`` / ``lagrangian_bound`` evaluate the Lagrangian dual of
the MUS **LP relaxation** (capacity constraints 2d/2e dualized with
multipliers ``lam``/``mu`` >= 0): every dual point is a certified *upper
bound* on the integral optimum, subgradient descent tightens it, and at the
dual optimum the bound equals the LP-relaxation value.  Unlike the B&B,
evaluation is one vectorized pass per iteration — it scales to hundreds of
requests, which is what makes the optimality gap measurable past the
``ilp`` policy's 24-request refusal (the ``lp-bound`` policy in
:mod:`~repro.core.policies` pairs the bound with a price-directed greedy
primal so it also schedules).
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

from .gus import Assignment
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = [
    "solve_bnb",
    "solve_exhaustive",
    "lagrangian_dual",
    "lagrangian_bound",
    "price_directed_greedy",
]


def _prepare(inst: FlatInstance):
    us = np.asarray(us_tensor(inst))
    feas = np.asarray(hard_feasible(inst))
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma, dtype=np.float64)
    eta = np.asarray(inst.eta, dtype=np.float64)
    N, M, L = us.shape
    # Per-request candidate list: (us, j, l, v, u_charged) feasibility-filtered,
    # sorted by us descending.
    cands = []
    for i in range(N):
        lst = []
        for j in range(M):
            for l in range(L):
                if feas[i, j, l]:
                    uu = 0.0 if j == cover[i] else float(u[i, j, l])
                    lst.append((float(us[i, j, l]), j, l, float(v[i, j, l]), uu))
        lst.sort(key=lambda t: -t[0])
        cands.append(lst)
    return us, cands, cover, gamma, eta, N


def solve_bnb(
    inst: FlatInstance, *, node_limit: int = 5_000_000, strict: bool = False
) -> Tuple[Assignment, float]:
    """Exact optimum of (2).  Returns (assignment, objective = mean US).

    When the node budget trips, the search stops and the best solution found
    so far is returned (anytime behaviour) — unless ``strict=True``, which
    raises instead, so callers that certify optimality (the optimality-gap
    benchmarks) cannot silently divide by a non-optimal "optimum".
    """
    us, cands, cover, gamma0, eta0, N = _prepare(inst)

    # Sort requests so the ones with the largest optimistic US go first.
    opt_us = np.array([c[0][0] if c else 0.0 for c in cands])
    order = np.argsort(-opt_us)
    # optimistic suffix sums over the *sorted* order
    suffix = np.zeros(N + 1)
    for pos in range(N - 1, -1, -1):
        suffix[pos] = suffix[pos + 1] + max(opt_us[order[pos]], 0.0)

    best_val = -np.inf
    best_assign = [(-1, -1)] * N
    cur_assign = [(-1, -1)] * N
    nodes = 0

    gamma = gamma0.copy()
    eta = eta0.copy()

    def dfs(pos, cur_val):
        nonlocal best_val, best_assign, nodes
        nodes += 1
        if nodes > node_limit:
            return
        if cur_val + suffix[pos] <= best_val + 1e-12:
            return
        if pos == N:
            if cur_val > best_val:
                best_val = cur_val
                best_assign = list(cur_assign)
            return
        i = int(order[pos])
        s_i = int(cover[i])
        for usv, j, l, vv, uu in cands[i]:
            if vv > gamma[j] + 1e-9:
                continue
            if uu > eta[s_i] + 1e-9:
                continue
            gamma[j] -= vv
            eta[s_i] -= uu
            cur_assign[i] = (j, l)
            dfs(pos + 1, cur_val + usv)
            gamma[j] += vv
            eta[s_i] += uu
            cur_assign[i] = (-1, -1)
        # drop branch
        dfs(pos + 1, cur_val)

    dfs(0, 0.0)
    if strict and nodes > node_limit:
        raise RuntimeError(
            f"solve_bnb hit node_limit={node_limit} before exhausting the "
            f"search on a {N}-request instance; the returned value would not "
            "be a certified optimum"
        )
    jv = np.array([a[0] for a in best_assign], np.int32)
    lv = np.array([a[1] for a in best_assign], np.int32)
    return Assignment(jv, lv), float(best_val) / max(N, 1)


def solve_exhaustive(inst: FlatInstance) -> Tuple[Assignment, float]:
    """Brute force over all (M*L + 1)^N assignments.  Tiny instances only."""
    us, cands, cover, gamma0, eta0, N = _prepare(inst)
    options = [c + [None] for c in cands]  # None = drop
    best_val, best = -np.inf, None
    for choice in itertools.product(*options):
        gamma = gamma0.copy()
        eta = eta0.copy()
        val, ok = 0.0, True
        for i, ch in enumerate(choice):
            if ch is None:
                continue
            usv, j, l, vv, uu = ch
            gamma[j] -= vv
            eta[int(cover[i])] -= uu
            if gamma[j] < -1e-9 or eta[int(cover[i])] < -1e-9:
                ok = False
                break
            val += usv
        if ok and val > best_val:
            best_val, best = val, choice
    jv = np.array([(-1 if c is None else c[1]) for c in best], np.int32)
    lv = np.array([(-1 if c is None else c[2]) for c in best], np.int32)
    return Assignment(jv, lv), float(best_val) / N


# ---------------------------------------------------------------------------
# Lagrangian dual of the LP relaxation (scalable upper bound)
# ---------------------------------------------------------------------------


def _dual_arrays(inst: FlatInstance):
    us = np.asarray(us_tensor(inst), np.float64)
    feas = np.asarray(hard_feasible(inst))
    v = np.asarray(inst.v, np.float64)
    u = np.asarray(inst.u, np.float64)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma, np.float64)
    eta = np.asarray(inst.eta, np.float64)
    N, M, L = us.shape
    local = cover[:, None] == np.arange(M)[None, :]
    u_eff = np.where(local[:, :, None], 0.0, u)  # comm charged only when offloading
    score = np.where(feas, us, -np.inf)
    return score, v, u_eff, cover, gamma, eta, N, M, L


def lagrangian_dual(
    inst: FlatInstance, *, n_iter: int = 120
) -> Tuple[float, np.ndarray, np.ndarray]:
    """Minimize the Lagrangian dual of the MUS LP relaxation by projected
    subgradient descent.  Returns ``(bound, lam, mu)`` where ``bound`` is
    the best (smallest) dual value found, in *mean-US* units — a certified
    upper bound on ``solve_bnb``'s optimum for ANY iterate, since

        D(lam, mu) = lam @ gamma + mu @ eta
                     + sum_i max(0, max_jl [us - lam_j v - mu_{s_i} u])

    dominates every feasible assignment whenever ``lam, mu >= 0``.
    ``lam``/``mu`` are the final multipliers (for price-directed rounding),
    not necessarily the ones attaining ``bound``.
    """
    score, v, u_eff, cover, gamma, eta, N, M, L = _dual_arrays(inst)
    lam = np.zeros(M)
    mu = np.zeros(M)
    best = np.inf
    idx_n = np.arange(N)
    # step length for the normalized direction g/||g||: a diminishing
    # us_scale * N / (||g|| * sqrt(it+1)) — ||g|| is dominated by the
    # capacity terms, so this lands the multipliers in US-per-capacity units
    finite = score[np.isfinite(score)]
    us_scale = float(np.max(finite)) if finite.size else 0.0

    for it in range(n_iter):
        reduced = (
            score
            - lam[None, :, None] * v
            - mu[cover][:, None, None] * u_eff
        )
        flat = reduced.reshape(N, -1)
        pick = np.argmax(flat, axis=1)
        val = flat[idx_n, pick]
        active = val > 0.0  # LP serves request i only if its reduced US is positive
        dual = float(lam @ gamma + mu @ eta + np.sum(np.maximum(val[active], 0.0)))
        best = min(best, dual)

        j_pick, l_pick = np.divmod(pick, L)
        g_lam = gamma.copy()
        g_mu = eta.copy()
        if active.any():
            np.subtract.at(g_lam, j_pick[active], v[idx_n[active], j_pick[active], l_pick[active]])
            np.subtract.at(g_mu, cover[active], u_eff[idx_n[active], j_pick[active], l_pick[active]])
        norm = float(np.sqrt(g_lam @ g_lam + g_mu @ g_mu))
        if norm < 1e-12:
            break
        step = max(us_scale, 1e-6) * N / (norm * np.sqrt(it + 1.0))
        lam = np.maximum(lam - step * g_lam / norm, 0.0)
        mu = np.maximum(mu - step * g_mu / norm, 0.0)
    return best / max(N, 1), lam, mu


def lagrangian_bound(inst: FlatInstance, *, n_iter: int = 120) -> float:
    """Certified upper bound on the MUS optimum (mean-US units); see
    :func:`lagrangian_dual`."""
    bound, _, _ = lagrangian_dual(inst, n_iter=n_iter)
    return bound


def price_directed_greedy(
    inst: FlatInstance, lam: np.ndarray, mu: np.ndarray
) -> Assignment:
    """Feasible primal from dual prices: GUS's sequential greedy, but
    ranking candidates by *reduced* US (``us - lam_j v - mu_{s_i} u``) and
    dropping requests whose best reduced US is non-positive — capacity the
    multipliers already "charge" for is left to later requests.  Honors the
    true capacity constraints, so the result is always feasible."""
    score, v, u_eff, cover, gamma_c, eta_c, N, M, L = _dual_arrays(inst)
    gamma = gamma_c.copy()
    eta = eta_c.copy()
    reduced = score - lam[None, :, None] * v - mu[cover][:, None, None] * u_eff
    out_j = np.full(N, -1, np.int32)
    out_l = np.full(N, -1, np.int32)
    for i in range(N):
        s_i = int(cover[i])
        ok = (
            np.isfinite(reduced[i])
            & (reduced[i] > 0.0)
            & (v[i] <= gamma[:, None] + 1e-9)
            & (((np.arange(M) == s_i)[:, None]) | (u_eff[i] <= eta[s_i] + 1e-9))
        )
        if not ok.any():
            continue
        masked = np.where(ok, reduced[i], -np.inf)
        j, l = np.unravel_index(int(np.argmax(masked)), (M, L))
        out_j[i], out_l[i] = j, l
        gamma[j] -= v[i, j, l]
        if j != s_i:
            eta[s_i] -= u_eff[i, j, l]
    return Assignment(out_j, out_l)
