"""Exact MUS solver — depth-first branch & bound (CPLEX stand-in).

The MUS ILP (Eq. 2) is NP-hard (Theorem 1), so exact solving is reserved for
small instances; we use it as the oracle behind the paper's "GUS achieves on
average 90% of the optimal" claim (Sec. IV).

Bounding: at each node the remaining requests contribute at most their best
feasible US *ignoring capacity* (an admissible relaxation of 2d/2e), so
``current + optimistic_suffix <= best`` prunes.  Requests are pre-sorted by
their optimistic US descending, which tightens the bound early.

``solve_exhaustive`` enumerates every assignment vector — used in tests to
verify the B&B on tiny instances.
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

from .gus import Assignment
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = ["solve_bnb", "solve_exhaustive"]


def _prepare(inst: FlatInstance):
    us = np.asarray(us_tensor(inst))
    feas = np.asarray(hard_feasible(inst))
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma, dtype=np.float64)
    eta = np.asarray(inst.eta, dtype=np.float64)
    N, M, L = us.shape
    # Per-request candidate list: (us, j, l, v, u_charged) feasibility-filtered,
    # sorted by us descending.
    cands = []
    for i in range(N):
        lst = []
        for j in range(M):
            for l in range(L):
                if feas[i, j, l]:
                    uu = 0.0 if j == cover[i] else float(u[i, j, l])
                    lst.append((float(us[i, j, l]), j, l, float(v[i, j, l]), uu))
        lst.sort(key=lambda t: -t[0])
        cands.append(lst)
    return us, cands, cover, gamma, eta, N


def solve_bnb(
    inst: FlatInstance, *, node_limit: int = 5_000_000, strict: bool = False
) -> Tuple[Assignment, float]:
    """Exact optimum of (2).  Returns (assignment, objective = mean US).

    When the node budget trips, the search stops and the best solution found
    so far is returned (anytime behaviour) — unless ``strict=True``, which
    raises instead, so callers that certify optimality (the optimality-gap
    benchmarks) cannot silently divide by a non-optimal "optimum".
    """
    us, cands, cover, gamma0, eta0, N = _prepare(inst)

    # Sort requests so the ones with the largest optimistic US go first.
    opt_us = np.array([c[0][0] if c else 0.0 for c in cands])
    order = np.argsort(-opt_us)
    # optimistic suffix sums over the *sorted* order
    suffix = np.zeros(N + 1)
    for pos in range(N - 1, -1, -1):
        suffix[pos] = suffix[pos + 1] + max(opt_us[order[pos]], 0.0)

    best_val = -np.inf
    best_assign = [(-1, -1)] * N
    cur_assign = [(-1, -1)] * N
    nodes = 0

    gamma = gamma0.copy()
    eta = eta0.copy()

    def dfs(pos, cur_val):
        nonlocal best_val, best_assign, nodes
        nodes += 1
        if nodes > node_limit:
            return
        if cur_val + suffix[pos] <= best_val + 1e-12:
            return
        if pos == N:
            if cur_val > best_val:
                best_val = cur_val
                best_assign = list(cur_assign)
            return
        i = int(order[pos])
        s_i = int(cover[i])
        for usv, j, l, vv, uu in cands[i]:
            if vv > gamma[j] + 1e-9:
                continue
            if uu > eta[s_i] + 1e-9:
                continue
            gamma[j] -= vv
            eta[s_i] -= uu
            cur_assign[i] = (j, l)
            dfs(pos + 1, cur_val + usv)
            gamma[j] += vv
            eta[s_i] += uu
            cur_assign[i] = (-1, -1)
        # drop branch
        dfs(pos + 1, cur_val)

    dfs(0, 0.0)
    if strict and nodes > node_limit:
        raise RuntimeError(
            f"solve_bnb hit node_limit={node_limit} before exhausting the "
            f"search on a {N}-request instance; the returned value would not "
            "be a certified optimum"
        )
    jv = np.array([a[0] for a in best_assign], np.int32)
    lv = np.array([a[1] for a in best_assign], np.int32)
    return Assignment(jv, lv), float(best_val) / max(N, 1)


def solve_exhaustive(inst: FlatInstance) -> Tuple[Assignment, float]:
    """Brute force over all (M*L + 1)^N assignments.  Tiny instances only."""
    us, cands, cover, gamma0, eta0, N = _prepare(inst)
    options = [c + [None] for c in cands]  # None = drop
    best_val, best = -np.inf, None
    for choice in itertools.product(*options):
        gamma = gamma0.copy()
        eta = eta0.copy()
        val, ok = 0.0, True
        for i, ch in enumerate(choice):
            if ch is None:
                continue
            usv, j, l, vv, uu = ch
            gamma[j] -= vv
            eta[int(cover[i])] -= uu
            if gamma[j] < -1e-9 or eta[int(cover[i])] < -1e-9:
                ok = False
                break
            val += usv
        if ok and val > best_val:
            best_val, best = val, choice
    jv = np.array([(-1 if c is None else c[1]) for c in best], np.int32)
    lv = np.array([(-1 if c is None else c[2]) for c in best], np.int32)
    return Assignment(jv, lv), float(best_val) / N
