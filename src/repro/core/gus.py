"""GUS — the paper's greedy scheduler (Algorithm 1) as a composable JAX module.

Two implementations:

* ``gus_schedule_np``  — direct NumPy transcription of Algorithm 1 (the oracle).
* ``gus_schedule``     — pure-JAX: ``lax.fori_loop`` over requests (the greedy
  is sequential in its capacity state) with fully vectorized masked-argmax over
  the (M, L) candidate grid per step.  ``jit``-able and ``vmap``-able over a
  leading instance-batch axis — the paper's 20 000 Monte-Carlo repetitions
  become one device program.

Both return ``Assignment(j, l)`` with j = l = -1 encoding *drop*.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = ["Assignment", "gus_schedule", "gus_schedule_np", "gus_schedule_batch"]

NEG = -1e30


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Assignment:
    """Scheduling decision per request: server j and variant l (-1 = dropped)."""

    j: jnp.ndarray  # (..., N) int32
    l: jnp.ndarray  # (..., N) int32

    def served(self):
        return self.j >= 0

    def offloaded(self, inst: FlatInstance):
        return self.served() & (self.j != inst.cover)


# ---------------------------------------------------------------------------
# NumPy reference (Algorithm 1, line-by-line)
# ---------------------------------------------------------------------------

def gus_schedule_np(inst: FlatInstance) -> Assignment:
    cover = np.asarray(inst.cover)
    A = np.asarray(inst.A)
    C = np.asarray(inst.C)
    acc = np.asarray(inst.acc)
    ctime = np.asarray(inst.ctime)
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    avail = np.asarray(inst.avail)
    gamma = np.asarray(inst.gamma).copy()
    eta = np.asarray(inst.eta).copy()
    N, M, L = acc.shape

    us = np.asarray(us_tensor(inst))
    out_j = np.full(N, -1, np.int32)
    out_l = np.full(N, -1, np.int32)

    for i in range(N):  # foreach request (line 1)
        s_i = cover[i]  # line 2
        # line 3: servers sorted by US descending
        order = np.argsort(-us[i], axis=None)
        for flat in order:
            j, l = divmod(int(flat), L)
            # line 4: deadline, accuracy floor, compute capacity, placement
            if not avail[i, j, l]:
                continue
            if ctime[i, j, l] > C[i] or acc[i, j, l] < A[i]:
                continue
            if v[i, j, l] > gamma[j]:
                continue
            if j == s_i:  # lines 5-9: local processing
                out_j[i], out_l[i] = j, l
                gamma[j] -= v[i, j, l]
                break
            elif u[i, j, l] <= eta[s_i]:  # lines 10-14: offload
                out_j[i], out_l[i] = j, l
                gamma[j] -= v[i, j, l]
                eta[s_i] -= u[i, j, l]
                break
        # else: dropped (stays -1)
    return Assignment(jnp.asarray(out_j), jnp.asarray(out_l))


# ---------------------------------------------------------------------------
# Pure-JAX implementation
# ---------------------------------------------------------------------------

def _gus_body(i, state, *, inst, us, feas):
    gamma, eta, out_j, out_l = state
    M, L = us.shape[1], us.shape[2]
    s_i = inst.cover[i]

    row_us = us[i]          # (M, L)
    row_v = inst.v[i]
    row_u = inst.u[i]
    is_local = jnp.arange(M) == s_i  # (M,)

    ok = (
        feas[i]
        & (row_v <= gamma[:, None])
        & (is_local[:, None] | (row_u <= eta[s_i]))
    )
    score = jnp.where(ok, row_us, NEG)
    flat = jnp.argmax(score.reshape(-1))
    any_ok = score.reshape(-1)[flat] > NEG
    j = (flat // L).astype(jnp.int32)
    l = (flat % L).astype(jnp.int32)

    served = any_ok
    offload = served & (j != s_i)
    gamma = gamma.at[j].add(jnp.where(served, -row_v[j, l], 0.0))
    eta = eta.at[s_i].add(jnp.where(offload, -row_u[j, l], 0.0))
    out_j = out_j.at[i].set(jnp.where(served, j, -1))
    out_l = out_l.at[i].set(jnp.where(served, l, -1))
    return gamma, eta, out_j, out_l


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm"))
def gus_schedule(
    inst: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
) -> Assignment:
    """Run GUS on one instance.  ``relax_*`` implement the paper's
    Happy-Computation / Happy-Communication baselines (constraints 2d/2e
    dropped)."""
    us = us_tensor(inst)
    feas = hard_feasible(inst)
    N = us.shape[0]
    gamma0 = jnp.full_like(inst.gamma, jnp.inf) if relax_compute else inst.gamma
    eta0 = jnp.full_like(inst.eta, jnp.inf) if relax_comm else inst.eta
    out_j = jnp.full((N,), -1, jnp.int32)
    out_l = jnp.full((N,), -1, jnp.int32)
    body = partial(_gus_body, inst=inst, us=us, feas=feas)
    gamma, eta, out_j, out_l = jax.lax.fori_loop(
        0, N, body, (gamma0, eta0, out_j, out_l)
    )
    return Assignment(out_j, out_l)


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm"))
def gus_schedule_batch(
    batch: FlatInstance, *, relax_compute: bool = False, relax_comm: bool = False
) -> Assignment:
    """vmapped GUS over a leading instance-batch axis (Monte-Carlo runs)."""
    fn = partial(
        gus_schedule, relax_compute=relax_compute, relax_comm=relax_comm
    )
    return jax.vmap(fn)(batch)
