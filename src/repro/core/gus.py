"""GUS — the paper's greedy scheduler (Algorithm 1) as a composable JAX module.

Three implementations behind one dispatcher:

* ``gus_schedule_np``  — direct NumPy transcription of Algorithm 1 (the oracle).
* ``backend="xla"``    — pure-JAX: ``lax.fori_loop`` over requests (the greedy
  is sequential in its capacity state) with fully vectorized masked-argmax over
  the (M, L) candidate grid per step.  ``jit``-able and ``vmap``-able over a
  leading instance-batch axis — the paper's 20 000 Monte-Carlo repetitions
  become one device program.  The default.
* ``backend="pallas"`` — the fused Pallas kernel
  (:mod:`repro.kernels.gus_pallas`): utility computation, feasibility and the
  greedy capacity loop in one on-chip program, one grid step per frame in the
  batch.  Compiled Mosaic on TPU; ``interpret=True`` (plain jax ops) on CPU,
  which is how CI validates it.

All three return ``Assignment(j, l)`` with j = l = -1 encoding *drop* and are
held to **bit-identical** assignments on the same frame — integer outputs, so
exact equality, not tolerance, is the test bar (``tests/test_gus_parity.py``).
The backend is picked per call (``backend=``) or process-wide via the
``REPRO_GUS_BACKEND`` environment variable (read when no explicit ``backend=``
is passed; the default is ``"xla"``).

The shared tie-break rule: among equal-utility feasible candidates, the lowest
flat ``(j * L + l)`` index wins.  The JAX paths get this from ``argmax``'s
first-occurrence semantics; the NumPy oracle uses a *stable* descending sort
so duplicate-utility frames (padding rows, quantized QoS tiers) cannot drift
between implementations.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gus_pallas import gus_assign_pallas
from repro.obs.profiler import annotate

from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = [
    "Assignment",
    "GUS_BACKENDS",
    "gus_schedule",
    "gus_schedule_np",
    "gus_schedule_batch",
    "gus_backend_fn",
    "resolve_gus_backend",
]

NEG = -1e30

#: registered GUS dispatch backends (``gus_schedule``'s ``backend=``)
GUS_BACKENDS = ("xla", "pallas")


def resolve_gus_backend(backend=None) -> str:
    """Resolve a ``backend=`` argument under the engine-wide precedence
    order (explicit > ``REPRO_GUS_BACKEND`` > ``"xla"``), delegating to
    :func:`repro.core.options.resolve_backend` — the single environment
    lookup site for the backend axis."""
    from .options import resolve_backend

    return resolve_backend(backend)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Assignment:
    """Scheduling decision per request: server j and variant l (-1 = dropped)."""

    j: jnp.ndarray  # (..., N) int32
    l: jnp.ndarray  # (..., N) int32

    def served(self):
        return self.j >= 0

    def offloaded(self, inst: FlatInstance):
        return self.served() & (self.j != inst.cover)


# ---------------------------------------------------------------------------
# NumPy reference (Algorithm 1, line-by-line)
# ---------------------------------------------------------------------------

def gus_schedule_np(inst: FlatInstance) -> Assignment:
    cover = np.asarray(inst.cover)
    A = np.asarray(inst.A)
    C = np.asarray(inst.C)
    acc = np.asarray(inst.acc)
    ctime = np.asarray(inst.ctime)
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    avail = np.asarray(inst.avail)
    gamma = np.asarray(inst.gamma).copy()
    eta = np.asarray(inst.eta).copy()
    N, M, L = acc.shape

    us = np.asarray(us_tensor(inst))
    out_j = np.full(N, -1, np.int32)
    out_l = np.full(N, -1, np.int32)

    for i in range(N):  # foreach request (line 1)
        s_i = cover[i]  # line 2
        # line 3: servers sorted by US descending.  The sort is *stable* so
        # equal-utility candidates keep ascending flat (j*L + l) order — the
        # same tie-break argmax's first-occurrence rule gives the JAX and
        # Pallas backends, which is what makes bit-parity well-defined on
        # duplicate-utility frames.
        order = np.argsort(-us[i], axis=None, kind="stable")
        for flat in order:
            j, l = divmod(int(flat), L)
            # line 4: deadline, accuracy floor, compute capacity, placement
            if not avail[i, j, l]:
                continue
            if ctime[i, j, l] > C[i] or acc[i, j, l] < A[i]:
                continue
            if v[i, j, l] > gamma[j]:
                continue
            if j == s_i:  # lines 5-9: local processing
                out_j[i], out_l[i] = j, l
                gamma[j] -= v[i, j, l]
                break
            elif u[i, j, l] <= eta[s_i]:  # lines 10-14: offload
                out_j[i], out_l[i] = j, l
                gamma[j] -= v[i, j, l]
                eta[s_i] -= u[i, j, l]
                break
        # else: dropped (stays -1)
    return Assignment(jnp.asarray(out_j), jnp.asarray(out_l))


# ---------------------------------------------------------------------------
# Pure-JAX implementation
# ---------------------------------------------------------------------------

def _gus_body(i, state, *, inst, us, feas):
    gamma, eta, out_j, out_l = state
    M, L = us.shape[1], us.shape[2]
    s_i = inst.cover[i]

    row_us = us[i]          # (M, L)
    row_v = inst.v[i]
    row_u = inst.u[i]
    is_local = jnp.arange(M) == s_i  # (M,)

    ok = (
        feas[i]
        & (row_v <= gamma[:, None])
        & (is_local[:, None] | (row_u <= eta[s_i]))
    )
    score = jnp.where(ok, row_us, NEG)
    flat = jnp.argmax(score.reshape(-1))
    any_ok = score.reshape(-1)[flat] > NEG
    j = (flat // L).astype(jnp.int32)
    l = (flat % L).astype(jnp.int32)

    served = any_ok
    offload = served & (j != s_i)
    gamma = gamma.at[j].add(jnp.where(served, -row_v[j, l], 0.0))
    eta = eta.at[s_i].add(jnp.where(offload, -row_u[j, l], 0.0))
    out_j = out_j.at[i].set(jnp.where(served, j, -1))
    out_l = out_l.at[i].set(jnp.where(served, l, -1))
    return gamma, eta, out_j, out_l


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm"))
def _gus_schedule_xla(
    inst: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
) -> Assignment:
    """The jitted XLA implementation (the default backend)."""
    us = us_tensor(inst)
    feas = hard_feasible(inst)
    N = us.shape[0]
    gamma0 = jnp.full_like(inst.gamma, jnp.inf) if relax_compute else inst.gamma
    eta0 = jnp.full_like(inst.eta, jnp.inf) if relax_comm else inst.eta
    out_j = jnp.full((N,), -1, jnp.int32)
    out_l = jnp.full((N,), -1, jnp.int32)
    if N == 0:  # static under jit; fori_loop would trace a size-0 gather
        return Assignment(out_j, out_l)
    body = partial(_gus_body, inst=inst, us=us, feas=feas)
    gamma, eta, out_j, out_l = jax.lax.fori_loop(
        0, N, body, (gamma0, eta0, out_j, out_l)
    )
    return Assignment(out_j, out_l)


def _relaxed_budgets(inst: FlatInstance, relax_compute: bool, relax_comm: bool):
    """The Happy-* budget substitution, shared by both JAX backends."""
    gamma0 = jnp.full_like(inst.gamma, jnp.inf) if relax_compute else inst.gamma
    eta0 = jnp.full_like(inst.eta, jnp.inf) if relax_comm else inst.eta
    return gamma0, eta0


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm", "interpret"))
def _gus_schedule_pallas(
    inst: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
    interpret: bool = True,
) -> Assignment:
    """Single-frame entry to the fused Pallas kernel (batch of one grid
    program; ``vmap`` lifts it to one program per batched frame)."""
    gamma0, eta0 = _relaxed_budgets(inst, relax_compute, relax_comm)
    add = lambda x: jnp.asarray(x)[None]  # noqa: E731 — lift to batch of 1
    j, l = gus_assign_pallas(
        add(inst.cover), add(inst.A), add(inst.C), add(inst.w_a), add(inst.w_c),
        add(inst.acc), add(inst.ctime), add(inst.v), add(inst.u), add(inst.avail),
        add(gamma0), add(eta0), add(inst.max_as), add(inst.max_cs),
        interpret=interpret,
    )
    return Assignment(j[0], l[0])


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm", "interpret"))
def _gus_schedule_batch_pallas(
    batch: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
    interpret: bool = True,
) -> Assignment:
    """Natively-batched Pallas entry: grid = the leading batch axis, one
    grid program per frame — no vmap lifting."""
    gamma0, eta0 = _relaxed_budgets(batch, relax_compute, relax_comm)
    j, l = gus_assign_pallas(
        batch.cover, batch.A, batch.C, batch.w_a, batch.w_c,
        batch.acc, batch.ctime, batch.v, batch.u, batch.avail,
        gamma0, eta0, batch.max_as, batch.max_cs,
        interpret=interpret,
    )
    return Assignment(j, l)


def _pallas_interpret() -> bool:
    from repro.kernels.gus_pallas import gus_pallas_interpret_default

    return gus_pallas_interpret_default()


def gus_schedule(
    inst: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
    backend: str = None,
) -> Assignment:
    """Run GUS on one instance.  ``relax_*`` implement the paper's
    Happy-Computation / Happy-Communication baselines (constraints 2d/2e
    dropped).  ``backend`` selects the implementation (``"xla"`` jitted
    loop, ``"pallas"`` fused kernel; ``None`` defers to the
    ``REPRO_GUS_BACKEND`` environment variable) — assignments are
    bit-identical across backends."""
    if resolve_gus_backend(backend) == "pallas":
        with annotate("gus/pallas_kernel"):
            return _gus_schedule_pallas(
                inst, relax_compute=relax_compute, relax_comm=relax_comm,
                interpret=_pallas_interpret(),
            )
    with annotate("gus/xla"):
        return _gus_schedule_xla(
            inst, relax_compute=relax_compute, relax_comm=relax_comm
        )


@partial(jax.jit, static_argnames=("relax_compute", "relax_comm"))
def _gus_schedule_batch_xla(
    batch: FlatInstance, *, relax_compute: bool = False, relax_comm: bool = False
) -> Assignment:
    fn = partial(
        _gus_schedule_xla, relax_compute=relax_compute, relax_comm=relax_comm
    )
    return jax.vmap(fn)(batch)


def gus_schedule_batch(
    batch: FlatInstance,
    *,
    relax_compute: bool = False,
    relax_comm: bool = False,
    backend: str = None,
) -> Assignment:
    """GUS over a leading instance-batch axis (Monte-Carlo runs): vmapped
    XLA by default, or the natively-batched Pallas kernel (one grid program
    per frame) with ``backend="pallas"``."""
    if resolve_gus_backend(backend) == "pallas":
        with annotate("gus/pallas_kernel_batch"):
            return _gus_schedule_batch_pallas(
                batch, relax_compute=relax_compute, relax_comm=relax_comm,
                interpret=_pallas_interpret(),
            )
    with annotate("gus/xla_batch"):
        return _gus_schedule_batch_xla(
            batch, relax_compute=relax_compute, relax_comm=relax_comm
        )


@functools.lru_cache(maxsize=None)
def gus_backend_fn(backend: str):
    """A stable-identity ``FlatInstance -> Assignment`` callable for one
    backend.  The fleet runner's compiled-program cache keys on the schedule
    function's identity, so ad-hoc ``partial(gus_schedule, backend=...)``
    objects would force a re-trace per call — this cache hands every caller
    the same object per backend."""
    backend = resolve_gus_backend(backend)
    if backend == "xla":
        return gus_schedule  # the default object every existing cache keys on
    return functools.partial(gus_schedule, backend=backend)
