"""Time-slotted edge-cluster simulator — the paper's testbed, virtualized.

Reproduces the Sec. IV testbed protocol:

* users submit requests to their covering edge server's *admission queue*;
* a decision algorithm runs at the end of every time frame (or earlier if the
  queue is full — paper: queue length 4, frame 3000 ms);
* the queuing delay T^q of a request is the measured wait until its frame's
  decision, exactly as in the completion-time model;
* actual communication delays are stochastic (lognormal jitter around
  size/bandwidth — the "wireless channel");
* the scheduler sees only an *estimate* of bandwidth, updated by the paper's
  rule  E[B_{t+1}] = (B_t + B_{t-1}) / 2  from observed transfers;
* per-frame compute/communication capacities (gamma, eta) refresh each frame.

A request is *satisfied* iff its realized completion time <= C_i and the
served variant's accuracy >= A_i (Definition II.1's hard form).

Beyond the paper, four axes are pluggable:

* **workload** — a named :mod:`~repro.core.scenarios` entry shapes arrivals,
  QoS draws, per-frame capacity masks (outages) and mobility;
* **arrival engine** — ``streaming=True`` (or a scenario registered with
  ``streaming=True``) swaps the materialized trace for the bounded-memory
  :class:`~repro.core.streaming.ArrivalStream`, opening long-horizon and
  nonstationary workloads;
* **congestion** — ``SimConfig.congestion``
  (:class:`~repro.core.queueing.CongestionConfig`) makes service times
  load-dependent: over-committed servers carry a backlog across frames,
  realized processing/transfer times inflate with the over-commit ratio,
  and the scheduler sees only the backlog-reduced frame budget.  This is
  the paper's testbed congestion, under which the Happy-* constraint
  relaxations collapse below GUS;
* **decision path** — by default each frame is padded to a fixed shape
  (see :func:`repro.core.instance.pad_instance`) and scheduled by the
  *jitted* ``gus_schedule``; any registered :class:`~repro.core.policies.Policy`
  (GUS variants, the paper's five baselines, the exact ILP oracle) runs on
  the same hot path via ``policy=``; ``gus_schedule_np`` stays available as
  the NumPy parity oracle.

Per-frame policy/simulator state is an explicit
:class:`~repro.core.queueing.PolicyCarry` (PRNG-key chain, per-server
backlogs, EMA load estimates, bandwidth-estimator state) threaded through
``simulate``'s frame loop and — as the ``lax.scan`` carry — through
:func:`simulate_fleet`'s single jitted/vmapped device program.

:func:`simulate_fleet` additionally shards the replication axis across a
1-D ``("rep",)`` device mesh (``devices=``) and can run its frame scan in
bounded-memory windows (``window=``) — both bit-identical to the
single-device, fully-materialized program.  See the function docstring.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import queue as queue_mod
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import QOS_ACC_EDGES, MetricsFrame, MetricsResult
from repro.obs.profiler import annotate, step_annotation
from repro.obs.trace import (
    CAT_BUILD,
    CAT_COMPILE,
    CAT_DISPATCH,
    CAT_GEN,
    CAT_METRICS,
    CAT_SCHED,
    Stopwatch,
    instant,
    span,
)

from .gus import Assignment, gus_backend_fn, gus_schedule
from .impairments import (
    AdmissionConfig,
    ImpairmentConfig,
    ResilienceEngine,
    admission_keep,
    apply_queue_cap,
    predicted_inflation,
)
from .instance import FlatInstance, pad_instance, stack_instances
from .options import (
    _UNSET,
    EngineOptions,
    fold_deprecated_kwargs,
    resolve_options,
)
from .policies import Policy, get_policy
from .queueing import (
    CongestionConfig,
    comm_inflation,
    committed_loads,
    compute_inflation,
    congested_ctime,
    effective_capacity,
    ema_update,
    fleet_policy_carry,
    frame_metrics,
    init_policy_carry,
    step_backlog,
)
from .satisfaction import hard_feasible, mean_us, satisfied_mask, us_tensor
from .scenarios import (
    Request,
    RequestColumns,
    Scenario,
    _resolve_rng_mode,
    bucket_arrivals,
    bucket_columns,
    get_scenario,
)
from .streaming import (
    ArrivalStream,
    max_frame_arrivals,
    stream_trace,
    stream_trace_columns,
)

__all__ = [
    "ClusterSpec",
    "SimConfig",
    "SimResult",
    "FleetResult",
    "EngineOptions",
    "simulate",
    "simulate_fleet",
    "demo_cluster_spec",
]


@dataclasses.dataclass
class ClusterSpec:
    """Static cluster description (servers, services, placement, profiles)."""

    n_edge: int
    n_cloud: int
    # per-server
    gamma_frame: np.ndarray       # (M,) compute capacity per frame (chip-ms)
    eta_frame: np.ndarray         # (M,) comm capacity per frame (KB)
    # per (server, service, variant)
    proc_ms: np.ndarray           # (M, K, L) mean processing delay
    placed: np.ndarray            # (M, K, L) bool
    acc: np.ndarray               # (K, L) accuracy (%)
    bandwidth_true: float = 600.0  # bytes/ms, hidden truth the channel jitters around
    cloud_extra_delay: float = 100.0

    @property
    def n_servers(self) -> int:
        return self.n_edge + self.n_cloud

    def is_cloud(self) -> np.ndarray:
        return np.arange(self.n_servers) >= self.n_edge


@dataclasses.dataclass
class SimConfig:
    horizon_ms: float = 120_000.0
    frame_ms: float = 3000.0
    queue_cap: int = 4                # paper: fixed queue length of 4
    arrival_rate_per_s: float = 2.0   # Poisson arrivals per edge server
    # request QoS draws
    acc_req_mean: float = 50.0
    acc_req_std: float = 0.0          # paper testbed: fixed A_i = 50%
    delay_req_ms: float = 53_000.0    # paper testbed: fixed C_i = 53 s
    req_size_lo: float = 20_000.0
    req_size_hi: float = 120_000.0
    channel_sigma: float = 0.25       # lognormal jitter of the wireless channel
    proc_sigma: float = 0.05
    move_prob: float = 0.0            # per-frame user mobility (extensions)
    w_a: float = 1.0
    w_c: float = 1.0
    max_as: float = 100.0
    max_cs: float = 12_000.0
    adapt_max_cs: bool = True         # paper: "we may have to adapt Max_cs"
    bandwidth_init: float = 600.0     # scheduler's initial estimate B_0
    #: load-dependent service times (disabled by default: delays stay
    #: load-independent and every result is bit-identical to the
    #: pre-congestion simulator)
    congestion: CongestionConfig = dataclasses.field(default_factory=CongestionConfig)
    #: network/server fault injection — per-edge link-quality traces and
    #: stochastic MTBF/MTTR server outages (disabled by default: no engine
    #: is built and results are bit-identical to the unimpaired simulator)
    impairments: ImpairmentConfig = dataclasses.field(default_factory=ImpairmentConfig)
    #: admission control — per-server queue caps and deadline-based
    #: shedding (disabled by default, and inert at its defaults even when
    #: enabled; see :class:`repro.core.impairments.AdmissionConfig`)
    admission: AdmissionConfig = dataclasses.field(default_factory=AdmissionConfig)


@dataclasses.dataclass
class SimResult:
    n_requests: int
    n_served: int
    n_satisfied: int
    n_local: int
    n_cloud: int
    n_edge_offload: int
    n_dropped: int
    mean_us: float
    mean_completion_ms: float
    mean_queue_ms: float
    bandwidth_estimates: List[float]
    #: work-accounting of the congestion model (None when disabled):
    #: enqueued/drained/carried chip-ms + KB totals and inflation stats
    congestion_stats: Optional[Dict[str, float]] = None
    #: fault-injection accounting (None unless impairments or admission
    #: control are enabled): requests shed at admission, assignments
    #: refused by the queue cap, frames with a down server
    resilience_stats: Optional[Dict[str, float]] = None
    #: wall-clock seconds per pipeline phase, from the span recorder
    #: (``gen_s`` arrival generation / stream pulls, ``build_s`` frame
    #: instance building, ``sched_s`` scheduler calls, ``realize_s``
    #: realized-delay accounting, ``total_s`` end to end) — the single-run
    #: counterpart of ``FleetResult.gen_s`` / ``dispatch_s``
    timings: Optional[Dict[str, float]] = None
    #: per-decision metric stream (``metrics=True`` only; None otherwise)
    metrics: Optional[MetricsResult] = None

    @property
    def satisfied_pct(self) -> float:
        return 100.0 * self.n_satisfied / max(self.n_requests, 1)

    @property
    def local_pct(self) -> float:
        return 100.0 * self.n_local / max(self.n_requests, 1)

    @property
    def cloud_pct(self) -> float:
        return 100.0 * self.n_cloud / max(self.n_requests, 1)

    @property
    def edge_offload_pct(self) -> float:
        return 100.0 * self.n_edge_offload / max(self.n_requests, 1)

    def as_dict(self) -> Dict[str, float]:
        d = {
            "n_requests": self.n_requests,
            "satisfied_pct": self.satisfied_pct,
            "local_pct": self.local_pct,
            "cloud_pct": self.cloud_pct,
            "edge_offload_pct": self.edge_offload_pct,
            "dropped_pct": 100.0 * self.n_dropped / max(self.n_requests, 1),
            "mean_us": self.mean_us,
            "mean_completion_ms": self.mean_completion_ms,
            "mean_queue_ms": self.mean_queue_ms,
        }
        if self.congestion_stats is not None:
            d["mean_compute_inflation"] = self.congestion_stats["mean_compute_inflation"]
            d["final_backlog_gamma"] = self.congestion_stats["final_backlog_gamma"]
        return d


def _pad_bucket(n: int) -> int:
    """Round the frame's queue length up to a power-of-two bucket (min 4) so
    the jitted scheduler compiles once per bucket, not once per queue length."""
    return max(4, 1 << max(n - 1, 0).bit_length())


def _pad_bucket_fine(n: int) -> int:
    """Bucket schedule for the hierarchical class axis: powers of two up to
    4096, multiples of 1024 above.

    Class counts sit wherever quantization puts them — at 10^5 users/frame
    the mega-city lands near 19k classes, which the power-of-two schedule
    pads to 32768 (≈70% dead rows in every (C, M, L) tensor *and* ≈70%
    dead steps in the allocator's scan over classes).  Above 4096 the waste
    is capped at ~5% instead; the compile-cache cost stays bounded because
    a window's bucket moves only when its class count crosses a 1024
    boundary, and city-scale frames drawn from one arrival process cluster
    tightly."""
    if n <= 4096:
        return max(4, 1 << max(n - 1, 0).bit_length())
    return ((n + 1023) // 1024) * 1024


#: default width of a fleet replication group — the unit of device dispatch
#: in :func:`simulate_fleet`.  One program is compiled per group shape and
#: reused for every group on every device, which is what keeps multi-device
#: results bit-identical to the single-device run.  Fleets with
#: ``n_rep <= FLEET_REP_GROUP`` run as a single group (the legacy layout).
FLEET_REP_GROUP = 8


def _frame_arrays(
    reqs: Sequence[Request], spec: ClusterSpec, cfg: SimConfig, now_ms: float, bw_est: float,
    link=None, lean: bool = False,
) -> Dict[str, np.ndarray]:
    """Numpy request-row tensors for one frame, using the scheduler's
    *estimated* bandwidth for comm delays — shared by
    :func:`_build_frame_instance` and the fleet's batched grid builder.

    ``reqs`` is either a list of :class:`Request` objects or a
    :class:`~repro.core.scenarios.RequestColumns` view (the vectorized
    trace); the columnar branch narrows the same float64 values to float32,
    so the two layouts produce bit-identical tensors from identical draws.

    ``link`` is an optional pair of per-request ``(bandwidth_scale,
    extra_latency_ms)`` arrays from the resilience engine, gathered by each
    request's covering edge: transfer time becomes ``size / (bw * scale) +
    lat``.  ``None`` (impairments off) leaves the formula untouched; at
    amplitude 0 the scale is exactly 1.0 and the latency exactly 0.0, so
    the result is bitwise identical either way.
    """
    M = spec.n_servers
    L = spec.acc.shape[1]
    N = len(reqs)
    is_cloud = spec.is_cloud()

    if isinstance(reqs, RequestColumns):
        cover = reqs.cover.astype(np.int32)
        A = reqs.A.astype(np.float32)
        C = reqs.C.astype(np.float32)
        Tq = (now_ms - reqs.arrival_ms).astype(np.float32)
        size = reqs.size_bytes.astype(np.float32)
        svc = reqs.service.astype(np.int32)
    else:
        cover = np.array([r.cover for r in reqs], np.int32)
        A = np.array([r.A for r in reqs], np.float32)
        C = np.array([r.C for r in reqs], np.float32)
        Tq = np.array([now_ms - r.arrival_ms for r in reqs], np.float32)
        size = np.array([r.size_bytes for r in reqs], np.float32)
        svc = np.array([r.service for r in reqs], np.int32)

    local = cover[:, None] == np.arange(M)[None, :]
    transfer = size[:, None] / bw_est
    if link is not None:
        # bandwidth scales divide the transfer time, extra latency adds;
        # at identity (scale 1.0, lat 0.0) both ops are bitwise no-ops
        bw_scale, extra_lat = link
        transfer = transfer / np.asarray(bw_scale, np.float64)[:, None] \
            + np.asarray(extra_lat, np.float64)[:, None]
    comm = transfer + np.where(is_cloud[None, :], spec.cloud_extra_delay, 0.0)
    comm = np.where(local, 0.0, comm)

    proc = spec.proc_ms[:, svc, :].transpose(1, 0, 2)       # (N, M, L)
    ctime = Tq[:, None, None] + proc + comm[:, :, None]
    if lean:
        # class-grid builder fast path: the candidate gathers/broadcasts
        # (acc, avail, v, u) are pure float32 lookups of spec tensors and
        # are rebuilt on device from these per-row vectors — only ctime's
        # float64 link math must stay host-side to agree bitwise with the
        # request-level paths
        return dict(cover=cover, A=A, C=C, ctime=ctime, svc=svc, size=size)
    avail = spec.placed[:, svc, :].transpose(1, 0, 2)
    # broadcast view, not a copy: every consumer only reads (scatter/slice
    # assignment or jnp.asarray), and skipping the 16MB materialization
    # keeps the producer thread off the critical path
    acc = np.broadcast_to(spec.acc[svc][:, None, :], (N, M, L))
    u = np.where(local[:, :, None], 0.0, (size / 1024.0)[:, None, None])
    return dict(
        cover=cover, A=A, C=C, acc=acc, ctime=ctime, v=proc,
        u=np.broadcast_to(u, (N, M, L)), avail=avail,
    )


def _build_frame_instance(
    reqs: Sequence[Request],
    spec: ClusterSpec,
    cfg: SimConfig,
    now_ms: float,
    bw_est: float,
    max_cs: float,
    gamma=None,
    eta=None,
    link=None,
) -> FlatInstance:
    """FlatInstance for the requests pending in this frame."""
    N = len(reqs)
    arr = _frame_arrays(reqs, spec, cfg, now_ms, bw_est, link=link)
    return FlatInstance(
        cover=jnp.asarray(arr["cover"]),
        A=jnp.asarray(arr["A"]),
        C=jnp.asarray(arr["C"]),
        w_a=jnp.full((N,), cfg.w_a, jnp.float32),
        w_c=jnp.full((N,), cfg.w_c, jnp.float32),
        acc=jnp.asarray(arr["acc"], jnp.float32),
        ctime=jnp.asarray(arr["ctime"], jnp.float32),
        v=jnp.asarray(arr["v"], jnp.float32),
        u=jnp.asarray(arr["u"], jnp.float32),
        avail=jnp.asarray(arr["avail"]),
        gamma=jnp.asarray(spec.gamma_frame if gamma is None else gamma, jnp.float32),
        eta=jnp.asarray(spec.eta_frame if eta is None else eta, jnp.float32),
        max_as=jnp.float32(cfg.max_as),
        max_cs=jnp.float32(max_cs),
    )


def _build_frame_batch(
    frames: List[List[Request]],
    spec: ClusterSpec,
    cfg: SimConfig,
    frame_starts: Sequence[float],
    budgets,
    n_pad: int,
    links=None,
    lean: bool = False,
) -> FlatInstance:
    """Stacked, padded ``FlatInstance`` for a whole grid of frames at once.

    ``links`` (optional, aligned with ``frames`` like ``budgets``) carries
    each frame's per-*server* ``(bandwidth_scale, extra_latency_ms)`` pair
    from the resilience engine; the builder gathers them per request by
    covering edge and hands them to :func:`_frame_arrays`.

    Fills preallocated numpy tensors frame by frame and converts each leaf
    to a device array *once* — the fleet's hot-path grid builder.  With the
    per-frame ``jnp`` round-trips gone, building a 10^3-frame window costs
    milliseconds instead of seconds.  The pad-row fill constants mirror
    :func:`repro.core.instance.pad_instance`, and values are bit-identical
    to stacking ``pad_instance(_build_frame_instance(...), n_pad)`` per
    frame (pinned by the sharded-fleet parity tests through the unchanged
    sequential path).

    A fully columnar grid (every frame a :class:`RequestColumns` — the
    vectorized rng mode) skips the per-frame Python loop: the grid's
    requests are concatenated, :func:`_frame_arrays` runs *once* over all
    of them (its formulas are elementwise given each request's ``now_ms``),
    and one fancy-indexed scatter per leaf writes the real rows — the same
    values the per-frame fill writes, computed by the same elementwise ops.
    """
    F = len(frames)
    M = spec.n_servers
    L = spec.acc.shape[1]
    cover = np.zeros((F, n_pad), np.int32)
    A = np.full((F, n_pad), 1e9, np.float32)     # unreachable accuracy floor
    C = np.full((F, n_pad), -1.0, np.float32)    # already-expired deadline
    w_a = np.zeros((F, n_pad), np.float32)       # padded rows contribute zero US
    w_c = np.zeros((F, n_pad), np.float32)
    # ``lean`` (hierarchical fast path): the four candidate tensors that are
    # pure spec gathers are never materialized on host — (F, 1, 1, 1)
    # dummies hold their slots and the caller rebuilds them on device from
    # the per-row ``svc``/``size`` vectors returned alongside the instance
    big = (F, 1, 1, 1) if lean else (F, n_pad, M, L)
    acc = np.zeros(big, np.float32)
    ctime = np.full((F, n_pad, M, L), 1e9, np.float32)
    v = np.zeros(big, np.float32)
    u = np.zeros(big, np.float32)
    avail = np.zeros(big, bool)
    svc_p = np.zeros((F, n_pad), np.int32) if lean else None
    size_p = np.zeros((F, n_pad), np.float32) if lean else None
    gamma = np.zeros((F, M), np.float32)
    eta = np.zeros((F, M), np.float32)
    for i in range(F):
        g, e = budgets[i]
        gamma[i] = g
        eta[i] = e
    columnar = F > 0 and all(isinstance(b, RequestColumns) for b in frames)
    if columnar:
        lengths = np.fromiter((len(b) for b in frames), np.int64, F)
        nn = int(lengths.sum())
        if nn:
            cat = RequestColumns.concatenate(frames)
            row = np.repeat(np.arange(F), lengths)
            now = np.repeat(
                np.asarray(frame_starts, np.float64) + cfg.frame_ms, lengths
            )
            link = None
            if links is not None:
                cov = cat.cover.astype(np.intp)
                sc = np.stack([l[0] for l in links])  # (F, M)
                la = np.stack([l[1] for l in links])
                link = (sc[row, cov], la[row, cov])
            arr = _frame_arrays(
                cat, spec, cfg, now, spec.bandwidth_true, link=link, lean=lean
            )
            # rows land at columns 0..n_i-1 of their frame by construction
            # (``col`` above is a within-frame arange), so the scatter is
            # really F contiguous slice writes — orders of magnitude fewer
            # index computations than one 12M-element fancy-indexed store
            # when frames hold 10^4+ classes
            starts = np.cumsum(lengths) - lengths
            for i in range(F):
                n_i = int(lengths[i])
                if n_i == 0:
                    continue
                sl = slice(int(starts[i]), int(starts[i]) + n_i)
                cover[i, :n_i] = arr["cover"][sl]
                A[i, :n_i] = arr["A"][sl]
                C[i, :n_i] = arr["C"][sl]
                w_a[i, :n_i] = cfg.w_a
                w_c[i, :n_i] = cfg.w_c
                ctime[i, :n_i] = arr["ctime"][sl]
                if lean:
                    svc_p[i, :n_i] = arr["svc"][sl]
                    size_p[i, :n_i] = arr["size"][sl]
                else:
                    acc[i, :n_i] = arr["acc"][sl]
                    v[i, :n_i] = arr["v"][sl]
                    u[i, :n_i] = arr["u"][sl]
                    avail[i, :n_i] = arr["avail"][sl]
    else:
        for i, (reqs, t0) in enumerate(zip(frames, frame_starts)):
            n = len(reqs)
            if n == 0:
                continue
            link = None
            if links is not None:
                cov = (
                    reqs.cover.astype(np.intp)
                    if isinstance(reqs, RequestColumns)
                    else np.array([r.cover for r in reqs], np.intp)
                )
                sc, la = links[i]
                link = (sc[cov], la[cov])
            arr = _frame_arrays(
                reqs, spec, cfg, t0 + cfg.frame_ms, spec.bandwidth_true,
                link=link, lean=lean,
            )
            cover[i, :n] = arr["cover"]
            A[i, :n] = arr["A"]
            C[i, :n] = arr["C"]
            w_a[i, :n] = cfg.w_a
            w_c[i, :n] = cfg.w_c
            ctime[i, :n] = arr["ctime"]
            if lean:
                svc_p[i, :n] = arr["svc"]
                size_p[i, :n] = arr["size"]
            else:
                acc[i, :n] = arr["acc"]
                v[i, :n] = arr["v"]
                u[i, :n] = arr["u"]
                avail[i, :n] = arr["avail"]
    # numpy leaves on purpose: the fleet slices replication groups on host
    # and device_puts each slice straight onto its target device (jnp ops
    # consume numpy leaves transparently on the metrics path)
    inst = FlatInstance(
        cover=cover,
        A=A,
        C=C,
        w_a=w_a,
        w_c=w_c,
        acc=acc,
        ctime=ctime,
        v=v,
        u=u,
        avail=avail,
        gamma=gamma,
        eta=eta,
        max_as=np.full((F,), cfg.max_as, np.float32),
        max_cs=np.full((F,), cfg.max_cs, np.float32),
    )
    if lean:
        return inst, svc_p, size_p
    return inst


def _apply_mobility_inplace(
    reqs: Sequence[Request], n_edge: int, move_prob: float, rng: np.random.Generator
) -> None:
    """Re-attach each pending request's covering edge with prob ``move_prob``.

    Accepts a Request list or a :class:`RequestColumns` view — the RNG draw
    count (two batches of ``len(reqs)``, nothing when the frame is empty) is
    identical either way, so both trace layouts stay on one draw sequence.
    """
    if move_prob <= 0 or not reqs:
        return
    from .extensions import apply_mobility

    if isinstance(reqs, RequestColumns):
        reqs.cover = apply_mobility(
            reqs.cover.astype(np.int32), n_edge, move_prob, rng
        ).astype(np.int64)
        return
    cov = np.array([r.cover for r in reqs], np.int32)
    cov = apply_mobility(cov, n_edge, move_prob, rng)
    for r, c in zip(reqs, cov):
        r.cover = int(c)


def _frame_budgets(
    spec: ClusterSpec, cfg: SimConfig, scn: Scenario, frame_start_ms: float,
    engine: Optional[ResilienceEngine] = None,
):
    """Fresh per-frame (gamma, eta) budgets, masked by the scenario's
    capacity stream (outages etc.) and — when a resilience engine is active —
    by its stochastic MTBF/MTTR outage stream."""
    g = spec.gamma_frame.astype(np.float64)
    e = spec.eta_frame.astype(np.float64)
    scale = scn.capacity_scale(frame_start_ms, cfg, spec.n_edge, spec.n_servers)
    if scale is not None:
        g = g * scale
        e = e * scale
    if engine is not None:
        up = engine.capacity_scale(int(round(frame_start_ms / cfg.frame_ms)))
        if up is not None:
            g = g * up
            e = e * up
    return g.copy(), e.copy()


def _frame_budgets_batch(
    spec: ClusterSpec, cfg: SimConfig, scn: Scenario,
    frame_starts_ms: np.ndarray,
    engine: Optional[ResilienceEngine] = None,
):
    """Vectorized :func:`_frame_budgets` over a window of frame starts.

    One ``capacity_scale_batch`` call replaces F scalar hook calls — the
    host cost that dominated ``gen_s`` at mega-city frame counts.  Returns
    ``(F, M)`` gamma and eta arrays, bit-identical to per-frame
    :func:`_frame_budgets` calls: the batch hook fills unscaled frames with
    exact ``1.0`` (the f64 multiplicative identity) and the same f64
    multiply order is used either way.
    """
    t = np.asarray(frame_starts_ms, np.float64)
    F = t.size
    g = np.repeat(spec.gamma_frame.astype(np.float64)[None, :], F, axis=0)
    e = np.repeat(spec.eta_frame.astype(np.float64)[None, :], F, axis=0)
    scale = scn.capacity_scale_batch(t, cfg, spec.n_edge, spec.n_servers)
    if scale is not None:
        g = g * scale
        e = e * scale
    if engine is not None:
        for i in range(F):
            up = engine.capacity_scale(int(round(t[i] / cfg.frame_ms)))
            if up is not None:
                g[i] = g[i] * up
                e[i] = e[i] * up
    return g, e


def _resolve_policy(
    scheduler, policy
) -> Optional[Policy]:
    """Normalize the (scheduler, policy) pair to an optional bound Policy.

    Returns the resolved :class:`Policy` when one was requested (by name, as
    a Policy object, or as a name passed positionally through ``scheduler``),
    else ``None`` — meaning "use ``scheduler`` as a raw callable / default".
    """
    if policy is not None:
        if scheduler is not None:
            raise ValueError("pass either scheduler= or policy=, not both")
        return get_policy(policy)
    if isinstance(scheduler, (str, Policy)):
        return get_policy(scheduler)
    return None


def _apply_backend(pol, scheduler, backend):
    """Fold a ``backend=`` request into the (pol, scheduler) pair.

    ``backend`` selects the *implementation* of the default GUS scheduler
    (``"xla"`` jitted loop / ``"pallas"`` fused kernel — bit-identical
    assignments, see :mod:`repro.core.gus`), so it only composes with the
    default scheduler or the explicit ``"gus"`` policy; combining it with a
    different policy or a raw callable is an error, not a silent no-op.
    GUS-cored policies (``happy_*``) follow the ``REPRO_GUS_BACKEND``
    environment variable instead.
    """
    if backend is None:
        return pol, scheduler
    if pol is not None and pol.name != "gus":
        raise ValueError(
            f"backend={backend!r} selects the default GUS scheduler's "
            f"implementation; policy {pol.name!r} does not take it (set "
            "REPRO_GUS_BACKEND to steer GUS-cored policies process-wide)"
        )
    if pol is None and scheduler is not None:
        raise ValueError("pass either scheduler= or backend=, not both")
    return None, gus_backend_fn(backend)


def _fold_hier_scheduler(pol, scheduler, opts, allow_backend=False):
    """Fold ``EngineOptions(scheduler="hierarchical")`` into the (pol,
    scheduler) pair: the hierarchical layout *is* the ``gus-hier`` policy,
    so it composes only with the default scheduler / ``"gus"`` /
    ``"gus-hier"`` — any other policy or a raw callable is an error, not a
    silent override.  ``allow_backend=True`` (the fleet) lets ``backend=``
    through: there it selects the hierarchical allocator's implementation
    (:func:`repro.core.aggregation.hier_backend_fn` — XLA scan or fused
    Pallas kernel, bit-identical cells); :func:`simulate`'s single-frame
    hier path stays host-side, so there it still raises."""
    if pol is None and scheduler is not None:
        raise ValueError(
            "EngineOptions(scheduler='hierarchical') does not compose with "
            "a raw scheduler callable; drop one of the two"
        )
    if opts.backend is not None and not allow_backend:
        raise ValueError(
            f"backend={opts.backend!r} with "
            "EngineOptions(scheduler='hierarchical') selects the device "
            "allocator, which only the fleet path runs — use simulate_fleet "
            "(simulate's hier path is host-side)"
        )
    if pol is not None and pol.name not in ("gus", "gus-hier"):
        raise ValueError(
            "EngineOptions(scheduler='hierarchical') maps to the 'gus-hier' "
            f"policy; it does not compose with policy {pol.name!r}"
        )
    return get_policy("gus-hier"), None


class _ArrivalSource:
    """Uniform pull interface over the two arrival engines.

    *Materialized* (the default) keeps the legacy semantics and RNG
    consumption bit-for-bit: the full trace is drawn up front from the
    simulator's own generator.  *Streaming* wraps an
    :class:`~repro.core.streaming.ArrivalStream` — memory stays bounded and
    ``n_total`` counts submissions as they are emitted.
    """

    def __init__(self, reqs=None, stream: Optional[ArrivalStream] = None,
                 limit: Optional[int] = None):
        self._reqs = reqs
        self._idx = 0
        self._stream = stream
        self._limit = limit
        self._emitted = 0

    def pull(self, t_ms: float) -> List[Request]:
        """All not-yet-pulled arrivals with ``arrival_ms < t_ms``."""
        if self._stream is None:
            out = []
            while self._idx < len(self._reqs) and self._reqs[self._idx].arrival_ms < t_ms:
                out.append(self._reqs[self._idx])
                self._idx += 1
            return out
        if self._limit is not None and self._emitted >= self._limit:
            return []
        out = self._stream.take_until(t_ms)
        if self._limit is not None and self._emitted + len(out) > self._limit:
            out = out[: self._limit - self._emitted]
        self._emitted += len(out)
        return out

    @property
    def exhausted(self) -> bool:
        if self._stream is None:
            return self._idx >= len(self._reqs)
        return self._stream.exhausted or (
            self._limit is not None and self._emitted >= self._limit
        )

    @property
    def n_total(self) -> int:
        """Total submissions (call after the run for the streaming source)."""
        return len(self._reqs) if self._stream is None else self._emitted


def simulate(
    spec: ClusterSpec,
    cfg: SimConfig,
    scheduler: Optional[Callable[[FlatInstance], Assignment]] = None,
    *,
    policy: Union[str, Policy, None] = None,
    scenario: Union[str, Scenario] = "paper-default",
    seed: int = 0,
    n_requests: Optional[int] = None,
    options: Optional[EngineOptions] = None,
    streaming=_UNSET,
    rng_mode=_UNSET,
    backend=_UNSET,
    metrics=_UNSET,
) -> SimResult:
    """Run the virtual testbed.

    ``options`` is the consolidated engine configuration
    (:class:`~repro.core.options.EngineOptions`); the per-call keywords
    below (``streaming`` / ``rng_mode`` / ``backend`` / ``metrics``) are
    *deprecated aliases* that build the same object — they emit a
    :class:`DeprecationWarning` and raise when combined with an explicit
    ``options=``.  Fleet-only fields (``window`` / ``prefetch`` /
    ``devices`` / ``rep_group``) are ignored here, so one options value can
    drive both entry points.  Unset fields resolve along **explicit > env
    var > scenario default** (:func:`~repro.core.options.resolve_options`).

    ``EngineOptions(scheduler="hierarchical")`` swaps the dense per-request
    grid for the class-aggregate path (:mod:`repro.core.aggregation`) — it
    maps to the ``"gus-hier"`` policy and composes with the default
    scheduler / ``policy="gus"`` / ``policy="gus-hier"`` only.

    ``metrics=True`` additionally records one
    :class:`~repro.obs.metrics.MetricsFrame` per scheduling decision
    (per-server utilization/backlog, shed/refusal counts, per-QoS-class
    satisfaction, assignment tiers) into ``SimResult.metrics`` — computed
    from the *same* counters as the aggregate result, so the stream's
    totals match the ``SimResult`` exactly.  Single-run rows report the
    backlog *entering* each decision (the fleet's scan rows report the
    carried backlog after the frame).  With ``metrics=False`` (default)
    nothing extra runs and results are bit-identical to the
    pre-telemetry simulator.  ``SimResult.timings`` always carries the
    span-derived phase timings (generation / build / schedule / realize).

    ``backend`` picks the default GUS scheduler's implementation on the
    padded hot path (``"xla"`` jitted loop — the default — or ``"pallas"``
    fused kernel; assignments are bit-identical, see :mod:`repro.core.gus`).
    It composes only with the default scheduler / the ``"gus"`` policy.

    ``policy`` names a registered :class:`~repro.core.policies.Policy`
    (``"gus"``, ``"gus-ordered"``, the five baselines, ``"ilp"``,
    ``"lp-bound"``, or any custom registration); per-policy state rides an
    explicit :class:`~repro.core.queueing.PolicyCarry` threaded through the
    frame loop — ``random`` gets a fresh PRNG key per decision split from
    the carry's chain (seeded by ``seed``), a ``stateful`` policy receives
    the whole carry (backlogs, EMA load, its own key) and returns an
    updated one, and the ``ilp`` oracle schedules unpadded frames on the
    host.  Alternatively ``scheduler`` passes a raw callable FlatInstance
    -> Assignment (a policy name is also accepted positionally); the
    default is the *jitted* ``gus_schedule``.  Every frame's queue is
    padded to a power-of-two bucket with infeasible rows
    (:func:`pad_instance`), so the jitted path compiles once per bucket and
    returns the same assignments as the NumPy oracle on the real rows.

    ``scenario`` names a registered workload (see
    :func:`repro.core.scenarios.list_scenarios`) shaping arrivals, QoS,
    per-frame capacity masks and mobility; ``"paper-default"`` reproduces the
    paper's Sec. IV workload bit-for-bit.

    ``streaming`` selects the arrival engine: ``None`` defers to
    ``scenario.streaming``, ``True`` forces the bounded-memory
    :class:`~repro.core.streaming.ArrivalStream` (long horizons), ``False``
    forces the legacy materialized trace.

    ``rng_mode`` selects the arrival generator's draw discipline (``None``
    defers to ``scenario.rng_mode``): ``"paper-default"`` is the frozen
    per-request order — every historical trace bit-for-bit —
    ``"vectorized"`` draws the same process in numpy batches (~10x faster,
    different RNG consumption, so distributions match but individual
    traces differ; deterministic given the seed either way).

    With ``cfg.congestion.enabled``, service times become load-dependent:
    each server carries a work backlog across frames, the scheduler sees
    only the backlog-reduced budget, and realized processing/transfer times
    inflate with the over-commit ratio (see :mod:`repro.core.queueing`).

    If ``n_requests`` is given, the arrival process stops after that many
    submissions (the paper's x-axis in Fig. 1(e)-(h) is total #requests).
    """
    opts = fold_deprecated_kwargs(
        options,
        dict(streaming=streaming, rng_mode=rng_mode, backend=backend,
             metrics=metrics),
        caller="simulate",
    )
    scn = get_scenario(scenario)
    opts = resolve_options(opts, scenario=scn)
    metrics = bool(opts.metrics)
    pol = _resolve_policy(scheduler, policy)
    if opts.scheduler == "hierarchical":
        pol, scheduler = _fold_hier_scheduler(pol, scheduler, opts)
    pol, scheduler = _apply_backend(pol, scheduler, opts.backend)
    pad = True
    stateful = False
    needs_key = False
    if pol is not None:
        scheduler = pol.bind(spec.n_edge, spec.n_servers)
        pad = pol.pad
        stateful = pol.stateful
        needs_key = pol.needs_key and not pol.stateful
    elif scheduler is None:
        scheduler = gus_schedule
    ccfg = cfg.congestion
    acfg = cfg.admission
    rng = np.random.default_rng(seed)
    M, K, L = spec.proc_ms.shape
    move_prob = cfg.move_prob if scn.move_prob is None else scn.move_prob
    engine = (
        ResilienceEngine(cfg.impairments, spec.n_edge, M)
        if cfg.impairments.enabled else None
    )

    sw = Stopwatch()
    t_run0 = time.perf_counter()

    # --- arrivals (materialized trace, or bounded-memory stream) -------------
    use_stream = opts.streaming
    mode = opts.rng_mode
    if use_stream:
        source = _ArrivalSource(
            stream=ArrivalStream(scn, seed, spec.n_edge, K, cfg, rng_mode=mode),
            limit=n_requests,
        )
    else:
        with sw.span("sim/generate_trace", CAT_GEN):
            reqs = scn.generate_arrivals(rng, spec.n_edge, K, cfg, rng_mode=mode)
        if n_requests is not None:
            reqs = reqs[:n_requests]
        source = _ArrivalSource(reqs=reqs)

    # --- explicit state carry ------------------------------------------------
    # B_{t-1}, B_t for the EMA bandwidth rule + the congestion backlogs; the
    # PRNG chain for needs_key/stateful policies lives in carry.key.
    carry = init_policy_carry(M, seed=seed, bandwidth_init=cfg.bandwidth_init)
    bw_prev = bw_cur = cfg.bandwidth_init
    bw_log = [bw_cur]
    max_cs = cfg.max_cs

    n_served = n_sat = n_local = n_cloud = n_eo = n_drop = 0
    us_sum = 0.0
    comp_sum = 0.0
    q_sum = 0.0
    pending: List[Request] = []
    buffer: deque = deque()
    t = 0.0
    is_cloud = spec.is_cloud()

    # per-decision metric rows (metrics=True only)
    m_rows: List[MetricsFrame] = []
    m_times: List[float] = []
    m_qos_edges = np.asarray(QOS_ACC_EDGES, np.float64)
    m_nq = len(QOS_ACC_EDGES) + 1

    # congestion state (numpy, float64 like the budgets)
    backlog_g = np.zeros(M)
    backlog_e = np.zeros(M)
    committed_g = np.zeros(M)
    committed_e = np.zeros(M)
    drained_g = drained_e = 0.0
    infl_sum = 0.0
    infl_max = 1.0
    infl_n = 0

    def _drain(backlog, committed, budget):
        """One frame-boundary backlog step; returns (new_backlog, drained).

        Same formula as :func:`repro.core.queueing.step_backlog` (which the
        fleet's scan uses), kept in float64 numpy for the host loop — the
        fleet-vs-sequential parity test pins the two implementations to
        each other."""
        new = np.maximum(backlog + committed - budget * ccfg.drain, 0.0)
        return new, float(np.sum(backlog + committed - new))

    # capacity budgets deplete WITHIN a wall-clock frame (queue-full decisions
    # fire early but do not refresh gamma/eta — they share the frame budget)
    frame_budget_g, frame_budget_e = _frame_budgets(spec, cfg, scn, 0.0, engine=engine)
    rem_gamma = frame_budget_g.copy()
    rem_eta = frame_budget_e.copy()
    frame_boundary = cfg.frame_ms
    n_shed = n_refused = 0
    frames_down = 0

    while t < cfg.horizon_ms + 10 * cfg.frame_ms:
        frame_end = t + cfg.frame_ms
        # admit arrivals in this frame; queue_cap per covering server
        qlen = {e: sum(1 for r in pending if r.cover == e) for e in range(spec.n_edge)}
        early_close = None
        with sw.span("sim/arrival_pull", CAT_GEN):
            buffer.extend(source.pull(frame_end))
        while buffer:
            r = buffer[0]
            if qlen.get(r.cover, 0) >= cfg.queue_cap:
                # queue full -> decision fires early (paper testbed behaviour)
                early_close = r.arrival_ms
                break
            pending.append(buffer.popleft())
            qlen[r.cover] = qlen.get(r.cover, 0) + 1
        decision_time = early_close if early_close is not None else frame_end
        if decision_time >= frame_boundary:  # new wall-clock frame: budgets refresh
            frame_boundary += cfg.frame_ms * np.ceil(
                (decision_time - frame_boundary + 1e-9) / cfg.frame_ms
            )
            if ccfg.enabled:
                ema = ema_update(
                    carry.ema_util, jnp.asarray(committed_g, jnp.float32),
                    jnp.asarray(frame_budget_g, jnp.float32), ccfg,
                )
                backlog_g, dg = _drain(backlog_g, committed_g, frame_budget_g)
                backlog_e, de = _drain(backlog_e, committed_e, frame_budget_e)
                drained_g += dg
                drained_e += de
                committed_g = np.zeros(M)
                committed_e = np.zeros(M)
                carry = dataclasses.replace(
                    carry,
                    backlog_gamma=jnp.asarray(backlog_g, jnp.float32),
                    backlog_eta=jnp.asarray(backlog_e, jnp.float32),
                    ema_util=ema,
                )
            frame_budget_g, frame_budget_e = _frame_budgets(
                spec, cfg, scn, frame_boundary - cfg.frame_ms, engine=engine
            )
            if ccfg.enabled:
                rem_gamma = np.maximum(frame_budget_g - backlog_g, 0.0)
                rem_eta = np.maximum(frame_budget_e - backlog_e, 0.0)
            else:
                rem_gamma = frame_budget_g.copy()
                rem_eta = frame_budget_e.copy()

        if pending:
            _apply_mobility_inplace(pending, spec.n_edge, move_prob, rng)
            bw_est = 0.5 * (bw_cur + bw_prev)  # E[B_{t+1}] = (B_t + B_{t-1})/2
            n_real = len(pending)
            link = None
            link_scale = link_lat = None
            if engine is not None:
                # the wall-clock frame the decision belongs to indexes the
                # impairment streams (early-close decisions share it)
                fi = int(round(frame_boundary / cfg.frame_ms)) - 1
                link_scale, link_lat = engine.link_frame(fi)
                up_now = engine.server_up(fi)
                frames_down += int((up_now < 1.0).any())
                cov = np.array([r.cover for r in pending], np.intp)
                link = (link_scale[cov], link_lat[cov])
                carry = dataclasses.replace(
                    carry,
                    link_bw=jnp.asarray(link_scale, jnp.float32),
                    server_up=jnp.asarray(up_now),
                )
            if metrics:
                # deltas of the run counters across this decision become the
                # MetricsFrame row; backlog is sampled *entering* the decision
                m_shed0, m_ref0 = n_shed, n_refused
                m_served0, m_sat0 = n_served, n_sat
                m_local0, m_cloud0, m_eo0 = n_local, n_cloud, n_eo
                m_us0 = us_sum
                m_backlog_g = backlog_g.astype(np.float32)
                m_backlog_e = backlog_e.astype(np.float32)
                m_qos_cnt = np.zeros(m_nq, np.int32)
                m_qos_sat = np.zeros(m_nq, np.int32)
                m_w = np.zeros(M)
                m_c = np.zeros(M)
            with sw.span("sim/frame_build", CAT_BUILD):
                inst = _build_frame_instance(
                    pending, spec, cfg, decision_time, bw_est, max_cs,
                    gamma=rem_gamma, eta=rem_eta, link=link,
                )
            if acfg.enabled and acfg.shed:
                # deadline shedding against the pre-frame (backlog-only)
                # inflation estimate — full budgets, like the fleet scan
                phi_pc, phi_pe = predicted_inflation(
                    jnp.asarray(backlog_g, jnp.float32),
                    jnp.asarray(backlog_e, jnp.float32),
                    jnp.asarray(frame_budget_g, jnp.float32),
                    jnp.asarray(frame_budget_e, jnp.float32),
                    ccfg,
                )
                tq_arr = jnp.asarray(
                    [decision_time - r.arrival_ms for r in pending], jnp.float32
                )
                keep = admission_keep(inst, tq_arr, phi_pc, phi_pe)
                n_shed += n_real - int(np.asarray(keep).sum())
                inst = dataclasses.replace(
                    inst, avail=inst.avail & keep[:, None, None]
                )
            # fixed-shape hot path: pad to a bucket so jitted schedulers
            # compile once per bucket; padded rows are infeasible -> dropped.
            # Non-padding policies (the ILP oracle) see the raw frame.
            frame_inst = pad_instance(inst, _pad_bucket(n_real)) if pad else inst
            with sw.span("sim/schedule", CAT_SCHED, n=n_real), \
                    annotate("sim/schedule"):
                if stateful:
                    assign, carry = scheduler(frame_inst, carry)
                elif needs_key:
                    # split order matches the legacy chain:
                    # (next, sub) = split(key)
                    nxt, sub = jax.random.split(carry.key)
                    carry = dataclasses.replace(carry, key=nxt)
                    assign = scheduler(frame_inst, sub)
                else:
                    assign = scheduler(frame_inst)
                # materialization syncs with the device, so the block times
                # the actual scheduler compute, not just its dispatch
                jv = np.asarray(assign.j)[:n_real]
                lv = np.asarray(assign.l)[:n_real]
            if acfg.enabled:
                # queue cap: refuse assignments to servers whose carried
                # backlog exceeds the cap (full frame budgets, like the
                # fleet scan); with the default inf cap nothing changes
                cov = np.array([r.cover for r in pending], np.intp)
                with np.errstate(invalid="ignore"):
                    over_c = backlog_g >= acfg.queue_cap_mult * frame_budget_g
                    over_e = backlog_e >= acfg.queue_cap_mult * frame_budget_e
                jc = np.maximum(jv, 0)
                refuse = (jv >= 0) & (
                    over_c[jc] | ((jv != cov) & over_e[cov])
                )
                n_refused += int(refuse.sum())
                jv = np.where(refuse, -1, jv)

            with sw.span("sim/realize", CAT_METRICS, n=n_real):
                # pass 1 — capacity commit (shared frame budget + backlog
                # growth)
                for idx, r in enumerate(pending):
                    j, l = int(jv[idx]), int(lv[idx])
                    if j < 0:
                        continue
                    local = j == r.cover
                    rem_gamma[j] -= spec.proc_ms[j, r.service, l]
                    committed_g[j] += spec.proc_ms[j, r.service, l]
                    if metrics:
                        m_w[j] += spec.proc_ms[j, r.service, l]
                    if not local:
                        rem_eta[r.cover] -= r.size_bytes / 1024.0
                        committed_e[r.cover] += r.size_bytes / 1024.0
                        if metrics:
                            m_c[r.cover] += r.size_bytes / 1024.0

                # the whole decision batch shares one inflation factor,
                # computed from the wall-clock frame's committed-so-far load
                # (matches the fleet's frame-synchronous semantics when
                # queue_cap never trips)
                if ccfg.enabled:
                    phi_c = np.asarray(
                        compute_inflation(backlog_g + committed_g, frame_budget_g, ccfg)
                    )
                    phi_e = np.asarray(
                        comm_inflation(backlog_e + committed_e, frame_budget_e, ccfg)
                    )
                    infl_sum += float(phi_c.sum())
                    infl_max = max(infl_max, float(phi_c.max()), float(phi_e.max()))
                    infl_n += M

                # pass 2 — realized delays and stats (RNG draw order
                # unchanged)
                observed_bw = []
                for idx, r in enumerate(pending):
                    j, l = int(jv[idx]), int(lv[idx])
                    if metrics:
                        m_cls = int(np.searchsorted(m_qos_edges, r.A, side="right"))
                        m_qos_cnt[m_cls] += 1
                    if j < 0:
                        n_drop += 1
                        continue
                    n_served += 1
                    local = j == r.cover
                    # realized delays
                    proc = spec.proc_ms[j, r.service, l] * rng.lognormal(0.0, cfg.proc_sigma)
                    if local:
                        comm = 0.0
                    else:
                        bw_real = spec.bandwidth_true * rng.lognormal(0.0, cfg.channel_sigma)
                        extra = 0.0
                        if engine is not None:  # the realized channel is impaired too
                            # plain-float arithmetic keeps the downstream
                            # accumulator dtypes identical to the unimpaired path
                            bw_real = bw_real * float(link_scale[r.cover])
                            extra = float(link_lat[r.cover])
                        comm = r.size_bytes / bw_real + extra + (
                            spec.cloud_extra_delay if is_cloud[j] else 0.0
                        )
                        # the estimator observes the *channel* (uninflated
                        # transfer, net of the link's known extra latency)
                        observed_bw.append(r.size_bytes / max(comm - extra - (spec.cloud_extra_delay if is_cloud[j] else 0.0), 1e-6))
                    if ccfg.enabled:
                        proc = proc * phi_c[j]
                        comm = comm * phi_e[r.cover]
                    tq = decision_time - r.arrival_ms
                    ct = tq + proc + comm
                    acc = spec.acc[r.service, l]
                    sat = (ct <= r.C) and (acc >= r.A)
                    if metrics and sat:
                        m_qos_sat[m_cls] += 1
                    n_sat += int(sat)
                    n_local += int(local)
                    n_cloud += int((not local) and is_cloud[j])
                    n_eo += int((not local) and (not is_cloud[j]))
                    us_sum += cfg.w_a * (acc - r.A) / cfg.max_as + cfg.w_c * (r.C - ct) / max_cs
                    comp_sum += ct
                    q_sum += tq
                    if cfg.adapt_max_cs:
                        max_cs = max(max_cs, ct)
                pending = []
                if observed_bw:
                    bw_prev, bw_cur = bw_cur, float(np.mean(observed_bw))
                    bw_log.append(0.5 * (bw_cur + bw_prev))
                    carry = dataclasses.replace(
                        carry, bw_prev=jnp.float32(bw_prev), bw_cur=jnp.float32(bw_cur)
                    )
            if metrics:
                with np.errstate(invalid="ignore"):
                    m_ug = np.where(
                        frame_budget_g > 0.0,
                        m_w / np.maximum(frame_budget_g, 1e-9), 0.0,
                    )
                    m_ue = np.where(
                        frame_budget_e > 0.0,
                        m_c / np.maximum(frame_budget_e, 1e-9), 0.0,
                    )
                m_rows.append(MetricsFrame(
                    n_arrivals=np.int32(n_real),
                    n_served=np.int32(n_served - m_served0),
                    n_satisfied=np.int32(n_sat - m_sat0),
                    n_shed=np.int32(n_shed - m_shed0),
                    n_refused=np.int32(n_refused - m_ref0),
                    tier_hist=np.array(
                        [n_local - m_local0, n_eo - m_eo0, n_cloud - m_cloud0],
                        np.int32,
                    ),
                    qos_sat=m_qos_sat,
                    qos_count=m_qos_cnt,
                    util_gamma=m_ug.astype(np.float32),
                    util_eta=m_ue.astype(np.float32),
                    backlog_gamma=m_backlog_g,
                    backlog_eta=m_backlog_e,
                    us_sum=np.float32(us_sum - m_us0),
                ))
                m_times.append(decision_time)

        t = decision_time if early_close is not None else frame_end
        if source.exhausted and not buffer and not pending:
            break

    congestion_stats = None
    if ccfg.enabled:
        # flush the last frame's committed work through one more drain step so
        # the conservation identity (enqueued == drained + carried) closes
        backlog_g, dg = _drain(backlog_g, committed_g, frame_budget_g)
        backlog_e, de = _drain(backlog_e, committed_e, frame_budget_e)
        drained_g += dg
        drained_e += de
        congestion_stats = {
            "work_enqueued_gamma": drained_g + float(backlog_g.sum()),
            "work_drained_gamma": drained_g,
            "work_enqueued_eta": drained_e + float(backlog_e.sum()),
            "work_drained_eta": drained_e,
            "final_backlog_gamma": float(backlog_g.sum()),
            "final_backlog_eta": float(backlog_e.sum()),
            "mean_compute_inflation": (infl_sum / infl_n) if infl_n else 1.0,
            "max_inflation": infl_max,
        }

    resilience_stats = None
    if engine is not None or acfg.enabled:
        resilience_stats = {
            "n_shed": float(n_shed),
            "n_refused": float(n_refused),
            "frames_with_down_server": float(frames_down),
        }

    n_total = source.n_total
    timings = {
        "gen_s": sw.total("sim/generate_trace", "sim/arrival_pull"),
        "build_s": sw.total("sim/frame_build"),
        "sched_s": sw.total("sim/schedule"),
        "realize_s": sw.total("sim/realize"),
        "total_s": time.perf_counter() - t_run0,
    }
    mres = None
    if metrics:
        mres = MetricsResult.from_rows(
            m_rows, m_times, spec.n_edge, cfg.frame_ms
        )
    return SimResult(
        n_requests=n_total,
        n_served=n_served,
        n_satisfied=n_sat,
        n_local=n_local,
        n_cloud=n_cloud,
        n_edge_offload=n_eo,
        n_dropped=n_total - n_served,
        mean_us=us_sum / max(n_total, 1),
        mean_completion_ms=comp_sum / max(n_served, 1),
        mean_queue_ms=q_sum / max(n_served, 1),
        bandwidth_estimates=bw_log,
        congestion_stats=congestion_stats,
        resilience_stats=resilience_stats,
        timings=timings,
        metrics=mres,
    )


# ---------------------------------------------------------------------------
# Vectorized Monte-Carlo fleet runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetResult:
    """Aggregate of R independent replications scheduled in one device program."""

    n_rep: int
    n_frames: int                  # frames per replication
    n_requests: int                # total across all replications
    n_served: int
    satisfied_per_rep: np.ndarray  # (R,) satisfied-% per replication
    mean_us_per_rep: np.ndarray    # (R,) mean US over that replication's requests
    #: (R, M) carried compute backlog after the last frame (None when the
    #: congestion model is disabled)
    final_backlog_per_rep: Optional[np.ndarray] = None
    #: mean compute-inflation factor across (rep, frame, server) cells
    mean_compute_inflation: float = 1.0
    #: devices the replication axis was sharded across (1 = unsharded)
    n_devices: int = 1
    #: frames per scan window (== n_frames when fully materialized)
    window: Optional[int] = None
    #: wall-clock seconds spent inside the jitted fleet programs (group
    #: dispatch + device compute + result materialization) — the phase
    #: device sharding accelerates; host-side arrival generation and
    #: metrics are excluded
    dispatch_s: float = 0.0
    #: wall-clock seconds the pipeline was *blocked* on host-side arrival
    #: generation + frame-grid building: the up-front trace/pre-pass cost
    #: plus, per window, either the inline build time (``prefetch=0``) or
    #: the time spent waiting on the producer's queue (``prefetch>0`` —
    #: build work hidden behind device compute never shows up here)
    gen_s: float = 0.0
    #: producer-queue depth the run used (0 = serial single-thread build)
    prefetch: int = 0
    #: per-span wall-clock totals from the run's :class:`~repro.obs.trace.
    #: Stopwatch` — ``gen_s``/``dispatch_s`` above are derived from these
    #: same spans, so the two views can never disagree
    timings: Optional[Dict[str, float]] = None
    #: per-(rep, frame) metric stream (``metrics=True`` only; None otherwise)
    metrics: Optional[MetricsResult] = None

    @property
    def satisfied_pct(self) -> float:
        return float(np.mean(self.satisfied_per_rep))

    @property
    def satisfied_std(self) -> float:
        return float(np.std(self.satisfied_per_rep))

    @property
    def mean_us(self) -> float:
        return float(np.mean(self.mean_us_per_rep))

    def as_dict(self) -> Dict[str, float]:
        d = {
            "n_rep": self.n_rep,
            "n_requests": self.n_requests,
            "n_devices": self.n_devices,
            "satisfied_pct": self.satisfied_pct,
            "satisfied_std": self.satisfied_std,
            "served_pct": 100.0 * self.n_served / max(self.n_requests, 1),
            "mean_us": self.mean_us,
        }
        if self.final_backlog_per_rep is not None:
            d["mean_compute_inflation"] = self.mean_compute_inflation
            d["final_backlog_gamma"] = float(self.final_backlog_per_rep.sum(-1).mean())
        return d


def _resolve_fleet_devices(devices: Optional[int], n_rep: int) -> int:
    """Resolve ``simulate_fleet``'s ``devices=`` argument to a shard count.

    ``None`` uses every local device (capped at ``n_rep``: a mesh longer
    than the replication axis only schedules padding).  Asking for more
    devices than ``jax.local_device_count()`` is an error, never a silent
    single-device fallback.
    """
    avail = jax.local_device_count()
    if devices is None:
        return max(1, min(avail, n_rep))
    devices = int(devices)
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if devices > avail:
        raise ValueError(
            f"simulate_fleet requested devices={devices} but only {avail} "
            "local device(s) are visible; launch with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N for virtual "
            "CPU devices, or lower devices="
        )
    return devices


class _RepFrameSource:
    """One replication's per-frame request buckets, materialized or lazy.

    *Materialized* reproduces the legacy fleet generation bit-for-bit: one
    ``default_rng(seed + rep)`` drives ``generate_arrivals`` (or the trace
    comes from :func:`stream_trace`) and then the per-frame mobility draws.
    *Lazy* holds an :class:`ArrivalStream` and draws each frame's bucket on
    demand, so a windowed fleet never materializes more than one window of
    requests — the stream's chunking invariance makes the buckets (and the
    mobility draw order) identical either way.

    In ``rng_mode="vectorized"`` the materialized trace stays columnar
    (:class:`RequestColumns` buckets) end to end — the grid builder fills
    frames from array slices and per-request Python objects never exist;
    the lazy stream uses the chunk-buffered vectorized engine.  Columnar
    and lazy buckets carry the same values for the same seed (one chunk
    code path underneath), so windowed==materialized holds in both modes.
    """

    def __init__(
        self, scn, rep_seed, n_edge, n_services, cfg, T, use_stream, lazy,
        rng_mode="paper-default",
    ):
        self.cfg = cfg
        self.n_edge = n_edge
        self.move_prob = cfg.move_prob if scn.move_prob is None else scn.move_prob
        self.rng = np.random.default_rng(rep_seed)
        self.stream: Optional[ArrivalStream] = None
        self.buckets = None  # List[List[Request]] | List[RequestColumns]
        vectorized = rng_mode == "vectorized"
        if lazy:
            self.stream = ArrivalStream(
                scn, rep_seed, n_edge, n_services, cfg, rng_mode=rng_mode
            )
        elif vectorized:
            if use_stream:
                cols = stream_trace_columns(scn, rep_seed, n_edge, n_services, cfg)
            else:
                cols = scn.generate_arrivals_columns(self.rng, n_edge, n_services, cfg)
            self.buckets = bucket_columns(cols, cfg.frame_ms, T)
        else:
            if use_stream:
                reqs = stream_trace(scn, rep_seed, n_edge, n_services, cfg)
            else:
                reqs = scn.generate_arrivals(self.rng, n_edge, n_services, cfg)
            self.buckets = bucket_arrivals(reqs, cfg.frame_ms, T)
        self._next = 0

    @property
    def max_bucket(self) -> int:
        """Largest per-frame bucket (materialized sources only)."""
        return max((len(b) for b in self.buckets), default=0)

    def take(self, upto_frame: int) -> List[List[Request]]:
        """Buckets for frames ``[next, upto_frame)``, mobility applied in
        frame order (the rep's single rng keeps the legacy draw sequence)."""
        out = []
        for tf in range(self._next, upto_frame):
            if self.buckets is not None:
                b = self.buckets[tf]
            else:
                b = self.stream.take_until((tf + 1) * self.cfg.frame_ms)
            _apply_mobility_inplace(b, self.n_edge, self.move_prob, self.rng)
            out.append(b)
        self._next = upto_frame
        return out


@functools.lru_cache(maxsize=None)
def _bound_policy_impl(pol: Policy, n_edge: int, n_servers: int):
    return pol.bind(n_edge, n_servers)


def _bound_policy(pol: Policy, n_edge: int, n_servers: int):
    """``pol.bind`` with a stable identity across ``simulate_fleet`` calls —
    the bound function keys the compiled-runner cache below, so repeated
    fleet calls (benchmark sweeps!) reuse the compiled program instead of
    re-tracing and re-compiling every time.  A cache miss drops an instant
    event on an active trace: binds front-run a jit compile, so the marks
    line up with the slow first window."""
    before = _bound_policy_impl.cache_info().misses
    fn = _bound_policy_impl(pol, n_edge, n_servers)
    if _bound_policy_impl.cache_info().misses > before:
        instant("compile/bind_policy", CAT_COMPILE, policy=pol.name)
    return fn


@functools.lru_cache(maxsize=128)
def _fleet_runner_impl(
    fn, stateful: bool, needs_key: bool, ccfg: CongestionConfig,
    acfg: AdmissionConfig, impaired: bool, metrics: bool, n_edge: int,
):
    """The fleet's jitted vmap-over-reps-of-scan-over-frames runner, cached
    by (schedule fn, policy mode, congestion/admission config, impairment
    flag, metrics flag).  jax's own jit cache then holds one executable per
    (group shape, device).

    Scan inputs per frame: the padded instance, the PRNG key, the queueing
    delays, and the resilience engine's per-frame link/up vectors (all-ones
    dummies when ``impaired`` is False — never read then, so XLA drops
    them).  Admission control runs inside the step: deadline shedding masks
    ``avail`` *before* the policy (against the pre-frame backlog-only
    inflation estimate), the queue cap refuses assignments *after* it and
    before the committed work enters the backlog.

    ``metrics=True`` threads the per-frame real-request count as one more
    scan input and emits a :class:`~repro.obs.metrics.MetricsFrame` as one
    more scan output, stacked on device across the window.  With
    ``metrics=False`` the step's traced program is exactly the pre-metrics
    one — same inputs, same outputs, same jaxpr — which is what the
    bitwise-parity tests pin (fusion changes can flip greedy argmax
    near-ties, see ``docs/architecture.md`` section 6)."""

    def step(carry, x):
        if metrics:
            inst, key, tq, link_bw, up, n_real_t = x
        else:
            inst, key, tq, link_bw, up = x
        if impaired:  # policy-visible network state rides the carry
            carry = dataclasses.replace(carry, link_bw=link_bw, server_up=up)
        if ccfg.enabled:
            run_inst = dataclasses.replace(
                inst,
                gamma=effective_capacity(inst.gamma, carry.backlog_gamma),
                eta=effective_capacity(inst.eta, carry.backlog_eta),
            )
        else:
            run_inst = inst
        keep = None
        if acfg.enabled and acfg.shed:
            phi_pc, phi_pe = predicted_inflation(
                carry.backlog_gamma, carry.backlog_eta, inst.gamma, inst.eta, ccfg
            )
            keep = admission_keep(inst, tq, phi_pc, phi_pe)
            run_inst = dataclasses.replace(
                run_inst, avail=run_inst.avail & keep[:, None, None]
            )
        if stateful:
            a, carry = fn(run_inst, carry)
        elif needs_key:
            a = fn(run_inst, key)
        else:
            a = fn(run_inst)
        n_refused = None
        if acfg.enabled:
            j_cap = apply_queue_cap(
                a.j, inst, carry.backlog_gamma, carry.backlog_eta, acfg
            )
            if metrics:
                real = jnp.arange(a.j.shape[0]) < n_real_t
                n_refused = jnp.sum(real & (a.j >= 0) & (j_cap < 0))
            a = Assignment(j_cap, a.l)
        if ccfg.enabled:
            w, c = committed_loads(inst, a.j, a.l)
            pc = compute_inflation(carry.backlog_gamma + w, inst.gamma, ccfg)
            pe = comm_inflation(carry.backlog_eta + c, inst.eta, ccfg)
            carry = dataclasses.replace(
                carry,
                backlog_gamma=step_backlog(carry.backlog_gamma, w, inst.gamma, ccfg),
                backlog_eta=step_backlog(carry.backlog_eta, c, inst.eta, ccfg),
                ema_util=ema_update(carry.ema_util, w, inst.gamma, ccfg),
            )
        else:
            pc = jnp.ones_like(inst.gamma)
            pe = jnp.ones_like(inst.eta)
        if not metrics:
            return carry, (a.j, a.l, pc, pe)
        real = jnp.arange(a.j.shape[0]) < n_real_t
        n_shed = (
            jnp.sum(real & ~keep) if keep is not None else jnp.int32(0)
        )
        mf = frame_metrics(
            inst, a.j, a.l, tq, pc, pe, n_real_t, n_edge, carry,
            n_shed, n_refused if n_refused is not None else jnp.int32(0),
        )
        return carry, (a.j, a.l, pc, pe, mf)

    if metrics:
        def per_rep(c0, inst_seq, key_seq, tq_seq, link_seq, up_seq, nreal_seq):
            return jax.lax.scan(
                step, c0,
                (inst_seq, key_seq, tq_seq, link_seq, up_seq, nreal_seq),
            )
    else:
        def per_rep(c0, inst_seq, key_seq, tq_seq, link_seq, up_seq):
            return jax.lax.scan(
                step, c0, (inst_seq, key_seq, tq_seq, link_seq, up_seq)
            )

    return jax.jit(jax.vmap(per_rep))


def _fleet_runner(
    fn, stateful: bool, needs_key: bool, ccfg: CongestionConfig,
    acfg: AdmissionConfig, impaired: bool,
    metrics: bool = False, n_edge: int = 0,
):
    """Cached-runner lookup that marks cache misses on an active trace —
    each miss front-runs a fresh trace + XLA compile of the fleet program,
    which is exactly the cliff a profile reader wants flagged."""
    before = _fleet_runner_impl.cache_info().misses
    run = _fleet_runner_impl(
        fn, stateful, needs_key, ccfg, acfg, impaired, metrics, n_edge
    )
    if _fleet_runner_impl.cache_info().misses > before:
        instant("compile/fleet_runner", CAT_COMPILE, metrics=metrics)
    return run


def _pad_reps(tree, pad_r: int):
    """Pad the leading replication axis with copies of replication 0 so it
    divides the group width; the padded rows are dropped after the run.
    Works on numpy and jax leaves alike (numpy stays numpy)."""
    def pad(x):
        xp = np if isinstance(x, np.ndarray) else jnp
        return xp.concatenate([x, xp.repeat(x[:1], pad_r, axis=0)])

    return jax.tree.map(pad, tree)


def simulate_fleet(
    spec: ClusterSpec,
    cfg: SimConfig,
    scheduler: Optional[Callable[[FlatInstance], Assignment]] = None,
    *,
    policy: Union[str, Policy, None] = None,
    scenario: Union[str, Scenario] = "paper-default",
    n_rep: int = 16,
    seed: int = 0,
    options: Optional[EngineOptions] = None,
    streaming=_UNSET,
    devices=_UNSET,
    window=_UNSET,
    rep_group=_UNSET,
    rng_mode=_UNSET,
    prefetch=_UNSET,
    backend=_UNSET,
    metrics=_UNSET,
) -> FleetResult:
    """Monte-Carlo fleet: R independent replications, one device program.

    ``options`` is the consolidated engine configuration
    (:class:`~repro.core.options.EngineOptions`); the per-call engine
    keywords below are *deprecated aliases* that build the same object —
    they emit a :class:`DeprecationWarning` and raise when combined with an
    explicit ``options=``.  The two call styles resolve to the same
    :class:`EngineOptions` and return bit-identical ``FleetResult``s
    (pinned in ``tests/test_options.py``).  Unset fields resolve along
    **explicit > env var > scenario default**
    (:func:`~repro.core.options.resolve_options`).

    ``EngineOptions(scheduler="hierarchical")`` routes the fleet to the
    class-aggregate path (:mod:`repro.core.aggregation`): every frame's
    requests are bucketed into QoS classes, the padded class grid is
    allocated by the *device-resident* analytic allocator
    (:func:`repro.core.aggregation.hier_cells` — jitted XLA scan or the
    fused Pallas kernel, selected by ``backend=`` / ``REPRO_GUS_BACKEND``)
    inside the same vmap-over-R / scan-over-T / prefetch pipeline as the
    dense path, and satisfaction is accounted *per member* at
    deaggregation — memory and schedule time scale with the number of
    *classes*, not requests, which is what sustains 10^5+ users per frame
    (``mega-city``).  The path composes with congestion, impairments
    (per-member link draws at deaggregation), admission control
    (class-level shedding + queue caps, exact on singleton/duplicate
    classes), streaming, windowed arrivals, and metrics; ``devices`` shards
    the class-tensor precompute over the mesh
    (:func:`_hier_class_tensors`).  ``REPRO_HIER_HOST_LOOP=1`` falls back
    to the PR-9 host loop for baseline comparisons.

    ``metrics=True`` adds a per-frame :class:`~repro.obs.metrics.MetricsFrame`
    output to the scan — stacked on device across each window, drained with
    the window's other outputs (no per-frame host sync) — and returns the
    stream as ``FleetResult.metrics``.  Rows report the *post-frame* carried
    backlog (the scan carry); :func:`simulate` rows report the backlog
    entering each decision.  With ``metrics=False`` (default) the traced
    program and every result field are bit-identical to a build without the
    telemetry layer.

    Every (replication, frame) pair becomes one fixed-shape padded
    ``FlatInstance``; the fleet is laid out as an ``(R, T)`` grid and
    scheduled by a single jitted program — ``vmap`` over the R replications
    of a ``lax.scan`` over the T frames, with the per-replication
    :class:`~repro.core.queueing.PolicyCarry` (congestion backlogs, EMA
    load, policy state) as the scan carry.  This is the throughput path for
    scenario sweeps (the paper runs 20 000 repetitions); with the
    congestion model disabled the carry is inert and results are
    bit-identical to scheduling all R*T frames in one flat vmap.

    ``devices`` shards the replication axis across the 1-D ``("rep",)``
    device mesh of :func:`repro.launch.mesh.make_fleet_mesh`: replications
    are cut into fixed-width groups of ``rep_group`` (default
    :data:`FLEET_REP_GROUP`; ``n_rep`` is padded up with throwaway
    replications and sliced back), and each group's slice of the instance
    grid, the PRNG-key chain, and the carry pytree is placed on the next
    mesh device round-robin.  Every group runs the *same* compiled
    vmap-over-group-of-``lax.scan`` program — only its device changes — and
    jax's async dispatch overlaps the groups across devices.  Replications
    never communicate, so sharded results are **bit-identical** to the
    single-device run.  (An SPMD ``shard_map`` layout was measured and
    rejected here: the partitioner compiles a different fusion of the
    scheduler per device count, and greedy argmax/argsort decisions amplify
    1-ulp differences into different assignments — see
    ``docs/architecture.md`` section 6.)  ``devices=None`` uses every local
    device, which with one visible device is exactly the single-device
    path; asking for more than ``jax.local_device_count()`` raises.
    ``rep_group`` must be held fixed when comparing runs across device
    counts; fleets with ``n_rep <= rep_group`` run as one group (the
    legacy single-program layout).  ``rep_group > n_rep`` clamps to
    ``n_rep`` — the group width can never exceed the replication count, and
    the clamped run is bit-identical to ``rep_group=n_rep`` (pinned in
    ``tests/test_options.py``); ``rep_group < 1`` raises.

    ``window`` bounds memory on long horizons: the (R, T) grid is built and
    scanned ``window`` frames at a time, threading the carry between
    chunks, instead of materializing all T frames' instance tensors at
    once.  On a ``streaming`` scenario the arrivals themselves are drawn
    one window at a time from each replication's
    :class:`~repro.core.streaming.ArrivalStream` (a count-only pre-pass
    fixes the padding bucket), so memory stays bounded at 10^5-frame
    horizons.  Windowed results are bit-identical to the materialized run.

    ``prefetch`` overlaps the host with the devices: a single producer
    thread builds window ``k+1``'s arrivals and instance grid (the same
    work, in the same order, as the serial loop — all host-side RNG lives
    in the producer, so results are **bit-identical**) while window ``k``'s
    replication groups compute, with a bounded queue of depth ``prefetch``
    applying backpressure.  ``prefetch=0`` degrades to the serial
    build-then-dispatch loop (the pre-overlap pipeline, and the reference
    the parity tests compare against); the default of 1 double-buffers.
    A builder exception propagates to the caller, and an early exit (or a
    caller-side error) drains and joins the producer — no hung threads.
    ``FleetResult.gen_s`` reports how long the pipeline actually *blocked*
    on host-side generation + building; hiding that time is the point.

    ``rng_mode`` (``None`` defers to ``scenario.rng_mode``) selects the
    arrival generator: ``"paper-default"`` keeps the frozen per-request
    draw order, ``"vectorized"`` generates in numpy batches and keeps the
    whole trace columnar (:class:`~repro.core.scenarios.RequestColumns`) so
    the grid builder fills frames from array slices — ~10x faster host
    generation, different (equally distributed, seed-deterministic) traces.

    ``policy`` names a registered :class:`~repro.core.policies.Policy`; a
    ``needs_key`` policy (``random``) receives one PRNG key per
    (replication, frame) pair split from ``seed`` (fed through the scan as
    inputs, preserving the legacy key chain), a ``stateful`` policy carries
    its own state in the scan carry, and a non-vmappable policy (the
    ``ilp`` / ``lp-bound`` oracles) falls back to a host-side loop over the
    *unpadded* frames — threading the same carry — feeding the same masked
    metrics path (``devices`` other than ``None``/1 raises there;
    ``window`` does not apply).

    Frame semantics are *frame-synchronous*: one decision per frame at the
    frame boundary (no queue-cap early closes), per-frame budgets refresh
    through the scenario's capacity stream, and the scheduler sees the true
    mean bandwidth.  Satisfaction is evaluated on the modeled completion
    times (like the paper's numerical Monte-Carlo) — inflated by the
    congestion factors when ``cfg.congestion.enabled``.  Use
    :func:`simulate` for stochastic channel realizations and the EMA
    bandwidth estimator.

    ``backend`` picks the default GUS scheduler's implementation for the
    whole grid (``"xla"`` / ``"pallas"``, bit-identical assignments — the
    Pallas kernel schedules one grid program per (replication, frame)
    inside the same vmapped scan); it composes only with the default
    scheduler / the ``"gus"`` policy.
    """
    opts = fold_deprecated_kwargs(
        options,
        dict(streaming=streaming, devices=devices, window=window,
             rep_group=rep_group, rng_mode=rng_mode, prefetch=prefetch,
             backend=backend, metrics=metrics),
        caller="simulate_fleet",
    )
    scn = get_scenario(scenario)
    opts = resolve_options(opts, scenario=scn)
    metrics = bool(opts.metrics)
    devices = opts.devices
    hier = opts.scheduler == "hierarchical"
    pol = _resolve_policy(scheduler, policy)
    if hier:
        # backend= now selects the hierarchical allocator's implementation
        # (XLA scan / fused Pallas kernel); admission control composes —
        # class-level shed + queue caps run inside the jitted hier runner
        pol, scheduler = _fold_hier_scheduler(
            pol, scheduler, opts, allow_backend=True
        )
    else:
        pol, scheduler = _apply_backend(pol, scheduler, opts.backend)
    ccfg = cfg.congestion
    acfg = cfg.admission
    T = max(1, int(np.ceil(cfg.horizon_ms / cfg.frame_ms)))
    K = spec.proc_ms.shape[1]
    M = spec.n_servers
    use_stream = opts.streaming
    host_side = (not hier) and pol is not None and (not pol.vmappable or not pol.pad)
    if hier:
        n_dev = _resolve_fleet_devices(devices, n_rep)
    elif host_side:
        if devices is not None and devices != 1:
            _resolve_fleet_devices(devices, n_rep)  # impossible counts error first
            raise ValueError(
                f"policy {pol.name!r} schedules host-side; devices={devices} "
                f"of {jax.local_device_count()} visible device(s) does not "
                "apply — the host-side loop drives exactly one device (use "
                "devices=None or 1)"
            )
        n_dev = 1
    else:
        n_dev = _resolve_fleet_devices(devices, n_rep)
    W = T if opts.window is None else max(1, min(int(opts.window), T))
    # lazy per-window arrival generation needs the stream's chunking
    # invariance; a materialized trace is bucketed up front either way.
    # The hierarchical path is windowed by construction, so it keeps the
    # stream lazy.
    lazy = use_stream and W < T and (hier or not host_side)
    mode = opts.rng_mode
    prefetch = opts.prefetch

    sw = Stopwatch()
    t_run0 = time.perf_counter()
    with sw.span("fleet/generate_traces", CAT_GEN, n_rep=n_rep):
        sources = [
            _RepFrameSource(
                scn, seed + rep, spec.n_edge, K, cfg, T, use_stream, lazy,
                rng_mode=mode,
            )
            for rep in range(n_rep)
        ]
        if hier:
            n_pad = 0  # the aggregated path never pads a request grid
        elif lazy:
            # count-only pre-pass: the global max bucket, in bounded memory —
            # one padding bucket for every window, identical to materialized
            n_max = max(
                max_frame_arrivals(
                    scn, seed + rep, spec.n_edge, K, cfg, T, rng_mode=mode
                )
                for rep in range(n_rep)
            )
            n_pad = _pad_bucket(n_max)
        else:
            n_max = max(src.max_bucket for src in sources)
            n_pad = _pad_bucket(n_max)
    # trace generation + padding pre-pass; per-window blocking adds to this
    gen_s = sw.total("fleet/generate_traces")
    # the resilience engine is replication-independent (same network
    # weather for every rep) and frame-indexed, so its traces tile across
    # the rep axis and extend prefix-stable window by window — what keeps
    # windowed/prefetched/sharded runs bitwise identical to serial
    engine = (
        ResilienceEngine(cfg.impairments, spec.n_edge, M)
        if cfg.impairments.enabled else None
    )

    if hier:
        return _simulate_fleet_hier(
            spec, cfg, scn, sources, n_rep=n_rep, T=T, W=W, opts=opts,
            n_dev=n_dev, gen_s=gen_s, engine=engine, metrics=metrics, sw=sw,
            t_run0=t_run0,
        )

    if host_side:
        return _simulate_fleet_host(
            spec, cfg, scn, pol, sources, n_rep=n_rep, T=T, n_pad=n_pad, seed=seed,
            gen_s=gen_s, engine=engine, metrics=metrics, sw=sw, t_run0=t_run0,
        )

    if pol is not None:
        fn = _bound_policy(pol, spec.n_edge, spec.n_servers)
        needs_key = pol.needs_key and not pol.stateful
        stateful = pol.stateful
    else:
        fn = gus_schedule if scheduler is None else scheduler
        needs_key = False
        stateful = False
    run = _fleet_runner(
        fn, stateful, needs_key, ccfg, acfg, engine is not None,
        metrics, spec.n_edge,
    )

    if needs_key:
        keys_all = np.asarray(jax.random.split(
            jax.random.PRNGKey(seed), n_rep * T
        )).reshape(n_rep, T, -1)
    else:  # dummy inputs keep the scan signature uniform
        keys_all = np.zeros((n_rep, T, 2), np.uint32)
    carry = fleet_policy_carry(n_rep, M, seed=seed, bandwidth_init=spec.bandwidth_true)

    # --- fixed-width replication groups, round-robined across the mesh ------
    # Every group of G replications runs the SAME jitted program (same
    # shapes, same HLO) no matter how many devices are in play — only the
    # device each group is placed on changes.  That is what makes sharded
    # results bit-identical to the single-device run: an SPMD partitioner
    # (shard_map) or a device-count-dependent batch width recompiles the
    # scheduler with different fusion, and greedy argmax/argsort decisions
    # amplify 1-ulp differences into different assignments.  jax dispatch
    # is async, so the per-group calls overlap across devices.
    # rep_group < 1 was rejected by resolve_options; > n_rep clamps (a group
    # can never be wider than the replication axis), documented above
    G = min(FLEET_REP_GROUP if opts.rep_group is None else int(opts.rep_group), n_rep)
    pad_r = (-n_rep) % G
    n_groups = (n_rep + pad_r) // G
    if n_dev > 1:
        from repro.launch.mesh import make_fleet_mesh

        group_devices = list(make_fleet_mesh(n_dev).devices.ravel())
    else:
        group_devices = [None]  # default device, no explicit placement

    def to_device(tree, dev):
        if dev is None:
            return tree
        return jax.tree.map(lambda x: jax.device_put(x, dev), tree)

    # worker threads drive the devices concurrently (XLA releases the GIL
    # during execution); more workers than physical cores only adds
    # contention on a CPU host, so cap there — device placement still
    # round-robins over the full mesh
    n_workers = min(n_dev, os.cpu_count() or 1)
    executor = ThreadPoolExecutor(max_workers=n_workers) if n_workers > 1 else None
    if pad_r:
        carry = _pad_reps(carry, pad_r)
        keys_all = _pad_reps(keys_all, pad_r)
    carries = [
        to_device(
            jax.tree.map(lambda x: x[g * G:(g + 1) * G], carry),
            group_devices[g % n_dev],
        )
        for g in range(n_groups)
    ]

    # per-(rep, frame) stores; the final reductions below see the same
    # values in the same order no matter how the frames were windowed
    sat_frames = np.zeros((n_rep, T), np.int64)
    served_frames = np.zeros((n_rep, T), np.int64)
    us_frames = np.zeros((n_rep, T), np.float32)
    n_real_frames = np.zeros((n_rep, T), np.int32)
    phi_frames = np.ones((n_rep, T, M), np.float32) if ccfg.enabled else None

    def build_window(t0: int):
        """Host-side build of one window: pull every replication's buckets,
        fill the queueing-delay rows, and assemble the padded instance grid.
        Pure numpy + the sources' own RNGs, so it runs unchanged — same
        work, same draw order — inline (``prefetch=0``) or on the producer
        thread (``prefetch>0``); that is the whole bit-identity argument."""
        t1 = min(t0 + W, T)
        Tc = t1 - t0
        frames: List = []
        frame_starts: List[float] = []
        n_real = np.zeros((n_rep, Tc), np.int32)
        tq_flat = np.zeros((n_rep * Tc, n_pad), np.float32)
        i = 0
        with sw.span("fleet/arrivals", CAT_GEN, t0=t0):
            for rep, src in enumerate(sources):
                for k, bucket in enumerate(src.take(t1)):
                    frame_start = (t0 + k) * cfg.frame_ms
                    frames.append(bucket)
                    frame_starts.append(frame_start)
                    nb = len(bucket)
                    n_real[rep, k] = nb
                    if nb:
                        if isinstance(bucket, RequestColumns):
                            tq_flat[i, :nb] = (
                                frame_start + cfg.frame_ms - bucket.arrival_ms
                            )
                        else:
                            tq_flat[i, :nb] = [
                                frame_start + cfg.frame_ms - r.arrival_ms
                                for r in bucket
                            ]
                    i += 1
        with sw.span("fleet/grid_build", CAT_BUILD, t0=t0):
            # per-frame budgets are replication-independent: one *batched*
            # capacity-stream call per window, reused across the R reps
            gb, eb = _frame_budgets_batch(
                spec, cfg, scn, (t0 + np.arange(Tc)) * cfg.frame_ms, engine=engine,
            )
            budgets_by_k = [(gb[k], eb[k]) for k in range(Tc)]
            R_pad = n_rep + pad_r
            if engine is not None:
                links_by_k = [engine.link_frame(t0 + k) for k in range(Tc)]
                links_arg = links_by_k * n_rep
                link_rt = np.broadcast_to(
                    np.stack([l[0] for l in links_by_k]).astype(np.float32),
                    (R_pad, Tc, M),
                )
                up_rt = np.broadcast_to(
                    np.stack([engine.server_up(t0 + k) for k in range(Tc)]),
                    (R_pad, Tc, M),
                )
            else:  # dummy xs keep the scan signature uniform (never read)
                links_arg = None
                link_rt = up_rt = np.broadcast_to(
                    np.ones((1, 1, M), np.float32), (R_pad, Tc, M)
                )
            batch = _build_frame_batch(
                frames, spec, cfg, frame_starts, budgets_by_k * n_rep, n_pad,
                links=links_arg,
            )  # leading axis: n_rep * Tc frames
            batch_rt = jax.tree.map(
                lambda x: x.reshape((n_rep, Tc) + x.shape[1:]), batch
            )
            tq_rt = tq_flat.reshape(n_rep, Tc, n_pad)
            nreal_rt = n_real
            if pad_r:
                batch_rt = _pad_reps(batch_rt, pad_r)
                tq_rt = _pad_reps(tq_rt, pad_r)
                nreal_rt = _pad_reps(nreal_rt, pad_r)
        return (t0, t1, Tc, batch, batch_rt, n_real, tq_flat, tq_rt,
                link_rt, up_rt, nreal_rt)

    window_starts = list(range(0, T, W))
    prod_thread = None
    if prefetch > 0 and len(window_starts) > 0:
        # bounded producer: builds windows ahead of the consumer, at most
        # `prefetch` in flight.  Timeout-polling puts let it notice a
        # consumer that stopped pulling (early exit / error) and unwind.
        work_q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
        stop_producer = threading.Event()

        def _offer(item) -> bool:
            while not stop_producer.is_set():
                try:
                    work_q.put(item, timeout=0.05)
                    return True
                except queue_mod.Full:
                    continue
            return False

        def _produce():
            try:
                for t0 in window_starts:
                    if not _offer(build_window(t0)):
                        return
            except BaseException as e:  # delivered to the consumer's get()
                _offer(e)

        prod_thread = threading.Thread(
            target=_produce, name="fleet-window-producer", daemon=True
        )
        prod_thread.start()

    def next_window(t0: int):
        """The consumer's pull: inline build when serial, else a queue get
        whose wait time is exactly the un-hidden host cost (gen_s)."""
        if prod_thread is None:
            return build_window(t0)
        item = work_q.get()
        if isinstance(item, BaseException):
            raise item
        return item

    m_acc: Optional[Dict[str, np.ndarray]] = None
    try:
        for wi, wi_t0 in enumerate(window_starts):
            with sw.span("fleet/window_wait", CAT_GEN, window=wi):
                (t0, t1, Tc, batch, batch_rt, n_real, tq_flat,
                 tq_rt, link_rt, up_rt, nreal_rt) = next_window(wi_t0)
            keys_rt = keys_all[:, t0:t1]

            def run_group(g):
                sl = slice(g * G, (g + 1) * G)
                dev = group_devices[g % n_dev]
                argv = [
                    carries[g],
                    to_device(jax.tree.map(lambda x: x[sl], batch_rt), dev),
                    to_device(keys_rt[sl], dev),
                    to_device(tq_rt[sl], dev),
                    to_device(np.ascontiguousarray(link_rt[sl]), dev),
                    to_device(np.ascontiguousarray(up_rt[sl]), dev),
                ]
                if metrics:
                    argv.append(to_device(nreal_rt[sl], dev))
                with annotate(f"fleet/group{g}"):
                    c, out = run(*argv)
                    # materialize here (XLA releases the GIL while computing,
                    # so worker threads overlap groups across devices); the
                    # carry stays device-resident for the next window
                    return c, jax.tree.map(np.asarray, out)

            with sw.span(
                "fleet/dispatch", CAT_DISPATCH, window=wi, n_groups=n_groups
            ), step_annotation("fleet/window", wi):
                if executor is None:
                    results = [run_group(g) for g in range(n_groups)]
                else:
                    results = list(executor.map(run_group, range(n_groups)))
            for g, (c, _) in enumerate(results):
                carries[g] = c
            with sw.span("fleet/window_metrics", CAT_METRICS, window=wi):
                jv, lv, pc, pe = (
                    np.concatenate([r[1][part] for r in results])[:n_rep]
                    for part in range(4)
                )
                assign = Assignment(
                    jnp.asarray(jv.reshape(n_rep * Tc, n_pad)),
                    jnp.asarray(lv.reshape(n_rep * Tc, n_pad)),
                )
                if ccfg.enabled:
                    phi_c = jnp.asarray(pc.reshape(n_rep * Tc, M))
                    phi_e = jnp.asarray(pe.reshape(n_rep * Tc, M))
                    mbatch = dataclasses.replace(
                        batch,
                        ctime=congested_ctime(
                            batch, jnp.asarray(tq_flat), phi_c, phi_e
                        ),
                    )
                    phi_frames[:, t0:t1] = pc
                else:
                    mbatch = batch

                sat = np.asarray(satisfied_mask(mbatch, assign.j, assign.l))
                us = np.asarray(mean_us(mbatch, assign.j, assign.l))
                real = np.arange(n_pad)[None, :] < n_real.reshape(-1)[:, None]
                served = (np.asarray(assign.j) >= 0) & real
                sat = sat & real
                sat_frames[:, t0:t1] = sat.sum(-1).reshape(n_rep, Tc)
                served_frames[:, t0:t1] = served.sum(-1).reshape(n_rep, Tc)
                us_frames[:, t0:t1] = us.reshape(n_rep, Tc)
                n_real_frames[:, t0:t1] = n_real
                if metrics:
                    # scan-stacked MetricsFrame leaves arrive as
                    # (G, Tc, ...) per group — stitch the rep axis back
                    mfw = jax.tree.map(
                        lambda *xs: np.concatenate(xs)[:n_rep],
                        *[r[1][4] for r in results],
                    )
                    if m_acc is None:
                        m_acc = {
                            f: np.zeros(
                                (n_rep, T) + getattr(mfw, f).shape[2:],
                                getattr(mfw, f).dtype,
                            )
                            for f in MetricsFrame._fields
                        }
                    for f in MetricsFrame._fields:
                        m_acc[f][:, t0:t1] = getattr(mfw, f)

    finally:
        if prod_thread is not None:
            # early exit or error: unblock the producer (it polls the stop
            # event between put attempts), drain whatever it queued, join
            stop_producer.set()
            while prod_thread.is_alive():
                try:
                    work_q.get_nowait()
                except queue_mod.Empty:
                    pass
                prod_thread.join(timeout=0.05)
            prod_thread.join()

    if executor is not None:
        executor.shutdown(wait=False)
    final_backlog = np.concatenate(
        [np.asarray(c.backlog_gamma) for c in carries]
    )[:n_rep]
    reqs_per_rep = n_real_frames.sum(1)
    sat_per_rep = sat_frames.sum(1)
    # mean_us averages over n_pad rows (padded rows contribute 0); recover the
    # per-rep sum (exact: n_pad is a power of two) and renormalize by the
    # rep's true request count
    us_sum_per_rep = (us_frames * n_pad).sum(1)
    gen_s += sw.total("fleet/window_wait")
    timings = sw.as_dict()
    timings["total_s"] = time.perf_counter() - t_run0
    mres = None
    if metrics and m_acc is not None:
        mres = MetricsResult.from_stacked(
            MetricsFrame(**m_acc),
            t_ms=(np.arange(T) + 1.0) * cfg.frame_ms,
            n_edge=spec.n_edge,
            frame_ms=cfg.frame_ms,
        )
    return FleetResult(
        n_rep=n_rep,
        n_frames=T,
        n_requests=int(reqs_per_rep.sum()),
        n_served=int(served_frames.sum()),
        satisfied_per_rep=100.0 * sat_per_rep / np.maximum(reqs_per_rep, 1),
        mean_us_per_rep=us_sum_per_rep / np.maximum(reqs_per_rep, 1),
        final_backlog_per_rep=final_backlog if ccfg.enabled else None,
        mean_compute_inflation=float(np.mean(phi_frames)) if ccfg.enabled else 1.0,
        n_devices=n_dev,
        window=W,
        dispatch_s=sw.total("fleet/dispatch"),
        gen_s=gen_s,
        prefetch=prefetch if prod_thread is not None else 0,
        timings=timings,
        metrics=mres,
    )


def _simulate_fleet_host(
    spec: ClusterSpec,
    cfg: SimConfig,
    scn: Scenario,
    pol: Policy,
    sources: List[_RepFrameSource],
    *,
    n_rep: int,
    T: int,
    n_pad: int,
    seed: int,
    gen_s: float = 0.0,
    engine: Optional[ResilienceEngine] = None,
    metrics: bool = False,
    sw: Optional[Stopwatch] = None,
    t_run0: Optional[float] = None,
) -> FleetResult:
    """Host-side fleet path for non-vmappable / non-padding policies (the
    ILP / LP-bound oracles): schedule each *unpadded* frame in a Python
    loop — threading the per-replication carry frame by frame — then re-pad
    the assignments with drops so the masked metrics tail is shared with
    the vmapped policies.  Impairments and admission control mirror the
    scan step exactly (same helpers, same order)."""
    ccfg = cfg.congestion
    acfg = cfg.admission
    M = spec.n_servers
    if sw is None:
        sw = Stopwatch()
    if t_run0 is None:
        t_run0 = time.perf_counter()
    fleet_frames: List[List[Request]] = []
    with sw.span("fleet/arrivals", CAT_GEN):
        for src in sources:
            fleet_frames.extend(src.take(T))
    raw_insts = []
    n_real = np.array([len(b) for b in fleet_frames], np.int32)
    tq_flat = np.zeros((len(fleet_frames), n_pad), np.float32)
    with sw.span("fleet/grid_build", CAT_BUILD):
        for i, bucket in enumerate(fleet_frames):
            frame_start = (i % T) * cfg.frame_ms
            gamma, eta = _frame_budgets(spec, cfg, scn, frame_start, engine=engine)
            link = None
            if engine is not None and len(bucket):
                sc, la = engine.link_frame(i % T)
                cov = (
                    bucket.cover.astype(np.intp)
                    if isinstance(bucket, RequestColumns)
                    else np.array([r.cover for r in bucket], np.intp)
                )
                link = (sc[cov], la[cov])
            raw_insts.append(_build_frame_instance(
                bucket, spec, cfg, frame_start + cfg.frame_ms,
                spec.bandwidth_true, cfg.max_cs, gamma=gamma, eta=eta, link=link,
            ))
            if bucket:
                if isinstance(bucket, RequestColumns):
                    tq_flat[i, : len(bucket)] = (
                        frame_start + cfg.frame_ms - bucket.arrival_ms
                    )
                else:
                    tq_flat[i, : len(bucket)] = [
                        frame_start + cfg.frame_ms - r.arrival_ms for r in bucket
                    ]
        batch = stack_instances([pad_instance(r, n_pad) for r in raw_insts])

    fn = pol.bind(spec.n_edge, spec.n_servers)
    keys = (
        jax.random.split(jax.random.PRNGKey(seed), len(raw_insts))
        if pol.needs_key and not pol.stateful else None
    )
    jv = np.full((len(raw_insts), n_pad), -1, np.int32)
    lv = np.full((len(raw_insts), n_pad), -1, np.int32)
    phi_c = np.ones((len(raw_insts), M), np.float32)
    phi_e = np.ones((len(raw_insts), M), np.float32)
    final_backlog = np.zeros((n_rep, M), np.float32)
    if metrics:
        m_shed = np.zeros(len(raw_insts), np.int32)
        m_refused = np.zeros(len(raw_insts), np.int32)
        m_w = np.zeros((len(raw_insts), M), np.float32)
        m_c = np.zeros((len(raw_insts), M), np.float32)
        m_bg = np.zeros((len(raw_insts), M), np.float32)
        m_be = np.zeros((len(raw_insts), M), np.float32)
    with sw.span("fleet/schedule_host", CAT_SCHED, n_rep=n_rep):
        for rep in range(n_rep):
            carry = init_policy_carry(
                M, seed=seed + rep, bandwidth_init=spec.bandwidth_true
            )
            for tf in range(T):
                i = rep * T + tf
                inst, n = raw_insts[i], n_real[i]
                if engine is not None:
                    carry = dataclasses.replace(
                        carry,
                        link_bw=jnp.asarray(engine.link_frame(tf)[0], jnp.float32),
                        server_up=jnp.asarray(engine.server_up(tf)),
                    )
                if ccfg.enabled:
                    run_inst = dataclasses.replace(
                        inst,
                        gamma=effective_capacity(inst.gamma, carry.backlog_gamma),
                        eta=effective_capacity(inst.eta, carry.backlog_eta),
                    )
                else:
                    run_inst = inst
                if acfg.enabled and acfg.shed and n:
                    phi_pc, phi_pe = predicted_inflation(
                        carry.backlog_gamma, carry.backlog_eta,
                        inst.gamma, inst.eta, ccfg,
                    )
                    keep = admission_keep(
                        inst, jnp.asarray(tq_flat[i, :n]), phi_pc, phi_pe
                    )
                    run_inst = dataclasses.replace(
                        run_inst, avail=run_inst.avail & keep[:, None, None]
                    )
                    if metrics:
                        m_shed[i] = int(n) - int(np.asarray(keep).sum())
                if pol.stateful:
                    a, carry = fn(run_inst, carry)
                elif keys is not None:
                    a = fn(run_inst, keys[i])
                else:
                    a = fn(run_inst)
                if acfg.enabled and n:
                    j_cap = apply_queue_cap(
                        a.j, inst, carry.backlog_gamma, carry.backlog_eta, acfg
                    )
                    if metrics:
                        m_refused[i] = int(np.sum(
                            (np.asarray(a.j) >= 0) & (np.asarray(j_cap) < 0)
                        ))
                    a = Assignment(j_cap, a.l)
                jv[i, :n] = np.asarray(a.j)
                lv[i, :n] = np.asarray(a.l)
                if ccfg.enabled or metrics:
                    w, c = committed_loads(
                        inst, jnp.asarray(a.j), jnp.asarray(a.l)
                    )
                    if metrics:
                        m_w[i] = np.asarray(w, np.float32)
                        m_c[i] = np.asarray(c, np.float32)
                if ccfg.enabled:
                    phi_c[i] = np.asarray(
                        compute_inflation(carry.backlog_gamma + w, inst.gamma, ccfg)
                    )
                    phi_e[i] = np.asarray(
                        comm_inflation(carry.backlog_eta + c, inst.eta, ccfg)
                    )
                    carry = dataclasses.replace(
                        carry,
                        backlog_gamma=step_backlog(
                            carry.backlog_gamma, w, inst.gamma, ccfg
                        ),
                        backlog_eta=step_backlog(
                            carry.backlog_eta, c, inst.eta, ccfg
                        ),
                        ema_util=ema_update(carry.ema_util, w, inst.gamma, ccfg),
                    )
                if metrics:  # post-frame carried backlog, like the scan rows
                    m_bg[i] = np.asarray(carry.backlog_gamma, np.float32)
                    m_be[i] = np.asarray(carry.backlog_eta, np.float32)
            final_backlog[rep] = np.asarray(carry.backlog_gamma)
    assign = Assignment(jv, lv)

    if ccfg.enabled:
        mbatch = dataclasses.replace(
            batch,
            ctime=congested_ctime(
                batch, jnp.asarray(tq_flat), jnp.asarray(phi_c), jnp.asarray(phi_e)
            ),
        )
    else:
        mbatch = batch

    sat = np.asarray(satisfied_mask(mbatch, assign.j, assign.l))  # (R*T, n_pad)
    us = np.asarray(mean_us(mbatch, assign.j, assign.l))          # (R*T,)
    real = np.arange(n_pad)[None, :] < n_real[:, None]
    served = (np.asarray(assign.j) >= 0) & real
    sat = sat & real

    mres = None
    if metrics:
        # vectorized post-pass over the padded grid — same definitions as
        # the scan's frame_metrics rows (served/sat masked to real rows,
        # utilization against the full frame budgets)
        with sw.span("fleet/window_metrics", CAT_METRICS):
            jb = np.asarray(assign.j)
            local = served & (jb == np.asarray(batch.cover))
            cloudm = served & (jb >= spec.n_edge)
            eo = served & ~local & ~cloudm
            tier = np.stack(
                [local.sum(-1), eo.sum(-1), cloudm.sum(-1)], -1
            ).astype(np.int32)
            edges = np.asarray(QOS_ACC_EDGES, np.float32)
            cls = (np.asarray(batch.A)[..., None] >= edges).sum(-1)
            nq = len(QOS_ACC_EDGES) + 1
            oh = cls[..., None] == np.arange(nq)
            qos_cnt = (oh & real[..., None]).sum(1).astype(np.int32)
            qos_sat = (oh & sat[..., None]).sum(1).astype(np.int32)
            gam = np.asarray(batch.gamma, np.float64)
            eta_b = np.asarray(batch.eta, np.float64)
            with np.errstate(invalid="ignore"):
                ug = np.where(gam > 0.0, m_w / np.maximum(gam, 1e-9), 0.0)
                ue = np.where(eta_b > 0.0, m_c / np.maximum(eta_b, 1e-9), 0.0)

            def rt(x):
                return x.reshape((n_rep, T) + x.shape[1:])

            mres = MetricsResult.from_stacked(
                MetricsFrame(
                    n_arrivals=rt(n_real.astype(np.int32)),
                    n_served=rt(served.sum(-1).astype(np.int32)),
                    n_satisfied=rt(sat.sum(-1).astype(np.int32)),
                    n_shed=rt(m_shed),
                    n_refused=rt(m_refused),
                    tier_hist=rt(tier),
                    qos_sat=rt(qos_sat),
                    qos_count=rt(qos_cnt),
                    util_gamma=rt(ug.astype(np.float32)),
                    util_eta=rt(ue.astype(np.float32)),
                    backlog_gamma=rt(m_bg),
                    backlog_eta=rt(m_be),
                    us_sum=rt((us * n_pad).astype(np.float32)),
                ),
                t_ms=(np.arange(T) + 1.0) * cfg.frame_ms,
                n_edge=spec.n_edge,
                frame_ms=cfg.frame_ms,
            )

    timings = sw.as_dict()
    timings["total_s"] = time.perf_counter() - t_run0
    reqs_per_rep = n_real.reshape(n_rep, T).sum(1)
    sat_per_rep = sat.reshape(n_rep, T, n_pad).sum((1, 2))
    us_sum_per_rep = (us * n_pad).reshape(n_rep, T).sum(1)
    return FleetResult(
        n_rep=n_rep,
        n_frames=T,
        n_requests=int(reqs_per_rep.sum()),
        n_served=int(served.sum()),
        satisfied_per_rep=100.0 * sat_per_rep / np.maximum(reqs_per_rep, 1),
        mean_us_per_rep=us_sum_per_rep / np.maximum(reqs_per_rep, 1),
        final_backlog_per_rep=final_backlog if ccfg.enabled else None,
        mean_compute_inflation=float(np.mean(phi_c)) if ccfg.enabled else 1.0,
        n_devices=1,
        window=T,
        gen_s=gen_s,
        timings=timings,
        metrics=mres,
    )


@jax.jit
def _us_feas_fused(batch: FlatInstance):
    """Fused single-dispatch ``(us_tensor, hard_feasible)`` over a class
    grid.  Same elementwise expression graph as the eager calls (bitwise
    identical values) but one H2D transfer per field and one fused XLA
    computation instead of a dozen eager dispatches with host temporaries —
    this runs on the producer thread at city scale, where it sits on the
    pipeline's critical path."""
    return us_tensor(batch).astype(jnp.float32), hard_feasible(batch)


@jax.jit
def _us_feas_lean(ctime, A, C, w_a, w_c, max_as, max_cs, cover, svc, size,
                  acc_sl, placed_t, proc_t):
    """Lean-build twin of :func:`_us_feas_fused`: reconstructs the candidate
    gathers (``acc``, ``avail``, ``v``, ``u``) on device from per-class
    vectors plus the (S, M, L)-transposed spec tensors, then evaluates the
    same elementwise expressions as ``us_tensor`` / ``hard_feasible``.
    Every rebuilt tensor is a pure float32 gather / select, so real rows
    are bitwise identical to the host-materialized versions; padded rows
    (``svc``/``size``/``cover`` zero, ``A`` 1e9, ``C`` -1) gather service
    0's values instead of zeros, which no output can see — their ``us`` is
    an exact 0 (zero weights), their ``feas`` an exact False (the 1e9
    accuracy floor), and the allocator never takes from an infeasible
    zero-count class."""
    acc_b = acc_sl[svc][..., None, :]                     # (F, Cp, 1, L)
    avail = placed_t[svc]                                 # (F, Cp, M, L)
    acc_term = (acc_b - A[..., None, None]) / max_as[..., None, None, None]
    time_term = (C[..., None, None] - ctime) / max_cs[..., None, None, None]
    us = w_a[..., None, None] * acc_term + w_c[..., None, None] * time_term
    feas = (
        avail & (acc_b >= A[..., None, None]) & (ctime <= C[..., None, None])
    )
    v = proc_t[svc]                                       # (F, Cp, M, L)
    local = cover[..., None] == jnp.arange(v.shape[-2])[None, None, :]
    u = jnp.where(local[..., None], 0.0, (size / 1024.0)[..., None, None])
    return (us.astype(jnp.float32), feas, v, jnp.broadcast_to(u, v.shape))


def _hier_class_tensors(batch: FlatInstance, n_dev: int):
    """Utility / feasibility tensors for a window's class grid, with the
    *class axis* sharded over the ``("rep",)`` device mesh when more than
    one device is visible.

    ``us_tensor`` / ``hard_feasible`` are elementwise per class row, so
    cutting the padded class axis into ``n_dev`` contiguous slabs and
    computing each slab on its own mesh device produces bit-identical
    values to the single-device call (no cross-class reduction exists to
    re-associate) — this is the one hierarchical tensor big enough at
    city-scale frames (``F x Cp x M x L``) to be worth spreading, and the
    allocator itself stays a sequential scan over classes (the budgets are
    a carry), so sharding lives here, not in the kernel.
    """
    if n_dev <= 1:
        # one fused jit call instead of eager op-by-op dispatch, and the
        # outputs stay on device: the runner consumes them next, and a host
        # round-trip of two (F, Cp, M, L) tensors at city scale costs more
        # than the allocator's whole scan
        return _us_feas_fused(batch)
    from repro.launch.mesh import make_fleet_mesh

    devs = list(make_fleet_mesh(n_dev).devices.ravel())
    Cp = batch.A.shape[1]
    cuts = np.linspace(0, Cp, n_dev + 1).astype(int)
    per_class = ("cover", "A", "C", "w_a", "w_c", "acc", "ctime", "v", "u",
                 "avail")
    us_p, fe_p = [], []
    for d, dev in enumerate(devs):
        lo, hi = int(cuts[d]), int(cuts[d + 1])
        if lo == hi:
            continue
        sub = dataclasses.replace(batch, **{
            f: jax.device_put(getattr(batch, f)[:, lo:hi], dev)
            for f in per_class
        })
        us_p.append(np.asarray(us_tensor(sub), np.float32))
        fe_p.append(np.asarray(hard_feasible(sub)))
    return np.concatenate(us_p, axis=1), np.concatenate(fe_p, axis=1)


@functools.lru_cache(maxsize=32)
def _hier_runner_impl(
    cells_fn, ccfg: CongestionConfig, acfg: AdmissionConfig,
    keep_pre: bool = False,
):
    """The hierarchical fleet's jitted vmap-over-reps-of-scan-over-frames
    runner, cached by (allocator backend fn, congestion config, admission
    config) — the hier twin of :func:`_fleet_runner_impl`.

    Scan inputs per frame: the padded *class* instance, the precomputed
    utility/feasibility tensors, the class queueing delays, and the member
    counts.  Admission control mirrors the dense step's order at class
    granularity: deadline shedding masks feasibility *before* the
    allocator (against the pre-frame backlog-only inflation estimate,
    evaluated on the count-weighted class representative), the queue cap
    refuses allocated cells *after* it and before the committed work
    enters the backlog — exact per-request semantics whenever classes are
    singletons or exact duplicates (the parity tests' scenarios), a
    representative approximation otherwise.

    ``keep_pre`` (only valid with congestion off): the keep mask is
    carry-independent (unit inflation makes admission's candidate test
    bitwise ``hard_feasible``), so the window builder reduces it from the
    feas tensor up front and ships it in the ``tq`` slot — the step
    then never touches ``inst.acc``/``ctime``/``avail``/``A``/``C``, and
    the caller passes slim dummies for them instead of transferring three
    ``(R, T, Cp, M, L)`` tensors per window.
    """
    shed = acfg.enabled and acfg.shed
    if keep_pre and ccfg.enabled:
        raise ValueError("keep_pre requires the congestion model off")

    def step(carry, x):
        bg, be = carry
        inst, us, feas, tq_c, count = x
        if ccfg.enabled:  # the allocator sees backlog-reduced budgets
            g_run = effective_capacity(inst.gamma, bg)
            e_run = effective_capacity(inst.eta, be)
        else:
            g_run, e_run = inst.gamma, inst.eta
        keep = None
        if shed:
            if keep_pre:  # tq slot carries the precomputed mask
                keep = tq_c
            else:
                phi_pc, phi_pe = predicted_inflation(
                    bg, be, inst.gamma, inst.eta, ccfg
                )
                keep = admission_keep(inst, tq_c, phi_pc, phi_pe)
            feas = feas & keep[:, None, None]
        take, start = cells_fn(
            us, feas, inst.v, inst.u, inst.cover, count, g_run, e_run
        )
        n_refused = jnp.int32(0)
        if acfg.enabled:  # queue cap: refuse cells on over-backlogged servers
            M = inst.gamma.shape[0]
            over_c = bg >= acfg.queue_cap_mult * inst.gamma
            over_e = be >= acfg.queue_cap_mult * inst.eta
            offl = jnp.arange(M)[None, :, None] != inst.cover[:, None, None]
            refuse = (take > 0) & (
                over_c[None, :, None] | (offl & over_e[inst.cover][:, None, None])
            )
            n_refused = jnp.sum(jnp.where(refuse, take, 0))
            take = jnp.where(refuse, 0, take)
        n_shed = (
            jnp.sum(jnp.where(keep, 0, count)) if keep is not None
            else jnp.int32(0)
        )
        tf = take.astype(jnp.float32)
        w = jnp.sum(tf * inst.v, axis=(0, 2))          # (M,) committed compute
        # inst.u is zero at local cells, so the per-class sum is exactly the
        # offloaded communication charged to the covering edge
        c_load = jnp.zeros_like(w).at[inst.cover].add(
            jnp.sum(tf * inst.u, axis=(1, 2))
        )
        if ccfg.enabled:
            pc = compute_inflation(bg + w, inst.gamma, ccfg)
            pe = comm_inflation(be + c_load, inst.eta, ccfg)
            bg = step_backlog(bg, w, inst.gamma, ccfg)
            be = step_backlog(be, c_load, inst.eta, ccfg)
        else:
            pc = jnp.ones_like(inst.gamma)
            pe = jnp.ones_like(inst.eta)
        return (bg, be), (take, start, pc, pe, w, c_load, n_shed, n_refused,
                          bg, be)

    def per_rep(c0, inst_seq, us_seq, feas_seq, tq_seq, cnt_seq):
        return jax.lax.scan(
            step, c0, (inst_seq, us_seq, feas_seq, tq_seq, cnt_seq)
        )

    return jax.jit(jax.vmap(per_rep))


def _simulate_fleet_hier(
    spec: ClusterSpec,
    cfg: SimConfig,
    scn: Scenario,
    sources: List[_RepFrameSource],
    *,
    n_rep: int,
    T: int,
    W: int,
    opts: EngineOptions,
    n_dev: int = 1,
    gen_s: float = 0.0,
    engine: Optional[ResilienceEngine] = None,
    metrics: bool = False,
    sw: Optional[Stopwatch] = None,
    t_run0: Optional[float] = None,
) -> FleetResult:
    """Class-aggregate fleet path for ``EngineOptions(scheduler="hierarchical")``.

    Never materializes a dense ``N x M x L`` request grid: each frame's
    arrivals are bucketed into QoS classes
    (:func:`repro.core.aggregation.aggregate_requests`), the count-weighted
    class representatives become one padded ``Cp x M x L`` candidate grid
    per (replication, frame), and the analytic allocator
    (:func:`repro.core.aggregation.hier_cells` — jitted XLA scan or the
    fused Pallas kernel, per ``opts.backend`` / ``REPRO_GUS_BACKEND``) runs
    *inside* the same vmap-over-R / ``lax.scan``-over-T / prefetch pipeline
    as the dense path, with the congestion backlog as the scan carry and
    class-level admission control (deadline shedding + queue caps) inside
    the jitted step.  ``REPRO_HIER_HOST_LOOP=1`` routes to the retained
    PR-9 per-window host loop (:func:`_simulate_fleet_hier_host`), the
    baseline the scaling benchmark compares against.

    Satisfaction is accounted **per member** on the host after each window:
    the fixed-shape ``(take, start)`` cells deaggregate deterministically
    (ascending member index within each class), and every allocated
    member's realized accuracy / completion time is recomputed with its
    *own* size, queueing delay, and — when impairments are on — the frame's
    per-edge link draw, using the exact op sequence of
    :func:`_frame_arrays`; the class mean only ever steers the allocation,
    never the accounting.  Memory and schedule time still scale with the
    class count, which is what sustains 10^5+ users per frame.
    """
    if os.environ.get("REPRO_HIER_HOST_LOOP", "0") not in ("0", "", "false", "False"):
        return _simulate_fleet_hier_host(
            spec, cfg, scn, sources, n_rep=n_rep, T=T, W=W, gen_s=gen_s,
            engine=engine, metrics=metrics, sw=sw, t_run0=t_run0,
        )
    from .aggregation import QuantizationConfig, aggregate_requests, hier_backend_fn

    ccfg = cfg.congestion
    acfg = cfg.admission
    M = spec.n_servers
    n_edge = spec.n_edge
    prefetch = opts.prefetch
    if sw is None:
        sw = Stopwatch()
    if t_run0 is None:
        t_run0 = time.perf_counter()
    quant = QuantizationConfig()
    edges_q = np.asarray(QOS_ACC_EDGES, np.float64)
    nq = len(QOS_ACC_EDGES) + 1
    cells_fn = hier_backend_fn(opts.backend)
    # congestion off -> the shed mask is carry-independent: precompute it in
    # the (overlappable) window build and dispatch a slim instance
    keep_pre = acfg.enabled and acfg.shed and not ccfg.enabled
    run = _hier_runner_impl(cells_fn, ccfg, acfg, keep_pre)
    # lean grid build: skip host-materializing the spec-gather candidate
    # tensors and rebuild them on device (valid whenever the runner's keep
    # mask is precomputable and the class axis is not host-sharded)
    lean = keep_pre and n_dev <= 1
    if lean:
        spec_acc_j = jnp.asarray(spec.acc, jnp.float32)
        spec_placed_tj = jnp.asarray(np.transpose(spec.placed, (1, 0, 2)))
        spec_proc_tj = jnp.asarray(
            np.transpose(spec.proc_ms, (1, 0, 2)), jnp.float32
        )

    reqs_per_rep = np.zeros(n_rep, np.int64)
    served_per_rep = np.zeros(n_rep, np.int64)
    sat_per_rep = np.zeros(n_rep, np.int64)
    us_sum_per_rep = np.zeros(n_rep, np.float64)
    phi_sum = 0.0
    phi_cnt = 0
    m_acc: Optional[Dict[str, np.ndarray]] = None
    if metrics:
        m_acc = {
            "n_arrivals": np.zeros((n_rep, T), np.int32),
            "n_served": np.zeros((n_rep, T), np.int32),
            "n_satisfied": np.zeros((n_rep, T), np.int32),
            "n_shed": np.zeros((n_rep, T), np.int32),
            "n_refused": np.zeros((n_rep, T), np.int32),
            "tier_hist": np.zeros((n_rep, T, 3), np.int32),
            "qos_sat": np.zeros((n_rep, T, nq), np.int32),
            "qos_count": np.zeros((n_rep, T, nq), np.int32),
            "util_gamma": np.zeros((n_rep, T, M), np.float32),
            "util_eta": np.zeros((n_rep, T, M), np.float32),
            "backlog_gamma": np.zeros((n_rep, T, M), np.float32),
            "backlog_eta": np.zeros((n_rep, T, M), np.float32),
            "us_sum": np.zeros((n_rep, T), np.float32),
        }

    def build_window(t0: int):
        """Host-side build of one window: aggregate every (rep, frame) into
        sorted classes, assemble the padded class grid, and precompute the
        class tensors.  Pure numpy + the sources' own RNGs, so it runs
        unchanged inline (``prefetch=0``) or on the producer thread."""
        t1 = min(t0 + W, T)
        Tc = t1 - t0
        with sw.span("fleet/hier_build", CAT_BUILD, t0=t0):
            gb, eb = _frame_budgets_batch(
                spec, cfg, scn, (t0 + np.arange(Tc)) * cfg.frame_ms, engine=engine,
            )
            budgets_by_k = [(gb[k], eb[k]) for k in range(Tc)]
            links_by_k = (
                [engine.link_frame(t0 + k) for k in range(Tc)]
                if engine is not None else None
            )
        frames_rc: List[RequestColumns] = []
        frame_starts: List[float] = []
        infos: List[List[Optional[dict]]] = []
        n_arr = np.zeros((n_rep, Tc), np.int32)
        n_cls = np.zeros((n_rep, Tc), np.int32)
        for rep, src in enumerate(sources):
            with sw.span("fleet/arrivals", CAT_GEN, t0=t0, rep=rep):
                buckets = src.take(t1)
            rep_infos: List[Optional[dict]] = []
            for k, bucket in enumerate(buckets):
                frame_start = (t0 + k) * cfg.frame_ms
                frame_end = frame_start + cfg.frame_ms
                frame_starts.append(frame_start)
                n = len(bucket)
                n_arr[rep, k] = n
                if not n:
                    z = np.zeros(0)
                    frames_rc.append(RequestColumns(
                        arrival_ms=z, cover=np.zeros(0, np.int64),
                        service=np.zeros(0, np.int64), A=z, C=z, size_bytes=z,
                    ))
                    rep_infos.append(None)
                    continue
                if isinstance(bucket, RequestColumns):
                    cov, svc = bucket.cover, bucket.service
                    A_r, C_r = bucket.A, bucket.C
                    size = bucket.size_bytes
                    arr_ms = bucket.arrival_ms
                else:
                    cov = np.array([r.cover for r in bucket], np.int64)
                    svc = np.array([r.service for r in bucket], np.int64)
                    A_r = np.array([r.A for r in bucket], np.float64)
                    C_r = np.array([r.C for r in bucket], np.float64)
                    size = np.array([r.size_bytes for r in bucket], np.float64)
                    arr_ms = np.array([r.arrival_ms for r in bucket], np.float64)
                with sw.span("fleet/hier_aggregate", CAT_BUILD, frame=t0 + k):
                    tq = frame_end - np.asarray(arr_ms, np.float64)
                    count, first_idx, members, offsets, repc = (
                        aggregate_requests(cov, svc, A_r, C_r, size, tq, quant)
                    )
                    # allocation order is by first member index — sort once
                    # here so the device allocator walks classes in order
                    order = np.argsort(first_idx, kind="stable")
                    n_c = count.shape[0]
                    rank = np.empty(n_c, np.int64)
                    rank[order] = np.arange(n_c)
                    cls_of_member = np.repeat(np.arange(n_c), count)
                    members_s = members[
                        np.argsort(rank[cls_of_member], kind="stable")
                    ]
                    count_s = count[order]
                    frames_rc.append(RequestColumns(
                        arrival_ms=frame_end - repc["tq"][order],
                        cover=repc["cover"][order],
                        service=repc["service"][order],
                        A=repc["A"][order],
                        C=repc["C"][order],
                        size_bytes=repc["size"][order],
                    ))
                    n_cls[rep, k] = n_c
                    rep_infos.append(dict(
                        members_s=members_s,
                        off_s=np.concatenate([[0], np.cumsum(count_s)]),
                        count_s=count_s,
                        tq_s=repc["tq"][order],
                        cov=cov, svc=svc, A=A_r, C=C_r, size=size, tq=tq,
                    ))
            infos.append(rep_infos)
        Cp = _pad_bucket_fine(int(n_cls.max())) if frames_rc else 4
        with sw.span("fleet/grid_build", CAT_BUILD, t0=t0):
            built = _build_frame_batch(
                frames_rc, spec, cfg, frame_starts, budgets_by_k * n_rep, Cp,
                links=None if links_by_k is None else links_by_k * n_rep,
                lean=lean,
            )  # leading axis: n_rep * Tc class frames
            if lean:
                batch, svc_p, size_p = built
                us_w, feas_w, v_w, u_w = _us_feas_lean(
                    batch.ctime, batch.A, batch.C, batch.w_a, batch.w_c,
                    batch.max_as, batch.max_cs, batch.cover, svc_p, size_p,
                    spec_acc_j, spec_placed_tj, spec_proc_tj,
                )
            else:
                batch = built
                us_w, feas_w = _hier_class_tensors(batch, n_dev)
            batch_rt = jax.tree.map(
                lambda x: np.asarray(x).reshape((n_rep, Tc) + x.shape[1:]), batch
            )
            us_rt = us_w.reshape(n_rep, Tc, Cp, M, -1)
            feas_rt = feas_w.reshape(n_rep, Tc, Cp, M, -1)
            cnt_rt = np.zeros((n_rep, Tc, Cp), np.int32)
            tq_rt = np.zeros((n_rep, Tc, Cp), np.float32)
            for rep in range(n_rep):
                for k in range(Tc):
                    info = infos[rep][k]
                    if info is None:
                        continue
                    nc = info["count_s"].shape[0]
                    cnt_rt[rep, k, :nc] = info["count_s"]
                    tq_rt[rep, k, :nc] = info["tq_s"]
            if keep_pre:
                # at unit inflation admission's candidate test is exactly
                # hard_feasible (the phi-1 additions in congested_ctime are
                # exact zeros), so the shed mask is a free reduction of the
                # feas tensor already in hand; slim the dispatched instance:
                # the runner never reads the per-cell candidate tensors, so
                # their H2D transfer would be pure waste
                tq_rt = feas_rt.any(axis=(-1, -2))
                slim5 = np.zeros((n_rep, Tc, 1, 1, 1), np.float32)
                slim3 = np.zeros((n_rep, Tc, 1), np.float32)
                batch_rt = dataclasses.replace(
                    batch_rt,
                    acc=slim5, ctime=slim5,
                    avail=np.zeros((n_rep, Tc, 1, 1, 1), bool),
                    A=slim3, C=slim3, w_a=slim3, w_c=slim3,
                )
                if lean:  # the allocator's load tensors, rebuilt on device
                    batch_rt = dataclasses.replace(
                        batch_rt,
                        v=v_w.reshape(n_rep, Tc, Cp, M, -1),
                        u=u_w.reshape(n_rep, Tc, Cp, M, -1),
                    )
            if n_dev <= 1:
                # commit the runner's inputs on the producer side so the
                # dispatch thread never pays the H2D copies for the big
                # class tensors — with prefetch they land here, overlapped
                batch_rt = jax.tree.map(jnp.asarray, batch_rt)
                cnt_rt = jnp.asarray(cnt_rt)
                tq_rt = jnp.asarray(tq_rt)
        return (t0, t1, Tc, batch_rt, us_rt, feas_rt, tq_rt, cnt_rt, infos,
                gb, eb, links_by_k, n_arr)

    window_starts = list(range(0, T, W))
    prod_thread = None
    if prefetch > 0 and len(window_starts) > 0:
        work_q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
        stop_producer = threading.Event()

        def _offer(item) -> bool:
            while not stop_producer.is_set():
                try:
                    work_q.put(item, timeout=0.05)
                    return True
                except queue_mod.Full:
                    continue
            return False

        def _produce():
            try:
                for t0 in window_starts:
                    if not _offer(build_window(t0)):
                        return
            except BaseException as e:  # delivered to the consumer's get()
                _offer(e)

        prod_thread = threading.Thread(
            target=_produce, name="fleet-hier-producer", daemon=True
        )
        prod_thread.start()

    def next_window(t0: int):
        if prod_thread is None:
            return build_window(t0)
        item = work_q.get()
        if isinstance(item, BaseException):
            raise item
        return item

    carry = (jnp.zeros((n_rep, M), jnp.float32), jnp.zeros((n_rep, M), jnp.float32))
    bw_true = spec.bandwidth_true
    try:
        def _post_window(wi, t0, Tc, infos, gb, eb, links_by_k, n_arr, outs):
            """Host-side accounting for one dispatched window.  Called one
            window *behind* the dispatch loop: ``outs`` are still-async
            device futures at enqueue time, and draining them here — after
            the next window's computation has already been issued — keeps
            the device busy while the host deaggregates members."""
            nonlocal phi_sum, phi_cnt, reqs_per_rep
            with sw.span("fleet/hier_post", CAT_METRICS, window=wi):
                (take_a, start_a, pc_a, pe_a, w_a_, c_a, shed_a, ref_a,
                 bg_a, be_a) = jax.tree.map(np.asarray, outs)
                if ccfg.enabled:
                    phi_sum += float(pc_a.sum())
                    phi_cnt += pc_a.size
                reqs_per_rep += n_arr.sum(1)
                for rep in range(n_rep):
                    for k in range(Tc):
                        tf_idx = t0 + k
                        info = infos[rep][k]
                        g_full, e_full = gb[k], eb[k]
                        if metrics:
                            m_acc["n_arrivals"][rep, tf_idx] = n_arr[rep, k]
                            m_acc["n_shed"][rep, tf_idx] = shed_a[rep, k]
                            m_acc["n_refused"][rep, tf_idx] = ref_a[rep, k]
                            with np.errstate(invalid="ignore"):
                                m_acc["util_gamma"][rep, tf_idx] = np.where(
                                    g_full > 0.0,
                                    w_a_[rep, k] / np.maximum(g_full, 1e-9), 0.0,
                                )
                                m_acc["util_eta"][rep, tf_idx] = np.where(
                                    e_full > 0.0,
                                    c_a[rep, k] / np.maximum(e_full, 1e-9), 0.0,
                                )
                            m_acc["backlog_gamma"][rep, tf_idx] = bg_a[rep, k]
                            m_acc["backlog_eta"][rep, tf_idx] = be_a[rep, k]
                        if info is None:
                            continue
                        if metrics:
                            q_all = (info["A"][:, None] >= edges_q).sum(-1)
                            np.add.at(m_acc["qos_count"][rep, tf_idx], q_all, 1)
                        take = take_a[rep, k]
                        ci, jj, ll = np.nonzero(take)
                        if ci.size == 0:
                            continue
                        st = start_a[rep, k][ci, jj, ll]
                        lens = take[ci, jj, ll]
                        tot = int(lens.sum())
                        cellid = np.repeat(np.arange(ci.size), lens)
                        intra = np.arange(tot) - np.repeat(
                            np.cumsum(lens) - lens, lens
                        )
                        base = info["off_s"][ci] + st
                        midx = info["members_s"][base[cellid] + intra]
                        jm = jj[cellid].astype(np.int64)
                        lm = ll[cellid].astype(np.int64)
                        # --- per-member realized accounting: the exact op
                        # sequence of _frame_arrays at the chosen cells, so
                        # every member's channel draw, size, and queueing
                        # delay are its own (not the class mean's)
                        svc_m = info["svc"][midx]
                        cov_m = info["cov"][midx]
                        A_m = info["A"][midx].astype(np.float32)
                        C_m = info["C"][midx].astype(np.float32)
                        Tq_m = info["tq"][midx].astype(np.float32)
                        size_m = info["size"][midx].astype(np.float32)
                        acc_m = spec.acc[svc_m, lm]
                        proc_m = spec.proc_ms[jm, svc_m, lm]
                        local_m = jm == cov_m
                        transfer = size_m / bw_true
                        if links_by_k is not None:  # per-member link draw
                            sc, la = links_by_k[k]
                            transfer = (
                                transfer / np.asarray(sc, np.float64)[cov_m]
                                + np.asarray(la, np.float64)[cov_m]
                            )
                        comm = transfer + np.where(
                            jm >= n_edge, spec.cloud_extra_delay, 0.0
                        )
                        comm = np.where(local_m, 0.0, comm)
                        ct = ((Tq_m + proc_m) + comm).astype(np.float32)
                        if ccfg.enabled:  # congested_ctime, per member
                            pc_k = pc_a[rep, k]
                            pe_k = pe_a[rep, k]
                            comm_f = ct - proc_m - Tq_m
                            ct = (
                                ct
                                + proc_m * (pc_k[jm] - 1.0)
                                + comm_f * (pe_k[cov_m] - 1.0)
                            )
                        sat_m = (acc_m >= A_m) & (ct <= C_m)
                        us_m = (
                            cfg.w_a * (acc_m - A_m) / cfg.max_as
                            + cfg.w_c * (C_m - ct) / cfg.max_cs
                        )
                        served_per_rep[rep] += tot
                        sat_per_rep[rep] += int(sat_m.sum())
                        us_sum_per_rep[rep] += float(us_m.sum())
                        if metrics:
                            m_acc["n_served"][rep, tf_idx] = tot
                            m_acc["n_satisfied"][rep, tf_idx] = int(sat_m.sum())
                            cloud_m = (jm >= n_edge) & ~local_m
                            eo_m = ~local_m & ~cloud_m
                            m_acc["tier_hist"][rep, tf_idx] = (
                                int(local_m.sum()), int(eo_m.sum()),
                                int(cloud_m.sum()),
                            )
                            q_m = (A_m[:, None].astype(np.float64) >= edges_q).sum(-1)
                            np.add.at(
                                m_acc["qos_sat"][rep, tf_idx], q_m,
                                sat_m.astype(np.int64),
                            )
                            m_acc["us_sum"][rep, tf_idx] = float(us_m.sum())

        pending = None
        for wi, wi_t0 in enumerate(window_starts):
            with sw.span("fleet/window_wait", CAT_GEN, window=wi):
                (t0, t1, Tc, batch_rt, us_rt, feas_rt, tq_rt, cnt_rt, infos,
                 gb, eb, links_by_k, n_arr) = next_window(wi_t0)
            with sw.span(
                "fleet/dispatch", CAT_DISPATCH, window=wi
            ), step_annotation("fleet/hier_window", wi):
                carry, outs = run(
                    carry, batch_rt, us_rt, feas_rt, tq_rt, cnt_rt
                )
            if pending is not None:
                _post_window(*pending)
            pending = (wi, t0, Tc, infos, gb, eb, links_by_k, n_arr, outs)
        if pending is not None:
            _post_window(*pending)
    finally:
        if prod_thread is not None:
            stop_producer.set()
            while prod_thread.is_alive():
                try:
                    work_q.get_nowait()
                except queue_mod.Empty:
                    pass
                prod_thread.join(timeout=0.05)
            prod_thread.join()

    final_bg = np.asarray(carry[0])
    # window_wait wraps the inline build (serial) or the producer-queue get
    # (prefetch>0), so it already covers arrivals + aggregation blocking
    gen_s += sw.total("fleet/window_wait")
    timings = sw.as_dict()
    timings["total_s"] = time.perf_counter() - t_run0
    mres = None
    if metrics:
        mres = MetricsResult.from_stacked(
            MetricsFrame(**m_acc),
            t_ms=(np.arange(T) + 1.0) * cfg.frame_ms,
            n_edge=spec.n_edge,
            frame_ms=cfg.frame_ms,
        )
    return FleetResult(
        n_rep=n_rep,
        n_frames=T,
        n_requests=int(reqs_per_rep.sum()),
        n_served=int(served_per_rep.sum()),
        satisfied_per_rep=100.0 * sat_per_rep / np.maximum(reqs_per_rep, 1),
        mean_us_per_rep=us_sum_per_rep / np.maximum(reqs_per_rep, 1),
        final_backlog_per_rep=final_bg if ccfg.enabled else None,
        mean_compute_inflation=(
            phi_sum / phi_cnt if ccfg.enabled and phi_cnt else 1.0
        ),
        n_devices=n_dev,
        window=W,
        dispatch_s=sw.total("fleet/dispatch"),
        gen_s=gen_s,
        prefetch=prefetch if prod_thread is not None else 0,
        timings=timings,
        metrics=mres,
    )


def _simulate_fleet_hier_host(
    spec: ClusterSpec,
    cfg: SimConfig,
    scn: Scenario,
    sources: List[_RepFrameSource],
    *,
    n_rep: int,
    T: int,
    W: int,
    gen_s: float = 0.0,
    engine: Optional[ResilienceEngine] = None,
    metrics: bool = False,
    sw: Optional[Stopwatch] = None,
    t_run0: Optional[float] = None,
) -> FleetResult:
    """The PR-9 per-window *host loop* for the class-aggregate fleet, kept
    as the device pipeline's reference baseline (``REPRO_HIER_HOST_LOOP=1``
    routes here; ``benchmarks/fleet_scale.py`` uses it for the wall-time
    comparison).  Satisfaction is accounted *class-level* from the
    count-weighted representatives, admission control is not evaluated, and
    scheduling runs one frame at a time on the host — the three things
    :func:`_simulate_fleet_hier` fixes.

    Congestion mirrors the scan step in the same order: the scheduler sees
    the backlog-reduced budgets, inflation factors come from committed +
    carried load against the *full* budgets, realized completion times are
    inflated per :func:`repro.core.queueing.congested_ctime`'s formula at
    the chosen cells, and the backlog drains every frame.  Arrivals stream
    window by window (``W`` frames at a time), so long horizons stay
    bounded-memory end to end.
    """
    from .aggregation import AggregateClasses, QuantizationConfig, aggregate_requests, hier_assign

    ccfg = cfg.congestion
    M = spec.n_servers
    n_edge = spec.n_edge
    if sw is None:
        sw = Stopwatch()
    if t_run0 is None:
        t_run0 = time.perf_counter()
    quant = QuantizationConfig()
    edges_q = np.asarray(QOS_ACC_EDGES, np.float64)
    nq = len(QOS_ACC_EDGES) + 1

    reqs_per_rep = np.zeros(n_rep, np.int64)
    served_per_rep = np.zeros(n_rep, np.int64)
    sat_per_rep = np.zeros(n_rep, np.int64)
    us_sum_per_rep = np.zeros(n_rep, np.float64)
    bg = np.zeros((n_rep, M))  # carried compute backlog, f64 like the budgets
    be = np.zeros((n_rep, M))
    phi_sum = 0.0
    phi_cnt = 0
    m_acc: Optional[Dict[str, np.ndarray]] = None
    if metrics:
        m_acc = {
            "n_arrivals": np.zeros((n_rep, T), np.int32),
            "n_served": np.zeros((n_rep, T), np.int32),
            "n_satisfied": np.zeros((n_rep, T), np.int32),
            "n_shed": np.zeros((n_rep, T), np.int32),
            "n_refused": np.zeros((n_rep, T), np.int32),
            "tier_hist": np.zeros((n_rep, T, 3), np.int32),
            "qos_sat": np.zeros((n_rep, T, nq), np.int32),
            "qos_count": np.zeros((n_rep, T, nq), np.int32),
            "util_gamma": np.zeros((n_rep, T, M), np.float32),
            "util_eta": np.zeros((n_rep, T, M), np.float32),
            "backlog_gamma": np.zeros((n_rep, T, M), np.float32),
            "backlog_eta": np.zeros((n_rep, T, M), np.float32),
            "us_sum": np.zeros((n_rep, T), np.float32),
        }

    for t0 in range(0, T, W):
        t1 = min(t0 + W, T)
        Tc = t1 - t0
        with sw.span("fleet/hier_build", CAT_BUILD, t0=t0):
            gb, eb = _frame_budgets_batch(
                spec, cfg, scn, (t0 + np.arange(Tc)) * cfg.frame_ms, engine=engine,
            )
            links = (
                [engine.link_frame(t0 + k) for k in range(Tc)]
                if engine is not None else None
            )
        for rep, src in enumerate(sources):
            with sw.span("fleet/arrivals", CAT_GEN, t0=t0, rep=rep):
                buckets = src.take(t1)
            for k, bucket in enumerate(buckets):
                tf = t0 + k
                n = len(bucket)
                reqs_per_rep[rep] += n
                frame_end = (tf + 1) * cfg.frame_ms
                g_full, e_full = gb[k], eb[k]
                w_load = np.zeros(M)
                c_load = np.zeros(M)
                chunks = np.zeros((0, 4), np.int64)
                if n:
                    if isinstance(bucket, RequestColumns):
                        cov, svc = bucket.cover, bucket.service
                        A_r, C_r = bucket.A, bucket.C
                        size = bucket.size_bytes
                        arr_ms = bucket.arrival_ms
                    else:
                        cov = np.array([r.cover for r in bucket], np.int64)
                        svc = np.array([r.service for r in bucket], np.int64)
                        A_r = np.array([r.A for r in bucket], np.float64)
                        C_r = np.array([r.C for r in bucket], np.float64)
                        size = np.array([r.size_bytes for r in bucket], np.float64)
                        arr_ms = np.array([r.arrival_ms for r in bucket], np.float64)
                    with sw.span("fleet/hier_aggregate", CAT_BUILD, frame=tf):
                        tq = frame_end - np.asarray(arr_ms, np.float64)
                        count, first_idx, members, offsets, repc = (
                            aggregate_requests(cov, svc, A_r, C_r, size, tq, quant)
                        )
                        rc = RequestColumns(
                            arrival_ms=frame_end - repc["tq"],
                            cover=repc["cover"],
                            service=repc["service"],
                            A=repc["A"],
                            C=repc["C"],
                            size_bytes=repc["size"],
                        )
                        link = None
                        if links is not None:
                            sc, la = links[k]
                            link = (sc[repc["cover"]], la[repc["cover"]])
                        if ccfg.enabled:  # scheduler sees effective capacity
                            g_sched = np.maximum(g_full - bg[rep], 0.0)
                            e_sched = np.maximum(e_full - be[rep], 0.0)
                        else:
                            g_sched, e_sched = g_full, e_full
                        cls_inst = _build_frame_instance(
                            rc, spec, cfg, frame_end, spec.bandwidth_true,
                            cfg.max_cs, gamma=g_sched, eta=e_sched, link=link,
                        )
                        agg = AggregateClasses(
                            count=count, first_idx=first_idx, members=members,
                            offsets=offsets, cover=repc["cover"],
                            us=np.asarray(us_tensor(cls_inst)),
                            feas=np.asarray(hard_feasible(cls_inst)),
                            v=np.asarray(cls_inst.v),
                            u=np.asarray(cls_inst.u),
                        )
                    with sw.span("fleet/schedule_hier", CAT_SCHED, frame=tf):
                        chunks = hier_assign(agg, g_sched, e_sched, exact=False)
                    if len(chunks):
                        cc, jj, ll, take = (chunks[:, i] for i in range(4))
                        vv = agg.v[cc, jj, ll].astype(np.float64)
                        uu = agg.u[cc, jj, ll].astype(np.float64)
                        np.add.at(w_load, jj, take * vv)
                        off_m = jj != agg.cover[cc]
                        if off_m.any():
                            np.add.at(
                                c_load, agg.cover[cc][off_m], (take * uu)[off_m]
                            )
                # inflation from committed + carried load vs the FULL budgets
                # (the scan's order: schedule, commit, inflate, drain)
                if ccfg.enabled:
                    phi_c = np.asarray(
                        compute_inflation(bg[rep] + w_load, g_full, ccfg), np.float64
                    )
                    phi_e = np.asarray(
                        comm_inflation(be[rep] + c_load, e_full, ccfg), np.float64
                    )
                    phi_sum += float(phi_c.sum())
                    phi_cnt += M
                if len(chunks):
                    ct = np.asarray(cls_inst.ctime, np.float64)[cc, jj, ll]
                    acc_c = np.asarray(cls_inst.acc, np.float64)[cc, jj, ll]
                    if ccfg.enabled:  # congested_ctime's formula, class-level
                        comm = ct - vv - repc["tq"][cc]
                        ct = (
                            ct
                            + vv * (phi_c[jj] - 1.0)
                            + comm * (phi_e[agg.cover[cc]] - 1.0)
                        )
                    A_c = repc["A"][cc]
                    C_c = repc["C"][cc]
                    sat_c = (acc_c >= A_c) & (ct <= C_c)
                    us_c = (
                        cfg.w_a * (acc_c - A_c) / cfg.max_as
                        + cfg.w_c * (C_c - ct) / cfg.max_cs
                    )
                    served_per_rep[rep] += int(take.sum())
                    sat_per_rep[rep] += int((take * sat_c).sum())
                    us_sum_per_rep[rep] += float((take * us_c).sum())
                if ccfg.enabled:  # backlog conservation: see step_backlog
                    bg[rep] = np.maximum(
                        bg[rep] + w_load - g_full * ccfg.drain, 0.0
                    )
                    be[rep] = np.maximum(
                        be[rep] + c_load - e_full * ccfg.drain, 0.0
                    )
                if metrics:
                    m_acc["n_arrivals"][rep, tf] = n
                    if n:
                        cls_q = (repc["A"][:, None] >= edges_q).sum(-1)
                        np.add.at(m_acc["qos_count"][rep, tf], cls_q, count)
                    if len(chunks):
                        m_acc["n_served"][rep, tf] = int(take.sum())
                        m_acc["n_satisfied"][rep, tf] = int((take * sat_c).sum())
                        local_m = jj == agg.cover[cc]
                        cloud_m = (jj >= n_edge) & ~local_m
                        eo_m = ~local_m & ~cloud_m
                        m_acc["tier_hist"][rep, tf] = (
                            int(take[local_m].sum()),
                            int(take[eo_m].sum()),
                            int(take[cloud_m].sum()),
                        )
                        np.add.at(
                            m_acc["qos_sat"][rep, tf], cls_q[cc],
                            (take * sat_c).astype(np.int64),
                        )
                        m_acc["us_sum"][rep, tf] = float((take * us_c).sum())
                    with np.errstate(invalid="ignore"):
                        m_acc["util_gamma"][rep, tf] = np.where(
                            g_full > 0.0, w_load / np.maximum(g_full, 1e-9), 0.0
                        )
                        m_acc["util_eta"][rep, tf] = np.where(
                            e_full > 0.0, c_load / np.maximum(e_full, 1e-9), 0.0
                        )
                    m_acc["backlog_gamma"][rep, tf] = bg[rep]
                    m_acc["backlog_eta"][rep, tf] = be[rep]

    gen_s += sw.total("fleet/arrivals")
    timings = sw.as_dict()
    timings["total_s"] = time.perf_counter() - t_run0
    mres = None
    if metrics:
        mres = MetricsResult.from_stacked(
            MetricsFrame(**m_acc),
            t_ms=(np.arange(T) + 1.0) * cfg.frame_ms,
            n_edge=spec.n_edge,
            frame_ms=cfg.frame_ms,
        )
    return FleetResult(
        n_rep=n_rep,
        n_frames=T,
        n_requests=int(reqs_per_rep.sum()),
        n_served=int(served_per_rep.sum()),
        satisfied_per_rep=100.0 * sat_per_rep / np.maximum(reqs_per_rep, 1),
        mean_us_per_rep=us_sum_per_rep / np.maximum(reqs_per_rep, 1),
        final_backlog_per_rep=bg.astype(np.float32) if ccfg.enabled else None,
        mean_compute_inflation=(
            phi_sum / phi_cnt if ccfg.enabled and phi_cnt else 1.0
        ),
        n_devices=1,
        window=W,
        dispatch_s=sw.total("fleet/schedule_hier"),
        gen_s=gen_s,
        timings=timings,
        metrics=mres,
    )


def demo_cluster_spec(
    n_edge: int = 4,
    n_cloud: int = 1,
    n_services: int = 3,
    n_variants: int = 3,
    seed: int = 0,
) -> ClusterSpec:
    """A small heterogeneous cluster for examples, sweeps and smoke tests.

    Edges run the cheaper variants of every service at ~1 s latencies (the
    paper's RPi-class boxes); the cloud runs everything ~4x faster but costs
    a backhaul hop.  Deterministic given ``seed``.
    """
    rng = np.random.default_rng(seed)
    M = n_edge + n_cloud
    K, L = n_services, n_variants

    rel = np.geomspace(0.3, 1.0, L)                    # variant cost ladder
    acc = np.linspace(55.0, 85.0, L)[None, :] + rng.normal(0.0, 1.5, (K, L))
    acc = np.clip(np.sort(acc, axis=1), 1.0, 99.0).astype(np.float32)

    proc = np.empty((M, K, L), np.float32)
    placed = np.zeros((M, K, L), bool)
    for j in range(M):
        is_cloud = j >= n_edge
        base = 300.0 if is_cloud else rng.uniform(900.0, 1400.0)
        proc[j] = base * rel[None, :] * rng.uniform(0.95, 1.05, (K, L))
        placed[j] = True
        if not is_cloud and L > 1:
            placed[j, :, L - 1] = False  # biggest variant is cloud-only

    gamma = np.where(np.arange(M) >= n_edge, 12_000.0, 3900.0).astype(np.float32)
    eta = np.where(np.arange(M) >= n_edge, 3500.0, 350.0).astype(np.float32)
    return ClusterSpec(
        n_edge=n_edge,
        n_cloud=n_cloud,
        gamma_frame=gamma,
        eta_frame=eta,
        proc_ms=proc,
        placed=placed,
        acc=acc,
    )
