"""Hierarchical class-aggregate scheduling (the ``10^5+`` users-per-frame path).

The paper's GUS walks every request over the dense ``N x M x L`` grid, which
caps frames in the low thousands of requests.  But the QoS space is tiny:
requests differ only in (covering edge, service, accuracy floor ``A``,
deadline ``C``, payload size, queueing age ``Tq``), and with discrete QoS
tiers most of those axes collapse.  This module buckets requests into
**QoS classes** and schedules the class *aggregates* — a grid of
``n_classes x M x L`` with per-class member counts — then maps class-level
allocations back to individual requests.

The scheduler is two-level:

1. **Per-edge local pass** — embarrassingly parallel over covering edges:
   requests are bucketed into classes, each class's utility / feasibility /
   cost rows are built once from a representative member, and classes with
   no feasible candidate anywhere are retired immediately.  Nothing in this
   pass touches shared state.
2. **Global cloud-contention pass** — the per-edge class tables are merged
   in first-request-index order and a single sequential greedy allocates
   *chunks* (class, server j, variant l, count) against the shared capacity
   vectors, reconciling cross-edge contention for cloud compute, remote
   edge compute, and each edge's uplink ``eta``.  This is the only
   sequential step, and it runs over ``n_classes`` rows instead of ``N``.
3. **De-aggregation** — chunks are mapped back to per-request assignments
   by consuming each class's members in ascending request index, so the
   result is deterministic and reproducible regardless of how requests were
   grouped.

Parity with dense GUS
---------------------
In ``exact=True`` mode the chunk allocator emulates the NumPy oracle's
float32 sequential capacity subtraction member by member, re-checking only
the chosen cell (capacity is monotone decreasing, so the feasible-argmax of
a class of identical rows can only move when the chosen cell dies — at
which point the full argmax is recomputed).  Consequences, pinned by
``tests/test_aggregation.py``:

* with lossless keys (``decimals=None``) every class groups bit-identical
  rows; on frames where classes are index-contiguous (in particular on any
  frame where all classes are singletons, i.e. every real scenario frame)
  the assignment is **bit-identical** to :func:`repro.core.gus.gus_schedule_np`;
* with quantized keys the representative row stands in for near-identical
  members, trading exactness for aggregation — the satisfaction gap vs
  dense GUS stays within the paper-scale tolerance asserted in tests.

The fleet's ``scheduler="hierarchical"`` path (``simulator.py``) reuses
:func:`aggregate_requests` / :func:`hier_assign` / :func:`deaggregate` but
builds only the class-level tensors, never the dense ``N x M x L`` grid —
that is what bounds memory at ``10^5+`` users per frame.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .gus import Assignment
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = [
    "AggregateClasses",
    "QuantizationConfig",
    "aggregate_instance",
    "aggregate_requests",
    "hier_assign",
    "deaggregate",
    "hier_schedule_np",
    "make_gus_hier",
]


@dataclasses.dataclass(frozen=True)
class QuantizationConfig:
    """How request attributes are bucketed into QoS classes (fleet path).

    ``acc_decimals`` / ``deadline_decimals`` round the accuracy floor and
    deadline with :func:`numpy.round` (negative = coarser than integer), so
    discrete QoS tiers collapse losslessly.  ``size_bins`` / ``tq_bins``
    are equal-width bins over each frame's observed payload-size and
    queueing-age ranges.
    """

    acc_decimals: int = 0
    deadline_decimals: int = -2
    size_bins: int = 8
    tq_bins: int = 4


@dataclasses.dataclass(frozen=True)
class AggregateClasses:
    """Class-aggregate view of one frame: grouping plus per-class rows.

    ``members`` lists request indices grouped by class and ascending within
    each class; class ``c`` owns ``members[offsets[c]:offsets[c + 1]]``.
    ``us`` / ``feas`` / ``v`` / ``u`` are the representative rows on the
    ``(n_classes, M, L)`` candidate grid.
    """

    count: np.ndarray      # (n_c,) int64 member counts
    first_idx: np.ndarray  # (n_c,) int64 lowest member request index
    members: np.ndarray    # (N,)  int64 request indices, class-grouped
    offsets: np.ndarray    # (n_c + 1,) int64 slice bounds into ``members``
    cover: np.ndarray      # (n_c,) int64 covering edge
    us: np.ndarray         # (n_c, M, L) f32 utility of the representative
    feas: np.ndarray       # (n_c, M, L) bool hard feasibility
    v: np.ndarray          # (n_c, M, L) f32 compute cost
    u: np.ndarray          # (n_c, M, L) f32 comm cost

    @property
    def n_classes(self) -> int:
        return self.count.shape[0]


def _group(inv: np.ndarray, n_classes: int):
    """Grouping arrays from a class-id-per-request vector."""
    n = inv.shape[0]
    count = np.bincount(inv, minlength=n_classes).astype(np.int64)
    first_idx = np.full(n_classes, n, np.int64)
    np.minimum.at(first_idx, inv, np.arange(n, dtype=np.int64))
    members = np.argsort(inv, kind="stable").astype(np.int64)
    offsets = np.concatenate([[0], np.cumsum(count)]).astype(np.int64)
    return count, first_idx, members, offsets


def aggregate_instance(
    inst: FlatInstance, decimals: Optional[int] = None
) -> AggregateClasses:
    """Bucket a dense :class:`FlatInstance`'s rows into QoS classes.

    This is the per-edge local pass for the drop-in ``gus-hier`` policy: it
    operates on an instance the engine has already built, so rows are
    grouped directly by their candidate-grid content — two requests share a
    class iff their scheduling problem is identical: same covering edge,
    same QoS (``A``, ``C``, weights) and the same ``ctime``/``v``/``u``/
    ``acc``/``avail`` rows.  ``decimals=None`` keys on exact values
    (lossless classes); an integer rounds ``ctime`` and ``u`` first, merging
    near-identical requests (e.g. same tier, payloads within a bin).

    The representative of each class is its lowest-index member, whose
    *unrounded* rows feed utility and feasibility.
    """
    A = np.asarray(inst.A)
    N = A.shape[0]
    ct = np.asarray(inst.ctime, dtype=np.float64)
    uu = np.asarray(inst.u, dtype=np.float64)
    if decimals is not None:
        ct = np.round(ct, decimals)
        uu = np.round(uu, decimals)
    mat = np.concatenate(
        [
            np.asarray(inst.cover, dtype=np.float64)[:, None],
            A.astype(np.float64)[:, None],
            np.asarray(inst.C, dtype=np.float64)[:, None],
            np.asarray(inst.w_a, dtype=np.float64)[:, None],
            np.asarray(inst.w_c, dtype=np.float64)[:, None],
            ct.reshape(N, -1),
            uu.reshape(N, -1),
            np.asarray(inst.v, dtype=np.float64).reshape(N, -1),
            np.asarray(inst.acc, dtype=np.float64).reshape(N, -1),
            np.asarray(inst.avail).astype(np.float64).reshape(N, -1),
        ],
        axis=1,
    )
    _, inv = np.unique(mat, axis=0, return_inverse=True)
    inv = inv.reshape(-1)
    count, first_idx, members, offsets = _group(inv, int(inv.max()) + 1 if N else 0)

    # representative rows: utilities/feasibility via the same code the dense
    # schedulers use, gathered at each class's first member (bit-identical
    # to the corresponding rows of the full us/feas tensors).
    rep = first_idx
    us = np.asarray(us_tensor(inst))[rep]
    feas = np.asarray(hard_feasible(inst))[rep]
    return AggregateClasses(
        count=count,
        first_idx=first_idx,
        members=members,
        offsets=offsets,
        cover=np.asarray(inst.cover)[rep].astype(np.int64),
        us=us,
        feas=feas,
        v=np.asarray(inst.v)[rep],
        u=np.asarray(inst.u)[rep],
    )


def aggregate_requests(
    cover: np.ndarray,
    service: np.ndarray,
    A: np.ndarray,
    C: np.ndarray,
    size: np.ndarray,
    tq: np.ndarray,
    quant: Optional[QuantizationConfig] = None,
):
    """Bucket raw request columns into QoS classes (fleet path, no grid).

    Classes key on (covering edge, service, rounded ``A``, rounded ``C``,
    payload-size bin, queueing-age bin) per ``quant``.  Returns the
    grouping arrays plus *count-weighted mean* representative columns —
    ``(count, first_idx, members, offsets, rep)`` where ``rep`` is a dict
    of per-class ``cover``/``service`` (exact) and ``A``/``C``/``size``/
    ``tq`` (means).  The caller builds the ``(n_classes, M, L)`` candidate
    grid from ``rep`` — dense per-request tensors are never materialized.
    """
    quant = quant or QuantizationConfig()
    n = cover.shape[0]
    if n == 0:
        empty = np.zeros(0, np.int64)
        rep = dict(
            cover=empty,
            service=empty,
            A=np.zeros(0),
            C=np.zeros(0),
            size=np.zeros(0),
            tq=np.zeros(0),
        )
        return empty, empty, empty, np.zeros(1, np.int64), rep

    def _bin(x, bins):
        lo, hi = float(np.min(x)), float(np.max(x))
        if hi <= lo:
            return np.zeros(n, np.int64)
        edges = (x - lo) * (bins / (hi - lo))
        return np.clip(edges.astype(np.int64), 0, bins - 1)

    key = np.column_stack(
        [
            cover.astype(np.int64),
            service.astype(np.int64),
            np.round(A * 10.0 ** quant.acc_decimals).astype(np.int64),
            np.round(C * 10.0 ** quant.deadline_decimals).astype(np.int64),
            _bin(np.asarray(size, np.float64), quant.size_bins),
            _bin(np.asarray(tq, np.float64), quant.tq_bins),
        ]
    )
    _, inv = np.unique(key, axis=0, return_inverse=True)
    inv = inv.reshape(-1)
    n_c = int(inv.max()) + 1
    count, first_idx, members, offsets = _group(inv, n_c)

    fcount = count.astype(np.float64)

    def _mean(x):
        return np.bincount(inv, weights=np.asarray(x, np.float64), minlength=n_c) / fcount

    rep = dict(
        cover=cover.astype(np.int64)[first_idx],
        service=service.astype(np.int64)[first_idx],
        A=_mean(A),
        C=_mean(C),
        size=_mean(size),
        tq=_mean(tq),
    )
    return count, first_idx, members, offsets, rep


#: mirrors ``repro.core.gus.NEG`` — scores below this are "infeasible"
_NEG = -1e30


def hier_assign(
    agg: AggregateClasses,
    gamma: np.ndarray,
    eta: np.ndarray,
    *,
    exact: bool = False,
) -> np.ndarray:
    """Global cloud-contention pass: chunked greedy over class aggregates.

    Merges the per-edge class tables in first-request-index order (the same
    order dense GUS visits their members) and allocates each class in
    chunks: pick the feasible utility-argmax cell (first occurrence on the
    flat ``j * L + l`` axis — GUS's tie-break), fit as many members as the
    shared ``gamma``/``eta`` capacities allow, commit, and re-pick until
    the class is exhausted or nothing fits.  Local cells charge only the
    server's ``gamma``; offload cells also charge the covering edge's
    ``eta`` — the cross-edge coupling this pass exists to reconcile.

    ``exact=True`` consumes members one at a time with float32 capacity
    subtraction, reproducing :func:`repro.core.gus.gus_schedule_np`'s
    arithmetic bit for bit; ``exact=False`` sizes chunks analytically in
    float64 (the fleet path — one division instead of ``count`` updates).

    Returns an ``(n_chunks, 4)`` int64 array of ``(class, j, l, take)`` in
    allocation order.
    """
    dtype = np.float32 if exact else np.float64
    gamma = np.asarray(gamma, dtype).copy()
    eta = np.asarray(eta, dtype).copy()
    if agg.n_classes == 0:
        return np.zeros((0, 4), np.int64)
    M = gamma.shape[0]
    L = agg.us.shape[-1]
    server = np.arange(M)

    # pass-1 screening: classes infeasible everywhere never enter the queue
    alive = agg.feas.any(axis=(1, 2))
    order = np.argsort(agg.first_idx, kind="stable")
    order = order[alive[order]]

    chunks = []
    for c in order:
        rem = int(agg.count[c])
        s = int(agg.cover[c])
        row_us = agg.us[c]
        row_v = np.asarray(agg.v[c], dtype)
        row_u = np.asarray(agg.u[c], dtype)
        local = (server == s)[:, None]
        feas = agg.feas[c]
        while rem > 0:
            ok = feas & (row_v <= gamma[:, None]) & (local | (row_u <= eta[s]))
            if not ok.any():
                break
            flat = int(np.argmax(np.where(ok, row_us, _NEG)))
            j, l = divmod(flat, L)
            vv = row_v[j, l]
            uv = row_u[j, l]
            if exact:
                take = 0
                while take < rem:
                    if vv > gamma[j] or (j != s and uv > eta[s]):
                        break
                    gamma[j] -= vv
                    if j != s:
                        eta[s] -= uv
                    take += 1
            else:
                take = rem
                if vv > 0:
                    take = min(take, int(gamma[j] // vv))
                if j != s and uv > 0:
                    take = min(take, int(eta[s] // uv))
                gamma[j] -= take * vv
                if j != s:
                    eta[s] -= take * uv
            if take <= 0:
                break  # float edge: argmax cell passed ``ok`` but fits zero
            chunks.append((int(c), j, l, take))
            rem -= take
    if not chunks:
        return np.zeros((0, 4), np.int64)
    return np.asarray(chunks, np.int64)


def deaggregate(agg: AggregateClasses, chunks: np.ndarray, n_requests: int):
    """Map class-level chunks back to per-request ``(j, l)`` assignments.

    Each chunk consumes its class's members in ascending request index —
    the deterministic tie-break that makes hierarchical results reproducible
    and, on lossless classes, identical to dense GUS.  Unallocated members
    stay dropped (``-1``).
    """
    out_j = np.full(n_requests, -1, np.int32)
    out_l = np.full(n_requests, -1, np.int32)
    ptr = agg.offsets[:-1].copy()
    for c, j, l, take in chunks:
        sel = agg.members[ptr[c] : ptr[c] + take]
        out_j[sel] = j
        out_l[sel] = l
        ptr[c] += take
    return out_j, out_l


def make_gus_hier(decimals: Optional[int] = None):
    """A drop-in scheduler callable running GUS over class aggregates.

    ``decimals=None`` (the registered ``gus-hier`` default) keys classes on
    exact row content and allocates in exact mode — bit-parity with dense
    GUS on every frame whose classes are index-contiguous, which includes
    all frames with singleton classes.  Pass ``decimals`` to merge
    near-identical requests (lossy, bounded satisfaction drift).
    """

    def schedule(inst: FlatInstance) -> Assignment:
        n = int(np.asarray(inst.A).shape[0])
        if n == 0:
            z = jnp.zeros(0, jnp.int32)
            return Assignment(z, z)
        agg = aggregate_instance(inst, decimals=decimals)
        chunks = hier_assign(
            agg, np.asarray(inst.gamma), np.asarray(inst.eta), exact=True
        )
        out_j, out_l = deaggregate(agg, chunks, n)
        return Assignment(jnp.asarray(out_j), jnp.asarray(out_l))

    return schedule


def hier_schedule_np(inst: FlatInstance) -> Assignment:
    """Module-level exact-mode entry point (see :func:`make_gus_hier`)."""
    return make_gus_hier()(inst)
