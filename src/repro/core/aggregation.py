"""Hierarchical class-aggregate scheduling (the ``10^5+`` users-per-frame path).

The paper's GUS walks every request over the dense ``N x M x L`` grid, which
caps frames in the low thousands of requests.  But the QoS space is tiny:
requests differ only in (covering edge, service, accuracy floor ``A``,
deadline ``C``, payload size, queueing age ``Tq``), and with discrete QoS
tiers most of those axes collapse.  This module buckets requests into
**QoS classes** and schedules the class *aggregates* — a grid of
``n_classes x M x L`` with per-class member counts — then maps class-level
allocations back to individual requests.

The scheduler is two-level:

1. **Per-edge local pass** — embarrassingly parallel over covering edges:
   requests are bucketed into classes, each class's utility / feasibility /
   cost rows are built once from a representative member, and classes with
   no feasible candidate anywhere are retired immediately.  Nothing in this
   pass touches shared state.
2. **Global cloud-contention pass** — the per-edge class tables are merged
   in first-request-index order and a single sequential greedy allocates
   *chunks* (class, server j, variant l, count) against the shared capacity
   vectors, reconciling cross-edge contention for cloud compute, remote
   edge compute, and each edge's uplink ``eta``.  This is the only
   sequential step, and it runs over ``n_classes`` rows instead of ``N``.
3. **De-aggregation** — chunks are mapped back to per-request assignments
   by consuming each class's members in ascending request index, so the
   result is deterministic and reproducible regardless of how requests were
   grouped.

Parity with dense GUS
---------------------
In ``exact=True`` mode the chunk allocator emulates the NumPy oracle's
float32 sequential capacity subtraction member by member, re-checking only
the chosen cell (capacity is monotone decreasing, so the feasible-argmax of
a class of identical rows can only move when the chosen cell dies — at
which point the full argmax is recomputed).  Consequences, pinned by
``tests/test_aggregation.py``:

* with lossless keys (``decimals=None``) every class groups bit-identical
  rows; on frames where classes are index-contiguous (in particular on any
  frame where all classes are singletons, i.e. every real scenario frame)
  the assignment is **bit-identical** to :func:`repro.core.gus.gus_schedule_np`;
* with quantized keys the representative row stands in for near-identical
  members, trading exactness for aggregation — the satisfaction gap vs
  dense GUS stays within the paper-scale tolerance asserted in tests.

The fleet's ``scheduler="hierarchical"`` path (``simulator.py``) reuses
:func:`aggregate_requests` / :func:`hier_assign` / :func:`deaggregate` but
builds only the class-level tensors, never the dense ``N x M x L`` grid —
that is what bounds memory at ``10^5+`` users per frame.

Device backends
---------------
The fleet's analytic allocation also exists as a jitted XLA program
(:func:`hier_cells`, ``backend="xla"``) and a fused Pallas kernel
(:mod:`repro.kernels.hier_pallas`, ``backend="pallas"``), dispatched
through the same ``backend=`` / ``REPRO_GUS_BACKEND`` switch as the dense
GUS implementations.  All three speak a fixed-shape *cell* contract
instead of a variable-length chunk list: for classes *pre-sorted by first
request index*, ``(take, start)`` are ``(C, M, L)`` int32 tensors where
``take[c, j, l]`` members of class ``c`` run variant ``l`` on server ``j``
and ``start[c, j, l]`` is their offset into the class's (ascending)
member list.  Consecutive re-picks of one cell accumulate, so member
ranges stay contiguous and :func:`deaggregate` semantics are preserved.
The chunk sizing is float32 with one explicit IEEE op sequence —
``floor(budget / cost)``, ``min`` against the remainder, ``budget -
take * cost`` — shared verbatim by the NumPy oracle
(:func:`hier_cells_np`), the XLA scan and the Pallas kernel, which is
what makes three-way bit-parity (``tests/test_hier_parity.py``)
well-defined with jax's default float32 everywhere.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .gus import Assignment, resolve_gus_backend
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = [
    "AggregateClasses",
    "QuantizationConfig",
    "aggregate_instance",
    "aggregate_requests",
    "class_keys",
    "hier_assign",
    "hier_cells_np",
    "hier_cells",
    "hier_backend_fn",
    "deaggregate",
    "hier_schedule_np",
    "make_gus_hier",
]


@dataclasses.dataclass(frozen=True)
class QuantizationConfig:
    """How request attributes are bucketed into QoS classes (fleet path).

    ``acc_decimals`` / ``deadline_decimals`` round the accuracy floor and
    deadline with :func:`numpy.round` (negative = coarser than integer), so
    discrete QoS tiers collapse losslessly.  ``size_bin_bytes`` /
    ``tq_bin_ms`` are *anchored* absolute-width bins
    (``floor(x / width)``): a request's class key depends only on its own
    attributes, never on which other requests share the frame.  The earlier
    observed-min/max equal-width bins made keys a function of the frame's
    extremes, so the same trace produced different classes under
    ``rng_mode="vectorized"`` vs object mode (different float roundtrips)
    and under different window chunkings — the instability pinned down by
    ``test_class_keys_chunk_invariant``.  The defaults keep the old
    granularity on the default generator: 12.5 kB over the 20–120 kB
    payload range ≈ the old 8 bins, 750 ms over a frame ≈ the old 4 bins.
    """

    acc_decimals: int = 0
    deadline_decimals: int = -2
    size_bin_bytes: float = 12_500.0
    tq_bin_ms: float = 750.0


@dataclasses.dataclass(frozen=True)
class AggregateClasses:
    """Class-aggregate view of one frame: grouping plus per-class rows.

    ``members`` lists request indices grouped by class and ascending within
    each class; class ``c`` owns ``members[offsets[c]:offsets[c + 1]]``.
    ``us`` / ``feas`` / ``v`` / ``u`` are the representative rows on the
    ``(n_classes, M, L)`` candidate grid.
    """

    count: np.ndarray      # (n_c,) int64 member counts
    first_idx: np.ndarray  # (n_c,) int64 lowest member request index
    members: np.ndarray    # (N,)  int64 request indices, class-grouped
    offsets: np.ndarray    # (n_c + 1,) int64 slice bounds into ``members``
    cover: np.ndarray      # (n_c,) int64 covering edge
    us: np.ndarray         # (n_c, M, L) f32 utility of the representative
    feas: np.ndarray       # (n_c, M, L) bool hard feasibility
    v: np.ndarray          # (n_c, M, L) f32 compute cost
    u: np.ndarray          # (n_c, M, L) f32 comm cost

    @property
    def n_classes(self) -> int:
        return self.count.shape[0]


def _group(inv: np.ndarray, n_classes: int):
    """Grouping arrays from a class-id-per-request vector."""
    n = inv.shape[0]
    count = np.bincount(inv, minlength=n_classes).astype(np.int64)
    first_idx = np.full(n_classes, n, np.int64)
    np.minimum.at(first_idx, inv, np.arange(n, dtype=np.int64))
    members = np.argsort(inv, kind="stable").astype(np.int64)
    offsets = np.concatenate([[0], np.cumsum(count)]).astype(np.int64)
    return count, first_idx, members, offsets


def aggregate_instance(
    inst: FlatInstance, decimals: Optional[int] = None
) -> AggregateClasses:
    """Bucket a dense :class:`FlatInstance`'s rows into QoS classes.

    This is the per-edge local pass for the drop-in ``gus-hier`` policy: it
    operates on an instance the engine has already built, so rows are
    grouped directly by their candidate-grid content — two requests share a
    class iff their scheduling problem is identical: same covering edge,
    same QoS (``A``, ``C``, weights) and the same ``ctime``/``v``/``u``/
    ``acc``/``avail`` rows.  ``decimals=None`` keys on exact values
    (lossless classes); an integer rounds ``ctime`` and ``u`` first, merging
    near-identical requests (e.g. same tier, payloads within a bin).

    The representative of each class is its lowest-index member, whose
    *unrounded* rows feed utility and feasibility.
    """
    A = np.asarray(inst.A)
    N = A.shape[0]
    ct = np.asarray(inst.ctime, dtype=np.float64)
    uu = np.asarray(inst.u, dtype=np.float64)
    if decimals is not None:
        ct = np.round(ct, decimals)
        uu = np.round(uu, decimals)
    mat = np.concatenate(
        [
            np.asarray(inst.cover, dtype=np.float64)[:, None],
            A.astype(np.float64)[:, None],
            np.asarray(inst.C, dtype=np.float64)[:, None],
            np.asarray(inst.w_a, dtype=np.float64)[:, None],
            np.asarray(inst.w_c, dtype=np.float64)[:, None],
            ct.reshape(N, -1),
            uu.reshape(N, -1),
            np.asarray(inst.v, dtype=np.float64).reshape(N, -1),
            np.asarray(inst.acc, dtype=np.float64).reshape(N, -1),
            np.asarray(inst.avail).astype(np.float64).reshape(N, -1),
        ],
        axis=1,
    )
    _, inv = np.unique(mat, axis=0, return_inverse=True)
    inv = inv.reshape(-1)
    count, first_idx, members, offsets = _group(inv, int(inv.max()) + 1 if N else 0)

    # representative rows: utilities/feasibility via the same code the dense
    # schedulers use, gathered at each class's first member (bit-identical
    # to the corresponding rows of the full us/feas tensors).
    rep = first_idx
    us = np.asarray(us_tensor(inst))[rep]
    feas = np.asarray(hard_feasible(inst))[rep]
    return AggregateClasses(
        count=count,
        first_idx=first_idx,
        members=members,
        offsets=offsets,
        cover=np.asarray(inst.cover)[rep].astype(np.int64),
        us=us,
        feas=feas,
        v=np.asarray(inst.v)[rep],
        u=np.asarray(inst.u)[rep],
    )


def class_keys(
    cover: np.ndarray,
    service: np.ndarray,
    A: np.ndarray,
    C: np.ndarray,
    size: np.ndarray,
    tq: np.ndarray,
    quant: Optional[QuantizationConfig] = None,
) -> np.ndarray:
    """(n, 6) int64 class keys: (cover, service, rounded A, rounded C,
    payload-size bin, queueing-age bin).

    Every column is a pure per-request function — anchored ``floor(x /
    width)`` bins, no frame-level statistics — so the key assigned to a
    request is invariant to chunking, windowing, and the arrival
    generator's rng mode.  Exposed so tests (and downstream tooling) can
    assert that invariance directly.
    """
    quant = quant or QuantizationConfig()
    return np.column_stack(
        [
            np.asarray(cover).astype(np.int64),
            np.asarray(service).astype(np.int64),
            np.round(
                np.asarray(A, np.float64) * 10.0 ** quant.acc_decimals
            ).astype(np.int64),
            np.round(
                np.asarray(C, np.float64) * 10.0 ** quant.deadline_decimals
            ).astype(np.int64),
            np.floor(
                np.asarray(size, np.float64) / quant.size_bin_bytes
            ).astype(np.int64),
            np.floor(
                np.asarray(tq, np.float64) / quant.tq_bin_ms
            ).astype(np.int64),
        ]
    )


def _unique_inverse_rows(key: np.ndarray) -> np.ndarray:
    """Inverse indices of ``np.unique(key, axis=0)`` via mixed-radix packing.

    Shifting each column to zero and packing most-significant-first keeps
    the scalar order identical to lexicographic row order, so the inverse
    (and therefore every downstream class index) is bit-identical to the
    ``axis=0`` path — just without the void-dtype row sort, which dominates
    aggregation time at 10^5 requests/frame.  Falls back to ``axis=0`` when
    the packed radix would overflow int64 (pathological key ranges).
    """
    lo = key.min(axis=0)
    k = key - lo
    span = k.max(axis=0).astype(object) + 1
    radix = 1
    for s in span:
        radix *= int(s)
    if radix >= np.iinfo(np.int64).max:
        _, inv = np.unique(key, axis=0, return_inverse=True)
        return inv.reshape(-1)
    packed = k[:, 0]
    for c in range(1, key.shape[1]):
        packed = packed * int(span[c]) + k[:, c]
    _, inv = np.unique(packed, return_inverse=True)
    return inv


def aggregate_requests(
    cover: np.ndarray,
    service: np.ndarray,
    A: np.ndarray,
    C: np.ndarray,
    size: np.ndarray,
    tq: np.ndarray,
    quant: Optional[QuantizationConfig] = None,
):
    """Bucket raw request columns into QoS classes (fleet path, no grid).

    Classes key on :func:`class_keys` (covering edge, service, rounded
    ``A``, rounded ``C``, anchored payload-size bin, anchored queueing-age
    bin) per ``quant``.  Returns the grouping arrays plus *count-weighted
    mean* representative columns — ``(count, first_idx, members, offsets,
    rep)`` where ``rep`` is a dict of per-class ``cover``/``service``
    (exact) and ``A``/``C``/``size``/``tq`` (means).  The caller builds the
    ``(n_classes, M, L)`` candidate grid from ``rep`` — dense per-request
    tensors are never materialized.
    """
    quant = quant or QuantizationConfig()
    n = cover.shape[0]
    if n == 0:
        empty = np.zeros(0, np.int64)
        rep = dict(
            cover=empty,
            service=empty,
            A=np.zeros(0),
            C=np.zeros(0),
            size=np.zeros(0),
            tq=np.zeros(0),
        )
        return empty, empty, empty, np.zeros(1, np.int64), rep

    key = class_keys(cover, service, A, C, size, tq, quant)
    inv = _unique_inverse_rows(key)
    n_c = int(inv.max()) + 1
    count, first_idx, members, offsets = _group(inv, n_c)

    fcount = count.astype(np.float64)

    def _mean(x):
        return np.bincount(inv, weights=np.asarray(x, np.float64), minlength=n_c) / fcount

    rep = dict(
        cover=cover.astype(np.int64)[first_idx],
        service=service.astype(np.int64)[first_idx],
        A=_mean(A),
        C=_mean(C),
        size=_mean(size),
        tq=_mean(tq),
    )
    return count, first_idx, members, offsets, rep


#: mirrors ``repro.core.gus.NEG`` — scores below this are "infeasible"
_NEG = -1e30


def hier_assign(
    agg: AggregateClasses,
    gamma: np.ndarray,
    eta: np.ndarray,
    *,
    exact: bool = False,
) -> np.ndarray:
    """Global cloud-contention pass: chunked greedy over class aggregates.

    Merges the per-edge class tables in first-request-index order (the same
    order dense GUS visits their members) and allocates each class in
    chunks: pick the feasible utility-argmax cell (first occurrence on the
    flat ``j * L + l`` axis — GUS's tie-break), fit as many members as the
    shared ``gamma``/``eta`` capacities allow, commit, and re-pick until
    the class is exhausted or nothing fits.  Local cells charge only the
    server's ``gamma``; offload cells also charge the covering edge's
    ``eta`` — the cross-edge coupling this pass exists to reconcile.

    ``exact=True`` consumes members one at a time with float32 capacity
    subtraction, reproducing :func:`repro.core.gus.gus_schedule_np`'s
    arithmetic bit for bit; ``exact=False`` sizes chunks analytically in
    float32 via :func:`hier_cells_np` (the fleet path — one floor-division
    instead of ``count`` updates), with the same IEEE op sequence as the
    XLA and Pallas device backends, so the fleet's host oracle and its
    device program agree bit for bit.

    Returns an ``(n_chunks, 4)`` int64 array of ``(class, j, l, take)`` in
    allocation order.
    """
    if agg.n_classes == 0:
        return np.zeros((0, 4), np.int64)

    if not exact:  # analytic mode: delegate to the (take, start) cell oracle
        order_all = np.argsort(agg.first_idx, kind="stable")
        take, start = hier_cells_np(
            agg.us[order_all], agg.feas[order_all], agg.v[order_all],
            agg.u[order_all], agg.cover[order_all], agg.count[order_all],
            gamma, eta,
        )
        ci, jj, ll = np.nonzero(take > 0)
        if ci.size == 0:
            return np.zeros((0, 4), np.int64)
        # classes allocate strictly in order and within a class ``start`` is
        # the running member offset, so (class position, start) IS the
        # allocation order
        o = np.lexsort((start[ci, jj, ll], ci))
        return np.column_stack(
            [order_all[ci], jj, ll, take[ci, jj, ll]]
        )[o].astype(np.int64)

    gamma = np.asarray(gamma, np.float32).copy()
    eta = np.asarray(eta, np.float32).copy()
    M = gamma.shape[0]
    L = agg.us.shape[-1]
    server = np.arange(M)

    # pass-1 screening: classes infeasible everywhere never enter the queue
    alive = agg.feas.any(axis=(1, 2))
    order = np.argsort(agg.first_idx, kind="stable")
    order = order[alive[order]]

    chunks = []
    for c in order:
        rem = int(agg.count[c])
        s = int(agg.cover[c])
        row_us = agg.us[c]
        row_v = np.asarray(agg.v[c], np.float32)
        row_u = np.asarray(agg.u[c], np.float32)
        local = (server == s)[:, None]
        feas = agg.feas[c]
        while rem > 0:
            ok = feas & (row_v <= gamma[:, None]) & (local | (row_u <= eta[s]))
            if not ok.any():
                break
            flat = int(np.argmax(np.where(ok, row_us, _NEG)))
            j, l = divmod(flat, L)
            vv = row_v[j, l]
            uv = row_u[j, l]
            take = 0
            while take < rem:
                if vv > gamma[j] or (j != s and uv > eta[s]):
                    break
                gamma[j] -= vv
                if j != s:
                    eta[s] -= uv
                take += 1
            if take <= 0:
                break  # float edge: argmax cell passed ``ok`` but fits zero
            chunks.append((int(c), j, l, take))
            rem -= take
    if not chunks:
        return np.zeros((0, 4), np.int64)
    return np.asarray(chunks, np.int64)


def hier_cells_np(
    us: np.ndarray,
    feas: np.ndarray,
    v: np.ndarray,
    u: np.ndarray,
    cover: np.ndarray,
    count: np.ndarray,
    gamma: np.ndarray,
    eta: np.ndarray,
):
    """NumPy oracle for the device hierarchical allocator (analytic mode).

    Classes are processed **in the given order** (callers pre-sort by
    ``first_idx``); ``(take, start)`` are the fixed-shape cell tensors
    described in the module docstring.  All capacity arithmetic is float32
    with the exact op sequence of the XLA scan and the Pallas kernel:
    ``cap = floor(budget / cost)`` (f32 divide then f32 floor), ``take =
    min(rem, cap_gamma, cap_eta)``, ``budget -= f32(take) * cost``.
    Zero-count rows (padding) and classes with no feasible cell are
    skipped without touching the budgets.

    Re-picks of one cell are always consecutive (its utility never changes
    and feasibility is monotone), so accumulated ``take`` spans a
    contiguous member range from its first ``start`` — the property that
    lets a fixed-shape tensor replace the variable-length chunk list.
    """
    us = np.asarray(us, np.float32)
    feas = np.asarray(feas, bool)
    v = np.asarray(v, np.float32)
    u = np.asarray(u, np.float32)
    gamma = np.asarray(gamma, np.float32).copy()
    eta = np.asarray(eta, np.float32).copy()
    C, M, L = us.shape
    take = np.zeros((C, M, L), np.int32)
    start = np.zeros((C, M, L), np.int32)
    server = np.arange(M)
    neg = np.float32(_NEG)
    for c in range(C):
        rem = int(count[c])
        if rem <= 0 or not feas[c].any():
            continue
        s = int(cover[c])
        local = (server == s)[:, None]
        used = 0
        while rem > 0:
            ok = feas[c] & (v[c] <= gamma[:, None]) & (local | (u[c] <= eta[s]))
            if not ok.any():
                break
            flat = int(np.argmax(np.where(ok, us[c], neg)))
            j, l = divmod(flat, L)
            vv = v[c, j, l]
            uv = u[c, j, l]
            t_f = np.float32(rem)
            if vv > 0:
                t_f = min(t_f, np.floor(gamma[j] / vv))
            if j != s and uv > 0:
                t_f = min(t_f, np.floor(eta[s] / uv))
            t = int(t_f)
            if t < 1:
                break  # float edge: cell passed ``ok`` but fits zero members
            tf32 = np.float32(t)
            gamma[j] = gamma[j] - tf32 * vv
            if j != s:
                eta[s] = eta[s] - tf32 * uv
            if take[c, j, l] == 0:
                start[c, j, l] = used
            take[c, j, l] += t
            used += t
            rem -= t
    return take, start


@jax.jit
def _hier_cells_xla(us, feas, v, u, cover, count, gamma, eta):
    """Jitted XLA implementation of :func:`hier_cells_np`: ``lax.scan``
    over the (pre-sorted, padded) class axis threading the shared budget
    vectors, with an inner ``lax.while_loop`` sizing one chunk per
    iteration.  Bit-identical to the oracle — same f32 op sequence, same
    first-occurrence argmax tie-break."""
    us = jnp.asarray(us, jnp.float32)
    feas = jnp.asarray(feas, bool)
    v = jnp.asarray(v, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    cover = jnp.asarray(cover, jnp.int32)
    count = jnp.asarray(count, jnp.int32)
    gamma = jnp.asarray(gamma, jnp.float32)
    eta = jnp.asarray(eta, jnp.float32)
    C, M, L = us.shape
    neg = jnp.float32(_NEG)
    if C == 0:
        z = jnp.zeros((0, M, L), jnp.int32)
        return z, z

    def cls_step(carry, x):
        gamma, eta = carry
        us_c, feas_c, v_c, u_c, s, cnt = x
        is_local = jnp.arange(M, dtype=jnp.int32) == s

        def cond(st):
            return st[-1]

        def body(st):
            rem, gamma, eta, take, start, used, _ = st
            ok = (
                feas_c
                & (v_c <= gamma[:, None])
                & (is_local[:, None] | (u_c <= eta[s]))
            )
            score = jnp.where(ok, us_c, neg).reshape(-1)
            flat = jnp.argmax(score)
            any_ok = score[flat] > neg
            j = (flat // L).astype(jnp.int32)
            l = (flat % L).astype(jnp.int32)
            vv = v_c[j, l]
            uv = u_c[j, l]
            offl = j != s
            rem_f = rem.astype(jnp.float32)
            cap_g = jnp.where(
                vv > 0, jnp.floor(gamma[j] / jnp.where(vv > 0, vv, 1.0)), rem_f
            )
            cap_e = jnp.where(
                offl & (uv > 0),
                jnp.floor(eta[s] / jnp.where(uv > 0, uv, 1.0)),
                rem_f,
            )
            t_f = jnp.minimum(rem_f, jnp.minimum(cap_g, cap_e))
            t = t_f.astype(jnp.int32)
            do = any_ok & (t >= 1)
            tf32 = jnp.where(do, t, 0).astype(jnp.float32)
            gamma = gamma.at[j].add(-(tf32 * vv))
            eta = eta.at[s].add(jnp.where(offl, -(tf32 * uv), 0.0))
            first = take[j, l] == 0
            start = start.at[j, l].set(
                jnp.where(do & first, used, start[j, l])
            )
            take = take.at[j, l].add(jnp.where(do, t, 0))
            used = used + jnp.where(do, t, 0)
            rem = rem - jnp.where(do, t, 0)
            return rem, gamma, eta, take, start, used, do & (rem > 0)

        st0 = (
            cnt,
            gamma,
            eta,
            jnp.zeros((M, L), jnp.int32),
            jnp.zeros((M, L), jnp.int32),
            jnp.int32(0),
            feas_c.any() & (cnt > 0),
        )
        _, gamma, eta, take, start, _, _ = jax.lax.while_loop(cond, body, st0)
        return (gamma, eta), (take, start)

    (_, _), (take, start) = jax.lax.scan(
        cls_step, (gamma, eta), (us, feas, v, u, cover, count)
    )
    return take, start


def _hier_cells_pallas(us, feas, v, u, cover, count, gamma, eta):
    """Fused-Pallas entry: batch-of-1 lift into the hierarchical kernel
    (``vmap`` over the fleet's replication axis lifts it further, exactly
    like the dense GUS kernel).  The interpret flag resolves at trace
    time, same env switch as the dense kernel."""
    from repro.kernels.gus_pallas import gus_pallas_interpret_default
    from repro.kernels.hier_pallas import hier_cells_pallas

    add = lambda x: jnp.asarray(x)[None]  # noqa: E731 — lift to batch of 1
    take, start = hier_cells_pallas(
        add(us), add(feas), add(v), add(u), add(cover), add(count),
        add(gamma), add(eta), interpret=gus_pallas_interpret_default(),
    )
    return take[0], start[0]


def hier_cells(
    us, feas, v, u, cover, count, gamma, eta, *, backend: Optional[str] = None
):
    """Backend-dispatched analytic allocator over pre-sorted class tensors.

    ``backend`` follows the dense GUS precedence (explicit >
    ``REPRO_GUS_BACKEND`` > ``"xla"``); outputs are bit-identical across
    the NumPy oracle, XLA, and the Pallas kernel (integer tensors, exact
    equality — ``tests/test_hier_parity.py``)."""
    if resolve_gus_backend(backend) == "pallas":
        return _hier_cells_pallas(us, feas, v, u, cover, count, gamma, eta)
    return _hier_cells_xla(us, feas, v, u, cover, count, gamma, eta)


@functools.lru_cache(maxsize=None)
def _hier_backend_impl(resolved: str):
    if resolved == "pallas":
        return partial(hier_cells, backend="pallas")
    return _hier_cells_xla  # the default object existing caches key on


def hier_backend_fn(backend: Optional[str] = None):
    """A stable-identity cells callable for one backend — the hierarchical
    twin of :func:`repro.core.gus.gus_backend_fn`.  The fleet runner's
    compiled-program cache keys on this function's identity, so every
    caller must get the same object per resolved backend."""
    return _hier_backend_impl(resolve_gus_backend(backend))


def deaggregate(agg: AggregateClasses, chunks: np.ndarray, n_requests: int):
    """Map class-level chunks back to per-request ``(j, l)`` assignments.

    Each chunk consumes its class's members in ascending request index —
    the deterministic tie-break that makes hierarchical results reproducible
    and, on lossless classes, identical to dense GUS.  Unallocated members
    stay dropped (``-1``).
    """
    out_j = np.full(n_requests, -1, np.int32)
    out_l = np.full(n_requests, -1, np.int32)
    ptr = agg.offsets[:-1].copy()
    for c, j, l, take in chunks:
        sel = agg.members[ptr[c] : ptr[c] + take]
        out_j[sel] = j
        out_l[sel] = l
        ptr[c] += take
    return out_j, out_l


def make_gus_hier(decimals: Optional[int] = None):
    """A drop-in scheduler callable running GUS over class aggregates.

    ``decimals=None`` (the registered ``gus-hier`` default) keys classes on
    exact row content and allocates in exact mode — bit-parity with dense
    GUS on every frame whose classes are index-contiguous, which includes
    all frames with singleton classes.  Pass ``decimals`` to merge
    near-identical requests (lossy, bounded satisfaction drift).
    """

    def schedule(inst: FlatInstance) -> Assignment:
        n = int(np.asarray(inst.A).shape[0])
        if n == 0:
            z = jnp.zeros(0, jnp.int32)
            return Assignment(z, z)
        agg = aggregate_instance(inst, decimals=decimals)
        chunks = hier_assign(
            agg, np.asarray(inst.gamma), np.asarray(inst.eta), exact=True
        )
        out_j, out_l = deaggregate(agg, chunks, n)
        return Assignment(jnp.asarray(out_j), jnp.asarray(out_l))

    return schedule


def hier_schedule_np(inst: FlatInstance) -> Assignment:
    """Module-level exact-mode entry point (see :func:`make_gus_hier`)."""
    return make_gus_hier()(inst)
