"""Core of the reproduction: the paper's MUS problem, GUS greedy scheduler,
exact ILP oracle, baseline heuristics, the policy registry that puts them
all behind one interface, and the virtual-testbed simulator."""
from .instance import (
    FlatInstance,
    GeneratorConfig,
    generate_instance,
    generate_batch,
    stack_instances,
    pad_instance,
)
from .satisfaction import us_tensor, hard_feasible, mean_us, satisfied_mask
from .gus import Assignment, gus_schedule, gus_schedule_np, gus_schedule_batch
from .ilp import solve_bnb, solve_exhaustive
from .baselines import (
    random_assignment,
    offload_all,
    local_all,
    happy_computation,
    happy_communication,
    BASELINES,
)
from .scenarios import (
    Request,
    Scenario,
    SCENARIOS,
    register_scenario,
    get_scenario,
    list_scenarios,
)
from .policies import (
    Policy,
    POLICIES,
    register_policy,
    get_policy,
    list_policies,
    make_ilp_policy,
)
from .simulator import (
    ClusterSpec,
    SimConfig,
    SimResult,
    FleetResult,
    simulate,
    simulate_fleet,
    demo_cluster_spec,
)
from .extensions import gus_schedule_ordered, best_us_per_request, apply_mobility

__all__ = [
    "FlatInstance",
    "GeneratorConfig",
    "generate_instance",
    "generate_batch",
    "stack_instances",
    "pad_instance",
    "us_tensor",
    "hard_feasible",
    "mean_us",
    "satisfied_mask",
    "Assignment",
    "gus_schedule",
    "gus_schedule_np",
    "gus_schedule_batch",
    "solve_bnb",
    "solve_exhaustive",
    "random_assignment",
    "offload_all",
    "local_all",
    "happy_computation",
    "happy_communication",
    "BASELINES",
    "Request",
    "Scenario",
    "SCENARIOS",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "Policy",
    "POLICIES",
    "register_policy",
    "get_policy",
    "list_policies",
    "make_ilp_policy",
    "ClusterSpec",
    "SimConfig",
    "SimResult",
    "FleetResult",
    "simulate",
    "simulate_fleet",
    "demo_cluster_spec",
    "gus_schedule_ordered",
    "best_us_per_request",
    "apply_mobility",
]
