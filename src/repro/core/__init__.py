"""Core of the reproduction: the paper's MUS problem, GUS greedy scheduler,
exact ILP oracle, baseline heuristics and the virtual-testbed simulator."""
from .instance import FlatInstance, GeneratorConfig, generate_instance, generate_batch, stack_instances
from .satisfaction import us_tensor, hard_feasible, mean_us, satisfied_mask
from .gus import Assignment, gus_schedule, gus_schedule_np, gus_schedule_batch
from .ilp import solve_bnb, solve_exhaustive
from .baselines import (
    random_assignment,
    offload_all,
    local_all,
    happy_computation,
    happy_communication,
    BASELINES,
)
from .simulator import ClusterSpec, SimConfig, SimResult, simulate
from .extensions import gus_schedule_ordered, best_us_per_request, apply_mobility

__all__ = [
    "FlatInstance",
    "GeneratorConfig",
    "generate_instance",
    "generate_batch",
    "stack_instances",
    "us_tensor",
    "hard_feasible",
    "mean_us",
    "satisfied_mask",
    "Assignment",
    "gus_schedule",
    "gus_schedule_np",
    "gus_schedule_batch",
    "solve_bnb",
    "solve_exhaustive",
    "random_assignment",
    "offload_all",
    "local_all",
    "happy_computation",
    "happy_communication",
    "BASELINES",
    "ClusterSpec",
    "SimConfig",
    "SimResult",
    "simulate",
    "gus_schedule_ordered",
    "best_us_per_request",
    "apply_mobility",
]
