"""Policy registry — every scheduler in the repo behind one named interface.

The paper's evaluation is a *family* comparison: GUS against five baseline
heuristics (Sec. IV) and against the exact ILP optimum on small instances.
The simulator originally hard-wired ``gus_schedule``; a :class:`Policy`
wraps any scheduler ``FlatInstance -> Assignment`` together with the
metadata the simulator and benchmarks need to run it on the padded-frame
hot path:

* ``needs_key``    — the policy consumes a fresh ``jax.random`` key per
  frame (``random``).  :func:`~repro.core.simulator.simulate` splits a key
  chain seeded by its ``seed``; :func:`~repro.core.simulator.simulate_fleet`
  threads one key per (replication, frame) through the vmapped program.
* ``vmappable``    — the policy is a pure jit/vmap-compatible JAX function
  (everything except the host-side branch & bound).
* ``pad``          — the policy honors the padding contract of
  :func:`~repro.core.instance.pad_instance` (infeasible padded rows are
  dropped without touching capacity).  The ILP oracle instead schedules the
  *unpadded* frame — branch & bound is shape-flexible and every padded row
  would only add an empty candidate list.
* ``max_requests`` — hard per-frame size ceiling (ILP only: the B&B is
  exponential in the frame size, so it refuses frames it cannot certify).
* ``kind``         — ``"greedy"`` (GUS variants), ``"baseline"`` (the
  paper's restricted heuristics), ``"relaxed"`` (Happy-* constraint
  relaxations; *upper bounds* in the numerical model, see
  ``benchmarks/paper_figures.py``), or ``"oracle"`` (exact ILP).

A policy is *bound* to a cluster shape before use: ``bind(n_edge,
n_servers)`` returns the per-frame schedule function, closing over whatever
static state the policy needs (e.g. the cloud mask for ``offload_all``).

Registering a custom policy takes a handful of lines::

    import jax.numpy as jnp
    from repro.core import Policy, offload_all, register_policy, simulate

    register_policy(Policy(
        name="cloud-only",
        description="every request goes to the cloud tier",
        make=lambda n_edge, n_servers: (
            lambda inst: offload_all(inst, jnp.arange(n_servers) >= n_edge)
        ),
    ))
    simulate(spec, cfg, policy="cloud-only")
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Union

import jax.numpy as jnp

from .baselines import (
    local_all,
    offload_all,
    random_assignment,
)
from .extensions import gus_schedule_ordered
from .gus import Assignment, gus_schedule
from .ilp import lagrangian_dual, price_directed_greedy, solve_bnb
from .instance import FlatInstance

__all__ = [
    "Policy",
    "POLICIES",
    "register_policy",
    "get_policy",
    "list_policies",
    "make_ilp_policy",
    "ILP_DEFAULT_MAX_REQUESTS",
    "ILP_DEFAULT_NODE_LIMIT",
]

ILP_DEFAULT_MAX_REQUESTS = 24
ILP_DEFAULT_NODE_LIMIT = 200_000


@dataclasses.dataclass(frozen=True)
class Policy:
    """One named scheduling policy (see module docstring for the fields)."""

    name: str
    description: str
    #: factory ``(n_edge, n_servers) -> schedule_fn``; the returned function
    #: maps ``FlatInstance -> Assignment`` (plus a PRNG key when ``needs_key``,
    #: or a full :class:`~repro.core.queueing.PolicyCarry` when ``stateful``).
    make: Callable[[int, int], Callable]
    needs_key: bool = False
    vmappable: bool = True
    pad: bool = True
    max_requests: Optional[int] = None
    kind: str = "baseline"
    #: the schedule fn is ``(FlatInstance, PolicyCarry) -> (Assignment,
    #: PolicyCarry)``: it reads the simulator-threaded carry (EMA load
    #: estimates, its own PRNG chain via ``carry.key``) and returns an
    #: updated one.  The backlog and bandwidth-estimator fields stay
    #: simulator-owned (overwritten after the call); ``ema_util`` and
    #: ``key`` are policy-owned.  Must stay jit/vmap/scan-compatible when
    #: ``vmappable`` — the fleet threads the carry through ``lax.scan``.
    stateful: bool = False

    def bind(self, n_edge: int, n_servers: int) -> Callable:
        """Close over the cluster shape; returns the per-frame schedule fn."""
        return self.make(n_edge, n_servers)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

POLICIES: Dict[str, Policy] = {}


def register_policy(policy: Policy) -> Policy:
    """Register a :class:`Policy` under its ``name`` (last write wins).
    Returns the argument unchanged."""
    POLICIES[policy.name] = policy
    return policy


def get_policy(policy: Union[str, Policy]) -> Policy:
    """Resolve a policy by name (or pass a :class:`Policy` through)."""
    if isinstance(policy, Policy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise KeyError(
            f"unknown policy {policy!r}; registered: {', '.join(list_policies())}"
        ) from None


def list_policies() -> List[str]:
    """Registered policy names, in registration order (GUS first)."""
    return list(POLICIES)


# ---------------------------------------------------------------------------
# Built-in policies
# ---------------------------------------------------------------------------


def _make_ilp(
    n_edge: int,
    n_servers: int,
    *,
    max_requests: int = ILP_DEFAULT_MAX_REQUESTS,
    node_limit: int = ILP_DEFAULT_NODE_LIMIT,
    strict: bool = False,
) -> Callable[[FlatInstance], Assignment]:
    def schedule(inst: FlatInstance) -> Assignment:
        n = int(inst.n_requests)
        if n == 0:
            empty = jnp.full((0,), -1, jnp.int32)
            return Assignment(empty, empty)
        if n > max_requests:
            raise ValueError(
                f"ilp policy refuses a {n}-request frame (max {max_requests}); "
                "shrink the frame (queue_cap / arrival rate) or use a greedy policy"
            )
        assign, _ = solve_bnb(inst, node_limit=node_limit, strict=strict)
        return assign

    return schedule


def make_ilp_policy(
    *,
    max_requests: int = ILP_DEFAULT_MAX_REQUESTS,
    node_limit: int = ILP_DEFAULT_NODE_LIMIT,
    strict: bool = False,
    name: str = "ilp",
) -> Policy:
    """An ILP-oracle :class:`Policy` with custom frame-size / search budgets.

    The *registered* ``ilp`` uses the defaults, tuned for in-simulator frames
    (queue-capped, anytime behaviour is fine).  Benchmarks that certify the
    "~90% of optimal" claim should pass ``strict=True`` with a large
    ``node_limit``: ``strict`` makes the branch & bound raise instead of
    returning a best-so-far when the node budget trips, so "opt" is always a
    certified optimum.
    """
    return Policy(
        name=name,
        description=f"exact MUS optimum via branch & bound (<= {max_requests} requests)",
        make=functools.partial(
            _make_ilp, max_requests=max_requests, node_limit=node_limit,
            strict=strict,
        ),
        vmappable=False,
        pad=False,
        max_requests=max_requests,
        kind="oracle",
    )


register_policy(Policy(
    name="gus",
    description="Algorithm 1 (GUS): greedy max-US in arrival order, jitted",
    make=lambda n_edge, n_servers: gus_schedule,
    kind="greedy",
))

def _make_gus_hier(n_edge: int, n_servers: int):
    from .aggregation import make_gus_hier

    return make_gus_hier()


register_policy(Policy(
    name="gus-hier",
    description=(
        "GUS over QoS-class aggregates: bucket requests into classes, "
        "allocate class chunks, de-aggregate by request index"
    ),
    make=_make_gus_hier,
    vmappable=False,
    pad=False,
    kind="greedy",
))

register_policy(Policy(
    name="gus-ordered",
    description="GUS processing requests by descending best-achievable US",
    make=lambda n_edge, n_servers: gus_schedule_ordered,
    kind="greedy",
))

def _make_gus_adaptive(n_edge: int, n_servers: int):
    """GUS with resilience awareness, fed by the simulator-threaded carry:
    servers reported down (``carry.server_up``) are masked out of every
    request's candidate set, and a server whose EMA utilization runs over
    1 gets its visible capacity shaded down proportionally.  With
    congestion and impairments off the carry sits at its init values
    (``ema_util == 0``, ``server_up == 1``), both transforms are exact
    identities (``x / 1.0``, ``avail & True``), and the assignments are
    bit-identical to plain ``gus`` — pinned in ``tests/test_resilience.py``.
    """

    def schedule(inst: FlatInstance, carry):
        over = jnp.maximum(carry.ema_util - 1.0, 0.0)
        up = carry.server_up > 0.0
        shaded = dataclasses.replace(
            inst,
            gamma=inst.gamma / (1.0 + over),
            avail=inst.avail & up[None, :, None],
        )
        return gus_schedule(shaded), carry

    return schedule


register_policy(Policy(
    name="gus-adaptive",
    description="GUS reading the carry: skips down servers, shades overloaded ones",
    make=_make_gus_adaptive,
    kind="greedy",
    stateful=True,
))

register_policy(Policy(
    name="random",
    description="baseline 1: one uniformly-random server per request",
    make=lambda n_edge, n_servers: random_assignment,
    needs_key=True,
))

register_policy(Policy(
    name="offload_all",
    description="baseline 2: cloud servers only",
    make=lambda n_edge, n_servers: (
        lambda inst: offload_all(inst, jnp.arange(n_servers) >= n_edge)
    ),
))

register_policy(Policy(
    name="local_all",
    description="baseline 3: the covering edge server only",
    make=lambda n_edge, n_servers: local_all,
))

register_policy(Policy(
    name="happy_computation",
    description="baseline 4: GUS with the computation constraint (2d) relaxed",
    make=lambda n_edge, n_servers: (
        lambda inst: gus_schedule(inst, relax_compute=True)
    ),
    kind="relaxed",
))

register_policy(Policy(
    name="happy_communication",
    description="baseline 5: GUS with the communication constraint (2e) relaxed",
    make=lambda n_edge, n_servers: (
        lambda inst: gus_schedule(inst, relax_comm=True)
    ),
    kind="relaxed",
))

def _make_lp_bound(
    n_edge: int, n_servers: int, *, n_iter: int = 60
) -> Callable[[FlatInstance], Assignment]:
    def schedule(inst: FlatInstance) -> Assignment:
        n = int(inst.n_requests)
        if n == 0:
            empty = jnp.full((0,), -1, jnp.int32)
            return Assignment(empty, empty)
        _, lam, mu = lagrangian_dual(inst, n_iter=n_iter)
        return price_directed_greedy(inst, lam, mu)

    return schedule


register_policy(make_ilp_policy())

register_policy(Policy(
    name="lp-bound",
    description=(
        "LP-relaxation dual bound + price-directed greedy; scales past the "
        "ilp policy's frame-size refusal"
    ),
    make=_make_lp_bound,
    vmappable=False,
    pad=False,
    kind="oracle",
))
