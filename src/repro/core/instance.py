"""Problem-instance model for the MUS (Maximal User Satisfaction) problem.

The paper indexes decisions X_{ijkl} over requests i, servers j, services k and
model variants l.  Each request asks for exactly one service k_i, so we store
the *flattened* per-request view: every (i, j, l) tensor below has already been
gathered at k = k_i.  This loses no generality and keeps GUS/ILP tensors at
(N, M, L) instead of (N, M, K, L).

All arrays are plain numpy in the generator and converted to a jax pytree
(`FlatInstance`) so the GUS scheduler can jit/vmap over batches of instances.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FlatInstance",
    "GeneratorConfig",
    "generate_instance",
    "generate_batch",
    "stack_instances",
    "pad_instance",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatInstance:
    """One MUS problem instance, flattened to (N, M, L) request-major tensors.

    Shapes (unbatched):
      cover:  (N,)  int32   covering edge server s_i of request i
      A:      (N,)  f32     requested accuracy floor (same units as `acc`)
      C:      (N,)  f32     requested deadline (ms)
      w_a:    (N,)  f32     accuracy weight in the US metric
      w_c:    (N,)  f32     latency weight in the US metric
      acc:    (N, M, L) f32 accuracy delivered by variant l of service k_i on j
      ctime:  (N, M, L) f32 completion time  T^q_i + T^proc_{j,k_i,l} (+ T^comm)
      v:      (N, M, L) f32 computation cost charged against gamma_j
      u:      (N, M, L) f32 communication cost charged against eta_{s_i} if offloaded
      avail:  (N, M, L) bool service k_i / variant l placed on server j
      gamma:  (M,)  f32     computation capacity per server
      eta:    (M,)  f32     communication capacity per server
      max_as: ()    f32     normalizer: max accuracy in the system
      max_cs: ()    f32     normalizer: worst-case completion time in the system
    """

    cover: jnp.ndarray
    A: jnp.ndarray
    C: jnp.ndarray
    w_a: jnp.ndarray
    w_c: jnp.ndarray
    acc: jnp.ndarray
    ctime: jnp.ndarray
    v: jnp.ndarray
    u: jnp.ndarray
    avail: jnp.ndarray
    gamma: jnp.ndarray
    eta: jnp.ndarray
    max_as: jnp.ndarray
    max_cs: jnp.ndarray

    @property
    def n_requests(self) -> int:
        return self.A.shape[-1]

    @property
    def n_servers(self) -> int:
        return self.gamma.shape[-1]

    @property
    def n_variants(self) -> int:
        return self.acc.shape[-1]

    def is_local(self) -> jnp.ndarray:
        """(N, M) bool: True where server j is the covering server of i."""
        return self.cover[..., :, None] == jnp.arange(self.n_servers)


@dataclasses.dataclass(frozen=True)
class GeneratorConfig:
    """Defaults reproduce the paper's numerical setup (Sec. IV).

    9 heterogeneous edge servers + 1 cloud; |N|=100 requests, |K|=100 services,
    |L|=10 variants; edge T_proc ~ U[950, 1300] ms, cloud 300 ms;
    A_i ~ N(45, 10) [%], C_i ~ N(1000, 4000) ms; T^q ~ U[0, 50] ms;
    Max_as = 100 %, Max_cs = 12000 ms; mean bandwidth 600 bytes/ms.
    """

    n_requests: int = 100
    n_edge: int = 9
    n_cloud: int = 1
    n_services: int = 100
    n_variants: int = 10

    # Requested-QoS distributions (paper Sec. IV).
    acc_req_mean: float = 45.0
    acc_req_std: float = 10.0
    delay_req_mean: float = 1000.0
    delay_req_std: float = 4000.0
    queue_delay_max: float = 50.0
    w_a: float = 1.0
    w_c: float = 1.0

    # System-wide normalizers.
    max_as: float = 100.0
    max_cs: float = 12000.0

    # Processing-delay model: edge ~ U[proc_edge_lo, proc_edge_hi] for the
    # *largest* variant, cheaper variants scale down; cloud is proc_cloud.
    proc_edge_lo: float = 950.0
    proc_edge_hi: float = 1300.0
    proc_cloud: float = 300.0

    # Variant ladder: variant l has relative cost cost_ratio**(L-1-l) and an
    # accuracy that rises with cost (diminishing returns).  Variant L-1 is the
    # biggest/most accurate.
    acc_top: float = 92.0
    acc_bottom: float = 35.0

    # Communication: mean bandwidth (bytes/ms) between servers, request sizes.
    bandwidth: float = 600.0
    req_size_lo: float = 20_000.0   # bytes (e.g. a JPEG)
    req_size_hi: float = 120_000.0
    cloud_extra_delay: float = 100.0  # backhaul ms to reach the cloud tier

    # Capacities.  Three edge hardware classes (paper: "three types of edge
    # servers").  Units: compute = chip-ms per frame, comm = KB per frame.
    edge_compute_classes: tuple = (2600.0, 3900.0, 5200.0)
    edge_comm_classes: tuple = (400.0, 600.0, 800.0)
    cloud_compute: float = 26_000.0
    cloud_comm: float = 6000.0

    # Service placement: edge servers hold a random subset of services whose
    # size depends on their class; cloud holds everything (paper Sec. II).
    edge_services_frac: tuple = (0.25, 0.5, 0.75)
    # Not every variant fits on an edge box; the cheapest `edge_variants`
    # variants are placed on edges, all variants on the cloud.
    edge_variants: int = 6


def _variant_ladder(cfg: GeneratorConfig, rng: np.random.Generator):
    """Per-(service, variant) accuracy and relative cost.

    Accuracy follows a saturating curve in relative model cost with per-service
    jitter, mirroring how e.g. SqueezeNet/GoogleNet trade params for top-1.
    """
    L, K = cfg.n_variants, cfg.n_services
    rel_cost = np.geomspace(0.12, 1.0, L)  # variant 0 cheapest
    # saturating accuracy vs cost + per-service jitter
    base = cfg.acc_bottom + (cfg.acc_top - cfg.acc_bottom) * (
        1.0 - np.exp(-3.0 * rel_cost)
    ) / (1.0 - np.exp(-3.0))
    acc = base[None, :] + rng.normal(0.0, 2.0, size=(K, L))
    acc = np.clip(np.sort(acc, axis=1), 1.0, cfg.max_as)  # monotone in l
    return acc.astype(np.float32), rel_cost.astype(np.float32)


def generate_instance(
    seed: int,
    cfg: Optional[GeneratorConfig] = None,
    *,
    as_numpy: bool = False,
):
    """Draw one MUS instance per the paper's numerical setup."""
    cfg = cfg or GeneratorConfig()
    rng = np.random.default_rng(seed)
    N = cfg.n_requests
    M = cfg.n_edge + cfg.n_cloud
    K, L = cfg.n_services, cfg.n_variants
    is_cloud = np.arange(M) >= cfg.n_edge

    # --- servers -----------------------------------------------------------
    edge_class = rng.integers(0, len(cfg.edge_compute_classes), size=cfg.n_edge)
    gamma = np.empty(M, np.float32)
    eta = np.empty(M, np.float32)
    svc_frac = np.empty(M, np.float32)
    for j in range(M):
        if is_cloud[j]:
            gamma[j] = cfg.cloud_compute
            eta[j] = cfg.cloud_comm
            svc_frac[j] = 1.0
        else:
            c = edge_class[j]
            gamma[j] = cfg.edge_compute_classes[c]
            eta[j] = cfg.edge_comm_classes[c]
            svc_frac[j] = cfg.edge_services_frac[c]

    # --- services / variants ----------------------------------------------
    acc_kl, rel_cost = _variant_ladder(cfg, rng)

    # placement (M, K, L)
    placed = np.zeros((M, K, L), bool)
    for j in range(M):
        if is_cloud[j]:
            placed[j] = True
        else:
            ks = rng.random(K) < svc_frac[j]
            placed[j, ks, : cfg.edge_variants] = True

    # processing delay (M, K, L): per-server speed * per-variant relative cost
    proc = np.empty((M, K, L), np.float32)
    for j in range(M):
        base = (
            cfg.proc_cloud
            if is_cloud[j]
            else rng.uniform(cfg.proc_edge_lo, cfg.proc_edge_hi)
        )
        proc[j] = base * rel_cost[None, :] * rng.uniform(0.95, 1.05, size=(K, L))

    # --- requests -----------------------------------------------------------
    service = rng.integers(0, K, size=N)
    cover = rng.integers(0, cfg.n_edge, size=N)  # users attach to edges only
    A = np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std, N), 1.0, 99.0)
    C = np.clip(rng.normal(cfg.delay_req_mean, cfg.delay_req_std, N), 50.0, None)
    Tq = rng.uniform(0.0, cfg.queue_delay_max, N)
    size = rng.uniform(cfg.req_size_lo, cfg.req_size_hi, N)

    # --- pairwise comm delay (cover -> j) -----------------------------------
    # delay = size / bandwidth (+ backhaul if crossing to the cloud tier)
    comm_delay = size[:, None] / cfg.bandwidth + np.where(
        is_cloud[None, :], cfg.cloud_extra_delay, 0.0
    )
    local = cover[:, None] == np.arange(M)[None, :]
    comm_delay = np.where(local, 0.0, comm_delay)

    # --- flatten to (N, M, L) ------------------------------------------------
    acc_nml = np.broadcast_to(acc_kl[service][:, None, :], (N, M, L)).copy()
    proc_nml = proc[:, service, :].transpose(1, 0, 2)  # (N, M, L)
    ctime = Tq[:, None, None] + proc_nml + comm_delay[:, :, None]
    avail = placed[:, service, :].transpose(1, 0, 2)

    # computation cost: chip-ms actually consumed on the serving box;
    # communication cost: KB shipped off the covering box when offloading.
    v = proc_nml.copy()
    u = np.where(local[:, :, None], 0.0, (size / 1024.0)[:, None, None])
    u = np.broadcast_to(u, (N, M, L)).copy()

    arrays = dict(
        cover=cover.astype(np.int32),
        A=A.astype(np.float32),
        C=C.astype(np.float32),
        w_a=np.full(N, cfg.w_a, np.float32),
        w_c=np.full(N, cfg.w_c, np.float32),
        acc=acc_nml.astype(np.float32),
        ctime=ctime.astype(np.float32),
        v=v.astype(np.float32),
        u=u.astype(np.float32),
        avail=avail,
        gamma=gamma.astype(np.float32),
        eta=eta.astype(np.float32),
        max_as=np.float32(cfg.max_as),
        max_cs=np.float32(cfg.max_cs),
    )
    if as_numpy:
        return FlatInstance(**arrays)
    return FlatInstance(**{k: jnp.asarray(val) for k, val in arrays.items()})


def pad_instance(inst: FlatInstance, n_pad: int) -> FlatInstance:
    """Pad the request axis of an (unbatched) instance to ``n_pad`` rows.

    This is the fixed-shape contract the jitted schedulers rely on: padded
    rows are *infeasible everywhere* (``avail`` False) and *free* (zero
    v/u and zero US weights), so every scheduler that honors feasibility —
    ``gus_schedule``, ``gus_schedule_np``, all baselines — drops them
    (j = l = -1) without touching any capacity.  Because GUS processes
    requests by ascending index and padded rows sit at the end, the first
    ``N`` assignments are identical to running on the unpadded instance.

    Server-axis leaves (gamma, eta) and scalars (max_as, max_cs) pass
    through untouched.
    """
    N = inst.A.shape[-1]
    if n_pad == N:
        return inst
    if n_pad < N:
        raise ValueError(f"cannot pad {N} requests down to {n_pad}")
    p = n_pad - N

    def _pad(x, fill):
        x = jnp.asarray(x)
        return jnp.concatenate([x, jnp.full((p,) + x.shape[1:], fill, x.dtype)])

    return FlatInstance(
        cover=_pad(inst.cover, 0),
        A=_pad(inst.A, 1e9),        # unreachable accuracy floor
        C=_pad(inst.C, -1.0),       # already-expired deadline
        w_a=_pad(inst.w_a, 0.0),    # padded rows contribute zero US
        w_c=_pad(inst.w_c, 0.0),
        acc=_pad(inst.acc, 0.0),
        ctime=_pad(inst.ctime, 1e9),
        v=_pad(inst.v, 0.0),
        u=_pad(inst.u, 0.0),
        avail=_pad(inst.avail, False),
        gamma=inst.gamma,
        eta=inst.eta,
        max_as=inst.max_as,
        max_cs=inst.max_cs,
    )


def generate_batch(seed: int, n: int, cfg: Optional[GeneratorConfig] = None):
    """A batch of `n` instances stacked on a leading axis (for vmap)."""
    insts = [generate_instance(seed + i, cfg, as_numpy=True) for i in range(n)]
    return stack_instances(insts)


def stack_instances(insts):
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *insts)
