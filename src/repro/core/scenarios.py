"""Scenario engine — named workload scenarios for the virtual testbed.

The paper's Sec. IV experiments fix one workload: homogeneous Poisson
arrivals with a fixed (A_i, C_i) QoS draw.  Real edge deployments see far
richer traffic (diurnal load swings, flash crowds, user mobility,
heterogeneous user tiers, server outages).  This module turns "the workload"
into a first-class, registered object so every future experiment adds a
``Scenario`` subclass instead of forking the simulator.

A :class:`Scenario` shapes three per-frame streams consumed by
``repro.core.simulator``:

* **arrivals** — a (possibly time- and edge-varying) Poisson process, drawn
  by :meth:`Scenario.generate_arrivals` via thinning against the scenario's
  instantaneous rate :meth:`Scenario.rate`;
* **QoS** — per-request accuracy floor A_i and deadline C_i from
  :meth:`Scenario.draw_qos` (the paper's fixed draw by default);
* **capacity** — a per-frame multiplier in [0, 1] on every server's
  (gamma, eta) frame budgets from :meth:`Scenario.capacity_scale`
  (1 everywhere by default; an outage zeroes a server's column).

Scenarios are stateless: all randomness flows through the caller's
``numpy.random.Generator``, so a (scenario, seed) pair is reproducible.
The ``paper-default`` scenario draws *bit-identical* request streams to the
pre-scenario-engine simulator (same RNG consumption order).

Registry usage::

    from repro.core import get_scenario, list_scenarios, simulate
    simulate(spec, cfg, scenario="flash-crowd")
    for name in list_scenarios():
        print(name, get_scenario(name).description)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Request",
    "Scenario",
    "PaperDefaultScenario",
    "DiurnalScenario",
    "FlashCrowdScenario",
    "MobilityScenario",
    "HeteroTiersScenario",
    "OutageScenario",
    "SustainedOverloadScenario",
    "DiurnalWeekScenario",
    "SCENARIOS",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "bucket_arrivals",
]


@dataclasses.dataclass
class Request:
    """One user request as the testbed sees it (shared with the simulator)."""

    rid: int
    arrival_ms: float
    cover: int          # covering edge server at submission time
    service: int        # requested service k_i
    A: float            # accuracy floor (%)
    C: float            # deadline (ms)
    size_bytes: float   # payload shipped off the covering edge when offloading


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Base scenario: the paper's homogeneous Poisson workload.

    Subclasses override any of :meth:`rate`, :meth:`rate_bound`,
    :meth:`draw_qos`, :meth:`capacity_scale`, or :attr:`move_prob` — the
    arrival generator, simulator, and fleet runner consume only this
    interface.
    """

    name: str = "paper-default"
    description: str = "Sec. IV workload: homogeneous Poisson, fixed QoS draw"
    #: per-frame probability that a user re-attaches to a random edge;
    #: ``None`` defers to ``SimConfig.move_prob``.
    move_prob: Optional[float] = None
    #: when True the simulator defaults to the bounded-memory streaming
    #: arrival engine (:mod:`repro.core.streaming`) instead of materializing
    #: the full trace — the mode for long-horizon / nonstationary workloads.
    #: ``simulate(..., streaming=...)`` overrides per run.
    streaming: bool = False

    # -- arrival process ----------------------------------------------------
    def rate(self, edge: int, t_ms: float, cfg) -> float:
        """Instantaneous arrival rate (requests/s) at ``edge`` at time ``t_ms``."""
        return cfg.arrival_rate_per_s

    def rate_bound(self, edge: int, cfg) -> float:
        """Upper bound on :meth:`rate` over the horizon (thinning envelope).

        Must satisfy ``rate(edge, t, cfg) <= rate_bound(edge, cfg)`` for all t.
        """
        return cfg.arrival_rate_per_s

    # -- QoS draw -----------------------------------------------------------
    def draw_qos(self, rng: np.random.Generator, cfg) -> Tuple[float, float]:
        """Draw one request's (A_i, C_i).  Paper default: A ~ N(mean, std)
        clipped to [1, 99], C fixed."""
        a = float(np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std), 1, 99))
        return a, float(cfg.delay_req_ms)

    # -- capacity stream ----------------------------------------------------
    def capacity_scale(
        self, frame_start_ms: float, cfg, n_edge: int, n_servers: int
    ) -> Optional[np.ndarray]:
        """(M,) multiplier in [0, 1] applied to each server's per-frame
        (gamma, eta) budgets, or ``None`` for "no scaling" (all ones)."""
        return None

    # -- generator ----------------------------------------------------------
    def generate_arrivals(
        self, rng: np.random.Generator, n_edge: int, n_services: int, cfg
    ) -> List[Request]:
        """Draw the full request trace for one replication.

        Per edge: a thinned Poisson process against ``rate_bound``.  When the
        instantaneous rate equals the bound (constant-rate scenarios) the
        acceptance draw is skipped, which keeps ``paper-default`` bit-identical
        to the legacy inline generator.  Requests come back sorted by arrival.
        """
        reqs: List[Request] = []
        rid = 0
        for e in range(n_edge):
            rmax = float(self.rate_bound(e, cfg))
            if rmax <= 0.0:
                continue
            t = 0.0
            while t < cfg.horizon_ms:
                t += rng.exponential(1000.0 / rmax)
                if t >= cfg.horizon_ms:
                    break
                r_t = float(self.rate(e, t, cfg))
                if r_t < rmax and rng.random() >= r_t / rmax:
                    continue  # thinned away
                service = int(rng.integers(0, n_services))
                a, c = self.draw_qos(rng, cfg)
                reqs.append(
                    Request(
                        rid=rid,
                        arrival_ms=t,
                        cover=e,
                        service=service,
                        A=a,
                        C=c,
                        size_bytes=float(rng.uniform(cfg.req_size_lo, cfg.req_size_hi)),
                    )
                )
                rid += 1
        reqs.sort(key=lambda r: r.arrival_ms)
        for i, r in enumerate(reqs):  # rids in arrival order, like the testbed
            r.rid = i
        return reqs


def bucket_arrivals(
    reqs: List[Request], frame_ms: float, n_frames: int
) -> List[List[Request]]:
    """Group a materialized arrival trace into per-frame buckets.

    This is the fleet runner's frame-synchronous layout: frame ``t`` holds
    every arrival in ``[t * frame_ms, (t + 1) * frame_ms)``, and anything at
    or past the last boundary clamps into the final frame — the same
    bucketing the windowed streaming path reproduces by pulling an
    :class:`~repro.core.streaming.ArrivalStream` one frame at a time.
    """
    buckets: List[List[Request]] = [[] for _ in range(n_frames)]
    for r in reqs:
        buckets[min(int(r.arrival_ms // frame_ms), n_frames - 1)].append(r)
    return buckets


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario):
    """Register a :class:`Scenario` instance — or a Scenario subclass, which
    is instantiated with its defaults — under its ``name`` (last write wins).
    Returns the argument unchanged, so it works as a class decorator."""
    inst = scenario() if isinstance(scenario, type) else scenario
    SCENARIOS[inst.name] = inst
    return scenario


def get_scenario(scenario) -> Scenario:
    """Resolve a scenario by name (or pass a :class:`Scenario` through)."""
    if isinstance(scenario, Scenario):
        return scenario
    try:
        return SCENARIOS[scenario]
    except KeyError:
        raise KeyError(
            f"unknown scenario {scenario!r}; registered: {', '.join(list_scenarios())}"
        ) from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# Named scenarios
# ---------------------------------------------------------------------------


@register_scenario
@dataclasses.dataclass(frozen=True)
class PaperDefaultScenario(Scenario):
    """The paper's workload, verbatim (the base class defaults)."""

    name: str = "paper-default"
    description: str = "Sec. IV workload: homogeneous Poisson, fixed QoS draw"


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalScenario(Scenario):
    """Sinusoidal day/night load: rate(t) = base * (1 + amp * sin(2*pi*t/P)).

    One full cycle spans ``period_frac`` of the horizon, so short runs still
    see both the peak and the trough.
    """

    name: str = "diurnal"
    description: str = "sinusoidal day/night load swing around the base rate"
    amplitude: float = 0.8
    period_frac: float = 1.0  # cycles = 1 / period_frac over the horizon

    def rate(self, edge, t_ms, cfg):
        period = max(cfg.horizon_ms * self.period_frac, 1e-9)
        return cfg.arrival_rate_per_s * (
            1.0 + self.amplitude * math.sin(2.0 * math.pi * t_ms / period)
        )

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * (1.0 + self.amplitude)


@register_scenario
@dataclasses.dataclass(frozen=True)
class FlashCrowdScenario(Scenario):
    """A flash crowd hits a subset of edges mid-run: rate jumps ``burst_mult``x
    inside the [burst_start_frac, burst_end_frac) window of the horizon."""

    name: str = "flash-crowd"
    description: str = "10x burst on half the edges for the middle fifth of the run"
    burst_mult: float = 10.0
    burst_start_frac: float = 0.4
    burst_end_frac: float = 0.6
    hot_edge_stride: int = 2  # edges 0, 2, 4, ... catch the crowd

    def _hot(self, edge: int) -> bool:
        return edge % self.hot_edge_stride == 0

    def rate(self, edge, t_ms, cfg):
        base = cfg.arrival_rate_per_s
        in_burst = (
            self.burst_start_frac * cfg.horizon_ms
            <= t_ms
            < self.burst_end_frac * cfg.horizon_ms
        )
        return base * self.burst_mult if (self._hot(edge) and in_burst) else base

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * (self.burst_mult if self._hot(edge) else 1.0)


@register_scenario
@dataclasses.dataclass(frozen=True)
class MobilityScenario(Scenario):
    """Paper-default traffic, but users roam: every frame each pending user
    re-attaches to a uniformly random edge with probability ``move_prob``
    (the conclusion's future-work item, on by default here)."""

    name: str = "mobility"
    description: str = "Poisson load with per-frame user re-attachment (roaming)"
    move_prob: Optional[float] = 0.3


@register_scenario
@dataclasses.dataclass(frozen=True)
class HeteroTiersScenario(Scenario):
    """Heterogeneous demand: edges carry unequal load (repeating
    ``rate_mults`` pattern) and users split into a *strict* tier (high
    accuracy floor, tight deadline) and a *lenient* tier."""

    name: str = "hetero-tiers"
    description: str = "unequal per-edge load + strict/lenient user QoS mix"
    rate_mults: Tuple[float, ...] = (0.5, 1.0, 2.0)
    strict_frac: float = 0.5
    strict_acc_mean: float = 70.0
    strict_acc_std: float = 5.0
    strict_deadline_mult: float = 0.5
    lenient_deadline_mult: float = 1.5

    def rate(self, edge, t_ms, cfg):
        return cfg.arrival_rate_per_s * self.rate_mults[edge % len(self.rate_mults)]

    def rate_bound(self, edge, cfg):
        return self.rate(edge, 0.0, cfg)

    def draw_qos(self, rng, cfg):
        if rng.random() < self.strict_frac:
            a = float(np.clip(rng.normal(self.strict_acc_mean, self.strict_acc_std), 1, 99))
            return a, float(cfg.delay_req_ms * self.strict_deadline_mult)
        a = float(np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std), 1, 99))
        return a, float(cfg.delay_req_ms * self.lenient_deadline_mult)


@register_scenario
@dataclasses.dataclass(frozen=True)
class SustainedOverloadScenario(Scenario):
    """Arrivals sustained at ``rate_mult`` x the base rate for the whole
    horizon — demand permanently exceeds cluster capacity, so carried
    backlog grows without bound and capacity-relaxing policies
    (Happy-Computation / Happy-Communication) spiral once congestion
    (:class:`repro.core.queueing.CongestionConfig`) is enabled.  Streams
    by default: the long-horizon congestion workload."""

    name: str = "sustained-overload"
    description: str = "constant overload at rate_mult x base; streaming by default"
    streaming: bool = True
    rate_mult: float = 3.0

    def rate(self, edge, t_ms, cfg):
        return cfg.arrival_rate_per_s * self.rate_mult

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * self.rate_mult


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalWeekScenario(DiurnalScenario):
    """Seven full diurnal cycles over the horizon — the long-horizon
    nonstationary workload (run it with a large ``horizon_ms``; the
    streaming engine keeps memory bounded regardless)."""

    name: str = "diurnal-week"
    description: str = "seven day/night cycles over the horizon; streaming by default"
    streaming: bool = True
    period_frac: float = 1.0 / 7.0


@register_scenario
@dataclasses.dataclass(frozen=True)
class OutageScenario(Scenario):
    """Mid-run server outage: the per-frame (gamma, eta) budgets of
    ``down_servers`` are masked to zero inside the outage window.  A dead
    server can neither compute (gamma = 0) nor ship requests off its queue
    (eta = 0), so requests covered by a dead *edge* are dropped for the
    window, while the rest of the fleet must route around the hole that the
    dead server leaves in cluster capacity."""

    name: str = "outage"
    description: str = "servers lose all capacity for the middle third of the run"
    outage_start_frac: float = 0.33
    outage_end_frac: float = 0.66
    down_servers: Tuple[int, ...] = (0,)

    def capacity_scale(self, frame_start_ms, cfg, n_edge, n_servers):
        in_outage = (
            self.outage_start_frac * cfg.horizon_ms
            <= frame_start_ms
            < self.outage_end_frac * cfg.horizon_ms
        )
        if not in_outage:
            return None
        scale = np.ones(n_servers, np.float32)
        for j in self.down_servers:
            if 0 <= j < n_servers:
                scale[j] = 0.0
        return scale
