"""Scenario engine — named workload scenarios for the virtual testbed.

The paper's Sec. IV experiments fix one workload: homogeneous Poisson
arrivals with a fixed (A_i, C_i) QoS draw.  Real edge deployments see far
richer traffic (diurnal load swings, flash crowds, user mobility,
heterogeneous user tiers, server outages).  This module turns "the workload"
into a first-class, registered object so every future experiment adds a
``Scenario`` subclass instead of forking the simulator.

A :class:`Scenario` shapes three per-frame streams consumed by
``repro.core.simulator``:

* **arrivals** — a (possibly time- and edge-varying) Poisson process, drawn
  by :meth:`Scenario.generate_arrivals` via thinning against the scenario's
  instantaneous rate :meth:`Scenario.rate`;
* **QoS** — per-request accuracy floor A_i and deadline C_i from
  :meth:`Scenario.draw_qos` (the paper's fixed draw by default);
* **capacity** — a per-frame multiplier in [0, 1] on every server's
  (gamma, eta) frame budgets from :meth:`Scenario.capacity_scale`
  (1 everywhere by default; an outage zeroes a server's column).

Scenarios are stateless: all randomness flows through the caller's
``numpy.random.Generator``, so a (scenario, seed) pair is reproducible.
The ``paper-default`` scenario draws *bit-identical* request streams to the
pre-scenario-engine simulator (same RNG consumption order).

Two RNG modes generate that traffic (``Scenario.rng_mode``, overridable per
call):

* ``"paper-default"`` — the legacy per-request Python loop: one exponential
  gap, one thinning draw, one QoS draw at a time.  This is the default and
  its RNG consumption order is frozen (a golden trace in
  ``tests/test_arrival_gen.py`` guards it), so every historical result
  reproduces bit-for-bit.
* ``"vectorized"`` — batched generation: exponential inter-arrival gaps,
  thinning acceptances, and per-request attribute draws all happen in numpy
  chunks (:data:`VEC_CHUNK` gaps at a time per edge), ~10x faster at fleet
  scale.  It is *distributionally identical* to the per-request loop (same
  thinned-Poisson process, same QoS/size laws — property-tested) and
  deterministic given the seed, but it consumes the RNG in a different
  order, so it is strictly opt-in.  The vectorized trace is also available
  columnar (:class:`RequestColumns`) so the fleet's grid builder never
  touches per-request Python objects.

Registry usage::

    from repro.core import get_scenario, list_scenarios, simulate
    simulate(spec, cfg, scenario="flash-crowd")
    for name in list_scenarios():
        print(name, get_scenario(name).description)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Request",
    "RequestColumns",
    "Scenario",
    "PaperDefaultScenario",
    "DiurnalScenario",
    "FlashCrowdScenario",
    "MobilityScenario",
    "HeteroTiersScenario",
    "OutageScenario",
    "FlashCrowdOutageScenario",
    "SustainedOverloadScenario",
    "DiurnalWeekScenario",
    "SCENARIOS",
    "RNG_MODES",
    "VEC_CHUNK",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "bucket_arrivals",
    "bucket_columns",
]

#: the two arrival-RNG modes; see the module docstring
RNG_MODES = ("paper-default", "vectorized")

#: cap on exponential gaps drawn per numpy batch in ``rng_mode="vectorized"``.
#: Each chunk's actual size is the deterministic estimate
#: ``min(VEC_CHUNK, mean_remaining + 6*sqrt(mean_remaining+1) + 16)`` (>= 32),
#: a function of the process's current time only — so the draw order (and
#: therefore the trace) depends only on (scenario, seed, edge), never on how
#: the caller pulls arrivals.  Cap and formula are part of the vectorized
#: trace's definition: changing either changes every vectorized trace.
VEC_CHUNK = 512


def _resolve_rng_mode(mode) -> str:
    if mode not in RNG_MODES:
        raise ValueError(f"unknown rng_mode {mode!r}; expected one of {RNG_MODES}")
    return mode


@dataclasses.dataclass
class Request:
    """One user request as the testbed sees it (shared with the simulator)."""

    rid: int
    arrival_ms: float
    cover: int          # covering edge server at submission time
    service: int        # requested service k_i
    A: float            # accuracy floor (%)
    C: float            # deadline (ms)
    size_bytes: float   # payload shipped off the covering edge when offloading


@dataclasses.dataclass
class RequestColumns:
    """Columnar arrival trace — the struct-of-arrays twin of ``List[Request]``.

    The vectorized generator emits this so the fleet's grid builder
    (``repro.core.simulator._build_frame_batch``) can fill whole frames with
    array slices instead of per-request Python attribute reads.  Arrays are
    parallel, sorted by ``arrival_ms``; float columns stay float64 (the RNG's
    native width) and are narrowed to float32 exactly where the per-request
    path narrows its Python floats, so columnar and object traces built from
    the same draws produce bit-identical instance tensors.
    """

    arrival_ms: np.ndarray   # (N,) float64
    cover: np.ndarray        # (N,) int64
    service: np.ndarray      # (N,) int64
    A: np.ndarray            # (N,) float64
    C: np.ndarray            # (N,) float64
    size_bytes: np.ndarray   # (N,) float64

    def __len__(self) -> int:
        return int(self.arrival_ms.shape[0])

    def __bool__(self) -> bool:  # empty frames must be falsy, like an empty list
        return len(self) > 0

    def slice(self, lo: int, hi: int) -> "RequestColumns":
        """View of rows [lo, hi) (no copy)."""
        return RequestColumns(
            arrival_ms=self.arrival_ms[lo:hi],
            cover=self.cover[lo:hi],
            service=self.service[lo:hi],
            A=self.A[lo:hi],
            C=self.C[lo:hi],
            size_bytes=self.size_bytes[lo:hi],
        )

    def to_requests(self, rid0: int = 0) -> List[Request]:
        """Materialize :class:`Request` objects (rids ``rid0..rid0+N-1``).

        ``tolist()`` converts each column to Python natives in one C pass —
        an order of magnitude faster than per-element numpy scalar reads.
        """
        rows = zip(
            self.arrival_ms.tolist(),
            self.cover.tolist(),
            self.service.tolist(),
            self.A.tolist(),
            self.C.tolist(),
            self.size_bytes.tolist(),
        )
        return [
            Request(rid0 + i, t, cov, svc, a, c, size)
            for i, (t, cov, svc, a, c, size) in enumerate(rows)
        ]

    @staticmethod
    def concatenate(parts: Sequence["RequestColumns"]) -> "RequestColumns":
        if not parts:
            return _empty_columns()
        return RequestColumns(
            arrival_ms=np.concatenate([p.arrival_ms for p in parts]),
            cover=np.concatenate([p.cover for p in parts]),
            service=np.concatenate([p.service for p in parts]),
            A=np.concatenate([p.A for p in parts]),
            C=np.concatenate([p.C for p in parts]),
            size_bytes=np.concatenate([p.size_bytes for p in parts]),
        )

    def sorted_by_arrival(self) -> "RequestColumns":
        """Stable-sorted by arrival time (ties keep per-edge emission order,
        matching the per-request path's ``list.sort``)."""
        order = np.argsort(self.arrival_ms, kind="stable")
        return RequestColumns(
            arrival_ms=self.arrival_ms[order],
            cover=self.cover[order],
            service=self.service[order],
            A=self.A[order],
            C=self.C[order],
            size_bytes=self.size_bytes[order],
        )


def _empty_columns() -> RequestColumns:
    z = np.zeros(0, np.float64)
    return RequestColumns(
        arrival_ms=z,
        cover=np.zeros(0, np.int64),
        service=np.zeros(0, np.int64),
        A=z.copy(),
        C=z.copy(),
        size_bytes=z.copy(),
    )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Base scenario: the paper's homogeneous Poisson workload.

    Subclasses override any of :meth:`rate`, :meth:`rate_bound`,
    :meth:`draw_qos`, :meth:`capacity_scale`, or :attr:`move_prob` — the
    arrival generator, simulator, and fleet runner consume only this
    interface.
    """

    name: str = "paper-default"
    description: str = "Sec. IV workload: homogeneous Poisson, fixed QoS draw"
    #: per-frame probability that a user re-attaches to a random edge;
    #: ``None`` defers to ``SimConfig.move_prob``.
    move_prob: Optional[float] = None
    #: when True the simulator defaults to the bounded-memory streaming
    #: arrival engine (:mod:`repro.core.streaming`) instead of materializing
    #: the full trace — the mode for long-horizon / nonstationary workloads.
    #: ``simulate(..., streaming=...)`` overrides per run.
    streaming: bool = False
    #: default arrival-RNG mode (:data:`RNG_MODES`): ``"paper-default"`` is
    #: the frozen per-request draw order, ``"vectorized"`` the batched
    #: generator (~10x faster, different draw order — opt in).  Overridable
    #: per call via ``simulate(..., rng_mode=...)`` and friends.
    rng_mode: str = "paper-default"
    #: whether the scenario is sized for the dense per-request sweeps in
    #: ``benchmarks/`` (every-policy x every-scenario matrices).  City-scale
    #: workloads built for the hierarchical fleet path set this False; they
    #: are exercised by the mega-city smoke and ``fleet_scale --users-sweep``
    #: instead.
    dense_sweep: bool = True

    # -- arrival process ----------------------------------------------------
    def rate(self, edge: int, t_ms: float, cfg) -> float:
        """Instantaneous arrival rate (requests/s) at ``edge`` at time ``t_ms``."""
        return cfg.arrival_rate_per_s

    def rate_bound(self, edge: int, cfg) -> float:
        """Upper bound on :meth:`rate` over the horizon (thinning envelope).

        Must satisfy ``rate(edge, t, cfg) <= rate_bound(edge, cfg)`` for all t.
        """
        return cfg.arrival_rate_per_s

    def rate_batch(self, edge: int, t_ms: np.ndarray, cfg) -> np.ndarray:
        """Vectorized :meth:`rate` over an array of times (thinning hot path).

        Registered time-varying scenarios override this with true numpy
        expressions.  The default covers the two safe cases: a scenario that
        never overrode :meth:`rate` is constant-rate (broadcast), and one
        that overrode :meth:`rate` but not this method falls back to an
        elementwise loop — slower, but never silently wrong.
        """
        t = np.asarray(t_ms, np.float64)
        if type(self).rate is Scenario.rate:
            return np.full(t.shape, float(self.rate(edge, 0.0, cfg)))
        return np.fromiter(
            (float(self.rate(edge, float(x), cfg)) for x in t), np.float64, t.size
        )

    # -- QoS draw -----------------------------------------------------------
    def draw_qos(self, rng: np.random.Generator, cfg) -> Tuple[float, float]:
        """Draw one request's (A_i, C_i).  Paper default: A ~ N(mean, std)
        clipped to [1, 99], C fixed."""
        a = float(np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std), 1, 99))
        return a, float(cfg.delay_req_ms)

    def draw_qos_batch(
        self, rng: np.random.Generator, cfg, n: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Draw ``n`` requests' (A, C) arrays in one batch (vectorized mode).

        Subclasses that override :meth:`draw_qos` should override this too;
        until they do, the default detects the scalar override and loops it,
        so the vectorized mode stays distributionally faithful for any
        third-party scenario at reduced speed.
        """
        if (
            type(self).draw_qos is not Scenario.draw_qos
            and type(self).draw_qos_batch is Scenario.draw_qos_batch
        ):
            pairs = [self.draw_qos(rng, cfg) for _ in range(n)]
            a = np.array([p[0] for p in pairs], np.float64)
            c = np.array([p[1] for p in pairs], np.float64)
            return a, c
        a = np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std, n), 1.0, 99.0)
        return a, np.full(n, float(cfg.delay_req_ms))

    # -- capacity stream ----------------------------------------------------
    def capacity_scale(
        self, frame_start_ms: float, cfg, n_edge: int, n_servers: int
    ) -> Optional[np.ndarray]:
        """(M,) multiplier in [0, 1] applied to each server's per-frame
        (gamma, eta) budgets, or ``None`` for "no scaling" (all ones)."""
        return None

    def capacity_scale_batch(
        self, frame_starts_ms: np.ndarray, cfg, n_edge: int, n_servers: int
    ) -> Optional[np.ndarray]:
        """Vectorized :meth:`capacity_scale` over a window of frame starts.

        Returns ``(F, M)`` multipliers, or ``None`` when no frame in the
        window is scaled.  Unscaled frames carry rows of exact ``1.0``; the
        budgets are float64 and ``x * 1.0`` is the identity there, so a
        batched window is bit-identical to per-frame scalar calls.

        Like :meth:`rate_batch`, the default covers the two safe cases: a
        scenario that never overrode :meth:`capacity_scale` has a constant
        all-ones stream (return ``None`` without touching the frames), and
        one that overrode the scalar hook but not this method falls back to
        an elementwise loop — slower, but never silently wrong.
        """
        t = np.asarray(frame_starts_ms, np.float64)
        if type(self).capacity_scale is Scenario.capacity_scale:
            return None
        out = None
        for i in range(t.size):
            s = self.capacity_scale(float(t[i]), cfg, n_edge, n_servers)
            if s is not None:
                if out is None:
                    out = np.ones((t.size, n_servers), np.float64)
                out[i] = s
        return out

    # -- generator ----------------------------------------------------------
    def generate_arrivals(
        self,
        rng: np.random.Generator,
        n_edge: int,
        n_services: int,
        cfg,
        rng_mode: Optional[str] = None,
    ) -> List[Request]:
        """Draw the full request trace for one replication.

        ``rng_mode=None`` defers to :attr:`rng_mode`.  In ``"paper-default"``
        mode, per edge: a thinned Poisson process against ``rate_bound``,
        one request at a time.  When the instantaneous rate equals the bound
        (constant-rate scenarios) the acceptance draw is skipped, which
        keeps ``paper-default`` bit-identical to the legacy inline
        generator.  ``"vectorized"`` draws the same process in numpy batches
        (different RNG consumption, same distribution).  Requests come back
        sorted by arrival either way.
        """
        mode = _resolve_rng_mode(self.rng_mode if rng_mode is None else rng_mode)
        if mode == "vectorized":
            return self.generate_arrivals_columns(
                rng, n_edge, n_services, cfg
            ).to_requests()
        reqs: List[Request] = []
        rid = 0
        for e in range(n_edge):
            rmax = float(self.rate_bound(e, cfg))
            if rmax <= 0.0:
                continue
            t = 0.0
            while t < cfg.horizon_ms:
                t += rng.exponential(1000.0 / rmax)
                if t >= cfg.horizon_ms:
                    break
                r_t = float(self.rate(e, t, cfg))
                if r_t < rmax and rng.random() >= r_t / rmax:
                    continue  # thinned away
                service = int(rng.integers(0, n_services))
                a, c = self.draw_qos(rng, cfg)
                reqs.append(
                    Request(
                        rid=rid,
                        arrival_ms=t,
                        cover=e,
                        service=service,
                        A=a,
                        C=c,
                        size_bytes=float(rng.uniform(cfg.req_size_lo, cfg.req_size_hi)),
                    )
                )
                rid += 1
        reqs.sort(key=lambda r: r.arrival_ms)
        for i, r in enumerate(reqs):  # rids in arrival order, like the testbed
            r.rid = i
        return reqs

    def generate_arrivals_columns(
        self, rng: np.random.Generator, n_edge: int, n_services: int, cfg
    ) -> RequestColumns:
        """Vectorized trace as :class:`RequestColumns` (the fleet's format).

        Edges draw sequentially from the shared ``rng`` — each edge drains
        its chunked thinned-Poisson process (:func:`iter_edge_arrival_chunks`)
        to the horizon — then the merged trace is stable-sorted by arrival.
        ``generate_arrivals(rng_mode="vectorized")`` wraps exactly these
        columns into :class:`Request` objects, so the two views of one seed
        are the same trace.
        """
        parts: List[RequestColumns] = []
        for e in range(n_edge):
            parts.extend(
                edge_arrival_columns(self, rng, e, n_services, cfg, cfg.horizon_ms)
            )
        return RequestColumns.concatenate(parts).sorted_by_arrival()


def edge_arrival_columns(
    scn: Scenario,
    rng: np.random.Generator,
    edge: int,
    n_services: int,
    cfg,
    horizon_ms: float,
) -> List[RequestColumns]:
    """Drain one edge's chunk iterator into :class:`RequestColumns` parts.

    The single assembly point between :func:`iter_edge_arrival_chunks`'s raw
    ``(ts, svc, A, C, size)`` tuples and the columnar trace — shared by the
    materialized generator (shared rng, edges sequential) and the streaming
    engine's one-shot drain (spawned per-edge rngs), so the two cannot
    drift apart.
    """
    return [
        RequestColumns(
            arrival_ms=ts,
            cover=np.full(ts.size, edge, np.int64),
            service=svc,
            A=a,
            C=c,
            size_bytes=size,
        )
        for ts, svc, a, c, size in iter_edge_arrival_chunks(
            scn, rng, edge, n_services, cfg, horizon_ms
        )
    ]


def _scalar_hook_is_newer(cls: type, scalar_name: str, batch_name: str) -> bool:
    """True when ``scalar_name`` is overridden at a more-derived class than
    ``batch_name`` — i.e. somewhere down the MRO the scalar law changed but
    its batched twin did not, so the inherited batch implementation no
    longer matches.  The vectorized engine then falls back to looping the
    scalar hook: slower, never silently wrong.  (A plain ``is``-comparison
    against ``Scenario`` only catches direct subclasses; this works at any
    inheritance depth, e.g. a subclass of a registered scenario.)"""
    mro = cls.__mro__
    scalar_at = next(i for i, c in enumerate(mro) if scalar_name in c.__dict__)
    batch_at = next(i for i, c in enumerate(mro) if batch_name in c.__dict__)
    return scalar_at < batch_at


def _rate_batch(scn: Scenario, edge: int, ts: np.ndarray, cfg) -> np.ndarray:
    """``scn.rate_batch`` guarded by the MRO check above."""
    if _scalar_hook_is_newer(type(scn), "rate", "rate_batch"):
        return np.fromiter(
            (float(scn.rate(edge, float(x), cfg)) for x in ts), np.float64, ts.size
        )
    return np.asarray(scn.rate_batch(edge, ts, cfg), np.float64)


def _draw_qos_batch(
    scn: Scenario, rng: np.random.Generator, cfg, n: int
) -> Tuple[np.ndarray, np.ndarray]:
    """``scn.draw_qos_batch`` guarded by the MRO check above."""
    if _scalar_hook_is_newer(type(scn), "draw_qos", "draw_qos_batch"):
        pairs = [scn.draw_qos(rng, cfg) for _ in range(n)]
        a = np.array([p[0] for p in pairs], np.float64)
        c = np.array([p[1] for p in pairs], np.float64)
        return a, c
    a, c = scn.draw_qos_batch(rng, cfg, n)
    return np.asarray(a, np.float64), np.asarray(c, np.float64)


def iter_edge_arrival_chunks(
    scn: Scenario,
    rng: np.random.Generator,
    edge: int,
    n_services: int,
    cfg,
    horizon_ms: float,
) -> Iterator[Tuple[np.ndarray, ...]]:
    """One edge's vectorized thinned-Poisson process, one chunk at a time.

    Yields ``(arrival_ms, service, A, C, size_bytes)`` column chunks of
    accepted arrivals in time order.  Each iteration consumes the RNG in a
    fixed pattern — :data:`VEC_CHUNK` exponential gaps, :data:`VEC_CHUNK`
    thinning uniforms, then the accepted requests' attribute batches — so
    the draw sequence depends only on the generator's state, never on when
    or how far the consumer pulls.  That is the invariance that lets the
    one-shot trace, the streaming engine, and the count-only pre-pass all
    share this single code path (and each other's traces) in
    ``rng_mode="vectorized"``.
    """
    rmax = float(scn.rate_bound(edge, cfg))
    if rmax <= 0.0:
        return
    scale = 1000.0 / rmax
    t = 0.0
    while t < horizon_ms:
        # deterministic chunk size: expected remaining count + 6 sigma slack,
        # so one chunk usually finishes the horizon without gross overdraw
        mean_n = (horizon_ms - t) / scale
        n = int(min(VEC_CHUNK, max(32.0, mean_n + 6.0 * math.sqrt(mean_n + 1.0) + 16.0)))
        gaps = rng.exponential(scale, n)
        ts = t + np.cumsum(gaps)
        t = float(ts[-1])
        u = rng.random(n)  # thinning draws, paired with the gaps
        keep = ts < horizon_ms
        ts, u = ts[keep], u[keep]
        if ts.size:
            r_t = _rate_batch(scn, edge, ts, cfg)
            accept = u * rmax < r_t
            ts = ts[accept]
        if ts.size:
            svc = rng.integers(0, n_services, ts.size)
            a, c = _draw_qos_batch(scn, rng, cfg, ts.size)
            size = rng.uniform(cfg.req_size_lo, cfg.req_size_hi, ts.size)
            yield ts, svc, a, c, size


def bucket_arrivals(
    reqs: List[Request], frame_ms: float, n_frames: int
) -> List[List[Request]]:
    """Group a materialized arrival trace into per-frame buckets.

    This is the fleet runner's frame-synchronous layout: frame ``t`` holds
    every arrival in ``[t * frame_ms, (t + 1) * frame_ms)``, and anything at
    or past the last boundary clamps into the final frame — the same
    bucketing the windowed streaming path reproduces by pulling an
    :class:`~repro.core.streaming.ArrivalStream` one frame at a time.
    """
    buckets: List[List[Request]] = [[] for _ in range(n_frames)]
    for r in reqs:
        buckets[min(int(r.arrival_ms // frame_ms), n_frames - 1)].append(r)
    return buckets


def bucket_columns(
    cols: RequestColumns, frame_ms: float, n_frames: int
) -> List[RequestColumns]:
    """:func:`bucket_arrivals` for a columnar trace — per-frame column views.

    ``cols`` must be sorted by arrival (the generator's contract), so each
    frame is a contiguous slice found by ``searchsorted``; anything at or
    past the last boundary clamps into the final frame, exactly like the
    per-request bucketing.
    """
    edges = np.searchsorted(
        cols.arrival_ms, np.arange(1, n_frames) * frame_ms, side="left"
    )
    bounds = np.concatenate([[0], edges, [len(cols)]])
    return [
        cols.slice(int(bounds[i]), int(bounds[i + 1])) for i in range(n_frames)
    ]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario):
    """Register a :class:`Scenario` instance — or a Scenario subclass, which
    is instantiated with its defaults — under its ``name`` (last write wins).
    Returns the argument unchanged, so it works as a class decorator."""
    inst = scenario() if isinstance(scenario, type) else scenario
    SCENARIOS[inst.name] = inst
    return scenario


def get_scenario(scenario) -> Scenario:
    """Resolve a scenario by name (or pass a :class:`Scenario` through)."""
    if isinstance(scenario, Scenario):
        return scenario
    try:
        return SCENARIOS[scenario]
    except KeyError:
        raise KeyError(
            f"unknown scenario {scenario!r}; registered: {', '.join(list_scenarios())}"
        ) from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# Named scenarios
# ---------------------------------------------------------------------------


@register_scenario
@dataclasses.dataclass(frozen=True)
class PaperDefaultScenario(Scenario):
    """The paper's workload, verbatim (the base class defaults)."""

    name: str = "paper-default"
    description: str = "Sec. IV workload: homogeneous Poisson, fixed QoS draw"


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalScenario(Scenario):
    """Sinusoidal day/night load: rate(t) = base * (1 + amp * sin(2*pi*t/P)).

    One full cycle spans ``period_frac`` of the horizon, so short runs still
    see both the peak and the trough.
    """

    name: str = "diurnal"
    description: str = "sinusoidal day/night load swing around the base rate"
    amplitude: float = 0.8
    period_frac: float = 1.0  # cycles = 1 / period_frac over the horizon

    def rate(self, edge, t_ms, cfg):
        period = max(cfg.horizon_ms * self.period_frac, 1e-9)
        return cfg.arrival_rate_per_s * (
            1.0 + self.amplitude * math.sin(2.0 * math.pi * t_ms / period)
        )

    def rate_batch(self, edge, t_ms, cfg):
        period = max(cfg.horizon_ms * self.period_frac, 1e-9)
        t = np.asarray(t_ms, np.float64)
        return cfg.arrival_rate_per_s * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * t / period)
        )

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * (1.0 + self.amplitude)


@register_scenario
@dataclasses.dataclass(frozen=True)
class FlashCrowdScenario(Scenario):
    """A flash crowd hits a subset of edges mid-run: rate jumps ``burst_mult``x
    inside the [burst_start_frac, burst_end_frac) window of the horizon."""

    name: str = "flash-crowd"
    description: str = "10x burst on half the edges for the middle fifth of the run"
    burst_mult: float = 10.0
    burst_start_frac: float = 0.4
    burst_end_frac: float = 0.6
    hot_edge_stride: int = 2  # edges 0, 2, 4, ... catch the crowd

    def _hot(self, edge: int) -> bool:
        return edge % self.hot_edge_stride == 0

    def rate(self, edge, t_ms, cfg):
        base = cfg.arrival_rate_per_s
        in_burst = (
            self.burst_start_frac * cfg.horizon_ms
            <= t_ms
            < self.burst_end_frac * cfg.horizon_ms
        )
        return base * self.burst_mult if (self._hot(edge) and in_burst) else base

    def rate_batch(self, edge, t_ms, cfg):
        t = np.asarray(t_ms, np.float64)
        base = cfg.arrival_rate_per_s
        if not self._hot(edge):
            return np.full(t.shape, base)
        in_burst = (self.burst_start_frac * cfg.horizon_ms <= t) & (
            t < self.burst_end_frac * cfg.horizon_ms
        )
        return np.where(in_burst, base * self.burst_mult, base)

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * (self.burst_mult if self._hot(edge) else 1.0)


@register_scenario
@dataclasses.dataclass(frozen=True)
class MobilityScenario(Scenario):
    """Paper-default traffic, but users roam: every frame each pending user
    re-attaches to a uniformly random edge with probability ``move_prob``
    (the conclusion's future-work item, on by default here)."""

    name: str = "mobility"
    description: str = "Poisson load with per-frame user re-attachment (roaming)"
    move_prob: Optional[float] = 0.3


@register_scenario
@dataclasses.dataclass(frozen=True)
class HeteroTiersScenario(Scenario):
    """Heterogeneous demand: edges carry unequal load (repeating
    ``rate_mults`` pattern) and users split into a *strict* tier (high
    accuracy floor, tight deadline) and a *lenient* tier."""

    name: str = "hetero-tiers"
    description: str = "unequal per-edge load + strict/lenient user QoS mix"
    rate_mults: Tuple[float, ...] = (0.5, 1.0, 2.0)
    strict_frac: float = 0.5
    strict_acc_mean: float = 70.0
    strict_acc_std: float = 5.0
    strict_deadline_mult: float = 0.5
    lenient_deadline_mult: float = 1.5

    def rate(self, edge, t_ms, cfg):
        return cfg.arrival_rate_per_s * self.rate_mults[edge % len(self.rate_mults)]

    def rate_batch(self, edge, t_ms, cfg):
        return np.full(
            np.asarray(t_ms, np.float64).shape, float(self.rate(edge, 0.0, cfg))
        )

    def rate_bound(self, edge, cfg):
        return self.rate(edge, 0.0, cfg)

    def draw_qos(self, rng, cfg):
        if rng.random() < self.strict_frac:
            a = float(np.clip(rng.normal(self.strict_acc_mean, self.strict_acc_std), 1, 99))
            return a, float(cfg.delay_req_ms * self.strict_deadline_mult)
        a = float(np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std), 1, 99))
        return a, float(cfg.delay_req_ms * self.lenient_deadline_mult)

    def draw_qos_batch(self, rng, cfg, n):
        # same tier law as the scalar draw, batched: one tier uniform per
        # request, then a strict and a lenient normal selected by the mask
        # (both batches are drawn so consumption is data-independent)
        strict = rng.random(n) < self.strict_frac
        a_strict = np.clip(rng.normal(self.strict_acc_mean, self.strict_acc_std, n), 1, 99)
        a_lenient = np.clip(rng.normal(cfg.acc_req_mean, cfg.acc_req_std, n), 1, 99)
        a = np.where(strict, a_strict, a_lenient)
        c = np.where(
            strict,
            cfg.delay_req_ms * self.strict_deadline_mult,
            cfg.delay_req_ms * self.lenient_deadline_mult,
        )
        return a, c


@register_scenario
@dataclasses.dataclass(frozen=True)
class SustainedOverloadScenario(Scenario):
    """Arrivals sustained at ``rate_mult`` x the base rate for the whole
    horizon — demand permanently exceeds cluster capacity, so carried
    backlog grows without bound and capacity-relaxing policies
    (Happy-Computation / Happy-Communication) spiral once congestion
    (:class:`repro.core.queueing.CongestionConfig`) is enabled.  Streams
    by default: the long-horizon congestion workload."""

    name: str = "sustained-overload"
    description: str = "constant overload at rate_mult x base; streaming by default"
    streaming: bool = True
    rate_mult: float = 3.0

    def rate(self, edge, t_ms, cfg):
        return cfg.arrival_rate_per_s * self.rate_mult

    def rate_batch(self, edge, t_ms, cfg):
        return np.full(
            np.asarray(t_ms, np.float64).shape, cfg.arrival_rate_per_s * self.rate_mult
        )

    def rate_bound(self, edge, cfg):
        return cfg.arrival_rate_per_s * self.rate_mult


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalWeekScenario(DiurnalScenario):
    """Seven full diurnal cycles over the horizon — the long-horizon
    nonstationary workload (run it with a large ``horizon_ms``; the
    streaming engine keeps memory bounded regardless)."""

    name: str = "diurnal-week"
    description: str = "seven day/night cycles over the horizon; streaming by default"
    streaming: bool = True
    period_frac: float = 1.0 / 7.0


@register_scenario
@dataclasses.dataclass(frozen=True)
class OutageScenario(Scenario):
    """Mid-run server outage: the per-frame (gamma, eta) budgets of
    ``down_servers`` are masked to zero inside the outage window.  A dead
    server can neither compute (gamma = 0) nor ship requests off its queue
    (eta = 0), so requests covered by a dead *edge* are dropped for the
    window, while the rest of the fleet must route around the hole that the
    dead server leaves in cluster capacity."""

    name: str = "outage"
    description: str = "servers lose all capacity for the middle third of the run"
    outage_start_frac: float = 0.33
    outage_end_frac: float = 0.66
    down_servers: Tuple[int, ...] = (0,)

    def capacity_scale(self, frame_start_ms, cfg, n_edge, n_servers):
        in_outage = (
            self.outage_start_frac * cfg.horizon_ms
            <= frame_start_ms
            < self.outage_end_frac * cfg.horizon_ms
        )
        if not in_outage:
            return None
        scale = np.ones(n_servers, np.float32)
        for j in self.down_servers:
            if 0 <= j < n_servers:
                scale[j] = 0.0
        return scale

    def capacity_scale_batch(self, frame_starts_ms, cfg, n_edge, n_servers):
        return _outage_scale_batch(self, frame_starts_ms, cfg, n_servers)


@register_scenario
@dataclasses.dataclass(frozen=True)
class FlashCrowdOutageScenario(FlashCrowdScenario):
    """The resilience composite: a flash crowd *and* a server outage hit at
    once.  Arrivals follow :class:`FlashCrowdScenario` (``burst_mult`` x on
    the hot edges mid-run) while ``down_servers`` lose all capacity inside
    the same window — the flash crowd lands exactly when the cluster is a
    server short.  This is the stress test for admission control: without
    protection, the doomed burst's committed work snowballs into carried
    backlog (congestion on) and poisons the recovery; queue caps and
    deadline shedding bound the damage."""

    name: str = "flash-crowd-outage"
    description: str = "flash crowd on the hot edges while servers are down"
    outage_start_frac: float = 0.4
    outage_end_frac: float = 0.6
    down_servers: Tuple[int, ...] = (1,)

    def capacity_scale(self, frame_start_ms, cfg, n_edge, n_servers):
        in_outage = (
            self.outage_start_frac * cfg.horizon_ms
            <= frame_start_ms
            < self.outage_end_frac * cfg.horizon_ms
        )
        if not in_outage:
            return None
        scale = np.ones(n_servers, np.float32)
        for j in self.down_servers:
            if 0 <= j < n_servers:
                scale[j] = 0.0
        return scale

    def capacity_scale_batch(self, frame_starts_ms, cfg, n_edge, n_servers):
        return _outage_scale_batch(self, frame_starts_ms, cfg, n_servers)


def _outage_scale_batch(scn, frame_starts_ms, cfg, n_servers):
    """Shared vectorized outage-window mask for the two outage scenarios.

    Bit-identity with the scalar hook: frames inside the window get the
    same float32 ``0.0``/``1.0`` row the scalar hook builds, frames outside
    get exact ``1.0`` (the f64 multiplicative identity).
    """
    t = np.asarray(frame_starts_ms, np.float64)
    in_outage = (scn.outage_start_frac * cfg.horizon_ms <= t) & (
        t < scn.outage_end_frac * cfg.horizon_ms
    )
    if not in_outage.any():
        return None
    out = np.ones((t.size, n_servers), np.float64)
    down = [j for j in scn.down_servers if 0 <= j < n_servers]
    if down:
        out[np.ix_(in_outage, down)] = 0.0
    return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class MegaCityScenario(Scenario):
    """City-scale load: a diurnal swing *multiplied* by a mid-run flash
    crowd on the hot edges, at rates sized for 10^5+ arrivals per frame on
    a ~20-edge cluster (``rate_per_edge_per_s * frame_s * n_edge``).  QoS
    requirements are drawn from *discrete* tiers (accuracy floor x deadline
    multiplier), so the distinct-QoS space stays tiny no matter how many
    users arrive — the workload the hierarchical class-aggregate scheduler
    (:mod:`repro.core.aggregation`) is built for.  Streams and generates
    columnar (``vectorized``) by default; a materialized per-Request trace
    at this scale is exactly what the engine is trying not to build.
    """

    name: str = "mega-city"
    description: str = "10^5+ users/frame: diurnal x flash crowd, discrete QoS tiers"
    streaming: bool = True
    rng_mode: str = "vectorized"
    dense_sweep: bool = False
    rate_per_edge_per_s: float = 2400.0
    amplitude: float = 0.5
    period_frac: float = 1.0
    burst_mult: float = 3.0
    burst_start_frac: float = 0.4
    burst_end_frac: float = 0.6
    hot_edge_stride: int = 2
    acc_tiers: Tuple[float, ...] = (45.0, 55.0, 65.0)
    deadline_mults: Tuple[float, ...] = (0.75, 1.0, 1.5)

    def _hot(self, edge: int) -> bool:
        return edge % self.hot_edge_stride == 0

    def rate(self, edge, t_ms, cfg):
        period = max(cfg.horizon_ms * self.period_frac, 1e-9)
        r = self.rate_per_edge_per_s * (
            1.0 + self.amplitude * math.sin(2.0 * math.pi * t_ms / period)
        )
        in_burst = (
            self.burst_start_frac * cfg.horizon_ms
            <= t_ms
            < self.burst_end_frac * cfg.horizon_ms
        )
        return r * self.burst_mult if (self._hot(edge) and in_burst) else r

    def rate_batch(self, edge, t_ms, cfg):
        t = np.asarray(t_ms, np.float64)
        period = max(cfg.horizon_ms * self.period_frac, 1e-9)
        r = self.rate_per_edge_per_s * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * t / period)
        )
        if not self._hot(edge):
            return r
        in_burst = (self.burst_start_frac * cfg.horizon_ms <= t) & (
            t < self.burst_end_frac * cfg.horizon_ms
        )
        return np.where(in_burst, r * self.burst_mult, r)

    def rate_bound(self, edge, cfg):
        peak = self.rate_per_edge_per_s * (1.0 + self.amplitude)
        return peak * (self.burst_mult if self._hot(edge) else 1.0)

    def draw_qos(self, rng, cfg):
        a = self.acc_tiers[int(rng.integers(0, len(self.acc_tiers)))]
        m = self.deadline_mults[int(rng.integers(0, len(self.deadline_mults)))]
        return float(a), float(cfg.delay_req_ms * m)

    def draw_qos_batch(self, rng, cfg, n):
        a = np.asarray(self.acc_tiers, np.float64)[
            rng.integers(0, len(self.acc_tiers), n)
        ]
        c = cfg.delay_req_ms * np.asarray(self.deadline_mults, np.float64)[
            rng.integers(0, len(self.deadline_mults), n)
        ]
        return a, c
