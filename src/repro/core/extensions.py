"""Beyond-paper extensions to the scheduling core.

1. ``gus_schedule_ordered`` — the paper's GUS processes requests in arrival
   order; a myopic early pick can burn capacity a later request needed more.
   Processing requests by *descending best-achievable US* (a 2-approximation
   flavored greedy) closes part of the gap to the optimum at the same
   O(|N| (|L||M|)^2) complexity (+ one sort).

2. ``priority`` support — the paper's conclusion lists request priorities as
   future work.  We scale each request's US contribution by a priority weight
   p_i (the ILP objective becomes sum p_i US_i X_i); both GUS variants accept
   it and the ordered variant sorts by p_i * best-US.

3. ``apply_mobility`` — the conclusion's other future-work item.  Between
   frames users move: each request's covering edge server re-draws with
   probability ``move_prob`` (a memoryless mobility model).  The simulator
   applies it per frame; scheduling is unchanged (GUS is stateless per frame),
   which is exactly why the paper's per-frame formulation tolerates mobility.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .gus import NEG, Assignment
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = ["gus_schedule_ordered", "best_us_per_request", "apply_mobility"]


def best_us_per_request(inst: FlatInstance) -> jnp.ndarray:
    """(N,) best achievable US per request ignoring capacity (upper bound)."""
    us = us_tensor(inst)
    feas = hard_feasible(inst)
    return jnp.where(feas, us, NEG).max(axis=(-2, -1))


@partial(jax.jit, static_argnames=())
def gus_schedule_ordered(
    inst: FlatInstance, priority: Optional[jnp.ndarray] = None
) -> Assignment:
    """GUS with requests processed in descending (priority ·) best-US order.

    Same greedy inner rule as Algorithm 1; only the processing order differs.
    Returns assignments indexed by the ORIGINAL request order."""
    us = us_tensor(inst)
    feas = hard_feasible(inst)
    N, M, L = us.shape
    if priority is not None:
        us = us * priority[:, None, None]

    best = jnp.where(feas, us, NEG).max(axis=(-2, -1))
    order = jnp.argsort(-best)                     # process most-demanding first

    def body(pos, state):
        gamma, eta, out_j, out_l = state
        i = order[pos]
        s_i = inst.cover[i]
        is_local = jnp.arange(M) == s_i
        ok = (
            feas[i]
            & (inst.v[i] <= gamma[:, None])
            & (is_local[:, None] | (inst.u[i] <= eta[s_i]))
        )
        score = jnp.where(ok, us[i], NEG)
        flat = jnp.argmax(score.reshape(-1))
        any_ok = score.reshape(-1)[flat] > NEG
        j = (flat // L).astype(jnp.int32)
        l = (flat % L).astype(jnp.int32)
        offload = any_ok & (j != s_i)
        gamma = gamma.at[j].add(jnp.where(any_ok, -inst.v[i, j, l], 0.0))
        eta = eta.at[s_i].add(jnp.where(offload, -inst.u[i, j, l], 0.0))
        out_j = out_j.at[i].set(jnp.where(any_ok, j, -1))
        out_l = out_l.at[i].set(jnp.where(any_ok, l, -1))
        return gamma, eta, out_j, out_l

    init = (
        inst.gamma,
        inst.eta,
        jnp.full((N,), -1, jnp.int32),
        jnp.full((N,), -1, jnp.int32),
    )
    _, _, out_j, out_l = jax.lax.fori_loop(0, N, body, init)
    return Assignment(out_j, out_l)


def apply_mobility(cover: np.ndarray, n_edge: int, move_prob: float, rng) -> np.ndarray:
    """Memoryless user mobility: each user re-attaches to a random edge with
    probability ``move_prob`` (numpy; used by the simulator between frames)."""
    move = rng.random(cover.shape[0]) < move_prob
    new = rng.integers(0, n_edge, size=cover.shape[0]).astype(cover.dtype)
    return np.where(move, new, cover)
