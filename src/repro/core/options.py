"""Engine options — one consolidated, frozen configuration object.

``simulate`` and ``simulate_fleet`` grew one keyword argument per feature
axis (backend, rng_mode, streaming, window, prefetch, devices, rep_group,
metrics ... 14 keywords at the fleet entry point) and every site resolved
its own defaults — ``gus.py`` read ``REPRO_GUS_BACKEND`` ad hoc, the
simulator read ``scenario.streaming`` / ``scenario.rng_mode`` inline.  This
module replaces that sprawl with one frozen :class:`EngineOptions` value
accepted as ``options=`` by both entry points, and one
:func:`resolve_options` helper that enforces a single precedence order:

    **explicit argument  >  environment variable  >  scenario default
    >  built-in default**

Environment variables recognized (read at resolve time):

=====================  ========================  =========================
field                  variable                  values
=====================  ========================  =========================
``backend``            ``REPRO_GUS_BACKEND``     ``xla`` | ``pallas``
``rng_mode``           ``REPRO_RNG_MODE``        ``paper-default`` | ``vectorized``
``scheduler``          ``REPRO_SCHEDULER``       ``dense`` | ``hierarchical``
=====================  ========================  =========================

``backend`` is special: its environment fallback is applied at GUS
*dispatch* time (:func:`resolve_backend`, which
:func:`repro.core.gus.resolve_gus_backend` delegates to) rather than baked
into the resolved options.  That keeps the documented behaviour that
``REPRO_GUS_BACKEND`` steers GUS-*cored* policies (``happy_*``) process-wide
even though an explicit ``backend=`` only composes with the default
scheduler / the ``"gus"`` policy.  The precedence order is identical either
way; only the moment of the environment read differs.

The legacy per-call keywords (``simulate_fleet(devices=..., window=...)``)
remain as *deprecated aliases*: they build the same :class:`EngineOptions`,
emit a :class:`DeprecationWarning`, and raise when combined with an
explicit ``options=`` (two configuration styles in one call is always a
conflict).  Old-style and ``options=`` calls resolve to the same object, so
results are bit-identical between the two styles — pinned by
``tests/test_options.py``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Mapping, Optional

__all__ = [
    "EngineOptions",
    "SCHEDULERS",
    "ENV_BACKEND",
    "ENV_RNG_MODE",
    "ENV_SCHEDULER",
    "resolve_options",
    "resolve_backend",
]

#: the two engine scheduling layouts: ``"dense"`` schedules every request
#: row on the N x M x L grid (the paper's formulation); ``"hierarchical"``
#: buckets requests into QoS classes and schedules class aggregates
#: (:mod:`repro.core.aggregation`), the layout for 10^5+ users per frame.
SCHEDULERS = ("dense", "hierarchical")

ENV_BACKEND = "REPRO_GUS_BACKEND"
ENV_RNG_MODE = "REPRO_RNG_MODE"
ENV_SCHEDULER = "REPRO_SCHEDULER"

#: registered GUS backends, mirrored here (not imported) so this module
#: stays import-light; :mod:`repro.core.gus` asserts the two stay in sync.
_BACKENDS = ("xla", "pallas")

#: sentinel distinguishing "keyword not passed" from an explicit ``None``
#: in the deprecated-alias signatures of ``simulate`` / ``simulate_fleet``.
_UNSET = type("_Unset", (), {"__repr__": lambda self: "<unset>"})()


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Execution options shared by ``simulate`` and ``simulate_fleet``.

    Every field defaults to "unset" (``None``) where a scenario or
    environment default exists; :func:`resolve_options` fills those in.
    Fields that only apply to ``simulate_fleet`` (``window``, ``prefetch``,
    ``devices``, ``rep_group``) are ignored by ``simulate``, so one options
    value can drive both entry points.
    """

    #: GUS implementation on the padded hot path (``"xla"`` | ``"pallas"``);
    #: ``None`` defers to ``REPRO_GUS_BACKEND`` at dispatch, else ``"xla"``.
    backend: Optional[str] = None
    #: arrival-RNG draw discipline (``"paper-default"`` | ``"vectorized"``);
    #: ``None`` defers to ``REPRO_RNG_MODE``, then the scenario default.
    rng_mode: Optional[str] = None
    #: bounded-memory streaming arrivals; ``None`` defers to the scenario.
    streaming: Optional[bool] = None
    #: frames per fleet scan window (``None`` = fully materialized).
    window: Optional[int] = None
    #: producer-queue depth overlapping host builds with device compute.
    prefetch: int = 1
    #: device-mesh width for the fleet's replication axis (``None`` = all).
    devices: Optional[int] = None
    #: fixed replication-group width (``None`` = ``FLEET_REP_GROUP``).
    rep_group: Optional[int] = None
    #: record the per-decision metric stream.
    metrics: bool = False
    #: engine scheduling layout (:data:`SCHEDULERS`); ``None`` defers to
    #: ``REPRO_SCHEDULER``, else ``"dense"``.
    scheduler: Optional[str] = None


def _env_choice(env: Mapping[str, str], var: str, allowed, what: str):
    """Read and validate an environment override, or return ``None``."""
    raw = env.get(var)
    if raw is None or raw == "":
        return None
    if raw not in allowed:
        raise ValueError(
            f"environment variable {var}={raw!r} is not a valid {what}; "
            f"expected one of {', '.join(allowed)}"
        )
    return raw


def resolve_backend(backend: Optional[str] = None, env: Optional[Mapping[str, str]] = None) -> str:
    """The GUS-dispatch backend under the standard precedence order:
    explicit ``backend=`` > ``REPRO_GUS_BACKEND`` > ``"xla"``.

    This is the single environment-lookup site for the backend axis —
    :func:`repro.core.gus.resolve_gus_backend` delegates here, so the
    per-call dispatch in ``gus_schedule`` and the options resolution below
    can never disagree on precedence.
    """
    if env is None:
        env = os.environ
    if backend is not None:
        b = backend
    else:
        b = _env_choice(env, ENV_BACKEND, _BACKENDS, "GUS backend") or "xla"
    if b not in _BACKENDS:
        raise ValueError(
            f"unknown GUS backend {b!r}; expected one of {', '.join(_BACKENDS)}"
        )
    return b


def resolve_options(
    options: Optional[EngineOptions] = None,
    scenario=None,
    env: Optional[Mapping[str, str]] = None,
) -> EngineOptions:
    """Fill an :class:`EngineOptions`' unset fields along the precedence
    order **explicit > environment > scenario default > built-in default**.

    * ``rng_mode``  — explicit > ``REPRO_RNG_MODE`` > ``scenario.rng_mode``
      (> ``"paper-default"`` with no scenario); validated.
    * ``streaming`` — explicit > ``scenario.streaming`` (> ``False``).
    * ``scheduler`` — explicit > ``REPRO_SCHEDULER`` > ``"dense"``; validated.
    * ``backend``   — explicit only; the ``REPRO_GUS_BACKEND`` fallback is
      applied at dispatch by :func:`resolve_backend` (see module docstring),
      with identical precedence.
    * ``prefetch`` is clamped to ``>= 0``; ``rep_group``/``devices``/
      ``window`` are validated to be ``None`` or ``>= 1`` (the simulator
      adds the context-dependent checks, e.g. against the visible device
      count).

    Returns a new frozen :class:`EngineOptions` with every deferring field
    resolved; idempotent on an already-resolved value.
    """
    if env is None:
        env = os.environ
    opts = options if options is not None else EngineOptions()
    if not isinstance(opts, EngineOptions):
        raise TypeError(
            f"options must be an EngineOptions, got {type(opts).__name__}"
        )

    if opts.backend is not None:
        resolve_backend(opts.backend, env)  # validate early, resolve at dispatch

    rng_mode = opts.rng_mode
    if rng_mode is None:
        rng_mode = _env_choice(
            env, ENV_RNG_MODE, ("paper-default", "vectorized"), "rng_mode"
        )
    if rng_mode is None:
        rng_mode = scenario.rng_mode if scenario is not None else "paper-default"
    from .scenarios import _resolve_rng_mode

    rng_mode = _resolve_rng_mode(rng_mode)

    streaming = opts.streaming
    if streaming is None:
        streaming = bool(scenario.streaming) if scenario is not None else False

    scheduler = opts.scheduler
    if scheduler is None:
        scheduler = _env_choice(env, ENV_SCHEDULER, SCHEDULERS, "scheduler") or "dense"
    if scheduler not in SCHEDULERS:
        raise ValueError(
            f"unknown scheduler {scheduler!r}; expected one of {', '.join(SCHEDULERS)}"
        )

    for field in ("window", "devices", "rep_group"):
        val = getattr(opts, field)
        if val is not None and int(val) < 1:
            raise ValueError(f"{field} must be >= 1 or None, got {val}")

    return dataclasses.replace(
        opts,
        rng_mode=rng_mode,
        streaming=bool(streaming),
        scheduler=scheduler,
        prefetch=max(0, int(opts.prefetch)),
    )


def fold_deprecated_kwargs(
    options: Optional[EngineOptions], deprecated: dict, *, caller: str
) -> EngineOptions:
    """Merge the legacy per-call keywords into an :class:`EngineOptions`.

    ``deprecated`` maps field names to the values the caller received, with
    :data:`_UNSET` marking "not passed".  Any passed legacy keyword emits
    one :class:`DeprecationWarning` naming the offenders; combining legacy
    keywords with an explicit ``options=`` raises (the two styles cannot be
    merged without guessing which side wins).
    """
    passed = {k: v for k, v in deprecated.items() if v is not _UNSET}
    if options is not None:
        if passed:
            raise ValueError(
                f"{caller}() got both options= and the deprecated keyword(s) "
                f"{sorted(passed)}; move them into EngineOptions"
            )
        return options
    if passed:
        warnings.warn(
            f"{caller}({', '.join(sorted(passed))}) — per-call engine keywords are "
            f"deprecated; pass options=EngineOptions(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    return EngineOptions(**passed)
