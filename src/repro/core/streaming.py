"""Streaming arrival engine — frame-by-frame arrivals with bounded memory.

``Scenario.generate_arrivals`` materializes a replication's *entire* request
trace up front: fine for the paper's 2-minute horizons, prohibitive for
long-horizon (10^5+ frames) or nonstationary workloads.  An
:class:`ArrivalStream` generates the same kind of thinned-Poisson traffic
*online*: memory is O(n_edge) — one pending arrival per edge in a heap plus
the current frame's buffer — regardless of horizon.

Determinism and chunking invariance
-----------------------------------

Each edge draws from its own child generator, spawned from a root
``numpy.random.SeedSequence(seed)``.  Per edge, the draw order is exactly
the scenario's materialized loop (exponential gap, thinning acceptance,
service, QoS, size), so a ``(scenario, seed)`` pair fully determines the
trace — and because edges never share a stream, *when* arrivals are pulled
cannot change *what* is drawn: draining the stream frame-by-frame yields
bit-identical requests to draining it in one shot
(``tests/test_streaming.py`` pins this for every registered scenario).

The stream pops arrivals in global time order (the heap invariant: every
pushed next-arrival is later than the pop that produced it), so ``rid``s
are assigned in arrival order exactly like the materialized path.

The resilience layer's link/outage state is invariant the same way for a
different reason: :class:`repro.core.impairments.LinkTrace` is indexed by
*frame number* and memoized (the value at frame ``t`` depends only on the
profile, the seed, and ``t``), so how arrivals are pulled — streaming or
materialized, windowed or one-shot — cannot change which network weather
a frame sees.

RNG modes
---------

``rng_mode="paper-default"`` (the default, deferring to the scenario's
flag) draws per request, bit-identical to every pre-vectorization trace.
``rng_mode="vectorized"`` buffers each edge's process in numpy chunks
(:func:`repro.core.scenarios.iter_edge_arrival_chunks` — batched
exponential gaps + thinning), ~10x faster and chunking-invariant by the
same argument: each edge's chunk sequence depends only on its own
generator, so the pull pattern cannot change the draws.  The two modes
consume the RNG in different orders and therefore produce different (but
identically distributed) traces; pick per run, keep per study.

Usage::

    stream = ArrivalStream("sustained-overload", seed=0, n_edge=4,
                           n_services=3, cfg=cfg)
    while not stream.exhausted:
        frame = stream.take_until(t + cfg.frame_ms)   # bounded memory
        ...

``simulate(..., streaming=True)`` (or a scenario registered with
``streaming=True`` — see ``sustained-overload`` / ``diurnal-week``) runs
the testbed off a stream instead of a materialized trace.
"""
from __future__ import annotations

import heapq
import math
from typing import List, Optional, Union

import numpy as np

from repro.obs.trace import CAT_GEN, span

from .scenarios import (
    Request,
    RequestColumns,
    Scenario,
    _resolve_rng_mode,
    edge_arrival_columns,
    get_scenario,
    iter_edge_arrival_chunks,
)

__all__ = [
    "ArrivalStream",
    "stream_trace",
    "stream_trace_columns",
    "max_frame_arrivals",
]


class _VecEdgeBuffer:
    """One edge's chunk-buffered vectorized arrival process.

    Wraps :func:`repro.core.scenarios.iter_edge_arrival_chunks`; holds the
    current chunk's columns plus a cursor, so memory stays O(chunk) while
    the stream pops arrivals one at a time in time order.
    """

    __slots__ = ("_chunks", "_cols", "_pos")

    def __init__(self, scn, rng, edge, n_services, cfg, horizon_ms):
        self._chunks = iter_edge_arrival_chunks(
            scn, rng, edge, n_services, cfg, horizon_ms
        )
        self._cols = None
        self._pos = 0

    def peek_ms(self) -> Optional[float]:
        """Next arrival time, refilling from the chunk iterator; None at end."""
        while self._cols is None or self._pos >= self._cols[0].size:
            nxt = next(self._chunks, None)
            if nxt is None:
                return None
            self._cols = nxt
            self._pos = 0
        return float(self._cols[0][self._pos])

    def pop(self):
        """(t, service, A, C, size) of the arrival ``peek_ms`` looked at."""
        ts, svc, a, c, size = self._cols
        i = self._pos
        self._pos += 1
        return (
            float(ts[i]), int(svc[i]), float(a[i]), float(c[i]), float(size[i]),
        )


class ArrivalStream:
    """Online thinned-Poisson arrival generator for one replication.

    Memory is bounded: one lookahead arrival time per edge (a heap) plus
    whatever the caller pulls per frame — in vectorized mode, plus one
    numpy chunk per edge.  See the module docstring for the determinism
    contract.
    """

    def __init__(
        self,
        scenario: Union[str, Scenario],
        seed: int,
        n_edge: int,
        n_services: int,
        cfg,
        horizon_ms: Optional[float] = None,
        rng_mode: Optional[str] = None,
    ):
        self.scenario = get_scenario(scenario)
        self.cfg = cfg
        self.n_services = n_services
        self.horizon_ms = cfg.horizon_ms if horizon_ms is None else horizon_ms
        self.rng_mode = _resolve_rng_mode(
            self.scenario.rng_mode if rng_mode is None else rng_mode
        )
        root = np.random.SeedSequence(seed)
        self._rngs = [np.random.default_rng(s) for s in root.spawn(n_edge)]
        self._heap: List[tuple] = []
        self._n_emitted = 0
        self._vec: Optional[List[_VecEdgeBuffer]] = None
        if self.rng_mode == "vectorized":
            self._vec = [
                _VecEdgeBuffer(
                    self.scenario, self._rngs[e], e, n_services, cfg, self.horizon_ms
                )
                for e in range(n_edge)
            ]
            for e, buf in enumerate(self._vec):
                t = buf.peek_ms()
                if t is not None:
                    heapq.heappush(self._heap, (t, e))
        else:
            for e in range(n_edge):
                t = self._next_accepted(e, 0.0)
                if t is not None:
                    heapq.heappush(self._heap, (t, e))

    @property
    def n_emitted(self) -> int:
        """Requests emitted so far (the next rid)."""
        return self._n_emitted

    @property
    def exhausted(self) -> bool:
        """True once every edge's process has run past the horizon."""
        return not self._heap

    def peek_ms(self) -> float:
        """Arrival time of the next pending request (inf when exhausted)."""
        return self._heap[0][0] if self._heap else math.inf

    def _next_accepted(self, edge: int, t: float) -> Optional[float]:
        """Next *accepted* arrival at ``edge`` strictly after ``t`` via
        thinning against ``rate_bound`` (same draw order as the
        materialized ``Scenario.generate_arrivals`` loop), or ``None`` once
        the process passes the horizon."""
        rng = self._rngs[edge]
        rmax = float(self.scenario.rate_bound(edge, self.cfg))
        if rmax <= 0.0:
            return None
        while True:
            t += rng.exponential(1000.0 / rmax)
            if t >= self.horizon_ms:
                return None
            r_t = float(self.scenario.rate(edge, t, self.cfg))
            if r_t >= rmax or rng.random() < r_t / rmax:
                return t

    def take_until(self, t_ms: float) -> List[Request]:
        """Pop every arrival with ``arrival_ms < t_ms``, in arrival order."""
        cfg = self.cfg
        out: List[Request] = []
        while self._heap and self._heap[0][0] < t_ms:
            t, e = heapq.heappop(self._heap)
            if self._vec is not None:
                buf = self._vec[e]
                t, service, a, c, size = buf.pop()
                nxt = buf.peek_ms()
            else:
                rng = self._rngs[e]
                service = int(rng.integers(0, self.n_services))
                a, c = self.scenario.draw_qos(rng, cfg)
                size = float(rng.uniform(cfg.req_size_lo, cfg.req_size_hi))
                nxt = self._next_accepted(e, t)
            out.append(
                Request(
                    rid=self._n_emitted,
                    arrival_ms=t,
                    cover=e,
                    service=service,
                    A=a,
                    C=c,
                    size_bytes=size,
                )
            )
            self._n_emitted += 1
            if nxt is not None:
                heapq.heappush(self._heap, (nxt, e))
        return out


def stream_trace(
    scenario: Union[str, Scenario],
    seed: int,
    n_edge: int,
    n_services: int,
    cfg,
    rng_mode: Optional[str] = None,
) -> List[Request]:
    """Drain a fresh :class:`ArrivalStream` in one shot (the materialized
    view of the streaming process — reference path for parity tests and for
    the fleet runner on ``streaming=True`` scenarios)."""
    with span("stream/drain_trace", CAT_GEN, seed=seed):
        stream = ArrivalStream(
            scenario, seed, n_edge, n_services, cfg, rng_mode=rng_mode
        )
        return stream.take_until(math.inf)


def stream_trace_columns(
    scenario: Union[str, Scenario],
    seed: int,
    n_edge: int,
    n_services: int,
    cfg,
) -> RequestColumns:
    """The vectorized stream's full trace as columns, without Request objects.

    Bit-identical values to ``stream_trace(..., rng_mode="vectorized")``:
    the same spawned per-edge generators drain the same chunk iterators
    (:func:`~repro.core.scenarios.iter_edge_arrival_chunks`), and the stable
    sort reproduces the heap's tie order (per-edge emission order).  The
    fleet's materialized grid builder consumes this directly.
    """
    with span("stream/trace_columns", CAT_GEN, seed=seed):
        scn = get_scenario(scenario)
        root = np.random.SeedSequence(seed)
        parts: List[RequestColumns] = []
        for e, ss in enumerate(root.spawn(n_edge)):
            rng = np.random.default_rng(ss)
            parts.extend(
                edge_arrival_columns(scn, rng, e, n_services, cfg, cfg.horizon_ms)
            )
        return RequestColumns.concatenate(parts).sorted_by_arrival()


def max_frame_arrivals(
    scenario: Union[str, Scenario],
    seed: int,
    n_edge: int,
    n_services: int,
    cfg,
    n_frames: int,
    rng_mode: Optional[str] = None,
) -> int:
    """Largest per-frame arrival count of one replication, in bounded memory.

    Counting pre-pass over a *fresh* :class:`ArrivalStream` (determinism
    makes it draw the exact trace the caller will stream afterwards): each
    frame's requests are drawn, counted, and discarded.  The windowed fleet
    uses this to fix its padding bucket up front — every window then shares
    one compiled shape AND the bucket matches the materialized path's
    global maximum, which is what makes windowed-vs-materialized results
    bit-identical.

    In ``rng_mode="vectorized"`` the pass never builds ``Request`` objects:
    each edge's chunk iterator (the exact draws the stream will make) is
    drained and histogrammed into per-frame counts directly.
    """
    with span("stream/count_prepass", CAT_GEN, seed=seed):
        scn = get_scenario(scenario)
        mode = _resolve_rng_mode(scn.rng_mode if rng_mode is None else rng_mode)
        if mode == "vectorized":
            counts = np.zeros(n_frames, np.int64)
            root = np.random.SeedSequence(seed)
            for e, ss in enumerate(root.spawn(n_edge)):
                rng = np.random.default_rng(ss)
                for ts, *_ in iter_edge_arrival_chunks(
                    scn, rng, e, n_services, cfg, cfg.horizon_ms
                ):
                    idx = np.minimum(
                        (ts // cfg.frame_ms).astype(np.int64), n_frames - 1
                    )
                    np.add.at(counts, idx, 1)
            return int(counts.max()) if n_frames else 0
        stream = ArrivalStream(scenario, seed, n_edge, n_services, cfg, rng_mode=mode)
        mx = 0
        for tf in range(n_frames):
            mx = max(mx, len(stream.take_until((tf + 1) * cfg.frame_ms)))
        return mx
