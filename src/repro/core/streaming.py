"""Streaming arrival engine — frame-by-frame arrivals with bounded memory.

``Scenario.generate_arrivals`` materializes a replication's *entire* request
trace up front: fine for the paper's 2-minute horizons, prohibitive for
long-horizon (10^5+ frames) or nonstationary workloads.  An
:class:`ArrivalStream` generates the same kind of thinned-Poisson traffic
*online*: memory is O(n_edge) — one pending arrival per edge in a heap plus
the current frame's buffer — regardless of horizon.

Determinism and chunking invariance
-----------------------------------

Each edge draws from its own child generator, spawned from a root
``numpy.random.SeedSequence(seed)``.  Per edge, the draw order is exactly
the scenario's materialized loop (exponential gap, thinning acceptance,
service, QoS, size), so a ``(scenario, seed)`` pair fully determines the
trace — and because edges never share a stream, *when* arrivals are pulled
cannot change *what* is drawn: draining the stream frame-by-frame yields
bit-identical requests to draining it in one shot
(``tests/test_streaming.py`` pins this for every registered scenario).

The stream pops arrivals in global time order (the heap invariant: every
pushed next-arrival is later than the pop that produced it), so ``rid``s
are assigned in arrival order exactly like the materialized path.

Usage::

    stream = ArrivalStream("sustained-overload", seed=0, n_edge=4,
                           n_services=3, cfg=cfg)
    while not stream.exhausted:
        frame = stream.take_until(t + cfg.frame_ms)   # bounded memory
        ...

``simulate(..., streaming=True)`` (or a scenario registered with
``streaming=True`` — see ``sustained-overload`` / ``diurnal-week``) runs
the testbed off a stream instead of a materialized trace.
"""
from __future__ import annotations

import heapq
import math
from typing import List, Optional, Union

import numpy as np

from .scenarios import Request, Scenario, get_scenario

__all__ = ["ArrivalStream", "stream_trace", "max_frame_arrivals"]


class ArrivalStream:
    """Online thinned-Poisson arrival generator for one replication.

    Memory is bounded: one lookahead arrival time per edge (a heap) plus
    whatever the caller pulls per frame.  See the module docstring for the
    determinism contract.
    """

    def __init__(
        self,
        scenario: Union[str, Scenario],
        seed: int,
        n_edge: int,
        n_services: int,
        cfg,
        horizon_ms: Optional[float] = None,
    ):
        self.scenario = get_scenario(scenario)
        self.cfg = cfg
        self.n_services = n_services
        self.horizon_ms = cfg.horizon_ms if horizon_ms is None else horizon_ms
        root = np.random.SeedSequence(seed)
        self._rngs = [np.random.default_rng(s) for s in root.spawn(n_edge)]
        self._heap: List[tuple] = []
        self._n_emitted = 0
        for e in range(n_edge):
            t = self._next_accepted(e, 0.0)
            if t is not None:
                heapq.heappush(self._heap, (t, e))

    @property
    def n_emitted(self) -> int:
        """Requests emitted so far (the next rid)."""
        return self._n_emitted

    @property
    def exhausted(self) -> bool:
        """True once every edge's process has run past the horizon."""
        return not self._heap

    def peek_ms(self) -> float:
        """Arrival time of the next pending request (inf when exhausted)."""
        return self._heap[0][0] if self._heap else math.inf

    def _next_accepted(self, edge: int, t: float) -> Optional[float]:
        """Next *accepted* arrival at ``edge`` strictly after ``t`` via
        thinning against ``rate_bound`` (same draw order as the
        materialized ``Scenario.generate_arrivals`` loop), or ``None`` once
        the process passes the horizon."""
        rng = self._rngs[edge]
        rmax = float(self.scenario.rate_bound(edge, self.cfg))
        if rmax <= 0.0:
            return None
        while True:
            t += rng.exponential(1000.0 / rmax)
            if t >= self.horizon_ms:
                return None
            r_t = float(self.scenario.rate(edge, t, self.cfg))
            if r_t >= rmax or rng.random() < r_t / rmax:
                return t

    def take_until(self, t_ms: float) -> List[Request]:
        """Pop every arrival with ``arrival_ms < t_ms``, in arrival order."""
        cfg = self.cfg
        out: List[Request] = []
        while self._heap and self._heap[0][0] < t_ms:
            t, e = heapq.heappop(self._heap)
            rng = self._rngs[e]
            service = int(rng.integers(0, self.n_services))
            a, c = self.scenario.draw_qos(rng, cfg)
            out.append(
                Request(
                    rid=self._n_emitted,
                    arrival_ms=t,
                    cover=e,
                    service=service,
                    A=a,
                    C=c,
                    size_bytes=float(rng.uniform(cfg.req_size_lo, cfg.req_size_hi)),
                )
            )
            self._n_emitted += 1
            nxt = self._next_accepted(e, t)
            if nxt is not None:
                heapq.heappush(self._heap, (nxt, e))
        return out


def stream_trace(
    scenario: Union[str, Scenario],
    seed: int,
    n_edge: int,
    n_services: int,
    cfg,
) -> List[Request]:
    """Drain a fresh :class:`ArrivalStream` in one shot (the materialized
    view of the streaming process — reference path for parity tests and for
    the fleet runner on ``streaming=True`` scenarios)."""
    stream = ArrivalStream(scenario, seed, n_edge, n_services, cfg)
    return stream.take_until(math.inf)


def max_frame_arrivals(
    scenario: Union[str, Scenario],
    seed: int,
    n_edge: int,
    n_services: int,
    cfg,
    n_frames: int,
) -> int:
    """Largest per-frame arrival count of one replication, in bounded memory.

    Counting pre-pass over a *fresh* :class:`ArrivalStream` (determinism
    makes it draw the exact trace the caller will stream afterwards): each
    frame's requests are drawn, counted, and discarded.  The windowed fleet
    uses this to fix its padding bucket up front — every window then shares
    one compiled shape AND the bucket matches the materialized path's
    global maximum, which is what makes windowed-vs-materialized results
    bit-identical.
    """
    stream = ArrivalStream(scenario, seed, n_edge, n_services, cfg)
    mx = 0
    for tf in range(n_frames):
        mx = max(mx, len(stream.take_until((tf + 1) * cfg.frame_ms)))
    return mx
