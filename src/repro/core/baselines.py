"""The paper's five baseline heuristics (Sec. IV, "Baseline algorithms").

All share GUS's feasibility rules (2b/2c + capacities) but differ in *which*
servers they consider:

1. Random-Assignment  — one uniformly-random server per request.
2. Offload-All        — cloud servers only.
3. Local-All          — the covering edge server only.
4. Happy-Computation  — GUS with the computation constraint (2d) relaxed.
5. Happy-Communication— GUS with the communication constraint (2e) relaxed.

All are jit/vmap-compatible like ``gus_schedule``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .gus import NEG, Assignment, gus_schedule
from .instance import FlatInstance
from .satisfaction import hard_feasible, us_tensor

__all__ = [
    "random_assignment",
    "offload_all",
    "local_all",
    "happy_computation",
    "happy_communication",
    "BASELINES",
]


def _restricted_greedy(inst: FlatInstance, server_mask_per_req: jnp.ndarray) -> Assignment:
    """Greedy sequential assignment restricted to ``server_mask_per_req``
    ((N, M) bool).  Within the allowed servers, picks the best-US feasible
    variant; capacities update sequentially as in GUS."""
    us = us_tensor(inst)
    feas = hard_feasible(inst) & server_mask_per_req[:, :, None]
    N, M, L = us.shape

    def body(i, state):
        gamma, eta, out_j, out_l = state
        s_i = inst.cover[i]
        is_local = jnp.arange(M) == s_i
        ok = (
            feas[i]
            & (inst.v[i] <= gamma[:, None])
            & (is_local[:, None] | (inst.u[i] <= eta[s_i]))
        )
        score = jnp.where(ok, us[i], NEG)
        flat = jnp.argmax(score.reshape(-1))
        any_ok = score.reshape(-1)[flat] > NEG
        j = (flat // L).astype(jnp.int32)
        l = (flat % L).astype(jnp.int32)
        offload = any_ok & (j != s_i)
        gamma = gamma.at[j].add(jnp.where(any_ok, -inst.v[i, j, l], 0.0))
        eta = eta.at[s_i].add(jnp.where(offload, -inst.u[i, j, l], 0.0))
        out_j = out_j.at[i].set(jnp.where(any_ok, j, -1))
        out_l = out_l.at[i].set(jnp.where(any_ok, l, -1))
        return gamma, eta, out_j, out_l

    init = (
        inst.gamma,
        inst.eta,
        jnp.full((N,), -1, jnp.int32),
        jnp.full((N,), -1, jnp.int32),
    )
    _, _, out_j, out_l = jax.lax.fori_loop(0, N, body, init)
    return Assignment(out_j, out_l)


@partial(jax.jit, static_argnames=())
def random_assignment(inst: FlatInstance, key: jax.Array) -> Assignment:
    """Paper baseline 1: a single random server is drawn per request; serve
    there if feasible, else drop."""
    N, M, _ = inst.acc.shape
    picks = jax.random.randint(key, (N,), 0, M)
    mask = jax.nn.one_hot(picks, M, dtype=bool)
    return _restricted_greedy(inst, mask)


@jax.jit
def offload_all(inst: FlatInstance, cloud_mask: jnp.ndarray) -> Assignment:
    """Paper baseline 2: every request goes to the cloud tier.

    ``cloud_mask``: (M,) bool marking cloud servers."""
    N = inst.A.shape[0]
    mask = jnp.broadcast_to(cloud_mask[None, :], (N, cloud_mask.shape[0]))
    return _restricted_greedy(inst, mask)


@jax.jit
def local_all(inst: FlatInstance) -> Assignment:
    """Paper baseline 3: only the covering edge server is considered."""
    N, M, _ = inst.acc.shape
    mask = inst.cover[:, None] == jnp.arange(M)[None, :]
    return _restricted_greedy(inst, mask)


def happy_computation(inst: FlatInstance) -> Assignment:
    """Paper baseline 4: computation constraint (2d) relaxed."""
    return gus_schedule(inst, relax_compute=True)


def happy_communication(inst: FlatInstance) -> Assignment:
    """Paper baseline 5: communication constraint (2e) relaxed."""
    return gus_schedule(inst, relax_comm=True)


BASELINES = {
    "random": random_assignment,
    "offload_all": offload_all,
    "local_all": local_all,
    "happy_computation": happy_computation,
    "happy_communication": happy_communication,
}
