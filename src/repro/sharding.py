"""Logical-axis sharding (MaxText-style) with a divisibility fallback.

Model code annotates tensors with *logical* axis names ("vocab", "heads",
"ff", "experts", "batch", ...).  A rules table maps logical axes to mesh axes;
at resolve time any mesh axis that does not evenly divide the dim is dropped
(e.g. kv_heads=4 on a model=16 mesh axis -> replicated), so the same model
code lowers on every mesh without per-arch special cases.

Activation constraints go through a context (``sharding_ctx``) so the model
code stays mesh-agnostic: outside a context they are no-ops (CPU tests), and
under ``use_sharding(mesh, rules)`` they become ``with_sharding_constraint``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "resolve_spec",
    "shard",
    "use_sharding",
    "current_ctx",
    "spec_for_shape",
    "named_sharding_for",
]

MeshAxes = Union[str, Tuple[str, ...], None]

# Logical axis -> mesh axis/axes.  "pod" composes with "data" for pure-DP
# across pods (DCN-friendly: only gradient/infeed collectives cross pods).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": None,            # d_model replicated (Megatron-style)
    "heads": "model",         # query heads
    "kv_heads": "model",      # falls back to replication when kv < mesh
    "head_dim": None,
    "ff": "model",
    "experts": "model",       # expert parallelism
    "expert_ff": None,
    "seq": None,              # no context parallelism in the baseline
    "kv_seq": None,
    "d_inner": "model",       # mamba inner channels
    "ssm_heads": "model",
    "ssm_headdim": None,   # fallback when ssm_heads cannot divide the mesh
    "state": None,
    "conv": None,
    "layers": None,           # stacked-scan leading axis
    "capacity": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = DEFAULT_RULES


_ctx = _Ctx()


def current_ctx() -> Tuple[Optional[Mesh], Dict[str, MeshAxes]]:
    return _ctx.mesh, _ctx.rules


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate activation-sharding constraints for model code in scope."""
    prev = (_ctx.mesh, _ctx.rules)
    _ctx.mesh = mesh
    _ctx.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def resolve_spec(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> P:
    """Logical names -> PartitionSpec, dropping non-dividing/absent mesh axes."""
    rules = rules or _ctx.rules or DEFAULT_RULES
    assert len(shape) == len(logical), (shape, logical)
    mesh_axes_present = set(mesh.axis_names)
    out, used = [], set()
    for dim, name in zip(shape, logical):
        mapped = rules.get(name) if name else None
        if mapped is None:
            out.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        keep = []
        size_so_far = 1
        for a in axes:
            if a not in mesh_axes_present or a in used:
                continue
            a_size = _axis_size(mesh, a)
            if dim % (size_so_far * a_size) == 0:
                keep.append(a)
                size_so_far *= a_size
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_for_shape(shape, logical, mesh=None, rules=None) -> P:
    mesh = mesh or _ctx.mesh
    if mesh is None:
        return P()
    return resolve_spec(shape, logical, mesh, rules)


def named_sharding_for(shape, logical, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes; no-op outside use_sharding."""
    mesh = _ctx.mesh
    if mesh is None:
        return x
    spec = resolve_spec(np.shape(x), logical, mesh, _ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
