import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "10")
"""Multi-pod dry-run (deliverable (e)).

Lowers + compiles every (architecture × input shape) on the production meshes
(16x16 single-pod and 2x16x16 multi-pod) with ShapeDtypeStruct inputs — no
device allocation — and records memory_analysis / cost_analysis / collective
bytes for the roofline table.

The two lines above MUST run before any other import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]
"""
import argparse
import sys
import time
import traceback


from ..configs import ARCH_IDS, get_config
from ..models.model import Model
from ..roofline import roofline_terms
from .mesh import make_production_mesh, mesh_name
from .specs import SHAPES, model_flops, shape_config
from .steps import build_prefill_step, build_serve_step, build_train_step


def _compile(cfg, shape, mesh, rules):
    model = Model(cfg)
    if shape.kind == "train":
        fn, args = build_train_step(model, mesh, shape, rules=rules)
    elif shape.kind == "prefill":
        fn, args = build_prefill_step(model, mesh, shape, rules=rules)
    else:
        fn, args = build_serve_step(model, mesh, shape, rules=rules)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _counts(compiled):
    from ..roofline import counts_from_artifacts

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return counts_from_artifacts(cost, compiled.as_text()), cost


def _loop_corrected_counts(cfg, shape, mesh, rules):
    """XLA:CPU cost_analysis counts lax.scan bodies once.  For scanned-layer
    models, compile UNROLLED 1-layer and 2-layer variants (cheap) and
    extrapolate:  total(L) = base + L * body  with  body = c2 - c1."""
    import dataclasses as dc

    def small(k):
        kw = dict(num_layers=k, scan_layers=False)
        if cfg.family == "encdec":
            kw["num_enc_layers"] = k
        return dc.replace(cfg, **kw)

    out = {}
    per_kind = {}
    c = {}
    for k in (1, 2):
        _, comp = _compile(small(k), shape, mesh, rules)
        c[k], _ = _counts(comp)
        del comp
    L = cfg.num_layers
    for key in ("flops", "bytes", "coll"):
        body = max(c[2][key] - c[1][key], 0.0)
        base = max(c[1][key] - body, 0.0)
        out[key] = base + L * body
    for kind in c[1]["coll_breakdown"]:
        body = max(c[2]["coll_breakdown"][kind] - c[1]["coll_breakdown"][kind], 0)
        base = max(c[1]["coll_breakdown"][kind] - body, 0)
        per_kind[kind] = base + L * body
    out["coll_breakdown"] = per_kind
    out["coll"] = float(sum(per_kind.values()))
    return out


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False, rules=None,
              loop_correct: bool = True, cfg_patch=None, opt: bool = False):
    """Returns (lowered, compiled, report) for one combination.

    ``cfg_patch`` (perf experiments) is applied AFTER shape_config so it wins
    over per-shape defaults like auto-remat.  ``opt`` applies the beyond-paper
    recommended settings found in §Perf: chunked flash-style attention +
    dots-saveable remat for train/prefill, kv_seq->model cache sharding for
    decode."""
    import dataclasses as _dc

    shape = SHAPES[shape_name]
    cfg = shape_config(get_config(arch), shape)
    if opt:
        if shape.kind in ("train", "prefill"):
            cfg = _dc.replace(cfg, attn_impl="chunked", remat_policy="dots")
        elif cfg.num_kv_heads % 16 != 0:
            # kv_seq sharding pays off ONLY when kv_heads cannot shard the
            # 16-way model axis (else it trades away head locality — measured
            # 3-10x regressions on kv=16 archs, see §Perf)
            from ..sharding import DEFAULT_RULES

            rules = dict(DEFAULT_RULES, kv_seq="model", **(rules or {}))
    if cfg_patch:
        cfg = _dc.replace(cfg, **cfg_patch)
    mesh = make_production_mesh(multi_pod=multi_pod)

    lowered, compiled = _compile(cfg, shape, mesh, rules)

    try:
        mem = compiled.memory_analysis()
        mem_str = str(mem)
    except Exception as e:  # CPU backend may not implement it
        mem_str = f"unavailable ({e})"
    raw_counts, cost = _counts(compiled)

    corrected = None
    if loop_correct and cfg.scan_layers:
        corrected = _loop_corrected_counts(cfg, shape, mesh, rules)
        # never report less than the raw artifact
        for key in ("flops", "bytes", "coll"):
            corrected[key] = max(corrected[key], raw_counts[key])

    report = roofline_terms(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name(mesh),
        n_devices=mesh.devices.size,
        cost_analysis=cost,
        hlo_text=compiled.as_text(),
        model_flops_total=model_flops(cfg, shape),
        memory_analysis=mem_str,
        corrected_counts=corrected,
    )
    return lowered, compiled, report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the beyond-paper optimized settings (§Perf)")
    ap.add_argument(
        "--no-loop-correct", dest="loop_correct", action="store_false",
        help="skip the 1/2-layer extrapolation fixing XLA:CPU's scan-body "
             "flop undercount (use for multi-pod lowering-only passes)",
    )
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
            if args.opt:
                tag += "__opt"
            t0 = time.time()
            try:
                _, compiled, report = lower_one(
                    arch, shape, multi_pod=args.multi_pod,
                    loop_correct=args.loop_correct, opt=args.opt,
                )
                if args.opt:
                    report.mesh += "+opt"
                report.save(os.path.join(args.out, tag + ".json"))
                print(f"[OK {time.time()-t0:6.1f}s] {report.row()}", flush=True)
                del compiled
            except Exception:
                n_fail += 1
                print(f"[FAIL {time.time()-t0:6.1f}s] {tag}", flush=True)
                traceback.print_exc()
                if not args.continue_on_error:
                    return 1
    print(f"done: {len(archs)*len(shapes)-n_fail} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
