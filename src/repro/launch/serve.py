"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Runs batched prefill+decode through the ServingEngine (reduced config on CPU)
and prints measured latencies — the numbers a production deployment would
feed back into the GUS scheduler's T^proc table."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, reduce_for_smoke
from ..models.model import Model
from ..serving import ServingEngine
from ..training import make_batch


def serve(arch: str, *, smoke: bool = True, batch: int = 4, prompt: int = 32, gen: int = 16, seed: int = 0):
    cfg = get_config(arch)
    if smoke:
        cfg = reduce_for_smoke(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params)
    b = make_batch(cfg, batch, prompt, np.random.default_rng(seed))
    res = eng.generate(b, max_new_tokens=gen)
    print(
        f"{arch}: batch={batch} prompt={prompt} gen={gen} -> "
        f"prefill={res.prefill_ms:.1f}ms decode={res.decode_ms_per_token:.2f}ms/tok "
        f"total={res.total_ms:.1f}ms"
    )
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["squeeze-lm", "mid-lm", "google-lm"], default="squeeze-lm")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    serve(args.arch, batch=args.batch, prompt=args.prompt, gen=args.gen)


if __name__ == "__main__":
    main()
