"""Sharded (pjit) step builders: train / prefill / serve, with in/out
shardings resolved from the logical-axis rule tables in ``repro.sharding``.

Rule profiles:
  * TRAIN_RULES — 2-D weight sharding: model-parallel dim on `model`, the
    complementary dim on `data` (FSDP-style; AdamW moments inherit it, so
    optimizer state is fully sharded across the pod).
  * SERVE_RULES — tensor-parallel weights on `model`, replicated across
    `data`; decode must not all-gather weights every token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.model import DecodeCache, Model
from ..sharding import DEFAULT_RULES, named_sharding_for, use_sharding
from ..training.optimizer import AdamWConfig
from ..training.train_loop import TrainState, make_train_step
from ..serving.engine import make_serve_step
from .specs import ShapeSpec, abstract_cache, abstract_state, input_specs

__all__ = [
    "TRAIN_RULES",
    "SERVE_RULES",
    "params_shardings",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
    "build_train_step",
    "build_prefill_step",
    "build_serve_step",
]

TRAIN_RULES = dict(DEFAULT_RULES, embed="data", d_inner_in=None)
SERVE_RULES = dict(DEFAULT_RULES)


def _ns(mesh, shape, logical, rules):
    return named_sharding_for(shape, logical, mesh, rules)


def params_shardings(model: Model, mesh: Mesh, rules) -> Any:
    aparams = model.abstract_params()
    logical = model.param_logical_specs()
    return jax.tree.map(
        lambda p, lg: _ns(mesh, p.shape, lg, rules), aparams, logical
    )


def state_shardings(model: Model, mesh: Mesh, rules) -> TrainState:
    ps = params_shardings(model, mesh, rules)
    rep = NamedSharding(mesh, P())
    from ..training.optimizer import AdamWState

    return TrainState(
        params=ps, opt=AdamWState(step=rep, m=ps, v=ps)
    )


def batch_shardings(cfg: ModelConfig, specs: Dict[str, Any], mesh: Mesh, rules) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        logical = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = _ns(mesh, v.shape, logical, rules)
    return out


def cache_shardings(model: Model, acache: DecodeCache, mesh: Mesh, rules) -> DecodeCache:
    def kv_spec(x):
        return _ns(mesh, x.shape, ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), rules)

    attn = (
        {k: kv_spec(v) for k, v in acache.attn.items()} if acache.attn is not None else None
    )
    cross = (
        {k: kv_spec(v) for k, v in acache.cross.items()} if acache.cross is not None else None
    )
    conv = (
        _ns(mesh, acache.conv.shape, ("layers", "batch", "conv", "d_inner"), rules)
        if acache.conv is not None
        else None
    )
    ssm = (
        _ns(mesh, acache.ssm.shape, ("layers", "batch", "ssm_heads", "state", "head_dim"), rules)
        if acache.ssm is not None
        else None
    )
    return DecodeCache(
        index=NamedSharding(mesh, P()), attn=attn, conv=conv, ssm=ssm, cross=cross
    )


# ---------------------------------------------------------------------------
# step builders — each returns (jitted_fn, example_abstract_args)
# ---------------------------------------------------------------------------

def build_train_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeSpec,
    rules: Optional[dict] = None,
    opt_cfg: Optional[AdamWConfig] = None,
):
    rules = rules or TRAIN_RULES
    raw_step = make_train_step(model, opt_cfg or AdamWConfig())

    def step(state, batch):
        with use_sharding(mesh, rules):
            return raw_step(state, batch)

    astate = abstract_state(model)
    aspecs = input_specs(model.cfg, shape)
    st_sh = state_shardings(model, mesh, rules)
    b_sh = batch_shardings(model.cfg, aspecs, mesh, rules)
    rep = NamedSharding(mesh, P())
    metrics_sh = {k: rep for k in ("loss", "ce", "router_aux", "grad_norm", "lr")}
    fn = jax.jit(
        step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metrics_sh),
        donate_argnums=(0,),
    )
    return fn, (astate, aspecs)


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeSpec, rules=None):
    from ..serving.engine import make_prefill_step

    rules = rules or SERVE_RULES
    raw = make_prefill_step(model)

    def step(params, batch, cache):
        with use_sharding(mesh, rules):
            return raw(params, batch, cache)

    aparams = model.abstract_params()
    aspecs = input_specs(model.cfg, shape)
    acache = abstract_cache(model, shape)
    p_sh = params_shardings(model, mesh, rules)
    b_sh = batch_shardings(model.cfg, aspecs, mesh, rules)
    c_sh = cache_shardings(model, acache, mesh, rules)
    tok_sh = _ns(mesh, (shape.global_batch, 1), ("batch", None), rules)
    fn = jax.jit(
        step,
        in_shardings=(p_sh, b_sh, c_sh),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(2,),
    )
    return fn, (aparams, aspecs, acache)


def build_serve_step(model: Model, mesh: Mesh, shape: ShapeSpec, rules=None):
    rules = rules or SERVE_RULES
    raw = make_serve_step(model)

    def step(params, tokens, cache):
        with use_sharding(mesh, rules):
            return raw(params, tokens, cache)

    aparams = model.abstract_params()
    acache = abstract_cache(model, shape)
    atoks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    p_sh = params_shardings(model, mesh, rules)
    c_sh = cache_shardings(model, acache, mesh, rules)
    tok_sh = _ns(mesh, (shape.global_batch, 1), ("batch", None), rules)
    fn = jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, c_sh),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(2,),
    )
    return fn, (aparams, atoks, acache)
