"""Production mesh definitions.

As a FUNCTION (not module-level constant) so importing never touches jax
device state — the dry-run sets XLA_FLAGS for 512 host devices before any
jax import; tests/benches see the real single device."""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "make_fleet_mesh", "mesh_name"]


def _make_mesh(shape, axes):
    # jax >= 0.5 takes axis_types (and 0.7+ defaults to Explicit); jax 0.4.x
    # has no jax.sharding.AxisType — its meshes are always Auto.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (256 chips) or 2x16x16 two-pod fleet (512 chips).

    Axes: `pod` (DCN, pure-DP) x `data` (batch) x `model` (tensor/expert)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices are available."""
    return _make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n_devices: Optional[int] = None):
    """1-D ``("rep",)`` mesh for sharding ``simulate_fleet``'s replication axis.

    Uses the first ``n_devices`` local devices (all of them by default).
    Requesting more devices than the process can see raises — never a silent
    fallback; start the process with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get N virtual
    CPU devices for testing."""
    avail = jax.local_device_count()
    n = avail if n_devices is None else int(n_devices)
    if n < 1 or n > avail:
        raise ValueError(
            f"make_fleet_mesh(n_devices={n_devices}): need 1 <= n_devices <= "
            f"jax.local_device_count() == {avail}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N for virtual "
            "CPU devices"
        )
    return _make_mesh((n,), ("rep",))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
