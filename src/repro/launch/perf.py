import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "10")
"""Perf-iteration harness (§Perf): lower one (arch x shape) under a NAMED
experiment variant (sharding-rule override and/or config tweak), emit the
three roofline terms, and diff against the baseline report.

Each experiment encodes one hypothesis from EXPERIMENTS.md §Perf.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch yi-9b --shape decode_32k \
      --variant kvseq_model
"""
import argparse
import dataclasses
import sys
import time

from ..configs import ARCH_IDS, get_config
from ..sharding import DEFAULT_RULES
from .dryrun import lower_one
from .specs import SHAPES

# ---------------------------------------------------------------------------
# experiment variants: name -> dict(rules=..., cfg_patch=..., note=...)
# ---------------------------------------------------------------------------

VARIANTS = {
    "baseline": dict(rules=None, cfg_patch={}, note="paper-faithful baseline"),
    # decode: shard the KV-cache sequence axis over `model` when kv_heads
    # cannot be sharded (GQA kv < mesh) — turns a replicated multi-GB cache
    # into 1/16 per chip; softmax over the sharded axis costs one tiny
    # all-reduce of (B,H) stats instead of replicated reads.
    "kvseq_model": dict(
        rules={"kv_seq": "model"},
        cfg_patch={},
        note="decode KV cache sharded over model on the sequence axis",
    ),
    # decode: ALSO pull the final logits all-gather out: vocab stays sharded
    # and only the (B,1) argmax index is exchanged.
    # long-context decode (batch=1): the data axis is idle; shard the cache
    # sequence over BOTH axes -> 256-way context parallelism for the cache
    "kvseq_2d": dict(
        rules={"kv_seq": ("data", "model")},
        cfg_patch={},
        note="cache seq sharded over data+model (256-way context parallel)",
    ),
    # ssm: 24 heads cannot shard a 16-way axis (replicated); shard the
    # headdim channels instead (64 % 16 == 0)
    "ssm_headdim_model": dict(
        rules={"ssm_headdim": "model", "ssm_heads": None},
        cfg_patch={},
        note="shard SSD head channels instead of (non-dividing) heads",
    ),
    # decode: int8-quantized KV cache (per-token-per-head scales) halves the
    # cache byte stream vs bf16 on top of kv_seq sharding
    "kvseq_int8": dict(
        rules={"kv_seq": "model"},
        cfg_patch={"kv_cache_dtype": "int8"},
        note="kv_seq sharding + int8 KV cache",
    ),
    "kvseq_localtopk": dict(
        rules={"kv_seq": "model"},
        cfg_patch={"local_argmax": True},
        note="kv_seq sharding + distributed argmax (no logits all-gather)",
    ),
    # train/prefill: flash-style chunked attention — never materializes the
    # (S,T) f32 score tensor (the baseline's dominant HBM term) and statically
    # slices the causal/windowed k-range (~2x fewer score FLOPs)
    "attn_chunked": dict(
        rules=None, cfg_patch={"attn_impl": "chunked"},
        note="chunked flash-style attention, causal k-slicing",
    ),
    "attn_chunked_kvseq": dict(
        rules={"kv_seq": "model"}, cfg_patch={"attn_impl": "chunked"},
        note="chunked attention + kv_seq sharding",
    ),
    # train: activation-checkpoint the scanned block
    "remat_on": dict(rules=None, cfg_patch={"remat": True}, note="remat scanned block"),
    "remat_off": dict(rules=None, cfg_patch={"remat": False}, note="no remat"),
    # moe: when n_experts cannot divide the mesh (qwen2-moe: 60 on 16), the
    # (E, C, D) expert activations replicate; shard the CAPACITY dim instead
    "moe_capacity_sharded": dict(
        rules={"capacity": "model", "experts": None},
        cfg_patch={"attn_impl": "chunked"},
        note="expert activations sharded on capacity (experts replicated)",
    ),
    # moe: int16 routing intermediates in the dispatch path
    "moe_small_dispatch": dict(
        rules=None,
        cfg_patch={"moe_dispatch_dtype": "int16"},
        note="MoE dispatch one-hot/cumsum in int16 instead of int32",
    ),
    # moe: lower capacity factor (less dispatch traffic, more drops)
    "moe_cf1": dict(rules=None, cfg_patch={"capacity_factor": 1.0}, note="capacity factor 1.0"),
    # combined best-known for MoE training
    "moe_best": dict(
        rules=None,
        cfg_patch={"attn_impl": "chunked", "capacity_factor": 1.0},
        note="chunked attention + capacity 1.0",
    ),
    "attn_chunked_noremat": dict(
        rules=None, cfg_patch={"attn_impl": "chunked", "remat": False},
        note="chunked attention, remat off (bytes vs residency trade)",
    ),
    # selective remat: keep matmul outputs, recompute only elementwise chain —
    # most of remat-off's byte/flop win at a fraction of the residency cost
    "attn_chunked_remat_dots": dict(
        rules=None, cfg_patch={"attn_impl": "chunked", "remat_policy": "dots"},
        note="chunked attention + dots-saveable remat policy",
    ),
    # serve without FSDP is the default; this measures the (bad) train-rules
    # alternative to quantify why SERVE_RULES exists
    "serve_with_train_rules": dict(
        rules={"embed": "data"}, cfg_patch={}, note="FSDP rules in decode (ablation)"
    ),
}


def run_variant(arch: str, shape: str, variant: str, out_dir: str = "reports/perf"):
    spec = VARIANTS[variant]
    cfg_patch = dict(spec["cfg_patch"])
    rules = dict(DEFAULT_RULES, **(spec["rules"] or {})) if spec["rules"] else None

    # config patches that are real ModelConfig fields get applied via replace;
    # feature flags (local_argmax, moe_dispatch_dtype) are module-level toggles
    import repro.models.moe as moe_mod
    import repro.serving.engine as eng_mod

    from repro.configs.base import ModelConfig

    base_cfg = get_config(arch)
    field_names = {f.name for f in dataclasses.fields(ModelConfig)}
    cfg_fields = {k: v for k, v in cfg_patch.items() if k in field_names}
    flags = {k: v for k, v in cfg_patch.items() if k not in field_names}

    old_dispatch = getattr(moe_mod, "DISPATCH_DTYPE", None)
    old_argmax = getattr(eng_mod, "LOCAL_ARGMAX", None)
    if "moe_dispatch_dtype" in flags:
        moe_mod.DISPATCH_DTYPE = flags["moe_dispatch_dtype"]
    if "local_argmax" in flags:
        eng_mod.LOCAL_ARGMAX = bool(flags["local_argmax"])

    try:
        t0 = time.time()
        _, compiled, report = lower_one(
            arch, shape, rules=rules, loop_correct=True, cfg_patch=cfg_fields or None
        )
        dt = time.time() - t0
    finally:
        if old_dispatch is not None or "moe_dispatch_dtype" in flags:
            moe_mod.DISPATCH_DTYPE = old_dispatch or "int32"
        if old_argmax is not None or "local_argmax" in flags:
            eng_mod.LOCAL_ARGMAX = bool(old_argmax)

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{variant}"
    report.save(os.path.join(out_dir, tag + ".json"))
    print(f"[{variant:24s} {dt:6.1f}s] {report.row()}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--variant", choices=list(VARIANTS), action="append", required=True)
    ap.add_argument("--out", default="reports/perf")
    args = ap.parse_args(argv)
    for v in args.variant:
        run_variant(args.arch, args.shape, v, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
