"""Launchers: production mesh, multi-pod dry-run, train and serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time and
must be the first jax-touching import of its process."""
from .mesh import make_fleet_mesh, make_production_mesh, make_test_mesh, mesh_name
from .specs import SHAPES, ShapeSpec, input_specs, shape_config, model_flops

__all__ = [
    "make_fleet_mesh",
    "make_production_mesh",
    "make_test_mesh",
    "mesh_name",
    "SHAPES",
    "ShapeSpec",
    "input_specs",
    "shape_config",
    "model_flops",
]
