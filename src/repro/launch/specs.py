"""Input specifications for the dry-run: ShapeDtypeStruct stand-ins for every
model input of every (architecture × input shape) combination — weak-type
correct, shardable, zero device allocation.

INPUT SHAPES (assignment):
  train_4k     seq=4096    global_batch=256   (training -> train_step)
  prefill_32k  seq=32768   global_batch=32    (inference prefill)
  decode_32k   seq=32768   global_batch=128   (ONE token vs 32k KV cache)
  long_500k    seq=524288  global_batch=1     (ONE token, sub-quadratic only:
               SSM/hybrid native; attention archs via sliding_window=8192)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model

__all__ = ["SHAPES", "ShapeSpec", "shape_config", "input_specs", "abstract_state", "model_flops"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

LONG_CONTEXT_WINDOW = 8192


def shape_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Per-shape config adjustments (the sub-quadratic carve-out)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        # attention-bearing archs run 500k ONLY as the sliding-window variant
        w = cfg.sliding_window or LONG_CONTEXT_WINDOW
        cfg = dataclasses.replace(cfg, sliding_window=min(w, LONG_CONTEXT_WINDOW))
    if shape.kind == "train" and cfg.num_layers >= 32:
        cfg = dataclasses.replace(cfg, remat=True)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _enc_len(cfg: ModelConfig, seq: int) -> int:
    return min(cfg.enc_seq_len, seq)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract batch for train/prefill kinds (tokens, labels, modality stubs)."""
    B = shape.global_batch
    S = shape.seq_len
    d = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm" and cfg.num_patches:
        P = min(cfg.num_patches, S)
        batch["vision_embeds"] = _sds((B, P, cfg.d_model), d)
        batch["vision_positions"] = _sds((B, P), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = _sds((B, _enc_len(cfg, S), cfg.d_model), d)
    return batch


def abstract_cache(model: Model, shape: ShapeSpec):
    cfg = model.cfg
    return jax.eval_shape(
        lambda: model.init_cache(
            shape.global_batch, shape.seq_len, enc_len=_enc_len(cfg, shape.seq_len)
        )
    )


def abstract_state(model: Model, with_opt: bool = True):
    """Abstract TrainState (params + AdamW moments) via eval_shape."""
    from ..training.optimizer import adamw_init
    from ..training.train_loop import TrainState

    params = model.abstract_params()
    if not with_opt:
        return params
    opt = jax.eval_shape(adamw_init, params)
    return TrainState(params, opt)


def decode_tokens_spec(shape: ShapeSpec):
    return _sds((shape.global_batch, 1), jnp.int32)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for the useful-compute ratio: 6·N_active·tokens (train),
    2·N_active·tokens (inference)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
