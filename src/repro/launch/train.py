"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

On real hardware this runs the pjit'd train step on the production mesh; in
this CPU container use ``--smoke`` (reduced config, tiny mesh) — the same code
path end to end, which is what the quickstart example and the integration
tests exercise."""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCH_IDS, get_config, reduce_for_smoke
from ..models.model import Model
from ..training import AdamWConfig, batch_iterator, init_state, make_train_step, save_checkpoint


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-3,
    seed: int = 0,
    ckpt: str | None = None,
    log_every: int = 10,
):
    cfg = get_config(arch)
    if smoke:
        cfg = reduce_for_smoke(cfg)
    model = Model(cfg)
    opt = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 10, 1))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_state(model, jax.random.PRNGKey(seed))
    it = batch_iterator(cfg, batch, seq, seed=seed)

    losses = []
    t0 = time.time()
    for i in range(steps):
        b = next(it)
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % log_every == 0 or i == 0:
            print(
                f"step {i+1:5d} loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"({(time.time()-t0)/(i+1):.2f}s/step)",
                flush=True,
            )
    if ckpt:
        save_checkpoint(ckpt, {"params": state.params}, step=steps)
        print(f"checkpoint -> {ckpt}")
    return state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["squeeze-lm", "mid-lm", "google-lm"], default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)
    _, losses = train(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt=args.ckpt,
    )
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
