from .model import Model, DecodeCache
from .layers import (
    ParamDecl,
    init_from_decl,
    specs_from_decl,
    apply_attention,
    apply_mlp,
    apply_norm,
    rope,
    make_positions,
)
from .moe import apply_moe, moe_decl, router_aux_loss
from .ssm import apply_mamba, mamba_decode_step, ssd_reference, init_ssm_state

__all__ = [
    "Model", "DecodeCache", "ParamDecl", "init_from_decl", "specs_from_decl",
    "apply_attention", "apply_mlp", "apply_norm", "rope", "make_positions",
    "apply_moe", "moe_decl", "router_aux_loss",
    "apply_mamba", "mamba_decode_step", "ssd_reference", "init_ssm_state",
]
