"""Core layers: declarative params, norms, RoPE, GQA attention, MLP.

Params are plain nested dicts.  Every parameter is declared once (shape +
logical sharding axes + init kind) in a *decl* tree; ``init_from_decl``
materializes values (optionally stacked over a leading layer axis for
scan-over-layers) and ``specs_from_decl`` yields the matching logical-axis
pytree consumed by ``repro.sharding``.  One source of truth, no sync bugs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard

__all__ = [
    "ParamDecl",
    "init_from_decl",
    "specs_from_decl",
    "norm_decl",
    "apply_norm",
    "mlp_decl",
    "apply_mlp",
    "attn_decl",
    "apply_attention",
    "rope",
    "make_positions",
    "embed_decl",
]


# ---------------------------------------------------------------------------
# Declarative params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "fan_in"     # fan_in | zeros | ones | normal | a_log | dt_bias
    scale: float = 1.0


def _leaf_init(key, d: ParamDecl, dtype) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "a_log":  # mamba: A in [1, 16) -> log
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if d.init == "dt_bias":  # mamba: dt ~ logU[1e-3, 1e-1], inverse softplus
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    # fan_in: truncated-normal-ish scaled by 1/sqrt(fan_in) (first dim = in)
    fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[0]
    if len(d.shape) >= 3:  # stacked expert weights (E, in, out): fan_in is dim -2
        fan_in = d.shape[-2]
    std = d.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)


def init_from_decl(key, decl: Dict[str, Any], dtype=jnp.float32, stack: Optional[int] = None):
    """Materialize a decl tree.  ``stack=L`` prepends a layer axis of size L to
    every leaf (for lax.scan over layers) while keeping fan-in per-layer."""
    leaves, treedef = jax.tree.flatten(decl, is_leaf=lambda x: isinstance(x, ParamDecl))
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, d in zip(keys, leaves):
        if stack is None:
            out.append(_leaf_init(k, d, dtype))
        else:
            sub = jax.random.split(k, stack)
            out.append(jnp.stack([_leaf_init(s, d, dtype) for s in sub]))
    return jax.tree.unflatten(treedef, out)


def specs_from_decl(decl: Dict[str, Any], stack: bool = False):
    def leaf(d: ParamDecl):
        return (("layers",) + d.logical) if stack else d.logical

    return jax.tree.map(leaf, decl, is_leaf=lambda x: isinstance(x, ParamDecl))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_decl(cfg: ModelConfig, dim: Optional[int] = None) -> Dict[str, ParamDecl]:
    dim = dim or cfg.d_model
    d = {"scale": ParamDecl((dim,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDecl((dim,), ("embed",), "zeros")
    return d


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def make_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(seq)[None, :] + offset, (batch, seq))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float, rotary_pct: float = 1.0):
    """x: (B, S, H, hd); positions: (B, S).  Rotates the first
    ``rotary_dim = even(hd * rotary_pct)`` channels (stablelm-2: 25%)."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, :, None, None].astype(jnp.float32) * freqs  # (B,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_decl(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamDecl]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    decl = {
        "w_up": ParamDecl((d, f), ("embed", "ff")),
        "w_down": ParamDecl((f, d), ("ff", "embed")),
    }
    if cfg.gated_mlp:
        decl["w_gate"] = ParamDecl((d, f), ("embed", "ff"))
    if cfg.mlp_bias:
        decl["b_up"] = ParamDecl((f,), ("ff",), "zeros")
        decl["b_down"] = ParamDecl((d,), ("embed",), "zeros")
        if cfg.gated_mlp:
            decl["b_gate"] = ParamDecl((f,), ("ff",), "zeros")
    return decl


def _act(cfg: ModelConfig):
    return jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu


def apply_mlp(p, x, cfg: ModelConfig):
    u = x @ p["w_up"]
    if cfg.mlp_bias:
        u = u + p["b_up"]
    if cfg.gated_mlp:
        g = x @ p["w_gate"]
        if cfg.mlp_bias:
            g = g + p["b_gate"]
        h = _act(cfg)(g) * u
    else:
        h = _act(cfg)(u)
    h = shard(h, "batch", None, "ff")
    y = h @ p["w_down"]
    if cfg.mlp_bias:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, optional cross-attention, caching)
# ---------------------------------------------------------------------------

def attn_decl(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamDecl]:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    decl = {
        "w_q": ParamDecl((d, H, hd), ("embed", "heads", "head_dim")),
        "w_k": ParamDecl((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "w_v": ParamDecl((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "w_o": ParamDecl((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        decl["b_q"] = ParamDecl((H, hd), ("heads", "head_dim"), "zeros")
        decl["b_k"] = ParamDecl((KV, hd), ("kv_heads", "head_dim"), "zeros")
        decl["b_v"] = ParamDecl((KV, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.attn_out_bias:
        decl["b_o"] = ParamDecl((d,), ("embed",), "zeros")
    return decl


def _project_qkv(p, x, cfg: ModelConfig, kv_input=None):
    kv_input = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", kv_input, p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", kv_input, p["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Reference scaled-dot-product GQA attention.
    q: (B,S,H,hd), k/v: (B,T,KV,hd), mask: (B,1,S,T) or (S,T) bool."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, S, KV, rep, hd)
    # f32 accumulation INSIDE the dot (bf16 operands stay bf16 in HBM/on the
    # wire — a materialized f32 convert of the KV cache would double decode's
    # all-gather traffic, see EXPERIMENTS.md §Perf)
    logits = jnp.einsum(
        "bskrh,btkh->bkrst", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits / math.sqrt(hd)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:  # (B,1,S,T) -> (B,1,1,S,T)
        mask = mask[:, :, None]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkh->bskrh", w, v)
    return out.reshape(B, S, H, hd)


def _sdpa_chunked(q, k, v, cfg: ModelConfig, *, causal: bool, window: Optional[int]):
    """Flash-style chunked attention for full-sequence (train/prefill) paths.

    Never materializes the (S, T) score tensor: the q axis is processed in
    ``cfg.attn_block`` chunks (python loop -> unrolled HLO, so cost analysis
    sees every chunk), and for causal/windowed masks the k/v range of each
    chunk is statically SLICED rather than masked — ~2x fewer score FLOPs for
    causal, O(S·W) for sliding window.  Numerics: f32 score/softmax per chunk
    (matches the Pallas flash kernel's accumulator behaviour on TPU)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    blk = max(min(cfg.attn_block, S), 1)
    outs = []
    for i in range(0, S, blk):
        b = min(blk, S - i)
        qb = q[:, i : i + b].reshape(B, b, KV, rep, hd)
        # static k-range for this chunk
        hi = min(i + b, T) if causal else T
        lo = max(0, i + 1 - (window or T)) if (causal and window) else 0
        kb = k[:, lo:hi]
        vb = v[:, lo:hi]
        logits = jnp.einsum(
            "bskrh,btkh->bkrst", qb, kb, preferred_element_type=jnp.float32
        ) / math.sqrt(hd)
        qi = (i + jnp.arange(b))[:, None]
        kj = (lo + jnp.arange(hi - lo))[None, :]
        m = jnp.ones((b, hi - lo), bool)
        if causal:
            m &= kj <= qi
        if window is not None:
            m &= kj > qi - window
        logits = jnp.where(m[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        ob = jnp.einsum("bkrst,btkh->bskrh", w, vb).reshape(B, b, H, hd)
        outs.append(ob)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def causal_mask(sq: int, skv: int, window: Optional[int] = None, offset: int = 0):
    """(sq, skv) bool; query i attends key j iff j <= i+offset and within window."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


def apply_attention(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions,
    mode: str = "causal",          # causal | bidir | cross
    kv_input=None,                  # encoder memory for cross-attention
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_index: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
):
    """Returns (y, new_cache).  Caching protocol:

    * prefill/train: ``cache=None`` -> full attention over x, returns the
      (k, v) to seed a cache when requested by the caller via closure.
    * decode: ``cache={'k','v'}`` ring buffers (B, W, KV, hd) and
      ``cache_index`` = #tokens generated so far; x is (B, 1, D).
    """
    window = window if window is not None else cfg.sliding_window
    q, k, v = _project_qkv(p, x, cfg, kv_input)
    if mode != "cross":
        # `positions` carries absolute positions for both q and the new k
        # (decode passes the current position for the single new token).
        q = rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = rope(k, positions, cfg.rope_theta, cfg.rotary_pct)

    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    new_cache = None
    if cache is not None and mode != "cross":
        # decode: write the new token into the ring buffer
        W = cache["k"].shape[1]
        slot = (cache_index % W).astype(jnp.int32)
        if "k_scale" in cache:  # int8-quantized cache (kv_cache_dtype="int8")
            from .quant import dequantize_kv, quantize_kv

            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, slot, 0, 0)
                ),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, slot, 0, 0)
                ),
            }
            ck = dequantize_kv(new_cache["k"], new_cache["k_scale"], k.dtype)
            cv = dequantize_kv(new_cache["v"], new_cache["v_scale"], v.dtype)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            new_cache = {"k": ck, "v": cv}
        # validity: slot position wrote token (cache_index); others hold
        # token (cache_index - ((slot - pos) mod W)); valid iff age < min(W, idx+1)
        pos = jnp.arange(W)
        age = (slot - pos) % W
        valid = age <= jnp.minimum(cache_index, W - 1)
        if window is not None:
            valid &= age < window
        mask = valid[None, None, None, :]  # (1,1,1,W) -> broadcasting ok
        mask = jnp.broadcast_to(mask, (x.shape[0], 1, 1, W))
        if cfg.use_pallas:
            from ..kernels import ops as kops
            y = kops.decode_attention(q[:, 0], ck, cv, mask[:, 0, 0])[:, None]
        else:
            y = _sdpa(q, ck, cv, mask, cfg)
    elif mode == "cross":
        if cache is not None:  # pre-projected encoder memory
            k, v = cache["k"], cache["v"]
        new_cache = {"k": k, "v": v}
        T = k.shape[1]
        mask = jnp.ones((x.shape[1], T), bool)
        y = _sdpa(q, k, v, mask, cfg)
    else:
        S = x.shape[1]
        if cfg.use_pallas and mode == "causal":
            from ..kernels import ops as kops
            y = kops.flash_attention(q, k, v, causal=True, window=window)
        elif cfg.attn_impl == "chunked":
            y = _sdpa_chunked(q, k, v, cfg, causal=(mode == "causal"), window=window)
        else:
            if mode == "bidir":
                mask = jnp.ones((S, S), bool)
            else:
                mask = causal_mask(S, S, window)
            y = _sdpa(q, k, v, mask, cfg)
        new_cache = {"k": k, "v": v}

    y = shard(y, "batch", None, "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", y, p["w_o"])
    if cfg.attn_out_bias:
        out = out + p["b_o"]
    return out, new_cache
