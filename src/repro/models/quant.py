"""INT8 KV-cache quantization (symmetric, per-token-per-head scales).

Decode on TPU is HBM-bound on the cache stream; storing K/V as int8 halves
the bytes vs bf16 at <1% attention-output error (the scale granularity is one
(token, kv_head) vector of head_dim values).  Enabled per-config with
``kv_cache_dtype="int8"``; the dequantize happens in the attention reads
(VMEM-resident on TPU, fused by XLA).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["quantize_kv", "dequantize_kv"]


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., hd) -> (int8 values, f32 scale over the trailing dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of quantize_kv; ``scale`` broadcasts over the trailing dim."""
    return (q.astype(jnp.float32) * scale).astype(dtype)
