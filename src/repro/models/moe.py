"""Mixture-of-Experts layer: top-k routing with capacity-bounded
gather/scatter dispatch and expert parallelism on the ``model`` mesh axis.

Supports both assigned MoE flavors:
  * qwen2-moe-a2.7b — 4 *shared* (always-on) experts summed with 60 routed
    top-4 experts;
  * arctic-480b     — 128 routed top-2 experts in parallel with a *dense
    residual* MLP.

Dispatch: top-k one-hot -> position-in-expert cumsum -> capacity C slots per
expert -> gather to (E, C, D) (sharded E->model; GSPMD inserts the
all-to-alls) -> gated-SiLU expert FFN einsum -> weighted scatter-add combine.
Overflowing tokens are *dropped* (standard capacity-factor semantics); the
router aux (load-balance) loss discourages overflow.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .layers import ParamDecl, apply_mlp, mlp_decl, _act

__all__ = ["moe_decl", "apply_moe", "router_aux_loss", "capacity"]

# dtype of the dispatch one-hot/cumsum intermediates; int16 halves the bytes
# of the (T*K, E) rank tensor (safe while capacity < 32768) — perf variant
DISPATCH_DTYPE = "int32"



def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    # large capacities round to multiples of 128 so the capacity axis can
    # divide a mesh axis; tiny (test/decode-scale) capacities round to 8
    if c >= 128:
        return -(-c // 128) * 128
    return max(8, -(-c // 8) * 8)


def moe_decl(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.effective_moe_d_ff
    E = cfg.n_experts
    decl: Dict[str, Any] = {
        "router": ParamDecl((d, E), ("embed", "experts"), "normal", 0.02),
        "w_gate": ParamDecl((E, d, f), ("experts", "embed", "expert_ff")),
        "w_up": ParamDecl((E, d, f), ("experts", "embed", "expert_ff")),
        "w_down": ParamDecl((E, f, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_expert_d_ff or f
        decl["shared"] = mlp_decl(cfg, d_ff=fs * cfg.n_shared_experts)
        decl["shared_gate"] = ParamDecl((d, 1), ("embed", None), "normal", 0.02)
    if cfg.dense_residual:
        decl["dense"] = mlp_decl(cfg)
    return decl


def apply_moe(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    Full sequences (S > 1) use GROUPED dispatch — routing, position-in-expert
    cumsum, capacity, gather and combine all happen per batch row, so under a
    batch-sharded mesh the entire dispatch is shard-local (no cross-device
    gathers of the token table; measured 1.5 TB/step of collectives saved on
    qwen2-moe train, see EXPERIMENTS.md §Perf).  Expert weights are shared
    across rows (replicated over `data`, FSDP-resharded under TRAIN_RULES).
    Decode (S == 1) keeps the global-token path: per-row capacity floors
    would multiply decode FLOPs ~E/top_k-fold for no benefit.

    Strategy is MESH-AWARE: when n_experts divides the `model` axis, the
    global expert-sharded path is cheaper (weights stay sharded; grouped
    would all-gather them — measured 88 s/step of collectives on arctic);
    when it does not (qwen2-moe: 60 on 16), grouped wins by 3-6x."""
    if x.shape[1] > 1:
        from ..sharding import current_ctx

        mesh, _ = current_ctx()
        model_size = (
            dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            if mesh is not None
            else 1
        )
        if mesh is None or cfg.n_experts % model_size != 0:
            return _apply_moe_grouped(p, x, cfg)
    return _apply_moe_global(p, x, cfg)


def _apply_moe_global(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, D)

    # --- routing -------------------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    aux = router_aux_loss(probs, expert_idx, E)

    # --- position-in-expert (capacity) ---------------------------------------
    # flatten the (T, K) choices in token-major order so earlier tokens win slots
    e_f = expert_idx.reshape(-1)                                  # (T*K,)
    g_f = gate_vals.reshape(-1).astype(x.dtype)
    t_f = jnp.repeat(jnp.arange(T), K)
    idt = jnp.dtype(DISPATCH_DTYPE)
    onehot = jax.nn.one_hot(e_f, E, dtype=idt)                    # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # rank within expert
    pos = (pos * onehot).sum(-1).astype(jnp.int32)                # (T*K,)
    keep = pos < C

    # --- gather to (E, C, D) --------------------------------------------------
    # dropped choices go to the C overflow slot / the T sentinel row
    slot = jnp.where(keep, pos, C)
    tok = jnp.where(keep, t_f, T)
    tok_map = jnp.full((E, C + 1), T, jnp.int32).at[e_f, slot].set(tok)[:, :C]
    gate_map = jnp.zeros((E, C + 1), x.dtype).at[e_f, slot].set(jnp.where(keep, g_f, 0))[:, :C]

    xpad = jnp.concatenate([xf, jnp.zeros((1, D), x.dtype)], axis=0)  # sentinel row
    xe = xpad[tok_map]                                            # (E, C, D)
    xe = shard(xe, "experts", "capacity", "embed")

    # --- expert FFN (gated SiLU/GELU) ----------------------------------------
    h = _act(cfg)(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = shard(h, "experts", "capacity", "expert_ff")
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])               # (E, C, D)

    # --- combine ---------------------------------------------------------------
    ypad = jnp.zeros((T + 1, D), x.dtype).at[tok_map.reshape(-1)].add(
        (ye * gate_map[..., None]).reshape(-1, D)
    )
    y = ypad[:T].reshape(B, S, D)
    y = shard(y, "batch", None, "embed")

    # --- always-on branches -----------------------------------------------------
    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(xf @ p["shared_gate"]).reshape(B, S, 1).astype(x.dtype)
        y = y + sg * apply_mlp(p["shared"], x, cfg)
    if cfg.dense_residual:
        y = y + apply_mlp(p["dense"], x, cfg)
    return y, aux


def _apply_moe_grouped(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-batch-row dispatch: every (B,)-leading tensor stays sharded on
    `data`; capacity is per row (S tokens)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(S, cfg)

    # --- routing (per row) ----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (B, S, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    aux = router_aux_loss(probs.reshape(-1, E), expert_idx.reshape(-1, K), E)

    # --- position-in-expert within each row ------------------------------------
    e_f = expert_idx.reshape(B, S * K)                            # (B, SK)
    g_f = gate_vals.reshape(B, S * K).astype(x.dtype)
    t_f = jnp.broadcast_to(jnp.repeat(jnp.arange(S), K)[None], (B, S * K))
    idt = jnp.dtype(DISPATCH_DTYPE)
    onehot = jax.nn.one_hot(e_f, E, dtype=idt)                    # (B, SK, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = (pos * onehot).sum(-1).astype(jnp.int32)                # (B, SK)
    keep = pos < C

    # --- per-row gather to (B, E, C, D) ----------------------------------------
    slot = jnp.where(keep, pos, C)
    tok = jnp.where(keep, t_f, S)                                 # S = sentinel row
    brange = jnp.arange(B)[:, None]
    tok_map = (
        jnp.full((B, E, C + 1), S, jnp.int32)
        .at[brange, e_f, slot]
        .set(tok)[:, :, :C]
    )
    gate_map = (
        jnp.zeros((B, E, C + 1), x.dtype)
        .at[brange, e_f, slot]
        .set(jnp.where(keep, g_f, 0))[:, :, :C]
    )

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)  # (B, S+1, D)
    xe = jnp.take_along_axis(
        xpad[:, :, None, :], tok_map.reshape(B, E * C)[:, :, None, None], axis=1
    )[:, :, 0, :].reshape(B, E, C, D)
    xe = shard(xe, "batch", None, "capacity", "embed")

    # --- expert FFN -------------------------------------------------------------
    h = _act(cfg)(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = shard(h, "batch", None, "capacity", "expert_ff")
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])             # (B, E, C, D)

    # --- per-row combine ---------------------------------------------------------
    ypad = jnp.zeros((B, S + 1, D), x.dtype).at[brange, tok_map.reshape(B, -1)].add(
        (ye * gate_map[..., None]).reshape(B, -1, D)
    )
    y = ypad[:, :S]
    y = shard(y, "batch", None, "embed")

    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x, p["shared_gate"])
        ).astype(x.dtype)
        y = y + sg * apply_mlp(p["shared"], x, cfg)
    if cfg.dense_residual:
        y = y + apply_mlp(p["dense"], x, cfg)
    return y, aux


def router_aux_loss(probs: jnp.ndarray, expert_idx: jnp.ndarray, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e, where f_e is the
    fraction of routed choices sent to e and P_e the mean router prob."""
    f = jnp.zeros(n_experts, jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    f = f / expert_idx.size
    P = probs.mean(axis=0)
    return n_experts * jnp.sum(f * P)
