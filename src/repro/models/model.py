"""Unified composable model covering all six assigned architecture families.

One ``Model`` class, configured entirely by ``ModelConfig``:

  dense  — pre-norm GQA transformer (yi-9b, qwen2-72b, stablelm-12b,
           starcoder2-15b)
  moe    — dense trunk with MoE FFN (qwen2-moe: shared+routed; arctic:
           routed + dense residual)
  ssm    — Mamba-2 / SSD stack (mamba2-130m)
  hybrid — Mamba-2 backbone + one weight-*shared* attention block applied
           every ``attn_every`` layers (zamba2-1.2b)
  encdec — bidirectional encoder over stubbed frame embeddings + causal
           decoder with cross-attention (seamless-m4t-medium)
  vlm    — decoder trunk consuming token embeddings with stubbed vision patch
           embeddings scattered at image-token positions (pixtral-12b)

API (all functional, jit/pjit-friendly):
  init(key) / abstract_params() / param_logical_specs()
  forward(params, batch)            -> (logits, aux)          train/teacher-forcing
  init_cache(batch, max_len)        -> DecodeCache
  prefill(params, batch, cache)     -> (last_logits, cache)
  decode_step(params, tokens, cache)-> (logits, cache)        one new token
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .layers import (
    ParamDecl,
    apply_attention,
    apply_mlp,
    apply_norm,
    attn_decl,
    init_from_decl,
    make_positions,
    mlp_decl,
    norm_decl,
    specs_from_decl,
)
from .moe import apply_moe, moe_decl
from .ssm import apply_mamba, init_ssm_state, mamba_decl, mamba_decode_step

__all__ = ["Model", "DecodeCache"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeCache:
    """Decode-time state.  ``index`` is the absolute #tokens consumed so far.

    attn:  {'k','v'} (L_attn, B, W, KV, hd) ring buffers (None if attn-free)
    conv:  (L_ssm, B, convw-1, ch)      (None unless ssm/hybrid)
    ssm:   (L_ssm, B, H, N, P)          (None unless ssm/hybrid)
    cross: {'k','v'} (L_dec, B, T_enc, KV, hd) projected encoder memory
    """

    index: jnp.ndarray
    attn: Optional[Dict[str, jnp.ndarray]] = None
    conv: Optional[jnp.ndarray] = None
    ssm: Optional[jnp.ndarray] = None
    cross: Optional[Dict[str, jnp.ndarray]] = None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat_policy(cfg: ModelConfig):
    """None = full remat; 'dots' saves matmul outputs and recomputes only the
    cheap elementwise chain (softmax/norms/masks) in the backward pass."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ decl
    def _block_decl(self, cross: bool = False) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family in ("dense", "vlm"):
            return {
                "ln1": norm_decl(cfg),
                "attn": attn_decl(cfg),
                "ln2": norm_decl(cfg),
                "mlp": mlp_decl(cfg),
            }
        if cfg.family == "moe":
            return {
                "ln1": norm_decl(cfg),
                "attn": attn_decl(cfg),
                "ln2": norm_decl(cfg),
                "moe": moe_decl(cfg),
            }
        if cfg.family in ("ssm", "hybrid"):
            return {"ln": norm_decl(cfg), "mamba": mamba_decl(cfg)}
        if cfg.family == "encdec":
            d = {
                "ln1": norm_decl(cfg),
                "attn": attn_decl(cfg),
                "ln2": norm_decl(cfg),
                "mlp": mlp_decl(cfg),
            }
            if cross:
                d["ln_x"] = norm_decl(cfg)
                d["xattn"] = attn_decl(cfg, cross=True)
            return d
        raise ValueError(cfg.family)

    def decl(self) -> Dict[str, Any]:
        cfg = self.cfg
        d: Dict[str, Any] = {
            "embed": ParamDecl(
                (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", 0.02
            ),
            "ln_f": norm_decl(cfg),
        }
        if not cfg.tie_embeddings:
            d["lm_head"] = ParamDecl(
                (cfg.d_model, cfg.vocab_size), ("embed", "vocab")
            )
        if cfg.family == "encdec":
            d["enc_layers"] = self._block_decl(cross=False)
            d["dec_layers"] = self._block_decl(cross=True)
            d["ln_enc"] = norm_decl(cfg)
        else:
            d["layers"] = self._block_decl()
        if cfg.family == "hybrid":
            d["shared_attn"] = {
                "ln1": norm_decl(cfg),
                "attn": attn_decl(cfg),
                "ln2": norm_decl(cfg),
                "mlp": mlp_decl(cfg),
            }
        return d

    # ------------------------------------------------------------------ init
    def _stack_sizes(self) -> Dict[str, int]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return {"enc_layers": cfg.num_enc_layers, "dec_layers": cfg.num_layers}
        return {"layers": cfg.num_layers}

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        decl = self.decl()
        dt = jnp.dtype(cfg.param_dtype)
        stacks = self._stack_sizes()
        keys = jax.random.split(key, len(decl))
        out = {}
        for k, (name, sub) in zip(keys, decl.items()):
            if name in stacks:
                out[name] = init_from_decl(k, sub, dt, stack=stacks[name])
            else:
                out[name] = init_from_decl(k, sub, dt)
        return out

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_logical_specs(self):
        decl = self.decl()
        stacks = self._stack_sizes()
        return {
            name: specs_from_decl(sub, stack=name in stacks)
            for name, sub in decl.items()
        }

    # -------------------------------------------------------------- embedding
    def _embed(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = params["embed"][tokens].astype(_dtype(cfg))
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(_dtype(cfg))
            vp = batch["vision_positions"]  # (B, P) int32 indices into S

            def merge(h_b, pos_b, emb_b):
                return h_b.at[pos_b].set(emb_b)

            h = jax.vmap(merge)(h, vp, ve)
        return shard(h, "batch", None, "embed")

    def _unembed(self, params, h) -> jnp.ndarray:
        cfg = self.cfg
        h = apply_norm(params["ln_f"], h, cfg)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
        else:
            logits = h @ params["lm_head"]
        if cfg.logits_softcap:
            logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
        return shard(logits.astype(jnp.float32), "batch", None, "vocab")

    # ----------------------------------------------------------------- blocks
    def _dense_block(self, p, h, positions, *, window=None, cache=None, index=None):
        cfg = self.cfg
        a, kv = apply_attention(
            p["attn"],
            apply_norm(p["ln1"], h, cfg),
            cfg,
            positions=positions,
            cache=cache,
            cache_index=index,
            window=window,
        )
        h = h + a
        x = apply_norm(p["ln2"], h, cfg)
        if cfg.family == "moe":
            m, aux = apply_moe(p["moe"], x, cfg)
        else:
            m, aux = apply_mlp(p["mlp"], x, cfg), jnp.float32(0.0)
        return h + m, kv, aux

    # ---------------------------------------------------------------- forward
    def forward(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Teacher-forcing forward over full sequences (train / eval)."""
        cfg = self.cfg
        h = self._embed(params, batch)
        B, S = batch["tokens"].shape
        positions = batch.get("positions", make_positions(B, S))
        aux_total = jnp.float32(0.0)

        if cfg.family in ("dense", "moe", "vlm"):
            h, aux_total = self._run_stack(params["layers"], h, positions)
        elif cfg.family == "ssm":
            h = self._run_ssm_stack(params["layers"], h)
        elif cfg.family == "hybrid":
            h = self._run_hybrid(params, h, positions)
        elif cfg.family == "encdec":
            mem = self._encode(params, batch)
            h, aux_total = self._run_decoder(params, h, positions, mem)
        logits = self._unembed(params, h)
        return logits, {"router_aux": aux_total}

    # stacked scan (dense/moe/vlm)
    def _run_stack(self, layers, h, positions):
        cfg = self.cfg

        def body(carry, lp):
            hh, aux = carry
            hh, _, a = self._dense_block(lp, hh, positions)
            return (hh, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
        if cfg.scan_layers:
            (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), layers)
        else:
            aux = jnp.float32(0.0)
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], layers)
                (h, aux), _ = body((h, aux), lp)
        return h, aux

    def _run_ssm_stack(self, layers, h):
        cfg = self.cfg

        def body(hh, lp):
            y = apply_mamba(lp["mamba"], apply_norm(lp["ln"], hh, cfg), cfg)
            return hh + y, None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, layers)
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], layers)
                h, _ = body(h, lp)
        return h

    def _run_hybrid(self, params, h, positions):
        """Mamba backbone; the weight-shared attention block fires on layers
        i ≡ 0 (mod attn_every).  Unrolled (sites need distinct cache slots)."""
        cfg = self.cfg
        sp = params["shared_attn"]
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            if cfg.attn_every and i % cfg.attn_every == 0:
                a, _ = apply_attention(
                    sp["attn"], apply_norm(sp["ln1"], h, cfg), cfg, positions=positions
                )
                h = h + a
                h = h + apply_mlp(sp["mlp"], apply_norm(sp["ln2"], h, cfg), cfg)
            y = apply_mamba(lp["mamba"], apply_norm(lp["ln"], h, cfg), cfg)
            h = h + y
        return h

    def _encode(self, params, batch) -> jnp.ndarray:
        """Encoder over stubbed frame embeddings (B, T_enc, d_model)."""
        cfg = self.cfg
        mem = batch["enc_embeds"].astype(_dtype(cfg))
        mem = shard(mem, "batch", None, "embed")
        B, T = mem.shape[:2]
        pos = make_positions(B, T)

        def body(hh, lp):
            a, _ = apply_attention(
                lp["attn"], apply_norm(lp["ln1"], hh, cfg), cfg,
                positions=pos, mode="bidir",
            )
            hh = hh + a
            hh = hh + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], hh, cfg), cfg)
            return hh, None

        if cfg.scan_layers:
            mem, _ = jax.lax.scan(body, mem, params["enc_layers"])
        else:
            for i in range(cfg.num_enc_layers):
                lp = jax.tree.map(lambda x: x[i], params["enc_layers"])
                mem, _ = body(mem, lp)
        return apply_norm(params["ln_enc"], mem, cfg)

    def _run_decoder(self, params, h, positions, mem):
        cfg = self.cfg

        def body(hh, lp):
            a, _ = apply_attention(
                lp["attn"], apply_norm(lp["ln1"], hh, cfg), cfg, positions=positions
            )
            hh = hh + a
            xa, _ = apply_attention(
                lp["xattn"], apply_norm(lp["ln_x"], hh, cfg), cfg,
                positions=positions, mode="cross", kv_input=mem,
            )
            hh = hh + xa
            hh = hh + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], hh, cfg), cfg)
            return hh, None

        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, params["dec_layers"])
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["dec_layers"])
                h, _ = body(h, lp)
        return h, jnp.float32(0.0)

    # ------------------------------------------------------------------ cache
    def n_attn_sites(self) -> int:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return cfg.num_layers
        if cfg.family == "encdec":
            return cfg.num_layers
        if cfg.family == "hybrid":
            return -(-cfg.num_layers // cfg.attn_every) if cfg.attn_every else 0
        return 0

    def cache_window(self, max_len: int) -> int:
        w = self.cfg.sliding_window
        return min(max_len, w) if w else max_len

    def init_cache(self, batch: int, max_len: int, enc_len: Optional[int] = None) -> DecodeCache:
        cfg = self.cfg
        dt = _dtype(cfg)
        attn = conv = ssm = cross = None
        n_attn = self.n_attn_sites()
        if n_attn:
            W = self.cache_window(max_len)
            kvshape = (n_attn, batch, W, cfg.num_kv_heads, cfg.head_dim)
            if cfg.kv_cache_dtype == "int8":
                sshape = kvshape[:-1] + (1,)
                attn = {
                    "k": jnp.zeros(kvshape, jnp.int8),
                    "v": jnp.zeros(kvshape, jnp.int8),
                    "k_scale": jnp.ones(sshape, jnp.float32),
                    "v_scale": jnp.ones(sshape, jnp.float32),
                }
            else:
                attn = {"k": jnp.zeros(kvshape, dt), "v": jnp.zeros(kvshape, dt)}
        if cfg.family in ("ssm", "hybrid"):
            c1, s1 = init_ssm_state(cfg, batch, dt)
            conv = jnp.broadcast_to(c1, (cfg.num_layers, *c1.shape)).copy()
            ssm = jnp.broadcast_to(s1, (cfg.num_layers, *s1.shape)).copy()
        if cfg.family == "encdec":
            T = enc_len or cfg.enc_seq_len
            xshape = (cfg.num_layers, batch, T, cfg.num_kv_heads, cfg.head_dim)
            cross = {"k": jnp.zeros(xshape, dt), "v": jnp.zeros(xshape, dt)}
        return DecodeCache(index=jnp.int32(0), attn=attn, conv=conv, ssm=ssm, cross=cross)

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, batch, cache: DecodeCache) -> Tuple[jnp.ndarray, DecodeCache]:
        """Consume a prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = make_positions(B, S)
        h = self._embed(params, batch)

        def fill_ring(ring, kv):
            # keep the last W tokens; slot = pos % W matches decode protocol
            W = ring.shape[1]
            keep = min(S, W)
            src = kv[:, S - keep :]
            slots = (jnp.arange(S - keep, S) % W).astype(jnp.int32)
            return ring.at[:, slots].set(src.astype(ring.dtype))

        def fill_ring_kv(cache_site, site_idx, kv):
            """Fill one layer/site's {k,v[,scales]} from full-sequence k/v."""
            from .quant import quantize_kv

            out = {}
            for name in ("k", "v"):
                ring = cache_site[name][site_idx]
                if cfg.kv_cache_dtype == "int8":
                    q, sc = quantize_kv(kv[name])
                    out[name] = fill_ring(ring, q)
                    out[name + "_scale"] = fill_ring(
                        cache_site[name + "_scale"][site_idx], sc
                    )
                else:
                    out[name] = fill_ring(ring, kv[name])
            return out

        attn_cache = cache.attn
        conv_cache, ssm_cache = cache.conv, cache.ssm
        cross_cache = cache.cross
        site = 0

        if cfg.family in ("dense", "moe", "vlm"):
            sites = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                h, kv, _ = self._dense_block(lp, h, positions)
                sites.append(fill_ring_kv(cache.attn, i, kv))
            attn_cache = {
                key: jnp.stack([st[key] for st in sites]) for key in sites[0]
            }
        elif cfg.family == "ssm":
            convs, ssms = [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                y, (cv, st) = apply_mamba(
                    lp["mamba"], apply_norm(lp["ln"], h, cfg), cfg, return_state=True
                )
                h = h + y
                convs.append(cv)
                ssms.append(st.astype(cache.ssm.dtype))
            conv_cache, ssm_cache = jnp.stack(convs), jnp.stack(ssms)
        elif cfg.family == "hybrid":
            sp = params["shared_attn"]
            convs, ssms, ak, av = [], [], [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                if cfg.attn_every and i % cfg.attn_every == 0:
                    a, kv = apply_attention(
                        sp["attn"], apply_norm(sp["ln1"], h, cfg), cfg, positions=positions
                    )
                    h = h + a
                    h = h + apply_mlp(sp["mlp"], apply_norm(sp["ln2"], h, cfg), cfg)
                    sites_h = fill_ring_kv(cache.attn, site, kv)
                    ak.append(sites_h)
                    site += 1
                y, (cv, st) = apply_mamba(
                    lp["mamba"], apply_norm(lp["ln"], h, cfg), cfg, return_state=True
                )
                h = h + y
                convs.append(cv)
                ssms.append(st.astype(cache.ssm.dtype))
            conv_cache, ssm_cache = jnp.stack(convs), jnp.stack(ssms)
            attn_cache = {key: jnp.stack([st[key] for st in ak]) for key in ak[0]}
        elif cfg.family == "encdec":
            mem = self._encode(params, batch)
            ak, av, xk, xv = [], [], [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["dec_layers"])
                a, kv = apply_attention(
                    lp["attn"], apply_norm(lp["ln1"], h, cfg), cfg, positions=positions
                )
                h = h + a
                xa, xkv = apply_attention(
                    lp["xattn"], apply_norm(lp["ln_x"], h, cfg), cfg,
                    positions=positions, mode="cross", kv_input=mem,
                )
                h = h + xa
                h = h + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], h, cfg), cfg)
                ak.append(fill_ring_kv(cache.attn, i, kv))
                xk.append(xkv["k"])
                xv.append(xkv["v"])
            attn_cache = {key: jnp.stack([st[key] for st in ak]) for key in ak[0]}
            cross_cache = {"k": jnp.stack(xk), "v": jnp.stack(xv)}

        logits = self._unembed(params, h[:, -1:, :])
        return logits, DecodeCache(
            index=jnp.int32(S),
            attn=attn_cache,
            conv=conv_cache,
            ssm=ssm_cache,
            cross=cross_cache,
        )

    # ------------------------------------------------------------ decode step
    def decode_step(self, params, tokens, cache: DecodeCache) -> Tuple[jnp.ndarray, DecodeCache]:
        """One new token per sequence.  tokens: (B, 1) int32."""
        cfg = self.cfg
        B = tokens.shape[0]
        idx = cache.index
        positions = jnp.broadcast_to(idx, (B, 1))
        h = params["embed"][tokens].astype(_dtype(cfg))
        h = shard(h, "batch", None, "embed")

        attn_cache, conv_cache, ssm_cache = cache.attn, cache.conv, cache.ssm

        if cfg.family in ("dense", "moe", "vlm"):
            def body(carry, xs):
                hh, aux = carry
                lp, lc = xs
                hh, kv, a = self._dense_block(
                    lp, hh, positions, cache=lc, index=idx
                )
                return (hh, aux + a), kv

            if cfg.scan_layers:
                (h, _), attn_cache = jax.lax.scan(
                    body, (h, jnp.float32(0.0)), (params["layers"], cache.attn)
                )
            else:
                per_layer = []
                aux = jnp.float32(0.0)
                for i in range(cfg.num_layers):
                    lp = jax.tree.map(lambda x: x[i], params["layers"])
                    lc = jax.tree.map(lambda x: x[i], cache.attn)
                    (h, aux), kv = body((h, aux), (lp, lc))
                    per_layer.append(kv)
                attn_cache = {
                    key: jnp.stack([kv[key] for kv in per_layer])
                    for key in per_layer[0]
                }
        elif cfg.family == "ssm":
            def body(hh, xs):
                lp, cv, st = xs
                y, ncv, nst = mamba_decode_step(
                    lp["mamba"], apply_norm(lp["ln"], hh, cfg), cfg, cv, st
                )
                return hh + y, (ncv, nst)

            if cfg.scan_layers:
                h, (conv_cache, ssm_cache) = jax.lax.scan(
                    body, h, (params["layers"], cache.conv, cache.ssm)
                )
            else:
                ncs, nss = [], []
                for i in range(cfg.num_layers):
                    lp = jax.tree.map(lambda x: x[i], params["layers"])
                    h, (ncv, nst) = body(h, (lp, cache.conv[i], cache.ssm[i]))
                    ncs.append(ncv)
                    nss.append(nst)
                conv_cache, ssm_cache = jnp.stack(ncs), jnp.stack(nss)
        elif cfg.family == "hybrid":
            sp = params["shared_attn"]
            site = 0
            ncs, nss, per_site = [], [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                if cfg.attn_every and i % cfg.attn_every == 0:
                    lc = jax.tree.map(lambda x: x[site], cache.attn)
                    a, kv = apply_attention(
                        sp["attn"], apply_norm(sp["ln1"], h, cfg), cfg,
                        positions=positions, cache=lc, cache_index=idx,
                    )
                    h = h + a
                    h = h + apply_mlp(sp["mlp"], apply_norm(sp["ln2"], h, cfg), cfg)
                    per_site.append(kv)
                    site += 1
                y, ncv, nst = mamba_decode_step(
                    lp["mamba"], apply_norm(lp["ln"], h, cfg), cfg,
                    cache.conv[i], cache.ssm[i],
                )
                h = h + y
                ncs.append(ncv)
                nss.append(nst)
            conv_cache, ssm_cache = jnp.stack(ncs), jnp.stack(nss)
            attn_cache = {
                key: jnp.stack([kv[key] for kv in per_site]) for key in per_site[0]
            }
        elif cfg.family == "encdec":
            def body(hh, xs):
                lp, lc, xc = xs
                a, kv = apply_attention(
                    lp["attn"], apply_norm(lp["ln1"], hh, cfg), cfg,
                    positions=positions, cache=lc, cache_index=idx,
                )
                hh = hh + a
                xa, _ = apply_attention(
                    lp["xattn"], apply_norm(lp["ln_x"], hh, cfg), cfg,
                    positions=positions, mode="cross", cache=xc,
                )
                hh = hh + xa
                hh = hh + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], hh, cfg), cfg)
                return hh, kv

            if cfg.scan_layers:
                h, attn_cache = jax.lax.scan(
                    body, h, (params["dec_layers"], cache.attn, cache.cross)
                )
            else:
                per_layer = []
                for i in range(cfg.num_layers):
                    lp = jax.tree.map(lambda x: x[i], params["dec_layers"])
                    lc = jax.tree.map(lambda x: x[i], cache.attn)
                    xc = jax.tree.map(lambda x: x[i], cache.cross)
                    h, kv = body(h, (lp, lc, xc))
                    per_layer.append(kv)
                attn_cache = {
                    key: jnp.stack([kv[key] for kv in per_layer])
                    for key in per_layer[0]
                }

        logits = self._unembed(params, h)
        return logits, DecodeCache(
            index=idx + 1,
            attn=attn_cache,
            conv=conv_cache,
            ssm=ssm_cache,
            cross=cache.cross,
        )
