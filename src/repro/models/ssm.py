"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) layer.

Implements the chunked matmul-rich SSD form for training/prefill (TPU/MXU
friendly; optionally routed through the Pallas kernel) and the O(1)-state
recurrent update for decode.

Block layout (mamba2-130m / zamba2 style):
  in_proj : d -> [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
  conv1d  : depthwise causal width-w over the (x | B | C) channels
  SSD     : y = SSD(x·, dt, A, B, C) + D ⊙ x
  gate    : y = RMSNormGated(y * silu(z))
  out_proj: d_inner -> d
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .layers import ParamDecl

__all__ = [
    "mamba_decl",
    "apply_mamba",
    "mamba_decode_step",
    "init_ssm_state",
    "ssd_reference",
]


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_nheads
    P = cfg.ssm_headdim
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    return di, H, P, G, N


def mamba_decl(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di, H, P, G, N = _dims(cfg)
    conv_ch = di + 2 * G * N
    return {
        "in_proj": ParamDecl((d, 2 * di + 2 * G * N + H), ("embed", "d_inner")),
        "conv_w": ParamDecl((cfg.ssm_conv, conv_ch), ("conv", "d_inner"), "normal", 0.2),
        "conv_b": ParamDecl((conv_ch,), ("d_inner",), "zeros"),
        "A_log": ParamDecl((H,), ("ssm_heads",), "a_log"),
        "dt_bias": ParamDecl((H,), ("ssm_heads",), "dt_bias"),
        "D": ParamDecl((H,), ("ssm_heads",), "ones"),
        "norm_scale": ParamDecl((di,), ("d_inner",), "ones"),
        "out_proj": ParamDecl((di, d), ("d_inner", "embed")),
    }


# ---------------------------------------------------------------------------
# SSD — chunked reference (pure jnp; the Pallas kernel mirrors this)
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., Q).  Returns (..., Q, Q) with out[i, j] = sum_{j < m <= i} x_m
    for i >= j, -inf otherwise (log of the causal decay matrix)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(x, dt, A, B, C, chunk: int, initial_state=None, return_final_state=False):
    """Chunked SSD (Algorithm in the Mamba-2 paper, matmul form).

    x : (b, S, H, P)   inputs per head
    dt: (b, S, H)      positive step sizes (softplus already applied)
    A : (H,)           negative decay rates
    B : (b, S, G, N)   input projections  (G groups, broadcast over H)
    C : (b, S, G, N)   output projections
    -> y: (b, S, H, P)  [, final_state (b, H, N, P)]
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    if S % chunk:
        # Right-pad with dt=0 tokens: decay exp(0)=1 and zero dt-weighted
        # contribution, so both outputs at real positions and the final state
        # are exactly preserved (outputs at pad positions are sliced off).
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = ssd_reference(x, dt, A, B, C, chunk, initial_state, return_final_state)
        if return_final_state:
            return out[0][:, :S], out[1]
        return out[:, :S]
    nc, Q = S // chunk, chunk
    rep = H // G

    in_dtype = x.dtype
    # SSD state recurrence is done in f32 (exp/cumsum are precision-critical)
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)

    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, G, N)
    Cc = C.reshape(b, nc, Q, G, N)
    dA = dtc * A  # (b, nc, Q, H), negative

    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b, nc, Q, H, N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (quadratic within the chunk) ---------------------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # (b, nc, H, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)       # (b, nc, H, Q, Q)
    y_intra = jnp.einsum(
        "bchqk,bchqk,bckh,bckhp->bcqhp", scores, L, dtc, xc
    )

    # ---- chunk states ---------------------------------------------------------
    dA_cum = jnp.cumsum(dA, axis=2)                          # (b, nc, Q, H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (b, nc, Q, H)
    states = jnp.einsum(
        "bcqh,bcqh,bcqhn,bcqhp->bchnp", decay_to_end, dtc, Bh, xc
    )                                                        # (b, nc, H, N, P)

    # ---- inter-chunk recurrence (scan over chunks) ----------------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b, nc, H)

    def step(carry, inp):
        s_prev = carry                                       # (b, H, N, P)
        s_c, g_c = inp                                       # state, decay of chunk c
        s_new = s_prev * g_c[..., None, None] + s_c
        return s_new, s_prev

    init = (
        jnp.zeros((b, H, N, P), x.dtype)
        if initial_state is None
        else initial_state.astype(x.dtype)
    )
    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b, nc, H, N, P)

    # ---- inter-chunk output ----------------------------------------------------
    in_decay = jnp.exp(dA_cum)                               # decay from chunk start
    y_inter = jnp.einsum(
        "bcqhn,bcqh,bchnp->bcqhp", Ch, in_decay, prev_states
    )
    y = (y_intra + y_inter).reshape(b, S, H, P).astype(in_dtype)
    if return_final_state:
        return y, final_state
    return y


# ---------------------------------------------------------------------------
# Layer forward (train / prefill)
# ---------------------------------------------------------------------------

def _split_proj(z_all, cfg: ModelConfig):
    di, H, P, G, N = _dims(cfg)
    z, xBC, dt = jnp.split(z_all, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, conv_state=None):
    """Depthwise causal conv1d.  xBC: (B, S, Ch); w: (W, Ch).
    If conv_state (B, W-1, Ch) is given, it is prepended (decode/streaming)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xBC], axis=1)                 # (B, S+W-1, Ch)
    out = sum(xp[:, i : i + xBC.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1) :, :] if W > 1 else None
    return out + b, new_state


def _gated_rmsnorm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def apply_mamba(p, x, cfg: ModelConfig, conv_state=None, ssm_state=None, return_state=False):
    """Full-sequence forward.  x: (B, S, D) -> y  or  (y, (conv_state, ssm_state))
    when ``return_state`` (used by prefill to seed the decode cache)."""
    B, S, D = x.shape
    di, H, P, G, N = _dims(cfg)
    zall = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zall, cfg)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = shard(xs.reshape(B, S, H, P), "batch", None, "ssm_heads", "ssm_headdim")
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt + p["dt_bias"])                  # (B, S, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cfg.use_pallas and not return_state:
        from ..kernels import ops as kops
        y = kops.ssd(xs, dt, A, Bm, Cm, chunk=cfg.ssd_chunk)
        final_state = None
    else:
        y, final_state = ssd_reference(
            xs, dt, A, Bm, Cm, cfg.ssd_chunk,
            initial_state=ssm_state, return_final_state=True,
        )
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, (new_conv, final_state)
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, H, P, G, N = _dims(cfg)
    conv_ch = di + 2 * G * N
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        jnp.zeros((batch, H, N, P), dtype),
    )


def mamba_decode_step(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """Single-token recurrent update.  x: (B, 1, D).
    conv_state: (B, W-1, Ch); ssm_state: (B, H, N, P)."""
    B = x.shape[0]
    di, H, P, G, N = _dims(cfg)
    zall = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zall, cfg)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, H, P)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                          # (B, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0] + p["dt_bias"])            # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    decay = jnp.exp(dt1 * A)[..., None, None]                 # (B, H, 1, 1)
    upd = (dt1[..., None, None] * Bh.astype(jnp.float32)[..., :, None]) * xs.astype(jnp.float32)[..., None, :]
    new_state = ssm_state.astype(jnp.float32) * decay + upd   # (B, H, N, P) f32
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + p["D"][None, :, None].astype(x.dtype) * xs
    y = y.reshape(B, 1, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    out = (y @ p["out_proj"]).astype(x.dtype)
    return out, new_conv, new_state.astype(ssm_state.dtype)
