"""stablelm-12b [dense] — StableLM-2 family (partial rotary, LayerNorm).
[hf:stabilityai/stablelm-2-1_6b (family); 12B sizing per assignment]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-12b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    norm="layernorm",
    activation="silu",
    rotary_pct=0.25,           # stablelm-2 partial rotary embeddings
    rope_theta=10_000.0,
    qkv_bias=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
