"""qwen2-72b [dense] — GQA kv=8, QKV bias.  [arXiv:2407.10671]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-72b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=False,
    remat=True,                # 80 layers: remat the scanned block for train
    dtype="bfloat16",
    param_dtype="bfloat16",
)
