"""Paper-analog model zoo variants for the testbed example.

The paper's testbed serves two CNNs: SqueezeNet (edge, cheap, lower accuracy)
and GoogleNet (cloud, costly, higher accuracy).  Our analog is a ladder of
tiny decoder LMs of increasing size — they actually train/serve on CPU in
``examples/serve_edge.py``, and their measured eval accuracy/latency feed the
GUS scheduler the way the paper's testbed measurements do."""
from .base import ModelConfig

SQUEEZE_LM = ModelConfig(       # edge variant (SqueezeNet analog)
    arch_id="squeeze-lm",
    family="dense",
    source="paper-analog: SqueezeNet (arXiv:1602.07360)",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    scan_layers=False,
)

MID_LM = ModelConfig(           # intermediate edge variant
    arch_id="mid-lm",
    family="dense",
    source="paper-analog: intermediate variant",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=1024,
    vocab_size=512,
    scan_layers=False,
)

GOOGLE_LM = ModelConfig(        # cloud variant (GoogleNet analog)
    arch_id="google-lm",
    family="dense",
    source="paper-analog: GoogleNet (arXiv:1409.4842)",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=512,
    scan_layers=False,
)

PAPER_ZOO = {c.arch_id: c for c in (SQUEEZE_LM, MID_LM, GOOGLE_LM)}
