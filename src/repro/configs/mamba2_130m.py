"""mamba2-130m [ssm] — pure SSD (state-space duality).  [arXiv:2405.21060]

24L d_model=768, attention-free, ssm_state=128, headdim=64, expand=2."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=1,               # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv=4,
    ssd_chunk=128,
    norm="rmsnorm",
    tie_embeddings=True,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
