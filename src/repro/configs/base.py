"""Unified model configuration covering all six assigned architecture families
(dense / MoE / SSM / hybrid / encoder-decoder audio / VLM)."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "reduce_for_smoke"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""               # citation (paper / model card)

    # trunk
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # flavor
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    activation: str = "silu"       # silu | gelu
    gated_mlp: bool = True         # False = classic 2-matrix GPT MLP (starcoder2)
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0        # stablelm-2 uses 0.25
    qkv_bias: bool = False         # qwen2 uses True
    attn_out_bias: bool = False
    mlp_bias: bool = False         # starcoder2 uses True
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # enables ring-buffer decode cache

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None       # expert hidden size (d_ff used if None)
    n_shared_experts: int = 0            # qwen2-moe: always-on experts
    shared_expert_d_ff: Optional[int] = None
    dense_residual: bool = False         # arctic: dense MLP parallel to MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssd_chunk: int = 128

    # hybrid (zamba2): one weight-shared attention block applied every k layers
    attn_every: int = 0

    # encoder-decoder (audio)
    num_enc_layers: int = 0
    enc_seq_len: int = 4096        # stubbed frame-embedding length for specs

    # VLM: stubbed vision frontend hands (B, num_patches, d_model) embeddings
    num_patches: int = 0
    image_token_id: int = 10       # token id replaced by patch embeddings

    # numerics / compile
    kv_cache_dtype: str = "auto"   # auto (activation dtype) | int8 (quantized)
    attn_impl: str = "reference"   # reference | chunked (flash-style, fused)
    attn_block: int = 1024         # q-chunk for the chunked path
    dtype: str = "float32"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: bool = False
    remat_policy: str = "full"     # full | dots (save matmuls, recompute rest)
    use_pallas: bool = False       # route attention/SSD through Pallas kernels
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # -- derived -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def effective_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def n_params(self) -> int:
        """Approximate parameter count (for scaling-law accuracy proxies and
        MODEL_FLOPS = 6·N·D bookkeeping)."""
        d, hd = self.d_model, self.head_dim
        mf = 3 if self.gated_mlp else 2  # matrices per MLP
        p = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "vlm"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            if self.family == "moe":
                ff = 3 * d * self.effective_moe_d_ff * self.n_experts
                ff += 3 * d * (self.shared_expert_d_ff or self.effective_moe_d_ff) * self.n_shared_experts
                if self.dense_residual:
                    ff += mf * d * self.d_ff
            else:
                ff = mf * d * self.d_ff
            p += self.num_layers * (attn + ff)
        elif self.family == "ssm":
            p += self.num_layers * self._mamba_block_params()
        elif self.family == "hybrid":
            p += self.num_layers * self._mamba_block_params()
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            p += attn + mf * d * self.d_ff  # one shared block
        elif self.family == "encdec":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            ff = mf * d * self.d_ff
            p += self.num_enc_layers * (attn + ff)
            p += self.num_layers * (2 * attn + ff)  # self + cross per dec layer
        return int(p)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        all_ff = 3 * d * self.effective_moe_d_ff * self.n_experts * self.num_layers
        act_ff = 3 * d * self.effective_moe_d_ff * self.top_k * self.num_layers
        return int(full - all_ff + act_ff)

    def _mamba_block_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        g = self.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * ns + self.ssm_nheads)
        conv = self.ssm_conv * (di + 2 * g * ns)
        out = di * d
        return in_proj + conv + out + 3 * self.ssm_nheads + di


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests:
    2 layers, d_model <= 512, <= 4 experts (spec requirement)."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    return dataclasses.replace(
        cfg,
        num_layers=2,
        num_enc_layers=min(cfg.num_enc_layers, 2),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d // heads,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else cfg.d_ff,
        moe_d_ff=min(cfg.effective_moe_d_ff, 256) if cfg.n_experts else None,
        shared_expert_d_ff=min(cfg.shared_expert_d_ff, 256) if cfg.shared_expert_d_ff else None,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=min(cfg.ssm_headdim, 32),
        ssd_chunk=32,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        num_patches=min(cfg.num_patches, 16) if cfg.num_patches else 0,
        enc_seq_len=min(cfg.enc_seq_len, 64),
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else None,
        scan_layers=False,
        dtype="float32",
        param_dtype="float32",
    )
