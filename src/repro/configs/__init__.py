"""Config registry: ``get_config(arch_id)`` / ``--arch <id>`` support."""
from .base import ModelConfig, reduce_for_smoke
from .pixtral_12b import CONFIG as PIXTRAL_12B
from .qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from .stablelm_12b import CONFIG as STABLELM_12B
from .qwen2_72b import CONFIG as QWEN2_72B
from .yi_9b import CONFIG as YI_9B
from .seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from .starcoder2_15b import CONFIG as STARCODER2_15B
from .arctic_480b import CONFIG as ARCTIC_480B
from .zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from .mamba2_130m import CONFIG as MAMBA2_130M
from .paper_zoo import PAPER_ZOO, SQUEEZE_LM, MID_LM, GOOGLE_LM

REGISTRY = {
    c.arch_id: c
    for c in (
        PIXTRAL_12B,
        QWEN2_MOE_A2_7B,
        STABLELM_12B,
        QWEN2_72B,
        YI_9B,
        SEAMLESS_M4T_MEDIUM,
        STARCODER2_15B,
        ARCTIC_480B,
        ZAMBA2_1_2B,
        MAMBA2_130M,
    )
}
REGISTRY.update(PAPER_ZOO)

ARCH_IDS = [
    "pixtral-12b",
    "qwen2-moe-a2.7b",
    "stablelm-12b",
    "qwen2-72b",
    "yi-9b",
    "seamless-m4t-medium",
    "starcoder2-15b",
    "arctic-480b",
    "zamba2-1.2b",
    "mamba2-130m",
]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}") from None


__all__ = ["ModelConfig", "reduce_for_smoke", "get_config", "REGISTRY", "ARCH_IDS", "PAPER_ZOO"]
