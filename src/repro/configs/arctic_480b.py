"""arctic-480b [moe] — 128 routed experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per expert) vocab=32000.
Arctic's dense-MoE hybrid: a dense residual MLP runs in parallel with the
routed expert FFN in every layer."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                 # dense-residual MLP width
    moe_d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    norm="rmsnorm",
    activation="silu",
    rope_theta=10_000.0,
    capacity_factor=1.25,
    remat=True,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
