"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=151936.
Shared experts are modeled as one always-on gated MLP of width 4*1408=5632
with a sigmoid shared-expert gate (matches the HF implementation)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    shared_expert_d_ff=1408,   # x4 shared experts -> one 5632-wide MLP
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    capacity_factor=1.25,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
