"""pixtral-12b [vlm] — Pixtral-ViT frontend (STUBBED) + Mistral-Nemo decoder.
[hf:mistralai/Pixtral-12B-2409]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The vision encoder/projector is a stub: input_specs() provides precomputed
patch embeddings (B, num_patches, d_model) scattered at image-token slots.
Mistral lineage -> sliding-window variant available for long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    num_patches=1024,          # stub frontend: 1024 patch embeddings
    image_token_id=10,
    # sliding_window stays None here; the launcher enables window=8192 for the
    # long_500k shape only (sub-quadratic carve-out, see DESIGN.md).
    dtype="bfloat16",
    param_dtype="bfloat16",
)
