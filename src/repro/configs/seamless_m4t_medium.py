"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596]

12L (decoder) + 12L (encoder) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The mel-spectrogram/conv feature extractor is a STUB: input_specs() provides
precomputed frame embeddings (B, enc_seq_len, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596",
    num_layers=12,             # decoder layers
    num_enc_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    enc_seq_len=4096,          # stubbed audio frame-embedding length
    dtype="bfloat16",
    param_dtype="bfloat16",
)
