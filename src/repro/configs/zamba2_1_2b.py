"""zamba2-1.2b [hybrid] — Mamba-2 backbone + weight-shared attention blocks.
[arXiv:2411.15242]

38L d_model=2048 32H (kv=32) d_ff=8192 ssm_state=64.  One *shared*
(weight-tied) attention+MLP block fires every 6th layer (7 sites)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv=4,
    ssd_chunk=128,
    attn_every=6,
    norm="rmsnorm",
    activation="gelu",
    scan_layers=False,         # hybrid sites need distinct cache slots
    dtype="bfloat16",
    param_dtype="bfloat16",
)
