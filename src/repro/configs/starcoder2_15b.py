"""starcoder2-15b [dense] — GQA, RoPE, LayerNorm + biases, GELU.
[arXiv:2402.19173]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,           # classic 2-matrix GPT MLP (d_ff = 4·d_model)
    rope_theta=100_000.0,
    qkv_bias=True,
    attn_out_bias=True,
    mlp_bias=True,
    sliding_window=4096,       # starcoder2 trains with a 4k sliding window
    dtype="bfloat16",
    param_dtype="bfloat16",
)
