"""yi-9b [dense] — llama-architecture GQA.  [arXiv:2403.04652]

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-9b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    norm="rmsnorm",
    activation="silu",
    rope_theta=10_000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
