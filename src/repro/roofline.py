"""Roofline analysis from compiled dry-run artifacts (spec deliverable (g)).

Per (arch × shape × mesh) we derive three terms from the compiled module:

  compute   = HLO_FLOPs_per_device / peak_FLOP/s            (197 TF bf16, v5e)
  memory    = HLO_bytes_per_device / HBM_bw                 (819 GB/s)
  collective= collective_bytes_per_device / link_bw         (~50 GB/s ICI)

``cost_analysis()`` gives FLOPs/bytes of the per-device partitioned program.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO text and
sum the *output* shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute (output size ~= wire traffic per device for
these ops; all-reduce moves ~2x in a ring, folded into a method note, not the
numbers).  MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) and
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

__all__ = ["HWSpec", "V5E", "collective_bytes", "roofline_terms", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HWSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12    # bf16 per chip
    hbm_bw: float = 819e9         # bytes/s per chip
    link_bw: float = 50e9         # bytes/s per ICI link


V5E = HWSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every `dtype[dims]` occurrence in a shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_OP_LINE_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+(?P<op>[a-z0-9-]+)\("
)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind *output* bytes summed over the module.

    Matches both sync ops (`all-gather(...)`) and async starts
    (`all-gather-start(...)`); `-done` ops are ignored (same payload)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                shape = m.group("shape")
                if shape.startswith("("):
                    # async start: tuple (operand, result, ...) — count the
                    # largest member once (all-gather: result; all-reduce:
                    # either; avoids double counting operand+result)
                    val = max(
                        (_shape_bytes(s) for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape)),
                        default=0,
                    )
                else:
                    val = _shape_bytes(shape)
                out[kind] += val
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_device: float
    useful_ratio: float
    memory_analysis: Optional[str] = None
    # XLA:CPU cost_analysis counts while-loop (lax.scan) bodies ONCE; for
    # scan-over-layers models the table values above are loop-corrected by
    # linear extrapolation from 1-layer/2-layer unrolled compiles.  The raw
    # (uncorrected) per-device counts are kept for reference:
    loop_corrected: bool = False
    raw_flops_per_device: Optional[float] = None
    raw_bytes_per_device: Optional[float] = None
    raw_coll_bytes_per_device: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=2, default=str)

    @staticmethod
    def load(path: str) -> "RooflineReport":
        with open(path) as f:
            return RooflineReport(**json.load(f))

    def row(self) -> str:
        return (
            f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
            f"comp={self.compute_s*1e3:9.3f}ms mem={self.memory_s*1e3:9.3f}ms "
            f"coll={self.collective_s*1e3:9.3f}ms -> {self.bottleneck:10s} "
            f"useful={self.useful_ratio:6.1%}"
        )


def counts_from_artifacts(cost_analysis: Dict[str, float], hlo_text: str) -> Dict[str, float]:
    """(flops, bytes, collective bytes) per device from a compiled artifact."""
    coll = collective_bytes(hlo_text)
    return {
        "flops": float(cost_analysis.get("flops", 0.0)),
        "bytes": float(
            cost_analysis.get("bytes accessed", cost_analysis.get("bytes_accessed", 0.0))
        ),
        "coll": float(sum(coll.values())),
        "coll_breakdown": coll,
    }


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    cost_analysis: Dict[str, float],
    hlo_text: str,
    model_flops_total: float,
    hw: HWSpec = V5E,
    memory_analysis: Optional[str] = None,
    corrected_counts: Optional[Dict[str, float]] = None,
) -> RooflineReport:
    raw = counts_from_artifacts(cost_analysis, hlo_text)
    use = corrected_counts or raw
    flops = use["flops"]
    bytes_accessed = use["bytes"]
    coll = use.get("coll_breakdown", raw["coll_breakdown"])
    coll_total = use["coll"]

    compute_s = flops / hw.peak_flops
    memory_s = bytes_accessed / hw.hbm_bw
    collective_s = coll_total / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    model_pd = model_flops_total / n_devices
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        coll_bytes_per_device=coll_total,
        coll_breakdown={k: int(v) for k, v in coll.items()},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_per_device=model_pd,
        useful_ratio=(model_pd / flops) if flops else 0.0,
        memory_analysis=memory_analysis,
        loop_corrected=corrected_counts is not None,
        raw_flops_per_device=raw["flops"],
        raw_bytes_per_device=raw["bytes"],
        raw_coll_bytes_per_device=raw["coll"],
    )
