"""Engine-API lint: no deprecated per-call engine keywords in user-facing code.

``simulate`` / ``simulate_fleet`` accept their engine axes (streaming,
rng_mode, backend, metrics, devices, window, prefetch, rep_group) two ways:
bundled in one ``options=EngineOptions(...)`` value (the API), or as
individual keywords (deprecated aliases kept for one release so downstream
call sites migrate on a ``DeprecationWarning``, not a crash).  Examples and
benchmarks are the code users copy from, so they must demonstrate the real
API.  This checker walks every ``.py`` file under ``examples/`` and
``benchmarks/`` and fails when a ``simulate*`` call passes a deprecated
keyword.

Tests are deliberately *not* linted: they pin the alias path (parity with
``options=``, the warning itself, the conflict error) and need the
deprecated spellings to do it.

Run (CI runs it in the lint job):

    python tools/lint_engine_api.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINTED_DIRS = ("examples", "benchmarks")
ENTRYPOINTS = {"simulate", "simulate_fleet"}
DEPRECATED_KW = {
    "streaming",
    "rng_mode",
    "backend",
    "metrics",
    "devices",
    "window",
    "prefetch",
    "rep_group",
}


def _call_name(node: ast.Call) -> str | None:
    """Bare-name or attribute tail: matches simulate(...), core.simulate(...)."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def lint_file(path: Path) -> list[str]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:  # a broken file is its own CI failure; skip here
        return [f"{path}: could not parse ({e.msg})"]
    errors = []
    rel = path.relative_to(REPO)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _call_name(node) not in ENTRYPOINTS:
            continue
        bad = sorted(
            kw.arg for kw in node.keywords
            if kw.arg is not None and kw.arg in DEPRECATED_KW
        )
        if bad:
            errors.append(
                f"{rel}:{node.lineno}: {_call_name(node)}() passes deprecated "
                f"engine keyword(s) {', '.join(bad)} — bundle them in "
                f"options=EngineOptions(...)"
            )
    return errors


def main(argv=None) -> int:
    roots = [REPO / d for d in LINTED_DIRS]
    errors = []
    n_files = 0
    for root in roots:
        for path in sorted(root.rglob("*.py")):
            n_files += 1
            errors.extend(lint_file(path))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"engine-api lint: {len(errors)} violation(s) in {n_files} files",
              file=sys.stderr)
        return 1
    print(f"engine-api lint: {n_files} files clean "
          f"(no deprecated simulate*/fleet keywords)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
