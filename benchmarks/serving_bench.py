"""Systems benchmark: continuous batching vs sequential serving.

Measures wall-clock and slot utilization for a bursty queue of requests on
the paper-analog edge model — the serving-layer number that motivates the
paper's per-frame admission protocol.  Prints CSV."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_zoo import SQUEEZE_LM
from repro.models import Model
from repro.serving import ContinuousBatcher, Request, ServingEngine

from .common import csv_row


def main(n_requests: int = 12, gen: int = 8, prompt: int = 16):
    model = Model(SQUEEZE_LM)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, SQUEEZE_LM.vocab_size, size=prompt).astype(np.int32)
               for _ in range(n_requests)]

    print("mode,slots,requests,total_s,req_per_s")
    # sequential (one request at a time)
    eng = ServingEngine(model, params)
    eng.generate({"tokens": jnp.asarray(prompts[0])[None]}, gen, max_len=64)  # warm
    t0 = time.perf_counter()
    for p in prompts:
        eng.generate({"tokens": jnp.asarray(p)[None]}, gen, max_len=64)
    seq_s = time.perf_counter() - t0
    print(csv_row("sequential", 1, n_requests, f"{seq_s:.2f}", f"{n_requests/seq_s:.2f}"))

    results = {}
    for slots in (2, 4):
        cb = ContinuousBatcher(model, params, n_slots=slots, max_len=64)
        cb.run([Request(900, prompts[0], 2)])  # warm compile
        cb.reset()
        t0 = time.perf_counter()
        out = cb.run([Request(i, p, gen) for i, p in enumerate(prompts)])
        dt = time.perf_counter() - t0
        assert len(out) == n_requests
        results[slots] = dt
        print(csv_row("continuous", slots, n_requests, f"{dt:.2f}", f"{n_requests/dt:.2f}"))
    return results


if __name__ == "__main__":
    main()
