"""Systems benchmark: GUS scheduling throughput.

The paper argues GUS is a 'polynomial constant-time' online decision
algorithm; here we measure the jit+vmap implementation's decisions/second —
the number that determines how many edge frames per second one controller
can schedule.  Prints CSV: impl,batch,instances_per_s,us_per_call."""
from __future__ import annotations

import time

import jax

from repro.core import GeneratorConfig, generate_batch, generate_instance, gus_schedule, gus_schedule_batch, gus_schedule_np

from .common import csv_row

CFG = GeneratorConfig()  # paper scale: N=100, M=10, L=10


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main():
    print("impl,batch,instances_per_s,us_per_call")
    inst = generate_instance(0, CFG)

    t_np = _time(lambda i: gus_schedule_np(i), inst, reps=1)
    print(csv_row("numpy", 1, f"{1/t_np:.1f}", f"{t_np*1e6:.0f}"))

    t_jax = _time(gus_schedule, inst)
    print(csv_row("jax-jit", 1, f"{1/t_jax:.1f}", f"{t_jax*1e6:.0f}"))

    for bs in (16, 64):
        batch = generate_batch(0, bs, CFG)
        t = _time(gus_schedule_batch, batch)
        print(csv_row("jax-vmap", bs, f"{bs/t:.1f}", f"{t/bs*1e6:.0f}"))
    return True


if __name__ == "__main__":
    main()
