"""Systems benchmark: GUS scheduling throughput.

The paper argues GUS is a 'polynomial constant-time' online decision
algorithm; here we measure the jit+vmap implementation's decisions/second —
the number that determines how many edge frames per second one controller
can schedule.  Prints CSV (impl,batch,instances_per_s,us_per_call) and
writes ``results/scheduler_throughput/BENCH_scheduler.json``.

Two device backends are measured: the jitted XLA loop (``jax-jit`` /
``jax-vmap`` rows) and the fused Pallas kernel (``pallas`` rows, see
:mod:`repro.kernels.gus_pallas`).  Before any Pallas row is timed its
assignments are asserted **bit-identical** to the XLA path — a CPU run
(interpret mode) therefore gates *parity*, while an accelerator run also
gates *speed*: on TPU the Pallas rows are compiled Mosaic, enter the
baseline gate, and the batch-64 Pallas point must be no slower than the
batch-64 XLA point.

CI gates on it: ``--compare benchmarks/baselines/BENCH_scheduler.json
--tolerance 0.50`` fails when a gated row's throughput regresses by more
than the band against the checked-in baseline (the wide band absorbs
shared-runner noise; ``--update-baseline`` refreshes the file).  The
un-jitted numpy oracle row and interpret-mode Pallas rows are reported but
never gated — parity references, not products.  The report's ``meta``
records the jax/jaxlib versions and the device platform/kind so baseline
mismatches across containers are diagnosable from the JSON alone.

Run:

    PYTHONPATH=src python -m benchmarks.scheduler_throughput
    PYTHONPATH=src python -m benchmarks.scheduler_throughput \\
        --compare benchmarks/baselines/BENCH_scheduler.json
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jaxlib
import numpy as np

from repro.core import (
    GeneratorConfig,
    aggregate_instance,
    generate_batch,
    generate_instance,
    gus_schedule,
    gus_schedule_batch,
    gus_schedule_np,
    hier_backend_fn,
    hier_cells_np,
)
from repro.kernels.gus_pallas import gus_pallas_interpret_default
from repro.kernels.hier_pallas import hier_cells_pallas

from .common import csv_row, gate_rows_against_baseline

CFG = GeneratorConfig()  # paper scale: N=100, M=10, L=10


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _env_meta() -> dict:
    """Toolchain + device identity for cross-container baseline forensics."""
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "device_platform": dev.platform,
        "device_kind": dev.device_kind,
        "n_devices": jax.local_device_count(),
        "pallas_interpret": gus_pallas_interpret_default(),
    }


def _assert_bit_parity(a, b, what: str):
    """Integer assignments must agree exactly — the Pallas rows are only
    timed after they have earned their place on the same plot."""
    for field in ("j", "l"):
        av, bv = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        if not np.array_equal(av, bv):
            raise SystemExit(
                f"scheduler bench: pallas/xla assignment mismatch on {what} "
                f"({field}: {int((av != bv).sum())} cells differ) — refusing "
                "to benchmark a kernel that is not bit-identical"
            )


def _hier_class_args(inst, k: int = 3):
    """Class tensors for the hierarchical allocator rows: a paper-scale
    frame tiled ``k``-fold so every class carries a multi-member count and
    the analytic chunk loop actually loops."""
    rep = lambda x: np.repeat(np.asarray(x), k, axis=0)  # noqa: E731
    import dataclasses

    tiled = dataclasses.replace(
        inst,
        cover=rep(inst.cover), A=rep(inst.A), C=rep(inst.C),
        w_a=rep(inst.w_a), w_c=rep(inst.w_c),
        acc=rep(inst.acc), ctime=rep(inst.ctime), v=rep(inst.v),
        u=rep(inst.u), avail=rep(inst.avail),
    )
    agg = aggregate_instance(tiled)
    o = np.argsort(agg.first_idx, kind="stable")
    return (
        agg.us[o], agg.feas[o], agg.v[o], agg.u[o],
        agg.cover[o].astype(np.int32), agg.count[o].astype(np.int32),
        np.asarray(inst.gamma, np.float32), np.asarray(inst.eta, np.float32),
    )


def _assert_cells_parity(got, exp, what: str):
    for name, g, e in zip(("take", "start"), got, exp):
        g, e = np.asarray(g), np.asarray(e)
        if not np.array_equal(g, e):
            raise SystemExit(
                f"scheduler bench: hier cell mismatch on {what} ({name}: "
                f"{int((g != e).sum())} cells differ) — refusing to "
                "benchmark an allocator that is not bit-identical"
            )


def run(repeats: int = 3) -> dict:
    print("impl,batch,instances_per_s,us_per_call")
    inst = generate_instance(0, CFG)
    env = _env_meta()
    # interpret-mode (CPU) Pallas rows are parity evidence, not perf claims;
    # only the compiled Mosaic path enters the perf gates
    pallas_gated = not env["pallas_interpret"]
    rows = []

    def add(impl, batch, per_call_s, gated):
        rows.append(
            {
                "impl": impl,
                "batch": batch,
                "instances_per_s": round(batch / per_call_s, 1),
                "us_per_call": round(per_call_s / batch * 1e6, 1),
                "gated": gated,
            }
        )
        print(csv_row(impl, batch, f"{batch / per_call_s:.1f}",
                      f"{per_call_s / batch * 1e6:.0f}"))

    add("numpy", 1, _time(lambda i: gus_schedule_np(i), inst, reps=1), gated=False)
    add("jax-jit", 1, _time(gus_schedule, inst, reps=repeats), gated=True)

    pallas1 = lambda i: gus_schedule(i, backend="pallas")  # noqa: E731
    _assert_bit_parity(pallas1(inst), gus_schedule(inst), "batch-1 instance")
    add("pallas", 1, _time(pallas1, inst, reps=repeats), gated=pallas_gated)

    pallas_b = lambda b: gus_schedule_batch(b, backend="pallas")  # noqa: E731
    for bs in (16, 64):
        batch = generate_batch(0, bs, CFG)
        add("jax-vmap", bs, _time(gus_schedule_batch, batch, reps=repeats),
            gated=True)
        _assert_bit_parity(
            pallas_b(batch), gus_schedule_batch(batch), f"batch-{bs} grid"
        )
        add("pallas", bs, _time(pallas_b, batch, reps=repeats),
            gated=pallas_gated)

    # hierarchical analytic allocator (class-aggregate fleet path): same
    # three-implementation story, parity asserted before any row is timed
    hargs = _hier_class_args(generate_instance(0, CFG, as_numpy=True))
    ref = hier_cells_np(*hargs)
    xla_fn, pal_fn = hier_backend_fn("xla"), hier_backend_fn("pallas")
    _assert_cells_parity(xla_fn(*hargs), ref, "hier frame (xla)")
    _assert_cells_parity(pal_fn(*hargs), ref, "hier frame (pallas)")
    add("hier-np", 1, _time(hier_cells_np, *hargs, reps=1), gated=False)
    add("hier-xla", 1, _time(xla_fn, *hargs, reps=repeats), gated=True)
    add("hier-pallas", 1, _time(pal_fn, *hargs, reps=repeats),
        gated=pallas_gated)

    # batched hier rows: vmap over a replication axis, the fleet's layout
    bs = 16
    hbatch = [np.broadcast_to(a, (bs,) + a.shape).copy() for a in hargs]
    vx = jax.jit(jax.vmap(xla_fn))
    _assert_cells_parity(
        jax.tree.map(lambda x: np.asarray(x)[0], tuple(vx(*hbatch))), ref,
        f"hier batch-{bs} (xla)")
    _assert_cells_parity(
        jax.tree.map(lambda x: np.asarray(x)[0],
                     tuple(hier_cells_pallas(*hbatch))), ref,
        f"hier batch-{bs} (pallas)")
    add("hier-xla", bs, _time(vx, *hbatch, reps=repeats), gated=True)
    add("hier-pallas", bs, _time(hier_cells_pallas, *hbatch, reps=repeats),
        gated=pallas_gated)

    return {
        "meta": {
            "bench": "scheduler_throughput",
            "n_requests": CFG.n_requests,
            "repeats": repeats,
            **env,
        },
        "rows": rows,
    }


def _row(report: dict, impl: str, batch: int):
    return next(
        (r for r in report["rows"] if r["impl"] == impl and r["batch"] == batch),
        None,
    )


def gate_pallas_vs_xla(report: dict, slack: float = 0.10):
    """Accelerator-only speed gate: the compiled Pallas kernel must be no
    slower than the jitted XLA path at the batch-64 bench point (``slack``
    absorbs timer noise).  Interpret-mode (CPU) runs skip this — there the
    Pallas rows gate parity, not speed."""
    if report["meta"].get("pallas_interpret", True):
        print("pallas-vs-xla speed gate skipped (interpret mode: parity-only)")
        return
    xla = _row(report, "jax-vmap", 64)
    pal = _row(report, "pallas", 64)
    if xla is None or pal is None:
        raise SystemExit("scheduler bench: missing batch-64 row for the "
                         "pallas-vs-xla gate")
    if pal["instances_per_s"] < xla["instances_per_s"] * (1.0 - slack):
        raise SystemExit(
            f"scheduler perf gate: pallas batch-64 {pal['instances_per_s']} "
            f"inst/s is slower than xla {xla['instances_per_s']} inst/s "
            f"(allowed slack {slack:.0%})"
        )
    print(f"pallas-vs-xla speed gate OK ({pal['instances_per_s']} vs "
          f"{xla['instances_per_s']} inst/s at batch 64)")


def compare_against_baseline(report: dict, baseline_path: str, tolerance: float):
    """Fail (SystemExit) when a gated row's throughput regresses by more than
    ``tolerance``; rows match on (impl, batch), unmatched rows are skipped."""
    baseline = json.loads(Path(baseline_path).read_text())
    gate_rows_against_baseline(
        [r for r in report["rows"] if r["gated"]],
        baseline.get("rows", []),
        key_fn=lambda r: (r["impl"], r["batch"]),
        metric="instances_per_s",
        tolerance=tolerance,
        baseline_path=baseline_path,
        unit=" inst/s",
        gate_name="scheduler perf gate",
    )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/scheduler_throughput")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--compare", metavar="BASELINE_JSON",
                    help="perf-regression gate against a checked-in baseline")
    ap.add_argument("--tolerance", type=float, default=0.50,
                    help="allowed fractional throughput drop for --compare "
                         "(wide by default: jit timings on shared runners are noisy)")
    ap.add_argument("--update-baseline", metavar="PATH",
                    help="also write the report to PATH (refresh the baseline)")
    args = ap.parse_args(argv)

    report = run(repeats=args.repeats)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "BENCH_scheduler.json"
    path.write_text(json.dumps(report, indent=2))
    print(f"wrote {path}")

    if args.update_baseline:
        Path(args.update_baseline).parent.mkdir(parents=True, exist_ok=True)
        Path(args.update_baseline).write_text(json.dumps(report, indent=2))
        print(f"baseline refreshed at {args.update_baseline}")
    if args.compare:
        gate_pallas_vs_xla(report)
        compare_against_baseline(report, args.compare, args.tolerance)
    return True


if __name__ == "__main__":
    main()
