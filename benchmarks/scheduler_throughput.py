"""Systems benchmark: GUS scheduling throughput.

The paper argues GUS is a 'polynomial constant-time' online decision
algorithm; here we measure the jit+vmap implementation's decisions/second —
the number that determines how many edge frames per second one controller
can schedule.  Prints CSV (impl,batch,instances_per_s,us_per_call) and
writes ``results/scheduler_throughput/BENCH_scheduler.json``.

CI gates on it: ``--compare benchmarks/baselines/BENCH_scheduler.json
--tolerance 0.50`` fails when a jitted row's throughput regresses by more
than the band against the checked-in baseline (the wide band absorbs
shared-runner noise; ``--update-baseline`` refreshes the file).  The
un-jitted numpy oracle row is reported but never gated — it is a parity
reference, not a product.

Run:

    PYTHONPATH=src python -m benchmarks.scheduler_throughput
    PYTHONPATH=src python -m benchmarks.scheduler_throughput \\
        --compare benchmarks/baselines/BENCH_scheduler.json
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.core import (
    GeneratorConfig,
    generate_batch,
    generate_instance,
    gus_schedule,
    gus_schedule_batch,
    gus_schedule_np,
)

from .common import csv_row, gate_rows_against_baseline

CFG = GeneratorConfig()  # paper scale: N=100, M=10, L=10


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(repeats: int = 3) -> dict:
    print("impl,batch,instances_per_s,us_per_call")
    inst = generate_instance(0, CFG)
    rows = []

    def add(impl, batch, per_call_s, gated):
        rows.append(
            {
                "impl": impl,
                "batch": batch,
                "instances_per_s": round(batch / per_call_s, 1),
                "us_per_call": round(per_call_s / batch * 1e6, 1),
                "gated": gated,
            }
        )
        print(csv_row(impl, batch, f"{batch / per_call_s:.1f}",
                      f"{per_call_s / batch * 1e6:.0f}"))

    add("numpy", 1, _time(lambda i: gus_schedule_np(i), inst, reps=1), gated=False)
    add("jax-jit", 1, _time(gus_schedule, inst, reps=repeats), gated=True)
    for bs in (16, 64):
        batch = generate_batch(0, bs, CFG)
        add("jax-vmap", bs, _time(gus_schedule_batch, batch, reps=repeats), gated=True)

    return {
        "meta": {
            "bench": "scheduler_throughput",
            "jax": jax.__version__,
            "n_requests": CFG.n_requests,
            "repeats": repeats,
        },
        "rows": rows,
    }


def compare_against_baseline(report: dict, baseline_path: str, tolerance: float):
    """Fail (SystemExit) when a gated row's throughput regresses by more than
    ``tolerance``; rows match on (impl, batch), unmatched rows are skipped."""
    baseline = json.loads(Path(baseline_path).read_text())
    gate_rows_against_baseline(
        [r for r in report["rows"] if r["gated"]],
        baseline.get("rows", []),
        key_fn=lambda r: (r["impl"], r["batch"]),
        metric="instances_per_s",
        tolerance=tolerance,
        baseline_path=baseline_path,
        unit=" inst/s",
        gate_name="scheduler perf gate",
    )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/scheduler_throughput")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--compare", metavar="BASELINE_JSON",
                    help="perf-regression gate against a checked-in baseline")
    ap.add_argument("--tolerance", type=float, default=0.50,
                    help="allowed fractional throughput drop for --compare "
                         "(wide by default: jit timings on shared runners are noisy)")
    ap.add_argument("--update-baseline", metavar="PATH",
                    help="also write the report to PATH (refresh the baseline)")
    args = ap.parse_args(argv)

    report = run(repeats=args.repeats)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "BENCH_scheduler.json"
    path.write_text(json.dumps(report, indent=2))
    print(f"wrote {path}")

    if args.update_baseline:
        Path(args.update_baseline).parent.mkdir(parents=True, exist_ok=True)
        Path(args.update_baseline).write_text(json.dumps(report, indent=2))
        print(f"baseline refreshed at {args.update_baseline}")
    if args.compare:
        compare_against_baseline(report, args.compare, args.tolerance)
    return True


if __name__ == "__main__":
    main()
