"""Scenario sweep: satisfied-user % per registered policy per registered scenario.

For every scenario in the registry this runs the virtual testbed once per
seed with every vmappable policy from :mod:`repro.core.policies` (GUS's
jitted hot path, ordered GUS, the paper's five heuristics) and, for GUS,
the vmapped Monte-Carlo fleet runner — the "as many scenarios as you can
imagine" benchmark the scenario engine exists for.  (The full matrix with
the ILP oracle included lives in ``benchmarks/paper_figures.py``.)

Prints CSV: sweep,scenario,policy,n_requests,satisfied_pct,dropped_pct,mean_us
then one fleet line per scenario and a GUS-vs-best-heuristic summary.

Run:  PYTHONPATH=src python -m benchmarks.scenario_sweep [--fast]
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    SimConfig,
    demo_cluster_spec,
    get_scenario,
    list_scenarios,
    simulate,
    simulate_fleet,
)

from .common import SWEEP_POLICIES, csv_row


def main(seeds=(0, 1, 2), n_rep=16, rate=2.0):
    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=60_000.0,
        arrival_rate_per_s=rate,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
    )
    print("sweep,scenario,policy,n_requests,satisfied_pct,dropped_pct,mean_us")
    results = {}
    # dense_sweep=False scenarios (mega-city) are hierarchical-fleet-scale
    # workloads; covered by the mega-city smoke and fleet_scale --users-sweep.
    names = [s for s in list_scenarios() if get_scenario(s).dense_sweep]
    for name in names:
        for pol in SWEEP_POLICIES:
            rs = [
                simulate(spec, cfg, policy=pol, scenario=name, seed=s).as_dict()
                for s in seeds
            ]
            r = {k: float(np.mean([x[k] for x in rs])) for k in rs[0]}
            results[(name, pol)] = r
            print(
                csv_row(
                    "scenario", name, pol, int(r["n_requests"]),
                    f"{r['satisfied_pct']:.2f}", f"{r['dropped_pct']:.2f}",
                    f"{r['mean_us']:.4f}",
                ),
                flush=True,
            )
        fleet = simulate_fleet(spec, cfg, scenario=name, n_rep=n_rep, seed=0)
        print(
            csv_row(
                "fleet", name, "gus", fleet.n_requests,
                f"{fleet.satisfied_pct:.2f}", f"{fleet.satisfied_std:.2f}",
                f"{fleet.mean_us:.4f}",
            ),
            flush=True,
        )

    # GUS should never trail the best restricted heuristic by more than
    # noise, anywhere (Happy-* are relaxations — upper bounds, not baselines)
    for name in names:
        g = results[(name, "gus")]["satisfied_pct"]
        best_h = max(
            results[(name, p)]["satisfied_pct"]
            for p in ("random", "local_all", "offload_all")
        )
        print(csv_row("claim", name, "gus_vs_best_heuristic", f"{g - best_h:+.2f}"))
        assert g >= best_h - 2.0, (name, g, best_h)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.fast:
        main(seeds=(0,), n_rep=4)
    else:
        main()
