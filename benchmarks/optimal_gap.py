"""Paper Sec. IV validation: GUS vs the exact ILP optimum.

"Our results confirm that the proposed algorithm performs close-to-optimal
 ... achieving in average 90% of the optimal value."

We solve small instances exactly with the policy registry's ``ilp`` oracle
(branch & bound) and report the mean GUS/OPT ratio.
Prints CSV: seed,opt,gus,ratio then the aggregate."""
from __future__ import annotations

import numpy as np

from repro.core import (
    generate_instance,
    get_policy,
    make_ilp_policy,
    mean_us,
)

from .common import GAP_NODE_LIMIT, csv_row, gap_regimes

REGIMES = gap_regimes(n_requests=10)


def main(n_instances: int = 25):
    print("regime,seed,opt,gus,ratio,gus_ordered,ratio_ordered")
    ratios, ratios_ord = [], []
    for regime, cfg in REGIMES.items():
        n_servers = cfg.n_edge + cfg.n_cloud
        ilp_fn = make_ilp_policy(node_limit=GAP_NODE_LIMIT, strict=True).bind(cfg.n_edge, n_servers)
        gus_fn = get_policy("gus").bind(cfg.n_edge, n_servers)
        ord_fn = get_policy("gus-ordered").bind(cfg.n_edge, n_servers)
        for seed in range(n_instances):
            inst = generate_instance(seed, cfg)
            o = ilp_fn(inst)
            a = gus_fn(inst)
            b = ord_fn(inst)
            opt = float(mean_us(inst, np.asarray(o.j), np.asarray(o.l)))
            g = float(mean_us(inst, a.j, a.l))
            go = float(mean_us(inst, b.j, b.l))
            if opt > 1e-9:
                ratios.append(g / opt)
                ratios_ord.append(go / opt)
                print(csv_row(regime, seed, f"{opt:.4f}", f"{g:.4f}", f"{g/opt:.3f}",
                              f"{go:.4f}", f"{go/opt:.3f}"))
    mean_ratio = float(np.mean(ratios))
    mean_ord = float(np.mean(ratios_ord))
    print(f"claim,gus_over_optimal_mean_ratio,{mean_ratio:.3f}")
    print(f"beyond_paper,ordered_gus_over_optimal_mean_ratio,{mean_ord:.3f}")
    assert mean_ratio >= 0.85, f"paper reports ~0.90; got {mean_ratio:.3f}"
    assert mean_ord >= mean_ratio - 0.02, "ordered GUS should not be worse"
    return mean_ratio


if __name__ == "__main__":
    main()
