"""Paper Sec. IV validation: GUS vs the exact ILP optimum.

"Our results confirm that the proposed algorithm performs close-to-optimal
 ... achieving in average 90% of the optimal value."

We solve small instances exactly with branch & bound and report the mean
GUS/OPT ratio.  Prints CSV: seed,opt,gus,ratio then the aggregate."""
from __future__ import annotations

import numpy as np

from repro.core import (
    GeneratorConfig,
    generate_instance,
    gus_schedule,
    gus_schedule_ordered,
    mean_us,
    solve_bnb,
)

from .common import csv_row

# Two regimes: ample capacity (greedy = optimal) and contended capacity
# (greedy pays for its myopia) — the paper's "average 90%" sits between.
REGIMES = {
    "ample": GeneratorConfig(
        n_requests=10, n_edge=3, n_cloud=1, n_services=5, n_variants=3
    ),
    "contended": GeneratorConfig(
        n_requests=10, n_edge=3, n_cloud=1, n_services=5, n_variants=3,
        edge_compute_classes=(400.0, 600.0, 800.0),
        edge_comm_classes=(60.0, 90.0, 120.0),
        cloud_compute=1600.0, cloud_comm=300.0,
    ),
}


def main(n_instances: int = 25):
    print("regime,seed,opt,gus,ratio,gus_ordered,ratio_ordered")
    ratios, ratios_ord = [], []
    for regime, cfg in REGIMES.items():
        for seed in range(n_instances):
            inst = generate_instance(seed, cfg)
            _, opt = solve_bnb(inst)
            a = gus_schedule(inst)
            b = gus_schedule_ordered(inst)
            g = float(mean_us(inst, a.j, a.l))
            go = float(mean_us(inst, b.j, b.l))
            if opt > 1e-9:
                ratios.append(g / opt)
                ratios_ord.append(go / opt)
                print(csv_row(regime, seed, f"{opt:.4f}", f"{g:.4f}", f"{g/opt:.3f}",
                              f"{go:.4f}", f"{go/opt:.3f}"))
    mean_ratio = float(np.mean(ratios))
    mean_ord = float(np.mean(ratios_ord))
    print(f"claim,gus_over_optimal_mean_ratio,{mean_ratio:.3f}")
    print(f"beyond_paper,ordered_gus_over_optimal_mean_ratio,{mean_ord:.3f}")
    assert mean_ratio >= 0.85, f"paper reports ~0.90; got {mean_ratio:.3f}"
    assert mean_ord >= mean_ratio - 0.02, "ordered GUS should not be worse"
    return mean_ratio


if __name__ == "__main__":
    main()
