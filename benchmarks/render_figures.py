"""Optional matplotlib renderer for the paper-figure pipeline.

Draws Fig. 1-style panels from ``results/paper_figures/paper_figures.json``
(written by ``benchmarks/paper_figures.py``) into PNGs next to the JSON.
Import-gated: matplotlib is NOT a dependency of this repo — without it the
script explains itself and exits cleanly, so CI and bare environments are
unaffected.  The JSON/markdown artifacts remain the source of truth; these
panels are for humans.

    python benchmarks/paper_figures.py --tiny          # writes the JSON
    python benchmarks/render_figures.py                # draws the panels
    python benchmarks/render_figures.py --json /tmp/f/paper_figures.json

Design notes: series colors follow a fixed policy -> hue map (identity is
stable across panels and filters), one y-axis per panel, thin marks on a
recessive grid, and the per-policy tables in ``paper_figures.md`` double as
the accessible table view of every panel.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# ---------------------------------------------------------------------------
# import gate
# ---------------------------------------------------------------------------

try:
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
except ImportError:  # pragma: no cover - exercised only without matplotlib
    matplotlib = None
    plt = None

# fixed policy -> color slots (validated categorical order; identity never
# re-assigned when a panel carries fewer series)
SERIES = {
    "gus": "#2a78d6",                  # blue
    "gus-ordered": "#eb6834",          # orange
    "random": "#1baf7a",               # aqua
    "offload_all": "#eda100",          # yellow
    "local_all": "#e87ba4",            # magenta
    "happy_computation": "#008300",    # green
    "happy_communication": "#4a3aa7",  # violet
    "ilp": "#e34948",                  # red
    "lp-bound": "#e34948",             # oracle family: red, dashed line style
}
DASHED = {"lp-bound"}

SURFACE = "#fcfcfb"
INK = "#0b0b0b"
MUTED = "#898781"
GRID = "#e1e0d9"
BASELINE = "#c3c2b7"

SWEEPS = ("arrival-rate", "num-users", "qos-deadline", "qos-accuracy")


def _style(ax, x_label: str, y_label: str, title: str) -> None:
    ax.set_facecolor(SURFACE)
    ax.set_title(title, color=INK, fontsize=11, loc="left")
    ax.set_xlabel(x_label, color=MUTED, fontsize=9)
    ax.set_ylabel(y_label, color=MUTED, fontsize=9)
    ax.tick_params(colors=MUTED, labelsize=8)
    ax.grid(True, axis="y", color=GRID, linewidth=0.8)
    ax.set_axisbelow(True)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("left", "bottom"):
        ax.spines[side].set_color(BASELINE)


def _ordered_policies(rows, key="policy"):
    seen = []
    for r in rows:
        if r[key] not in seen:
            seen.append(r[key])
    return [p for p in SERIES if p in seen] + [p for p in seen if p not in SERIES]


def render_sweep(fig_name: str, fig_data: dict, out: Path) -> Path:
    """One line panel: satisfied-% vs the sweep axis, one series per policy."""
    rows = fig_data["rows"]
    sat = {(r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
    xs = sorted({r["x"] for r in rows})
    fig, ax = plt.subplots(figsize=(6.4, 4.0), facecolor=SURFACE)
    for pol in _ordered_policies(rows):
        ys = [sat.get((x, pol)) for x in xs]
        ax.plot(
            xs, ys,
            color=SERIES.get(pol, MUTED),
            linestyle="--" if pol in DASHED else "-",
            linewidth=2.0, marker="o", markersize=4, label=pol,
        )
    _style(ax, fig_data["x_label"], "satisfied (%)", fig_name)
    ax.set_ylim(0, 105)
    ax.legend(fontsize=7, frameon=False, labelcolor=INK, ncol=2)
    path = out / f"{fig_name}.png"
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_scenarios(fig_data: dict, out: Path) -> Path:
    """Small multiples: one horizontal-bar panel per scenario.  Identity is
    carried by the axis labels, so bars stay single-hue with GUS emphasized;
    every bar is direct-labeled (the markdown table is the full table view)."""
    rows = fig_data["rows"]
    sat = {(r["scenario"], r["policy"]): r["satisfied_pct"] for r in rows}
    scns = sorted({r["scenario"] for r in rows})
    pols = _ordered_policies(rows)
    ncol = 2
    nrow = (len(scns) + ncol - 1) // ncol
    fig, axes = plt.subplots(
        nrow, ncol, figsize=(9.6, 2.2 * nrow), facecolor=SURFACE, squeeze=False
    )
    for k, scn in enumerate(scns):
        ax = axes[k // ncol][k % ncol]
        vals = [sat.get((scn, p), 0.0) for p in pols]
        colors = ["#2a78d6" if p == "gus" else "#9ec5f4" for p in pols]
        ax.barh(range(len(pols)), vals, color=colors, height=0.62)
        ax.set_yticks(range(len(pols)))
        ax.set_yticklabels(pols, fontsize=7, color=INK)
        ax.invert_yaxis()
        for i, v in enumerate(vals):
            ax.text(v + 1.2, i, f"{v:.0f}", va="center", fontsize=7, color=INK)
        _style(ax, "satisfied (%)", "", scn)
        ax.set_xlim(0, 112)
        ax.grid(True, axis="x", color=GRID, linewidth=0.8)
        ax.grid(False, axis="y")
    for k in range(len(scns), nrow * ncol):
        axes[k // ncol][k % ncol].set_visible(False)
    path = out / "scenarios.png"
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_optimality_gap(fig_data: dict, out: Path) -> Path:
    """Per-seed GUS/optimum ratios, one series per regime (first slots are
    all-pairs validated for dot panels)."""
    rows = fig_data["rows"]
    regimes = sorted({r["regime"] for r in rows})
    palette = ["#2a78d6", "#eb6834", "#1baf7a"]
    fig, ax = plt.subplots(figsize=(6.4, 4.0), facecolor=SURFACE)
    for k, regime in enumerate(regimes):
        pts = [r for r in rows if r["regime"] == regime]
        ax.plot(
            [r["seed"] for r in pts], [r["ratio"] for r in pts],
            "o", markersize=6, color=palette[k % len(palette)], label=regime,
        )
    ax.axhline(0.9, color=BASELINE, linewidth=1.0, linestyle=":")
    ax.text(0.02, 0.905, "paper: ~0.90 of optimal", transform=ax.get_yaxis_transform(),
            fontsize=7, color=MUTED)
    _style(ax, "instance seed", "GUS / bound (mean US)", "optimality-gap")
    ax.set_ylim(0.5, 1.05)
    ax.legend(fontsize=8, frameon=False, labelcolor=INK)
    path = out / "optimality-gap.png"
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_congestion(fig_data: dict, out: Path) -> Path:
    """Grouped bars per (scenario, rate) point under congestion — the
    Happy-* collapse panel."""
    rows = fig_data["rows"]
    sat = {(r["scenario"], r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
    pts = sorted({(r["scenario"], r["x"]) for r in rows})
    pols = _ordered_policies(rows)
    width = 1.0 / (len(pols) + 1.2)
    fig, ax = plt.subplots(figsize=(7.6, 4.2), facecolor=SURFACE)
    for k, pol in enumerate(pols):
        xs = [i + (k - len(pols) / 2) * width for i in range(len(pts))]
        ys = [sat.get((s, x, pol), 0.0) for s, x in pts]
        ax.bar(xs, ys, width=width * 0.92, color=SERIES.get(pol, MUTED), label=pol)
    ax.set_xticks(range(len(pts)))
    ax.set_xticklabels([f"{s}\n@ {x}/s" for s, x in pts], fontsize=8, color=INK)
    _style(ax, "", "satisfied (%)", "congestion: load-dependent service times")
    ax.set_ylim(0, 105)
    ax.legend(fontsize=7, frameon=False, labelcolor=INK, ncol=2)
    path = out / "congestion.png"
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render(json_path: Path, out: Path) -> list:
    data = json.loads(json_path.read_text())
    figures = data["figures"]
    out.mkdir(parents=True, exist_ok=True)
    written = []
    for name in SWEEPS:
        if name in figures:
            written.append(render_sweep(name, figures[name], out))
    if "scenarios" in figures:
        written.append(render_scenarios(figures["scenarios"], out))
    if "optimality-gap" in figures:
        written.append(render_optimality_gap(figures["optimality-gap"], out))
    if "congestion" in figures:
        written.append(render_congestion(figures["congestion"], out))
    return written


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default="results/paper_figures/paper_figures.json",
                    help="paper_figures.json written by benchmarks/paper_figures.py")
    ap.add_argument("--out", default=None,
                    help="output directory (default: alongside the JSON)")
    args = ap.parse_args(argv)

    if plt is None:
        print("render_figures: matplotlib is not installed; skipping "
              "(pip install matplotlib to draw the panels — the JSON and "
              "markdown artifacts are complete without it)")
        return 0
    json_path = Path(args.json)
    if not json_path.is_file():
        raise SystemExit(
            f"{json_path} not found — run benchmarks/paper_figures.py first"
        )
    out = Path(args.out) if args.out else json_path.parent
    for p in render(json_path, out):
        print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
