"""Beyond-paper extension benchmarks (the paper's stated future work):

 1. ordered-GUS vs GUS on the numerical setup (satisfied-% and mean US);
 2. user mobility: satisfied-% vs per-frame move probability — the paper's
    per-frame formulation should degrade gracefully (scheduling is stateless
    across frames);
 3. priorities: mean US of the top-priority decile under GUS-ordered vs
    priority-blind GUS.

Prints CSV rows."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    GeneratorConfig,
    SimConfig,
    generate_instance,
    gus_schedule,
    gus_schedule_np,
    gus_schedule_ordered,
    mean_us,
    satisfied_mask,
    simulate,
)

from .common import csv_row
from .fig1_testbed import HORIZON_MS, make_testbed_spec


def ordered_vs_arrival(n_instances: int = 40):
    print("bench,metric,gus,gus_ordered")
    cfg = GeneratorConfig()
    sat_a, sat_o, us_a, us_o = [], [], [], []
    for seed in range(n_instances):
        inst = generate_instance(seed, cfg)
        a = gus_schedule(inst)
        b = gus_schedule_ordered(inst)
        sat_a.append(float(satisfied_mask(inst, a.j, a.l).mean()))
        sat_o.append(float(satisfied_mask(inst, b.j, b.l).mean()))
        us_a.append(float(mean_us(inst, a.j, a.l)))
        us_o.append(float(mean_us(inst, b.j, b.l)))
    print(csv_row("ordered", "satisfied_pct", f"{100*np.mean(sat_a):.2f}", f"{100*np.mean(sat_o):.2f}"))
    print(csv_row("ordered", "mean_us", f"{np.mean(us_a):.4f}", f"{np.mean(us_o):.4f}"))
    assert np.mean(us_o) >= np.mean(us_a) - 1e-4
    return np.mean(us_a), np.mean(us_o)


def mobility_sweep(probs=(0.0, 0.2, 0.5), n=800, seeds=(0, 1)):
    print("bench,move_prob,satisfied_pct,local_pct")
    spec = make_testbed_spec()
    spec.gamma_frame = np.array([3900.0, 3900.0, 3000.0], np.float32)
    spec.eta_frame = np.array([350.0, 350.0, 3500.0], np.float32)
    out = {}
    for mp in probs:
        cfg = SimConfig(
            horizon_ms=HORIZON_MS,
            arrival_rate_per_s=n / (spec.n_edge * HORIZON_MS / 1000.0),
            delay_req_ms=5000.0,
            acc_req_mean=50.0,
            move_prob=mp,
        )
        rs = [simulate(spec, cfg, gus_schedule_np, seed=s, n_requests=n).as_dict() for s in seeds]
        r = {k: float(np.mean([x[k] for x in rs])) for k in rs[0]}
        out[mp] = r
        print(csv_row("mobility", mp, f"{r['satisfied_pct']:.2f}", f"{r['local_pct']:.2f}"))
    # graceful degradation: mobility costs < 20 points of satisfaction
    assert out[probs[-1]]["satisfied_pct"] > out[0.0]["satisfied_pct"] - 20.0
    return out


def priority_decile(n_instances: int = 20):
    print("bench,metric,blind,priority_aware")
    cfg = GeneratorConfig()
    blind, aware = [], []
    rng = np.random.default_rng(0)
    for seed in range(n_instances):
        inst = generate_instance(seed, cfg)
        pri = jnp.asarray(rng.choice([1.0, 10.0], size=inst.n_requests, p=[0.9, 0.1]))
        top = np.asarray(pri) > 1.0
        a = gus_schedule(inst)
        b = gus_schedule_ordered(inst, priority=pri)
        sa = np.asarray(satisfied_mask(inst, a.j, a.l))
        sb = np.asarray(satisfied_mask(inst, b.j, b.l))
        if top.any():
            blind.append(sa[top].mean())
            aware.append(sb[top].mean())
    print(csv_row("priority", "top_decile_satisfied_pct",
                  f"{100*np.mean(blind):.2f}", f"{100*np.mean(aware):.2f}"))
    assert np.mean(aware) >= np.mean(blind) - 1e-9
    return np.mean(blind), np.mean(aware)


def main(fast: bool = False):
    ordered_vs_arrival(15 if fast else 40)
    mobility_sweep(seeds=(0,) if fast else (0, 1))
    priority_decile(8 if fast else 20)


if __name__ == "__main__":
    main()
