"""Telemetry overhead gate: tracing must be free when it is off.

Measures, at the fleet benchmark's 64-replication point (the same
``bench_spec``/``bench_cfg`` as ``benchmarks/fleet_scale.py``):

1. **Disabled-path overhead** — the cost the span instrumentation adds to
   a run with no recorder installed.  The per-span disabled cost (two
   ``perf_counter`` calls + a ``None`` check) is microbenchmarked
   directly, the span count of the bench point is taken from a recorded
   run, and their product over the untraced wall time is the overhead
   fraction.  This analytic form is robust to run-to-run noise that
   would swamp a naive wall-clock diff of two sub-second runs; the gate
   (``--assert-overhead``, CI uses 0.01) holds it under 1%.
2. **Enabled overheads** — wall-clock deltas of (a) recording host spans
   and (b) the ``metrics=True`` device stream, reported (not gated):
   enabling telemetry is allowed to cost, disabling it is not.

    PYTHONPATH=src python benchmarks/telemetry_overhead.py --assert-overhead 0.01
    PYTHONPATH=src python -m benchmarks.run --only telemetry
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

_SRC = Path(__file__).resolve().parent.parent / "src"
if _SRC.is_dir() and str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.core import EngineOptions, simulate_fleet  # noqa: E402
from repro.obs import CAT_SCHED, recording, span  # noqa: E402

try:  # imported as benchmarks.telemetry_overhead (run.py)
    from .fleet_scale import POLICY, bench_cfg, bench_spec
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from fleet_scale import POLICY, bench_cfg, bench_spec


def _per_span_disabled_s(iters: int = 200_000) -> float:
    """Microbenchmark one disabled span (no recorder installed)."""
    t0 = time.perf_counter()
    for _ in range(iters):
        with span("bench/disabled", CAT_SCHED):
            pass
    return (time.perf_counter() - t0) / iters


def _best_wall(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def measure(*, tiny: bool, repeats: int) -> dict:
    spec = bench_spec()
    cfg = bench_cfg(tiny)
    n_rep = 16 if tiny else 64
    kw = dict(policy=POLICY, n_rep=n_rep, seed=0)

    simulate_fleet(spec, cfg, **kw)  # warmup: compile out of the timings
    base_s = _best_wall(lambda: simulate_fleet(spec, cfg, **kw), repeats)

    with recording() as rec:
        traced_s = _best_wall(lambda: simulate_fleet(spec, cfg, **kw), 1)
    n_spans = sum(1 for e in rec.events() if e["ph"] == "X")

    m_opts = EngineOptions(metrics=True)
    simulate_fleet(spec, cfg, options=m_opts, **kw)  # metrics-variant warmup
    metrics_s = _best_wall(
        lambda: simulate_fleet(spec, cfg, options=m_opts, **kw), repeats
    )

    per_span_s = _per_span_disabled_s()
    disabled_overhead_s = n_spans * per_span_s
    return {
        "bench": {
            "tiny": tiny,
            "n_rep": n_rep,
            "repeats": repeats,
            "wall_s": round(base_s, 4),
        },
        "disabled": {
            "per_span_s": per_span_s,
            "n_spans": n_spans,
            "overhead_s": disabled_overhead_s,
            "overhead_frac": disabled_overhead_s / base_s,
        },
        "enabled_tracing": {
            "wall_s": round(traced_s, 4),
            "n_events": len(rec),
            "overhead_frac": round(traced_s / base_s - 1.0, 4),
        },
        "enabled_metrics": {
            "wall_s": round(metrics_s, 4),
            "overhead_frac": round(metrics_s / base_s - 1.0, 4),
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true", help="CI smoke: small point")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats per variant, best kept")
    ap.add_argument("--out", default="results/telemetry_overhead.json")
    ap.add_argument("--assert-overhead", type=float, default=None, metavar="F",
                    help="fail if the disabled-path overhead fraction "
                         "reaches F (CI gates at 0.01 = 1%%)")
    args = ap.parse_args(argv)

    report = measure(tiny=args.tiny, repeats=args.repeats)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(report, indent=2))

    d = report["disabled"]
    print(f"bench point: {report['bench']['n_rep']} reps, "
          f"{report['bench']['wall_s']}s untraced")
    print(f"disabled path: {d['n_spans']} spans x {d['per_span_s']:.2e}s "
          f"= {d['overhead_s']:.2e}s ({100 * d['overhead_frac']:.4f}%)")
    print(f"tracing on:    {100 * report['enabled_tracing']['overhead_frac']:+.2f}%")
    print(f"metrics on:    {100 * report['enabled_metrics']['overhead_frac']:+.2f}%")
    print(f"report -> {args.out}")

    if args.assert_overhead is not None and d["overhead_frac"] >= args.assert_overhead:
        raise SystemExit(
            f"telemetry overhead gate: disabled-path fraction "
            f"{d['overhead_frac']:.4f} >= {args.assert_overhead}"
        )
    if args.assert_overhead is not None:
        print(f"overhead gate: {d['overhead_frac']:.5f} < {args.assert_overhead}")


if __name__ == "__main__":
    main()
