"""Paper Fig. 1(a)-(d): numerical sweeps (requested delay, requested accuracy,
number of requests, queue delay), Monte-Carlo averaged, over every vmappable
policy in the registry (GUS, ordered GUS, the five baselines).

Each function prints CSV rows: figure,x,policy,satisfied_pct,mean_us,...
and asserts the paper's qualitative claims (monotone trends; GUS >= 1.5x the
weakest heuristics on satisfied-%)."""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core import GeneratorConfig

from .common import MC_RUNS, SWEEP_POLICIES, csv_row, run_policy_mc

BASE = GeneratorConfig()


def _sweep(figure: str, param_values, make_cfg, policies=SWEEP_POLICIES, mc=MC_RUNS):
    rows = {}
    print(f"figure,x,policy,satisfied_pct,mean_us,served_pct,local_pct,cloud_pct,edge_offload_pct")
    for x in param_values:
        cfg = make_cfg(x)
        for pol in policies:
            # crc32, not hash(): string hashing is salted per process, and the
            # MC draws (and the asserted claim ratios) must reproduce run-to-run
            seed = zlib.crc32(f"{figure}:{x}".encode()) % 10_000
            r = run_policy_mc(pol, cfg, seed=seed, mc=mc)
            rows[(x, pol)] = r
            print(
                csv_row(
                    figure, x, pol,
                    f"{r['satisfied_pct']:.2f}", f"{r['mean_us']:.4f}",
                    f"{r['served_pct']:.2f}", f"{r['local_pct']:.2f}",
                    f"{r['cloud_pct']:.2f}", f"{r['edge_offload_pct']:.2f}",
                ),
                flush=True,
            )
    return rows


def fig1a(mc=MC_RUNS):
    """Satisfied-% vs requested-delay mean: larger deadlines -> more served."""
    vals = [250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0]
    rows = _sweep(
        "fig1a", vals,
        lambda d: dataclasses.replace(BASE, delay_req_mean=d, delay_req_std=d / 4),
        mc=mc,
    )
    gus = [rows[(v, "gus")]["satisfied_pct"] for v in vals]
    assert gus[-1] > gus[0], f"fig1a: satisfied% should rise with deadline {gus}"
    return rows


def fig1b(mc=MC_RUNS):
    """Satisfied-% vs requested accuracy: stricter accuracy -> fewer satisfied."""
    vals = [30.0, 40.0, 50.0, 60.0, 70.0, 80.0]
    rows = _sweep(
        "fig1b", vals,
        lambda a: dataclasses.replace(BASE, acc_req_mean=a),
        mc=mc,
    )
    gus = [rows[(v, "gus")]["satisfied_pct"] for v in vals]
    assert gus[0] > gus[-1], f"fig1b: satisfied% should fall with accuracy {gus}"
    return rows


def fig1c(mc=MC_RUNS):
    """Satisfied-% vs number of requests: capacity saturates."""
    vals = [25, 50, 100, 200, 300]
    rows = _sweep(
        "fig1c", vals,
        lambda n: dataclasses.replace(BASE, n_requests=int(n)),
        mc=mc,
    )
    gus = [rows[(v, "gus")]["satisfied_pct"] for v in vals]
    assert gus[0] > gus[-1], f"fig1c: satisfied% should fall with load {gus}"
    return rows


def fig1d(mc=MC_RUNS):
    """Satisfied-% vs queue delay: longer waits eat the deadline budget."""
    vals = [0.0, 250.0, 500.0, 1000.0, 2000.0]
    rows = _sweep(
        "fig1d", vals,
        lambda q: dataclasses.replace(BASE, queue_delay_max=q),
        mc=mc,
    )
    gus = [rows[(v, "gus")]["satisfied_pct"] for v in vals]
    assert gus[0] >= gus[-1], f"fig1d: satisfied% should fall with queue delay {gus}"
    return rows


def check_gus_factor(rows_by_fig):
    """Paper: 'GUS outperforms the baseline heuristics ... by at least 50%'.

    Verified against the non-relaxed heuristics (random/local/offload) averaged
    over all sweep points (the relaxed Happy-* are upper bounds, not baselines)."""
    ratios = []
    for rows in rows_by_fig:
        xs = sorted({x for (x, _) in rows})
        for x in xs:
            g = rows[(x, "gus")]["satisfied_pct"]
            for pol in ("random", "local_all", "offload_all"):
                b = rows[(x, pol)]["satisfied_pct"]
                if b > 1e-6:
                    ratios.append(g / b)
    mean_ratio = float(np.mean(ratios))
    print(f"claim,gus_vs_heuristics_mean_ratio,{mean_ratio:.3f}")
    return mean_ratio


def main(mc=MC_RUNS):
    rows = [fig1a(mc), fig1b(mc), fig1c(mc), fig1d(mc)]
    ratio = check_gus_factor(rows)
    assert ratio >= 1.5, f"GUS should beat heuristics by >=50% on average, got {ratio:.2f}x"


if __name__ == "__main__":
    main()
