"""Benchmark entry point: one function per paper table/figure + systems
benches.  ``PYTHONPATH=src python -m benchmarks.run [--fast]``.

  fig1a-d   — numerical sweeps (Fig. 1(a)-(d))
  fig1e-h   — virtual-testbed sweeps (Fig. 1(e)-(h))
  figures   — paper-figure pipeline: every policy x scenario, JSON + markdown
  resilience — impairment/outage matrix only (the `resilience` paper figure)
  render    — matplotlib panels from the figures JSON (no-op without matplotlib)
  optimal   — GUS vs exact ILP (the ~90%-of-CPLEX table)
  sched     — GUS scheduling throughput (jit/vmap systems number)
  fleet     — sharded Monte-Carlo fleet throughput (BENCH_fleet.json)
  scenarios — satisfied-% per scheduler per registered workload scenario
  telemetry — disabled-path telemetry overhead gate (< 1%)
  roofline  — per-(arch x shape x mesh) roofline table from dry-run reports
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer MC runs")
    ap.add_argument(
        "--only",
        choices=["fig1num", "fig1test", "figures", "resilience", "render", "optimal", "sched", "fleet", "serving", "extensions", "scenarios", "telemetry", "roofline"],
        default=None,
    )
    args = ap.parse_args(argv)
    mc = 64 if args.fast else None

    from . import (
        fig1_numerical,
        fig1_testbed,
        fleet_scale,
        optimal_gap,
        paper_figures,
        render_figures,
        roofline_table,
        scenario_sweep,
        scheduler_throughput,
        serving_bench,
        telemetry_overhead,
        extensions_bench,
    )

    jobs = {
        "fig1num": lambda: fig1_numerical.main(**({"mc": mc} if mc else {})),
        "fig1test": lambda: fig1_testbed.main(
            n_points=(200, 1600) if args.fast else (200, 800, 1600),
            seeds=(0,) if args.fast else (0, 1, 2),
        ),
        "figures": lambda: paper_figures.run(tiny=args.fast),
        "resilience": lambda: paper_figures.run(
            tiny=args.fast, only=("resilience",),
            out="results/resilience",
        ),
        "render": lambda: render_figures.main([]),
        "optimal": lambda: optimal_gap.main(10 if args.fast else 25),
        "sched": lambda: scheduler_throughput.main([]),
        "fleet": lambda: fleet_scale.main(["--tiny"] if args.fast else []),
        "serving": lambda: serving_bench.main(6 if args.fast else 12),
        "extensions": lambda: extensions_bench.main(fast=args.fast),
        "scenarios": lambda: (
            scenario_sweep.main(seeds=(0,), n_rep=4) if args.fast else scenario_sweep.main()
        ),
        "telemetry": lambda: telemetry_overhead.main(
            ["--tiny", "--assert-overhead", "0.01"] if args.fast
            else ["--assert-overhead", "0.01"]
        ),
        "roofline": roofline_table.main,
    }
    # `resilience` is an alias for the CI smoke step; the full `figures`
    # pipeline already includes that figure, so skip the alias by default
    selected = [args.only] if args.only else [n for n in jobs if n != "resilience"]
    for name in selected:
        t0 = time.time()
        print(f"\n=== {name} " + "=" * 50, flush=True)
        jobs[name]()
        print(f"=== {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    sys.exit(main())
