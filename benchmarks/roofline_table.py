"""Deliverable (g): the roofline table, assembled from dry-run reports.

Reads reports/dryrun/*.json (produced by `python -m repro.launch.dryrun`) and
prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck and the useful-compute ratio.  Prints CSV."""
from __future__ import annotations

import glob
import json
import os

from .common import csv_row

REPORT_DIR = os.environ.get("DRYRUN_DIR", "reports/dryrun")


def load_reports(report_dir=REPORT_DIR):
    out = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def main():
    reports = load_reports()
    if not reports:
        print(f"no dry-run reports under {REPORT_DIR}; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --continue-on-error")
        return []
    print("arch,shape,mesh,compute_ms,memory_ms,collective_ms,bottleneck,useful_ratio,flops_per_dev,coll_bytes_per_dev")
    for r in reports:
        print(
            csv_row(
                r["arch"], r["shape"], r["mesh"],
                f"{r['compute_s']*1e3:.3f}", f"{r['memory_s']*1e3:.3f}",
                f"{r['collective_s']*1e3:.3f}", r["bottleneck"],
                f"{r['useful_ratio']:.3f}", f"{r['flops_per_device']:.3e}",
                f"{r['coll_bytes_per_device']:.3e}",
            )
        )
    return reports


if __name__ == "__main__":
    main()
