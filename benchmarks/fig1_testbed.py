"""Paper Fig. 1(e)-(h): virtual-testbed results vs total #requests.

The simulator mirrors the paper's testbed protocol (admission queues, 3000 ms
frames, queue cap 4, EMA bandwidth estimator, lognormal wireless jitter); the
model zoo is the paper-analog ladder (SqueezeNet/GoogleNet analogs) with
latencies from the roofline profile of the actual JAX models.

Prints CSV: figure,n_requests,policy,satisfied_pct,local_pct,cloud_pct,
edge_offload_pct,dropped_pct."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.configs.paper_zoo import GOOGLE_LM, MID_LM, SQUEEZE_LM
from repro.core import SimConfig, simulate
from repro.serving import ModelZoo, ServiceSpec, build_cluster_spec, variant_ladder

from .common import csv_row


def make_testbed_spec(seed: int = 0):
    """Two edge servers + one cloud (the paper's RPi4 x2 + desktop),
    SqueezeNet-analog on edges, GoogleNet-analog on the cloud."""
    services = [
        ServiceSpec("imgcls-a", [SQUEEZE_LM, MID_LM, GOOGLE_LM]),
        ServiceSpec("imgcls-b", [SQUEEZE_LM, MID_LM, GOOGLE_LM]),
        ServiceSpec("summarize", variant_ladder(get_config("mamba2-130m"), 3)),
    ]
    zoo = ModelZoo(services)
    spec = build_cluster_spec(
        zoo,
        edge_classes=["edge-1", "edge-1"],
        cloud_classes=["cloud-256"],
        edge_variants=2,          # only the two cheap variants fit on an edge
        edge_service_frac=1.0,
        seed=seed,
    )
    # calibrate T^proc to the paper's testbed measurements:
    # SqueezeNet-on-RPi4 ~1300 ms (edge), GoogleNet-on-desktop ~300 ms (cloud)
    scale_edge = 1300.0 / max(spec.proc_ms[0][spec.placed[0]].max(), 1e-9)
    spec.proc_ms[: spec.n_edge] *= scale_edge
    cl = spec.n_edge
    scale_cloud = 300.0 / max(spec.proc_ms[cl][spec.placed[cl]].max(), 1e-9)
    spec.proc_ms[cl:] *= scale_cloud
    return spec


#: registry policies on the testbed (random's per-frame PRNG keys are split
#: from the run's seed by the simulator, so runs are deterministic per seed)
POLICIES = ("gus", "random", "local_all", "offload_all")


HORIZON_MS = 120_000.0


def main(n_points=(200, 800, 1600), seeds=(0, 1, 2)):
    """x-axis = total #requests offered within the fixed 2-minute horizon
    (the paper raises offered load the same way on its 2-hour runs)."""
    spec = make_testbed_spec()
    # capacity calibration mirroring the paper's testbed: edge = 3 concurrent
    # classification threads (3 x 1300 chip-ms / frame), cloud desktop = 10
    # requests/frame at 300 ms, comm cap ~5 images/frame off each edge
    spec.gamma_frame = np.array([3900.0, 3900.0, 3000.0], np.float32)
    spec.eta_frame = np.array([350.0, 350.0, 3500.0], np.float32)
    print("figure,n_requests,policy,satisfied_pct,local_pct,cloud_pct,edge_offload_pct,dropped_pct")
    results = {}
    for n in n_points:
        rate = n / (spec.n_edge * HORIZON_MS / 1000.0)
        cfg = SimConfig(
            horizon_ms=HORIZON_MS,
            arrival_rate_per_s=rate,
            delay_req_ms=5000.0,   # scaled-down from the paper's 53 s to match
            acc_req_mean=50.0,     # the scaled zoo latencies (same ratios)
            queue_cap=4,
            frame_ms=3000.0,
        )
        for pol in POLICIES:
            rs = [
                simulate(spec, cfg, policy=pol, seed=s, n_requests=n).as_dict()
                for s in seeds
            ]
            r = {k: float(np.mean([x[k] for x in rs])) for k in rs[0]}
            results[(n, pol)] = r
            print(
                csv_row(
                    "fig1e-h", n, pol,
                    f"{r['satisfied_pct']:.2f}", f"{r['local_pct']:.2f}",
                    f"{r['cloud_pct']:.2f}", f"{r['edge_offload_pct']:.2f}",
                    f"{r['dropped_pct']:.2f}",
                ),
                flush=True,
            )
    # paper claims: GUS satisfied-% >= heuristics, ~50% better under load
    ratios = []
    for n in n_points:
        g = results[(n, "gus")]["satisfied_pct"]
        for pol in ("random", "local_all", "offload_all"):
            b = results[(n, pol)]["satisfied_pct"]
            if b > 1e-6:
                ratios.append(g / b)
            assert g >= b - 1.0, (n, pol, g, b)
    n_hi = max(n_points)
    hi_ratios = [
        results[(n_hi, "gus")]["satisfied_pct"] / max(results[(n_hi, p)]["satisfied_pct"], 1e-6)
        for p in ("random", "local_all", "offload_all")
    ]
    print(f"claim,testbed_gus_vs_heuristics_mean_ratio,{np.mean(ratios):.3f}")
    print(f"claim,testbed_gus_vs_heuristics_at_peak_load,{np.mean(hi_ratios):.3f}")
    assert np.mean(hi_ratios) >= 1.5, f"GUS should beat heuristics by >=50% under load: {hi_ratios}"
    return results


if __name__ == "__main__":
    main()
