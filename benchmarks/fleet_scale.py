"""Fleet-scale benchmark: Monte-Carlo throughput toward the paper's 20 000
replications, across replication counts, device meshes, and host-pipeline
modes.

Five sweeps over ``simulate_fleet`` on a paper-sized cluster (10 servers,
10 model variants), all with the jitted GUS policy (engine axes are passed
as one ``EngineOptions`` value — the per-call keywords are deprecated):

  replication_sweep  wall-clock and requests/s vs n_rep on one device
  device_sweep       fixed n_rep sharded over 1..D devices (strong scaling)
  weak_scaling       n_rep grows with the device count (per-device throughput)
  overlap_sweep      the 64-replication point under the host-pipeline modes:
                     the serial PR-4 loop (prefetch=0, per-request RNG) vs
                     the overlapped producer + vectorized columnar arrivals
                     (prefetch>0, rng_mode="vectorized", windowed)
  users_sweep        (``--users-sweep``) users-per-frame axis 10^3 -> 10^5 on
                     the ``mega-city`` scenario under the hierarchical
                     class-aggregate scheduler; asserts sub-quadratic
                     wall-time scaling in num_users

Each row reports the end-to-end wall time, the *dispatch* time
(``FleetResult.dispatch_s`` — the phase inside the jitted fleet programs,
which is what device sharding accelerates) and the *generation* time
(``FleetResult.gen_s`` — host-side arrival generation + frame-grid build
that actually *blocked* the pipeline; build work hidden behind device
compute by ``prefetch`` never shows up there).  Rows keep the best of
``--repeats`` runs to shave scheduler noise.

Writes ``results/fleet_scale/BENCH_fleet.json``.  CI gates on it three ways:

* perf-regression gate — ``--compare benchmarks/baselines/BENCH_fleet.json
  --tolerance 0.30`` fails when single-device throughput regresses by more
  than the band against the checked-in baseline
  (``--update-baseline`` refreshes the file);
* multi-device gate — ``--assert-scaling 1.0`` (run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) fails when the
  dispatch-phase throughput at the largest mesh does not beat one device;
* overlap gate — ``--assert-overlap 5.0`` fails unless the overlapped +
  vectorized mode cuts the blocking host generation+build time (``gen_s``)
  of the 64-replication point by at least that factor vs the serial
  per-request pipeline.

Run:

    python benchmarks/fleet_scale.py --tiny                 # CI smoke
    python benchmarks/fleet_scale.py                        # full sweep
    python benchmarks/fleet_scale.py --tiny --assert-overlap 5.0
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python benchmarks/fleet_scale.py --tiny --assert-scaling 1.0
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

_SRC = Path(__file__).resolve().parent.parent / "src"
if _SRC.is_dir() and str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

import jax

from repro.core import (
    EngineOptions,
    SimConfig,
    demo_cluster_spec,
    get_scenario,
    simulate_fleet,
)
from repro.core.impairments import (
    AdmissionConfig,
    BurstyLossLink,
    ImpairmentConfig,
    IntermittentLink,
)
from repro.obs import profile_trace

try:  # imported as benchmarks.fleet_scale (run.py)
    from .common import gate_rows_against_baseline
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from common import gate_rows_against_baseline

POLICY = "gus"


def bench_spec():
    """Paper-sized cluster: 9 edges + 1 cloud, 10 model variants — heavy
    enough per frame that the device program dominates a group's cost."""
    return demo_cluster_spec(n_edge=9, n_cloud=1, n_services=5, n_variants=10)


def bench_cfg(tiny: bool) -> SimConfig:
    return SimConfig(
        horizon_ms=12_000.0 if tiny else 30_000.0,
        arrival_rate_per_s=6.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
    )


def _measure(
    spec, cfg, *, n_rep: int, devices: int, repeats: int,
    scenario="paper-default", policy=POLICY, **opt_kw,
) -> dict:
    """Best-of-``repeats`` timing of one fleet configuration (plus one
    untimed warmup so compilation never lands in a timed run).  Extra
    keywords (prefetch, rng_mode, window, scheduler) become
    ``EngineOptions`` fields."""
    opts = EngineOptions(devices=devices, **opt_kw)
    simulate_fleet(
        spec, cfg, policy=policy, scenario=scenario, n_rep=n_rep, seed=0,
        options=opts,
    )
    best_wall = best_disp = best_gen = float("inf")
    fr = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        fr = simulate_fleet(
            spec, cfg, policy=policy, scenario=scenario, n_rep=n_rep, seed=0,
            options=opts,
        )
        wall = time.perf_counter() - t0
        best_wall = min(best_wall, wall)
        best_disp = min(best_disp, fr.dispatch_s)
        best_gen = min(best_gen, fr.gen_s)
    frames = n_rep * fr.n_frames
    return {
        "n_rep": n_rep,
        "devices": devices,
        "wall_s": round(best_wall, 4),
        "dispatch_s": round(best_disp, 4),
        "gen_s": round(best_gen, 4),
        "gen_share": round(best_gen / best_wall, 4),
        "n_requests": fr.n_requests,
        "n_frames": frames,
        "reqs_per_s": round(fr.n_requests / best_wall, 1),
        "frames_per_s": round(frames / best_wall, 1),
        "dispatch_frames_per_s": round(frames / max(best_disp, 1e-9), 1),
        "per_device_frames_per_s": round(frames / best_wall / devices, 1),
        **{k: v for k, v in opt_kw.items() if v is not None},
    }


def run_users_sweep(*, tiny: bool, repeats: int) -> list:
    """Users-per-frame scaling axis on the ``mega-city`` scenario under the
    hierarchical class-aggregate scheduler (``scheduler="hierarchical"``,
    windowed).  Each point rescales ``rate_per_edge_per_s`` so the nominal
    arrivals per frame hit the target (users = rate * n_edge * frame_s);
    asserts the measured wall time grows *sub-quadratically* in the request
    count between consecutive points — the whole point of scheduling class
    aggregates instead of 10^5 individual users.

    The sweep runs with **admission control and link impairments enabled**
    (class-level shedding + per-member realized channels — the composition
    the PR-9 host loop hard-raised on), and the largest point is re-timed
    under ``REPRO_HIER_HOST_LOOP=1`` (the retained PR-9 per-window host
    loop): the device pipeline must come in measurably faster in the full
    sweep (the 10^5-users point); in ``--tiny`` the speedup is reported
    but not asserted."""
    n_edge = 20
    spec = demo_cluster_spec(n_edge=n_edge, n_cloud=1, n_services=5, n_variants=10)
    cfg = SimConfig(
        horizon_ms=9_000.0,
        admission=AdmissionConfig(enabled=True, shed=True),
        impairments=ImpairmentConfig(
            enabled=True,
            link_profiles=(IntermittentLink(), BurstyLossLink()),
            seed=7,
        ),
    )
    frame_s = cfg.frame_ms / 1000.0
    base = get_scenario("mega-city")
    targets = [1_000, 10_000] if tiny else [1_000, 10_000, 100_000]
    # materialized columnar traces (streaming=False keeps arrivals as array
    # slices, never per-request objects) + per-frame windows with prefetch:
    # the producer thread builds frame k+1's class grid while the device
    # crunches frame k — the overlap the per-window host loop cannot have
    opts = EngineOptions(
        scheduler="hierarchical", window=1, prefetch=2, streaming=False
    )
    rows = []
    for users in targets:
        scn = dataclasses.replace(
            base, rate_per_edge_per_s=users / (n_edge * frame_s)
        )
        best_wall = float("inf")
        fr = None
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            fr = simulate_fleet(
                spec, cfg, policy="gus", scenario=scn, n_rep=1, seed=0,
                options=opts,
            )
            best_wall = min(best_wall, time.perf_counter() - t0)
        row = {
            "users_per_frame": users,
            "n_requests": fr.n_requests,
            "n_frames": fr.n_frames,
            "wall_s": round(best_wall, 4),
            "reqs_per_s": round(fr.n_requests / best_wall, 1),
            "satisfied_pct": round(float(fr.satisfied_per_rep.mean()), 3),
        }
        rows.append(row)
        print(f"users_sweep,users={users},n_requests={fr.n_requests},"
              f"{row['wall_s']}s,{row['reqs_per_s']} req/s", flush=True)

    # PR-9 host-loop baseline at the largest point (same trace; the host
    # loop ignores admission — it predates it — so it does strictly *less*
    # work and still has to lose on wall time)
    top = rows[-1]
    scn = dataclasses.replace(
        base, rate_per_edge_per_s=top["users_per_frame"] / (n_edge * frame_s)
    )
    host_wall = float("inf")
    os.environ["REPRO_HIER_HOST_LOOP"] = "1"
    try:
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            simulate_fleet(
                spec, cfg, policy="gus", scenario=scn, n_rep=1, seed=0,
                options=opts,
            )
            host_wall = min(host_wall, time.perf_counter() - t0)
    finally:
        del os.environ["REPRO_HIER_HOST_LOOP"]
    top["host_loop_wall_s"] = round(host_wall, 4)
    top["device_speedup_vs_host"] = round(host_wall / max(top["wall_s"], 1e-9), 2)
    print(f"users_sweep,host-loop baseline at {top['users_per_frame']} "
          f"users/frame: {top['host_loop_wall_s']}s vs device "
          f"{top['wall_s']}s ({top['device_speedup_vs_host']}x)", flush=True)
    if not tiny and top["device_speedup_vs_host"] <= 1.0:
        raise SystemExit(
            f"users_sweep gate: device hier pipeline ({top['wall_s']}s) is "
            f"not faster than the PR-9 host loop ({top['host_loop_wall_s']}s) "
            f"at the {top['users_per_frame']}-users point"
        )
    import math as _math

    for lo, hi in zip(rows, rows[1:]):
        ratio_n = hi["n_requests"] / max(lo["n_requests"], 1)
        ratio_t = hi["wall_s"] / max(lo["wall_s"], 1e-9)
        exponent = _math.log(ratio_t) / _math.log(ratio_n)
        if ratio_t >= ratio_n**2:
            raise SystemExit(
                f"users_sweep gate: wall time grew {ratio_t:.1f}x for a "
                f"{ratio_n:.1f}x request-count step "
                f"({lo['users_per_frame']} -> {hi['users_per_frame']} "
                f"users/frame) — scaling exponent {exponent:.2f} is not "
                f"sub-quadratic"
            )
        print(f"users_sweep gate: {lo['users_per_frame']} -> "
              f"{hi['users_per_frame']} users/frame scales with exponent "
              f"{exponent:.2f} (< 2 required)", flush=True)
    return rows


def run(*, tiny: bool, out: str, device_counts, repeats: int,
        users_sweep: bool = False) -> dict:
    spec = bench_spec()
    cfg = bench_cfg(tiny)
    # the device sweeps always run the full-size horizon: per-group compute
    # must dominate dispatch overhead for a scaling measurement to mean
    # anything, and at ~1 s per row they stay CI-affordable even in --tiny
    scale_cfg = bench_cfg(tiny=False)
    avail = jax.local_device_count()
    if device_counts is None:
        device_counts = [d for d in (1, 2, 4, 8) if d <= avail]
    device_counts = sorted(set(device_counts))

    rep_values = [16, 64] if tiny else [64, 256, 1024]
    rep_fixed = 64 if tiny else rep_values[-1]
    weak_base = 8 if tiny else 32

    print(f"# fleet_scale: tiny={tiny} devices={device_counts} (avail {avail})")
    replication_sweep = []
    for n_rep in rep_values:
        row = _measure(spec, cfg, n_rep=n_rep, devices=1, repeats=repeats)
        replication_sweep.append(row)
        print(f"replication_sweep,n_rep={n_rep},{row['wall_s']}s,"
              f"{row['reqs_per_s']} req/s", flush=True)

    device_sweep = []
    for d in device_counts:
        row = _measure(spec, scale_cfg, n_rep=rep_fixed, devices=d, repeats=repeats)
        device_sweep.append(row)
        print(f"device_sweep,devices={d},{row['wall_s']}s,"
              f"dispatch={row['dispatch_s']}s", flush=True)

    weak_scaling = []
    for d in device_counts:
        row = _measure(
            spec, scale_cfg, n_rep=weak_base * d, devices=d, repeats=repeats
        )
        weak_scaling.append(row)
        print(f"weak_scaling,devices={d},n_rep={weak_base * d},"
              f"per_device={row['per_device_frames_per_s']} frames/s", flush=True)

    # host-pipeline modes at the ISSUE's 64-replication point: the serial
    # PR-4 loop vs the overlapped producer + vectorized columnar arrivals.
    # `serial` pins prefetch=0 + the per-request RNG (the pre-overlap
    # pipeline, bit-identical to the default mode's results); `overlap`
    # windows the scan (~4 windows over the horizon) so the producer has
    # device compute to hide the grid build behind.
    import numpy as _np

    T = int(_np.ceil(cfg.horizon_ms / cfg.frame_ms))
    W = max(1, T // 4)
    overlap_sweep = []
    for label, kw in [
        ("serial", dict(prefetch=0, rng_mode="paper-default")),
        ("prefetch", dict(prefetch=2, window=W, rng_mode="paper-default")),
        ("vectorized", dict(prefetch=0, rng_mode="vectorized")),
        ("overlap", dict(prefetch=2, window=W, rng_mode="vectorized")),
    ]:
        row = _measure(spec, cfg, n_rep=64, devices=1, repeats=repeats, **kw)
        row["mode"] = label
        overlap_sweep.append(row)
        print(f"overlap_sweep,mode={label},{row['wall_s']}s,"
              f"gen={row['gen_s']}s ({row['gen_share']:.0%} of wall),"
              f"dispatch={row['dispatch_s']}s", flush=True)
    serial_row = overlap_sweep[0]
    overlap_row = overlap_sweep[-1]
    overlap_summary = {
        "n_rep": 64,
        "gen_s_serial": serial_row["gen_s"],
        "gen_s_overlap": overlap_row["gen_s"],
        "gen_s_reduction": round(serial_row["gen_s"] / max(overlap_row["gen_s"], 1e-9), 2),
        "gen_share_serial": serial_row["gen_share"],
        "gen_share_overlap": overlap_row["gen_share"],
        "wall_speedup": round(serial_row["wall_s"] / overlap_row["wall_s"], 2),
    }
    print(f"overlap: host gen+build blocking {serial_row['gen_s']}s -> "
          f"{overlap_row['gen_s']}s ({overlap_summary['gen_s_reduction']}x lower), "
          f"end-to-end {overlap_summary['wall_speedup']}x", flush=True)

    # scaling between the smallest and largest swept mesh (usually 1 -> D,
    # but an explicit --devices list without 1 still gets a valid report)
    base, top = device_sweep[0], device_sweep[-1]
    scaling = {
        "base_devices": base["devices"],
        "devices": top["devices"],
        "end_to_end": round(base["wall_s"] / top["wall_s"], 3),
        "dispatch": round(
            top["dispatch_frames_per_s"] / max(base["dispatch_frames_per_s"], 1e-9), 3
        ),
    }
    print(f"scaling {base['devices']} -> {top['devices']} devices: "
          f"end-to-end {scaling['end_to_end']}x, dispatch {scaling['dispatch']}x")

    report = {
        "meta": {
            "bench": "fleet_scale",
            "tiny": tiny,
            "policy": POLICY,
            "jax": jax.__version__,
            "devices_available": avail,
            "repeats": repeats,
            "horizon_ms": cfg.horizon_ms,
            "arrival_rate_per_s": cfg.arrival_rate_per_s,
        },
        "replication_sweep": replication_sweep,
        "device_sweep": device_sweep,
        "weak_scaling": weak_scaling,
        "scaling_1_to_max": scaling,
        "overlap_sweep": overlap_sweep,
        "overlap_summary": overlap_summary,
    }
    if users_sweep:
        report["users_sweep"] = run_users_sweep(tiny=tiny, repeats=repeats)
    out_dir = Path(out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "BENCH_fleet.json"
    path.write_text(json.dumps(report, indent=2))
    print(f"wrote {path}")
    return report


def compare_against_baseline(report: dict, baseline_path: str, tolerance: float):
    """Fail (SystemExit) when single-device throughput regresses by more
    than ``tolerance`` against the checked-in baseline.  Rows are matched
    on (n_rep, devices); unmatched rows are skipped, so the baseline can
    lag the sweep's shape."""
    baseline = json.loads(Path(baseline_path).read_text())
    gate_rows_against_baseline(
        report["replication_sweep"],
        baseline.get("replication_sweep", []),
        key_fn=lambda r: (r["n_rep"], r["devices"]),
        metric="reqs_per_s",
        tolerance=tolerance,
        baseline_path=baseline_path,
        unit=" req/s",
        gate_name="perf gate",
    )
    if "users_sweep" in report and baseline.get("users_sweep"):
        gate_rows_against_baseline(
            report["users_sweep"],
            baseline["users_sweep"],
            key_fn=lambda r: r["users_per_frame"],
            metric="reqs_per_s",
            tolerance=tolerance,
            baseline_path=baseline_path,
            unit=" req/s",
            gate_name="users-sweep perf gate",
        )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true", help="CI smoke: small sweep")
    ap.add_argument("--out", default="results/fleet_scale")
    ap.add_argument("--devices", type=int, action="append",
                    help="device count to sweep (repeatable; default powers "
                         "of two up to jax.local_device_count())")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repeats per row, best kept (default 3; 2 tiny)")
    ap.add_argument("--users-sweep", action="store_true",
                    help="also sweep users-per-frame 10^3 -> 10^5 (10^4 in "
                         "--tiny) on the mega-city scenario under the "
                         "hierarchical scheduler, asserting sub-quadratic "
                         "wall-time scaling")
    ap.add_argument("--compare", metavar="BASELINE_JSON",
                    help="perf-regression gate against a checked-in baseline")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional throughput drop for --compare")
    ap.add_argument("--assert-scaling", default=None, metavar="X",
                    help="fail unless dispatch-phase throughput at the largest "
                         "mesh beats X times one device; 'auto' requires >1.0 "
                         "on hosts with >= 4 cores (virtual devices have real "
                         "parallel headroom there) and a 0.7 no-degradation "
                         "floor on smaller hosts")
    ap.add_argument("--assert-overlap", type=float, default=None, metavar="X",
                    help="fail unless prefetch + rng_mode=vectorized cut the "
                         "blocking host generation+build time (gen_s) of the "
                         "64-replication point by more than X times vs the "
                         "serial per-request pipeline")
    ap.add_argument("--update-baseline", metavar="PATH",
                    help="also write the report to PATH (refresh the baseline)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace of the sweep "
                         "into DIR (fleet dispatch groups and scan windows "
                         "are annotated)")
    args = ap.parse_args(argv)

    repeats = args.repeats if args.repeats is not None else (2 if args.tiny else 3)
    with profile_trace(args.profile):
        report = run(tiny=args.tiny, out=args.out, device_counts=args.devices,
                     repeats=repeats, users_sweep=args.users_sweep)

    if args.update_baseline:
        Path(args.update_baseline).parent.mkdir(parents=True, exist_ok=True)
        Path(args.update_baseline).write_text(json.dumps(report, indent=2))
        print(f"baseline refreshed at {args.update_baseline}")
    if args.compare:
        compare_against_baseline(report, args.compare, args.tolerance)
    if args.assert_scaling is not None:
        cores = os.cpu_count() or 1
        if args.assert_scaling == "auto":
            floor = 1.0 if cores >= 4 else 0.7
        else:
            floor = float(args.assert_scaling)
        got = report["scaling_1_to_max"]["dispatch"]
        d_base = report["scaling_1_to_max"]["base_devices"]
        d_max = report["scaling_1_to_max"]["devices"]
        if d_max <= d_base:
            raise SystemExit("--assert-scaling needs a multi-device sweep; "
                             "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        if got <= floor:
            raise SystemExit(
                f"dispatch throughput scaling {d_base} -> {d_max} devices is "
                f"{got}x, required > {floor}x ({cores} cores)"
            )
        print(f"scaling gate: {got}x > {floor}x on {d_base} -> {d_max} devices "
              f"({cores} cores)")
    if args.assert_overlap is not None:
        got = report["overlap_summary"]["gen_s_reduction"]
        if got < args.assert_overlap:
            raise SystemExit(
                f"overlap gate: blocking host gen+build reduced only {got}x "
                f"at the 64-replication point, required >= {args.assert_overlap}x "
                f"(serial {report['overlap_summary']['gen_s_serial']}s vs "
                f"overlapped {report['overlap_summary']['gen_s_overlap']}s)"
            )
        print(f"overlap gate: gen_s reduced {got}x >= {args.assert_overlap}x")


if __name__ == "__main__":
    main()
