"""Paper-figure reproduction pipeline: every registered policy, every scenario.

Reproduces the paper's numerical figures as machine-readable sweeps over the
:mod:`repro.core.policies` registry and writes one JSON + one markdown
results table per run (default: ``results/paper_figures/``):

  arrival-rate    satisfied-% vs per-edge arrival rate       (load axis of Fig. 1)
  num-users       satisfied-% vs total number of requests    (Fig. 1(e)-(h) x-axis)
  qos-deadline    satisfied-% vs requested deadline C_i      (Fig. 1(a) analog)
  qos-accuracy    satisfied-% vs requested accuracy A_i      (Fig. 1(b) analog)
  scenarios       policy x scenario satisfied-% matrix, ILP oracle included
  optimality-gap  GUS / exact-optimum mean-US ratio          (the ~90% claim)
                  + GUS / LP-relaxation bound on 100-request instances
  congestion      satisfied-% under load-dependent service times — the
                  testbed regime where Happy-* collapse below GUS and the
                  paper's ">= 1.5x every baseline" claim is checked against
                  ALL FIVE baselines
  resilience      satisfied-% under network impairments and server outages
                  (policy x admission-mechanism matrix): link traces,
                  MTBF/MTTR outage streams, and a flash-crowd + outage
                  composite where admission control earns its keep

Sweeps ride the registry: the vmapped fleet runner for the jit-compatible
policies, the sequential testbed for the scenario matrix (so the host-side
ILP oracle can join on small frames).  In the *congestion-free* numerical
model the Happy-* policies relax a feasibility constraint at zero cost, so
there they are *upper bounds*, not baselines, and the ">= 50%" claim is
checked against the restricted heuristics (random / offload_all /
local_all), mirroring ``fig1_numerical.check_gus_factor``.  The
``congestion`` figure enables the load-dependent service-time model
(:mod:`repro.core.queueing`), under which over-commitment hurts, the
Happy-* relaxations collapse — exactly as in the paper's testbed — and the
>= 1.5x check runs against all five.

Run (no PYTHONPATH needed — the script finds ``src/`` itself):

    python benchmarks/paper_figures.py --tiny          # CI smoke, ~1 min
    python benchmarks/paper_figures.py                 # full sweep
    python benchmarks/paper_figures.py --only scenarios --out /tmp/figs

See ``docs/reproducing_paper.md`` for the figure-by-figure guide.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

_SRC = Path(__file__).resolve().parent.parent / "src"
if _SRC.is_dir() and str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

import numpy as np

from repro.core import (
    AdmissionConfig,
    CongestionConfig,
    EngineOptions,
    GeneratorConfig,
    HandoffLink,
    ImpairmentConfig,
    IntermittentLink,
    SatelliteLink,
    SimConfig,
    demo_cluster_spec,
    generate_instance,
    get_policy,
    get_scenario,
    lagrangian_bound,
    list_policies,
    list_scenarios,
    make_ilp_policy,
    mean_us,
    simulate,
    simulate_fleet,
)
from repro.core.scenarios import FlashCrowdOutageScenario

try:  # package mode (python -m benchmarks.paper_figures / benchmarks.run)
    from .common import GAP_NODE_LIMIT, gap_regimes
except ImportError:  # script mode (python benchmarks/paper_figures.py)
    from common import GAP_NODE_LIMIT, gap_regimes

FIGURES = (
    "arrival-rate",
    "num-users",
    "qos-deadline",
    "qos-accuracy",
    "scenarios",
    "optimality-gap",
    "congestion",
    "resilience",
)

#: restricted heuristics the paper's ">= 50%" claim is measured against
#: in the congestion-free numerical model
CLAIM_BASELINES = ("random", "offload_all", "local_all")

#: all five baselines — the congestion figure measures against every one,
#: because load-dependent delays make the Happy-* relaxations real baselines
ALL_BASELINES = CLAIM_BASELINES + ("happy_computation", "happy_communication")

#: per-scenario noise allowance (satisfied-%) for the GUS-beats-baseline
#: check — a few seeds per cell; the same tolerance scenario_sweep.py uses
SCENARIO_NOISE_PCT = 2.0


def _fleet_policies() -> List[str]:
    return [p for p in list_policies() if get_policy(p).vmappable]


def _base_cfg(tiny: bool, **overrides) -> SimConfig:
    kw = dict(
        horizon_ms=12_000.0 if tiny else 60_000.0,
        arrival_rate_per_s=2.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
    )
    kw.update(overrides)
    return SimConfig(**kw)


def _fleet_sweep(fig, x_label, values, make_cfg, spec, *, n_rep, policies, rng_mode=None):
    rows = []
    opts = EngineOptions(rng_mode=rng_mode)
    for x in values:
        cfg = make_cfg(x)
        for pol in policies:
            fr = simulate_fleet(spec, cfg, policy=pol, n_rep=n_rep, seed=0, options=opts)
            rows.append({
                "x": x,
                "policy": pol,
                "satisfied_pct": round(fr.satisfied_pct, 3),
                "satisfied_std": round(fr.satisfied_std, 3),
                "mean_us": round(fr.mean_us, 5),
                "n_requests": fr.n_requests,
            })
            print(f"{fig},{x},{pol},{fr.satisfied_pct:.2f}", flush=True)
    return {"x_label": x_label, "rows": rows}


def fig_arrival_rate(tiny: bool, replications=None, rng_mode=None) -> Dict:
    """Satisfied-% vs per-edge arrival rate (every vmappable policy, fleet)."""
    spec = demo_cluster_spec()
    values = [1.0, 4.0] if tiny else [0.5, 1.0, 2.0, 4.0, 8.0]
    return _fleet_sweep(
        "arrival-rate", "arrival rate (req/s per edge)", values,
        lambda r: _base_cfg(tiny, arrival_rate_per_s=r),
        spec, n_rep=replications or (2 if tiny else 8), policies=_fleet_policies(),
        rng_mode=rng_mode,
    )


def fig_qos_deadline(tiny: bool, replications=None, rng_mode=None) -> Dict:
    """Satisfied-% vs requested deadline C_i (stricter deadline -> fewer)."""
    spec = demo_cluster_spec()
    values = [2000.0, 8000.0] if tiny else [1500.0, 3000.0, 6000.0, 12000.0]
    return _fleet_sweep(
        "qos-deadline", "requested deadline C_i (ms)", values,
        lambda d: _base_cfg(tiny, delay_req_ms=d),
        spec, n_rep=replications or (2 if tiny else 8), policies=_fleet_policies(),
        rng_mode=rng_mode,
    )


def fig_qos_accuracy(tiny: bool, replications=None, rng_mode=None) -> Dict:
    """Satisfied-% vs requested accuracy A_i (stricter floor -> fewer)."""
    spec = demo_cluster_spec()
    values = [40.0, 70.0] if tiny else [30.0, 45.0, 60.0, 75.0]
    return _fleet_sweep(
        "qos-accuracy", "requested accuracy A_i (%)", values,
        lambda a: _base_cfg(tiny, acc_req_mean=a),
        spec, n_rep=replications or (2 if tiny else 8), policies=_fleet_policies(),
        rng_mode=rng_mode,
    )


def fig_num_users(tiny: bool) -> Dict:
    """Satisfied-% vs total submitted requests (sequential testbed)."""
    spec = demo_cluster_spec()
    values = [20, 60] if tiny else [25, 50, 100, 200]
    seeds = (0,) if tiny else (0, 1)
    policies = _fleet_policies()  # ilp excluded: its own figure below
    rows = []
    for n in values:
        cfg = _base_cfg(tiny, horizon_ms=120_000.0, arrival_rate_per_s=2.0)
        for pol in policies:
            rs = [
                simulate(spec, cfg, policy=pol, seed=s, n_requests=n)
                for s in seeds
            ]
            sat = float(np.mean([r.satisfied_pct for r in rs]))
            rows.append({
                "x": n,
                "policy": pol,
                "satisfied_pct": round(sat, 3),
                "mean_us": round(float(np.mean([r.mean_us for r in rs])), 5),
                "n_requests": int(np.mean([r.n_requests for r in rs])),
            })
            print(f"num-users,{n},{pol},{sat:.2f}", flush=True)
    return {"x_label": "total requests submitted", "rows": rows}


def fig_scenarios(tiny: bool) -> Dict:
    """The headline matrix: satisfied-% for every registered policy on every
    registered scenario, ILP oracle included (the sequential testbed's
    queue cap bounds frames to n_edge * queue_cap <= 12 requests here)."""
    spec = demo_cluster_spec(n_edge=3, n_cloud=1)
    seeds = (0,) if tiny else (0, 1)
    cfg = _base_cfg(tiny, horizon_ms=12_000.0 if tiny else 30_000.0)
    rows = []
    # city-scale scenarios (dense_sweep=False, e.g. mega-city) are sized for
    # the hierarchical fleet path; per-request simulation of every policy on
    # them would dominate the whole figure run.  Their coverage lives in the
    # mega-city smoke and the fleet_scale --users-sweep gate.
    for scn in [s for s in list_scenarios() if get_scenario(s).dense_sweep]:
        for pol in list_policies():
            rs = [simulate(spec, cfg, policy=pol, scenario=scn, seed=s) for s in seeds]
            sat = float(np.mean([r.satisfied_pct for r in rs]))
            rows.append({
                "scenario": scn,
                "policy": pol,
                "satisfied_pct": round(sat, 3),
                "dropped_pct": round(
                    float(np.mean([100.0 * r.n_dropped / max(r.n_requests, 1) for r in rs])), 3
                ),
                "mean_us": round(float(np.mean([r.mean_us for r in rs])), 5),
                "n_requests": int(np.mean([r.n_requests for r in rs])),
            })
            print(f"scenarios,{scn},{pol},{sat:.2f}", flush=True)
    return {"x_label": "scenario", "rows": rows}


def fig_congestion(tiny: bool, replications=None, rng_mode=None) -> Dict:
    """Satisfied-% under load-dependent service times (the testbed regime).

    Runs the vmapped fleet with the congestion model enabled
    (:class:`repro.core.queueing.CongestionConfig`): over-committed servers
    carry a backlog, realized delays inflate with the over-commit ratio,
    and the Happy-* constraint relaxations — upper bounds in every other
    figure — collapse below GUS exactly as in the paper's testbed.  Points
    cover the load axis on ``paper-default`` plus the ``sustained-overload``
    streaming scenario (which also smokes the bounded-memory arrival
    engine).  The claim check measures GUS against ALL FIVE baselines.
    """
    spec = demo_cluster_spec()
    ccfg = CongestionConfig(enabled=True)
    points = (
        [("paper-default", 8.0), ("sustained-overload", 2.0)]
        if tiny else
        [("paper-default", 2.0), ("paper-default", 4.0), ("paper-default", 8.0),
         ("sustained-overload", 2.0)]
    )
    n_rep = replications or (2 if tiny else 8)
    horizon = 24_000.0 if tiny else 30_000.0
    rows = []
    for scn, rate in points:
        cfg = _base_cfg(
            tiny, horizon_ms=horizon, arrival_rate_per_s=rate, congestion=ccfg
        )
        for pol in _fleet_policies():
            fr = simulate_fleet(
                spec, cfg, policy=pol, scenario=scn, n_rep=n_rep, seed=0,
                options=EngineOptions(rng_mode=rng_mode),
            )
            rows.append({
                "x": rate,
                "scenario": scn,
                "policy": pol,
                "satisfied_pct": round(fr.satisfied_pct, 3),
                "satisfied_std": round(fr.satisfied_std, 3),
                "mean_us": round(fr.mean_us, 5),
                "mean_compute_inflation": round(fr.mean_compute_inflation, 3),
                "n_requests": fr.n_requests,
            })
            print(f"congestion,{scn},{rate},{pol},{fr.satisfied_pct:.2f}", flush=True)
    return {"x_label": "arrival rate (req/s per edge), congestion enabled",
            "rows": rows}


def _resilience_regimes(tiny: bool):
    """Named impairment regimes for the resilience matrix.

    Each maps to ``(scenario, ImpairmentConfig, CongestionConfig,
    arrival_rate)``.  The link regimes run on ``paper-default`` without the
    congestion model — they probe the *network* mechanisms in isolation.
    The ``flash-crowd-outage`` composite piles a 3x flash crowd, a scripted
    mid-run outage, a stochastic MTBF/MTTR outage stream, and an
    intermittent link on top of load-dependent service times: the overload
    regime where admission control has something to protect.
    """
    cc_off = CongestionConfig()
    cc_on = CongestionConfig(enabled=True)
    intermittent = ImpairmentConfig(
        enabled=True, link_profiles=(IntermittentLink(),), seed=0
    )
    handoff = ImpairmentConfig(
        enabled=True,
        link_profiles=(HandoffLink(period_frames=4, period_jitter=1),),
        seed=0,
    )
    satellite = ImpairmentConfig(
        enabled=True, link_profiles=(SatelliteLink(),), seed=0
    )
    outage = ImpairmentConfig(
        enabled=True, outage_mtbf_frames=6.0, outage_mttr_frames=3.0,
        outage_servers=(1, 3), seed=0,
    )
    composite_imp = ImpairmentConfig(
        enabled=True, link_profiles=(IntermittentLink(),), seed=0,
        outage_mtbf_frames=6.0, outage_mttr_frames=3.0, outage_servers=(1,),
    )
    composite_scn = FlashCrowdOutageScenario(
        burst_mult=3.0, burst_start_frac=0.2, burst_end_frac=0.4,
        outage_start_frac=0.2, outage_end_frac=0.4,
    )
    regimes = {
        "disconnect-reconnect": ("paper-default", intermittent, cc_off, 2.0),
        "satellite": ("paper-default", satellite, cc_off, 2.0),
        "flash-crowd-outage": (composite_scn, composite_imp, cc_on, 4.0),
    }
    if not tiny:
        regimes["handoff"] = ("paper-default", handoff, cc_off, 2.0)
        regimes["outage-stream"] = ("paper-default", outage, cc_off, 2.0)
    return regimes


#: the admission-control setting the "protected" column of the resilience
#: matrix runs with (cap at one frame budget of backlog, shedding on)
PROTECTED_ADMISSION = AdmissionConfig(enabled=True, queue_cap_mult=1.0, shed=True)


def fig_resilience(tiny: bool, replications=None, rng_mode=None) -> Dict:
    """Satisfied-% under network impairments and server outages — the
    policy x mechanism matrix (paper Fig. 1(e)-(h) analog under faults).

    Every regime runs every vmapped policy twice: bare (``none``) and with
    admission control (``protected`` — per-server queue caps plus
    deadline-based shedding).  Two claims ride the matrix (asserted in
    :func:`run`): GUS stays at/above the restricted baselines under *every*
    impairment, and on the flash-crowd + outage composite protection
    *strictly* improves the over-committing ``happy_computation`` while
    leaving capacity-honoring GUS untouched (its backlog never grows, so
    the cap and the shed test are inert for it).
    """
    spec = demo_cluster_spec()
    n_rep = replications or (2 if tiny else 8)
    horizon = 18_000.0 if tiny else 30_000.0
    policies = (
        ["gus", "gus-adaptive", "happy_computation"] + list(CLAIM_BASELINES)
        if tiny else _fleet_policies()
    )
    rows = []
    for regime, (scn, icfg, ccfg, rate) in _resilience_regimes(tiny).items():
        for mech, acfg in (("none", AdmissionConfig()),
                           ("protected", PROTECTED_ADMISSION)):
            cfg = _base_cfg(
                tiny, horizon_ms=horizon, arrival_rate_per_s=rate,
                congestion=ccfg, impairments=icfg, admission=acfg,
            )
            for pol in policies:
                fr = simulate_fleet(
                    spec, cfg, policy=pol, scenario=scn, n_rep=n_rep, seed=0,
                    options=EngineOptions(rng_mode=rng_mode),
                )
                rows.append({
                    "regime": regime,
                    "mechanism": mech,
                    "policy": pol,
                    "satisfied_pct": round(fr.satisfied_pct, 3),
                    "satisfied_std": round(fr.satisfied_std, 3),
                    "mean_us": round(fr.mean_us, 5),
                    "n_requests": fr.n_requests,
                })
                print(f"resilience,{regime},{mech},{pol},{fr.satisfied_pct:.2f}",
                      flush=True)
    return {"x_label": "impairment regime x admission mechanism", "rows": rows}


def fig_optimality_gap(tiny: bool) -> Dict:
    """GUS vs the exact optimum through the registry's ``ilp`` oracle.

    Two regimes, as in ``benchmarks/optimal_gap.py``: *ample* capacity
    (greedy is near-optimal) and *contended* capacity (greedy pays for its
    myopia); the paper's "average 90% of optimal" sits between them.

    A third block, ``large-lp``, scores GUS against the **LP-relaxation
    bound** (``repro.core.ilp.lagrangian_bound``) on the paper's full
    100-request Sec. IV instances — far past the B&B's reach — so the gap
    stays measurable at the scale the paper actually reports.  Those ratios
    are conservative (the bound sits above the true optimum).
    """
    n_instances = 3 if tiny else 12
    regimes = gap_regimes(n_requests=8)
    rows = []
    for regime, gcfg in regimes.items():
        n_servers = gcfg.n_edge + gcfg.n_cloud
        fns = {
            p: get_policy(p).bind(gcfg.n_edge, n_servers)
            for p in ("gus", "gus-ordered")
        }
        # exhaustive search budget, so "opt" is the certified optimum (the
        # registered `ilp` policy's smaller budget is anytime, for live frames)
        fns["ilp"] = make_ilp_policy(node_limit=GAP_NODE_LIMIT, strict=True).bind(
            gcfg.n_edge, n_servers
        )
        for seed in range(n_instances):
            inst = generate_instance(seed, gcfg)
            vals = {}
            for p, fn in fns.items():
                a = fn(inst)
                vals[p] = float(mean_us(inst, np.asarray(a.j), np.asarray(a.l)))
            opt = vals["ilp"]
            rows.append({
                "regime": regime,
                "seed": seed,
                "certified": True,
                "opt": round(opt, 5),
                "gus": round(vals["gus"], 5),
                "gus_ordered": round(vals["gus-ordered"], 5),
                "ratio": round(vals["gus"] / opt, 4) if opt > 1e-9 else 1.0,
                "ratio_ordered": round(vals["gus-ordered"] / opt, 4) if opt > 1e-9 else 1.0,
            })
            print(f"optimality-gap,{regime},{seed},ratio={rows[-1]['ratio']}", flush=True)

    # large-lp block: the paper's full-size instances, against the LP bound
    big = GeneratorConfig()  # Sec. IV defaults: 100 requests, 9 edge + 1 cloud
    fns = {
        p: get_policy(p).bind(big.n_edge, big.n_edge + big.n_cloud)
        for p in ("gus", "gus-ordered")
    }
    for seed in range(2 if tiny else 6):
        inst = generate_instance(seed, big)
        bound = lagrangian_bound(inst)
        vals = {}
        for p, fn in fns.items():
            a = fn(inst)
            vals[p] = float(mean_us(inst, np.asarray(a.j), np.asarray(a.l)))
        rows.append({
            "regime": "large-lp",
            "seed": seed,
            "certified": False,  # LP bound >= optimum: ratios are conservative
            "opt": round(bound, 5),
            "gus": round(vals["gus"], 5),
            "gus_ordered": round(vals["gus-ordered"], 5),
            "ratio": round(vals["gus"] / bound, 4) if bound > 1e-9 else 1.0,
            "ratio_ordered": round(vals["gus-ordered"] / bound, 4) if bound > 1e-9 else 1.0,
        })
        print(f"optimality-gap,large-lp,{seed},ratio={rows[-1]['ratio']}", flush=True)
    return {"x_label": "instance seed", "rows": rows}


# ---------------------------------------------------------------------------
# Claims, markdown, output
# ---------------------------------------------------------------------------


def check_claims(figures: Dict[str, Dict]) -> Dict:
    """Cross-figure claim checks (recorded in the JSON, asserted in main)."""
    claims: Dict[str, Dict] = {}

    if "scenarios" in figures:
        rows = figures["scenarios"]["rows"]
        sat = {(r["scenario"], r["policy"]): r["satisfied_pct"] for r in rows}
        scns = sorted({r["scenario"] for r in rows})
        gus_mean = float(np.mean([sat[(s, "gus")] for s in scns]))
        per_baseline = {}
        for b in CLAIM_BASELINES:
            b_mean = float(np.mean([sat[(s, b)] for s in scns]))
            margins = {s: round(sat[(s, "gus")] - sat[(s, b)], 3) for s in scns}
            per_baseline[b] = {
                "baseline_mean": round(b_mean, 3),
                "gus_mean": round(gus_mean, 3),
                "gus_wins": bool(gus_mean >= b_mean),
                "scenario_margins": margins,
                # per-scenario, with a small noise allowance (few seeds)
                "wins_every_scenario": bool(
                    all(m >= -SCENARIO_NOISE_PCT for m in margins.values())
                ),
            }
        ilp_margin = None
        if any(p == "ilp" for (_, p) in sat):
            ilp_mean = float(np.mean([sat[(s, "ilp")] for s in scns]))
            ilp_margin = round(ilp_mean - gus_mean, 3)
        claims["gus_vs_baselines_scenarios"] = {
            "per_baseline": per_baseline,
            "gus_beats_every_baseline": all(
                v["gus_wins"] and v["wins_every_scenario"]
                for v in per_baseline.values()
            ),
            "ilp_minus_gus_satisfied_pct": ilp_margin,
        }

    for fig in ("arrival-rate", "num-users", "qos-deadline", "qos-accuracy"):
        if fig not in figures:
            continue
        rows = figures[fig]["rows"]
        sat = {(r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
        xs = sorted({r["x"] for r in rows})
        ratios = []
        for x in xs:
            for b in CLAIM_BASELINES:
                if sat.get((x, b), 0.0) > 1e-6:
                    ratios.append(sat[(x, "gus")] / sat[(x, b)])
        claims.setdefault("gus_vs_baselines_sweeps", {})[fig] = {
            "mean_ratio": round(float(np.mean(ratios)), 3) if ratios else None,
            "min_ratio": round(float(np.min(ratios)), 3) if ratios else None,
        }

    if "optimality-gap" in figures:
        rows = figures["optimality-gap"]["rows"]
        cert = [r for r in rows if r.get("certified", True)]
        claims["gus_over_optimal"] = {
            "mean_ratio": round(float(np.mean([r["ratio"] for r in cert])), 4),
            "mean_ratio_ordered": round(
                float(np.mean([r["ratio_ordered"] for r in cert])), 4
            ),
        }
        lp = [r for r in rows if not r.get("certified", True)]
        if lp:
            claims["gus_over_lp_bound"] = {
                "n_requests": 100,
                "mean_ratio": round(float(np.mean([r["ratio"] for r in lp])), 4),
                "min_ratio": round(float(np.min([r["ratio"] for r in lp])), 4),
            }

    if "congestion" in figures:
        rows = figures["congestion"]["rows"]
        sat = {(r["scenario"], r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
        points = sorted({(r["scenario"], r["x"]) for r in rows})
        # the loaded points: top sweep rate + every sustained-overload point
        max_rate = max(x for s, x in points if s == "paper-default")
        loaded = [(s, x) for s, x in points
                  if s == "sustained-overload" or x >= max_rate]
        collapse = {
            f"{s}@{x}": {
                "gus": sat[(s, x, "gus")],
                "happy_computation": sat[(s, x, "happy_computation")],
                "happy_communication": sat[(s, x, "happy_communication")],
                "both_below_gus": bool(
                    sat[(s, x, "happy_computation")] < sat[(s, x, "gus")]
                    and sat[(s, x, "happy_communication")] < sat[(s, x, "gus")]
                ),
            }
            for s, x in loaded
        }
        # the paper's >= 1.5x factor, now against ALL FIVE baselines
        factors = {
            f"{s}@{x}": round(
                sat[(s, x, "gus")]
                / max(max(sat[(s, x, b)] for b in ALL_BASELINES), 1e-9),
                3,
            )
            for s, x in points
        }
        claims["congestion_collapse"] = {
            "happy_collapse_under_load": all(
                v["both_below_gus"] for v in collapse.values()
            ),
            "collapse_points": collapse,
            "gus_over_best_of_five": factors,
            "max_factor": max(factors.values()),
            "factor_target": 1.5,
            "meets_factor_somewhere": bool(max(factors.values()) >= 1.5),
        }

    if "resilience" in figures:
        rows = figures["resilience"]["rows"]
        sat = {(r["regime"], r["mechanism"], r["policy"]): r["satisfied_pct"]
               for r in rows}
        regimes = sorted({r["regime"] for r in rows})
        # claim 1: GUS at/above every restricted baseline under EVERY impairment
        margins = {
            reg: {
                b: round(sat[(reg, "none", "gus")] - sat[(reg, "none", b)], 3)
                for b in CLAIM_BASELINES if (reg, "none", b) in sat
            }
            for reg in regimes
        }
        # claim 2: on the overload composite, protection strictly lifts the
        # over-committing happy_computation and never hurts GUS
        deltas = {
            (reg, p): round(
                sat[(reg, "protected", p)] - sat[(reg, "none", p)], 3
            )
            for reg in regimes
            for p in ("gus", "happy_computation")
            if (reg, "protected", p) in sat
        }
        comp = "flash-crowd-outage"
        claims["resilience"] = {
            "gus_margins_per_regime": margins,
            "gus_at_or_above_baselines_everywhere": bool(all(
                m >= -SCENARIO_NOISE_PCT
                for per in margins.values() for m in per.values()
            )),
            "protection_deltas": {f"{r}/{p}": d for (r, p), d in deltas.items()},
            "protection_lifts_overcommit_on_composite": bool(
                deltas.get((comp, "happy_computation"), 0.0) > 0.0
            ),
            "protection_never_hurts_gus": bool(all(
                d >= -SCENARIO_NOISE_PCT
                for (r, p), d in deltas.items() if p == "gus"
            )),
        }
    return claims


def _md_table(header: List[str], body: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(header) + " |",
           "|" + "|".join("---" for _ in header) + "|"]
    out += ["| " + " | ".join(row) + " |" for row in body]
    return out


def render_markdown(figures: Dict[str, Dict], claims: Dict, meta: Dict) -> str:
    lines = [
        "# Paper-figure results",
        "",
        f"Generated by `python benchmarks/paper_figures.py"
        f"{' --tiny' if meta['tiny'] else ''}` "
        f"(policies: {', '.join(meta['policies'])}).",
        "",
    ]
    if "scenarios" in figures:
        rows = figures["scenarios"]["rows"]
        sat = {(r["scenario"], r["policy"]): r["satisfied_pct"] for r in rows}
        scns = sorted({r["scenario"] for r in rows})
        pols = [p for p in meta["policies"] if any((s, p) in sat for s in scns)]
        lines += ["## Satisfied-% by scenario x policy", ""]
        lines += _md_table(
            ["scenario"] + pols,
            [[s] + [f"{sat[(s, p)]:.1f}" for p in pols] for s in scns],
        )
        lines.append("")
    for fig in ("arrival-rate", "num-users", "qos-deadline", "qos-accuracy"):
        if fig not in figures:
            continue
        rows = figures[fig]["rows"]
        sat = {(r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
        xs = sorted({r["x"] for r in rows})
        pols = [p for p in meta["policies"] if any((x, p) in sat for x in xs)]
        lines += [f"## {fig}: satisfied-% vs {figures[fig]['x_label']}", ""]
        lines += _md_table(
            [figures[fig]["x_label"]] + pols,
            [[str(x)] + [f"{sat[(x, p)]:.1f}" for p in pols] for x in xs],
        )
        lines.append("")
    if "congestion" in figures:
        rows = figures["congestion"]["rows"]
        sat = {(r["scenario"], r["x"], r["policy"]): r["satisfied_pct"] for r in rows}
        pts = sorted({(r["scenario"], r["x"]) for r in rows})
        pols = [p for p in meta["policies"]
                if any((s, x, p) in sat for s, x in pts)]
        lines += ["## congestion: satisfied-% with load-dependent service times", ""]
        lines += _md_table(
            ["scenario @ rate"] + pols,
            [[f"{s} @ {x}"] + [f"{sat[(s, x, p)]:.1f}" for p in pols]
             for s, x in pts],
        )
        lines += [
            "",
            "With the congestion model enabled, over-committed servers slow",
            "down, so Happy-Computation / Happy-Communication collapse below",
            "GUS under load — the paper's testbed behaviour.",
            "",
        ]
    if "resilience" in figures:
        rows = figures["resilience"]["rows"]
        sat = {(r["regime"], r["mechanism"], r["policy"]): r["satisfied_pct"]
               for r in rows}
        cells = sorted({(r["regime"], r["mechanism"]) for r in rows})
        pols = [p for p in meta["policies"]
                if any((g, m, p) in sat for g, m in cells)]
        lines += ["## resilience: satisfied-% under impairments "
                  "(regime x admission mechanism)", ""]
        lines += _md_table(
            ["regime / mechanism"] + pols,
            [[f"{g} / {m}"] + [f"{sat[(g, m, p)]:.1f}" for p in pols]
             for g, m in cells],
        )
        lines += [
            "",
            "Link impairments (disconnect/reconnect, handoff gaps, satellite",
            "latency) and server outages modulate transfer times and frame",
            "budgets; the `protected` rows add per-server queue caps and",
            "deadline shedding.  Capacity-honoring GUS rides every regime at",
            "the top while protection rescues the over-committing Happy-*",
            "policies on the flash-crowd + outage composite.",
            "",
        ]
    if "optimality-gap" in figures:
        rows = figures["optimality-gap"]["rows"]
        lines += ["## optimality-gap: GUS vs exact ILP / LP bound (mean US)", ""]
        lines += _md_table(
            ["regime", "seed", "opt/bound", "gus", "ratio", "gus-ordered", "ratio"],
            [[r["regime"], str(r["seed"]), f"{r['opt']:.4f}", f"{r['gus']:.4f}",
              f"{r['ratio']:.3f}", f"{r['gus_ordered']:.4f}",
              f"{r['ratio_ordered']:.3f}"] for r in rows],
        )
        lines += [
            "",
            "`large-lp` rows score GUS against the LP-relaxation bound",
            "(`repro.core.ilp.lagrangian_bound`) on 100-request instances —",
            "a conservative ratio, since the bound sits above the optimum.",
            "",
        ]
    lines += ["## Claims", "", "```json",
              json.dumps(claims, indent=2), "```", ""]
    lines += [
        "Happy-Computation / Happy-Communication relax a feasibility",
        "constraint, so in the congestion-free numerical model (delays",
        "independent of server load) they act as upper bounds rather than",
        "baselines, and the >= 50% claim is checked against random /",
        "offload_all / local_all there.  The `congestion` figure enables",
        "load-dependent service times, under which both Happy-* policies",
        "collapse below GUS — the paper's testbed behaviour — and the",
        "claim is re-checked against all five baselines.",
        "",
    ]
    return "\n".join(lines)


def run(
    *,
    tiny: bool = False,
    out: str = "results/paper_figures",
    only=None,
    replications: int = None,
    rng_mode: str = None,
):
    out = Path(out)
    selected = tuple(only) if only else FIGURES

    # fleet-backed figures take the --replications override (the paper's
    # Monte-Carlo averages 20 000); the sequential-testbed figures don't
    builders = {
        "arrival-rate": lambda: fig_arrival_rate(tiny, replications, rng_mode),
        "num-users": lambda: fig_num_users(tiny),
        "qos-deadline": lambda: fig_qos_deadline(tiny, replications, rng_mode),
        "qos-accuracy": lambda: fig_qos_accuracy(tiny, replications, rng_mode),
        "scenarios": lambda: fig_scenarios(tiny),
        "optimality-gap": lambda: fig_optimality_gap(tiny),
        "congestion": lambda: fig_congestion(tiny, replications, rng_mode),
        "resilience": lambda: fig_resilience(tiny, replications, rng_mode),
    }
    figures = {name: builders[name]() for name in selected}
    claims = check_claims(figures)

    meta = {
        "tiny": tiny,
        "replications": replications,
        "rng_mode": rng_mode or "paper-default",
        "policies": list_policies(),
        "scenarios": list_scenarios(),
        "figures": list(selected),
    }
    out.mkdir(parents=True, exist_ok=True)
    json_path = out / "paper_figures.json"
    md_path = out / "paper_figures.md"
    json_path.write_text(json.dumps(
        {"meta": meta, "figures": figures, "claims": claims}, indent=2
    ))
    md_path.write_text(render_markdown(figures, claims, meta))
    print(f"wrote {json_path} and {md_path}")

    # claim assertions AFTER writing, so artifacts survive a failed check
    if "scenarios" in figures:
        c = claims["gus_vs_baselines_scenarios"]
        assert c["gus_beats_every_baseline"], c
    if "optimality-gap" in figures:
        r = claims["gus_over_optimal"]["mean_ratio"]
        floor = 0.75 if tiny else 0.85
        assert r >= floor, f"paper reports ~0.90 of optimal; got {r:.3f}"
        lp = claims.get("gus_over_lp_bound")
        if lp:  # conservative (bound > optimum), so the floor is loose
            assert lp["mean_ratio"] >= 0.6, lp
    if "congestion" in figures:
        c = claims["congestion_collapse"]
        assert c["happy_collapse_under_load"], c["collapse_points"]
        factor_floor = 1.4 if tiny else 1.5
        assert c["max_factor"] >= factor_floor, c["gus_over_best_of_five"]
    if "resilience" in figures:
        c = claims["resilience"]
        assert c["gus_at_or_above_baselines_everywhere"], c["gus_margins_per_regime"]
        assert c["protection_lifts_overcommit_on_composite"], c["protection_deltas"]
        assert c["protection_never_hurts_gus"], c["protection_deltas"]
    return {"figures": figures, "claims": claims}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer points/seeds/replications")
    ap.add_argument("--out", default="results/paper_figures",
                    help="output directory for JSON + markdown")
    ap.add_argument("--only", action="append", choices=FIGURES,
                    help="run a subset of figures (repeatable)")
    ap.add_argument("--replications", type=int, default=None, metavar="R",
                    help="Monte-Carlo replications for the fleet-backed "
                         "figures (paper: 20000; sharded over every local "
                         "device — set XLA_FLAGS or use real accelerators)")
    ap.add_argument("--rng-mode", choices=["paper-default", "vectorized"],
                    default=None,
                    help="arrival generator for the fleet-backed figures: "
                         "'vectorized' cuts host-side generation ~10x for "
                         "large --replications runs (opt-in trace family; "
                         "see docs/reproducing_paper.md)")
    args = ap.parse_args(argv)
    if args.replications is not None and args.replications < 1:
        ap.error("--replications must be >= 1")
    return run(tiny=args.tiny, out=args.out, only=args.only,
               replications=args.replications, rng_mode=args.rng_mode)


if __name__ == "__main__":
    main()
