"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.core import (
    GeneratorConfig,
    generate_batch,
    get_policy,
    list_policies,
    mean_us,
    satisfied_mask,
)

MC_RUNS = 192          # paper uses 20 000; means stabilize far earlier
CHUNK = 64

#: Monte-Carlo sweep policies: everything in the registry that can ride the
#: vmapped batch path (the host-side ILP oracle gets its own benchmark).
SWEEP_POLICIES = tuple(p for p in list_policies() if get_policy(p).vmappable)

#: branch & bound budget for optimality-gap benchmarks; paired with
#: ``strict=True`` so solve_bnb raises rather than returning a best-so-far if
#: the budget ever trips — "opt" is always a certified optimum
#: (the registered `ilp` policy's smaller anytime budget is for live frames)
GAP_NODE_LIMIT = 5_000_000


def gap_regimes(n_requests: int = 10):
    """The two GUS-vs-optimal regimes shared by ``optimal_gap`` and
    ``paper_figures``: *ample* capacity (greedy is near-optimal) and
    *contended* capacity (greedy pays for its myopia) — the paper's
    "average 90% of the optimal" sits between them."""
    base = dict(
        n_requests=n_requests, n_edge=3, n_cloud=1, n_services=5, n_variants=3
    )
    return {
        "ample": GeneratorConfig(**base),
        "contended": GeneratorConfig(
            **base,
            edge_compute_classes=(400.0, 600.0, 800.0),
            edge_comm_classes=(60.0, 90.0, 120.0),
            cloud_compute=1600.0, cloud_comm=300.0,
        ),
    }


def run_policy_mc(name: str, cfg: GeneratorConfig, seed: int = 0, mc: int = MC_RUNS) -> Dict[str, float]:
    """Monte-Carlo average of satisfied-% / mean-US / served mix for any
    vmappable registered policy."""
    pol = get_policy(name)
    if not pol.vmappable:
        raise ValueError(f"policy {name!r} is not vmappable; MC sweeps need the batch path")
    n_servers = cfg.n_edge + cfg.n_cloud
    fn = pol.bind(cfg.n_edge, n_servers)

    sat, us, local_pct, cloud_pct, eo_pct, served = [], [], [], [], [], []
    for c0 in range(0, mc, CHUNK):
        n = min(CHUNK, mc - c0)
        batch = generate_batch(seed + c0, n, cfg)
        if pol.needs_key:
            keys = jax.random.split(jax.random.PRNGKey(seed + c0), n)
            a = jax.vmap(fn)(batch, keys)
        else:
            a = jax.vmap(fn)(batch)
        sm = satisfied_mask(batch, a.j, a.l)
        sat.append(np.asarray(sm.mean(-1)))
        us.append(np.asarray(mean_us(batch, a.j, a.l)))
        is_served = np.asarray(a.j) >= 0
        is_local = is_served & (np.asarray(a.j) == np.asarray(batch.cover))
        is_cloud = is_served & (np.asarray(a.j) >= cfg.n_edge)
        served.append(is_served.mean(-1))
        local_pct.append(is_local.mean(-1))
        cloud_pct.append(is_cloud.mean(-1))
        eo_pct.append((is_served & ~is_local & ~is_cloud).mean(-1))

    return {
        "satisfied_pct": 100 * float(np.mean(np.concatenate(sat))),
        "mean_us": float(np.mean(np.concatenate(us))),
        "served_pct": 100 * float(np.mean(np.concatenate(served))),
        "local_pct": 100 * float(np.mean(np.concatenate(local_pct))),
        "cloud_pct": 100 * float(np.mean(np.concatenate(cloud_pct))),
        "edge_offload_pct": 100 * float(np.mean(np.concatenate(eo_pct))),
    }


def csv_row(*cells) -> str:
    return ",".join(str(c) for c in cells)


def gate_rows_against_baseline(
    rows,
    baseline_rows,
    *,
    key_fn,
    metric: str,
    tolerance: float,
    baseline_path: str,
    unit: str = "",
    gate_name: str = "perf gate",
) -> int:
    """Shared perf-regression gate used by the CI benches.

    Matches ``rows`` to ``baseline_rows`` on ``key_fn(row)`` (unmatched rows
    are skipped, so a baseline can lag a sweep's shape), prints one verdict
    line per matched row, and raises ``SystemExit`` when any row's
    ``metric`` falls more than ``tolerance`` below its baseline — or when
    nothing matched at all.  Returns the number of rows checked.
    """
    old = {key_fn(r): r for r in baseline_rows}
    failures, checked = [], 0
    for row in rows:
        base = old.get(key_fn(row))
        if base is None:
            continue
        checked += 1
        floor = base[metric] * (1.0 - tolerance)
        verdict = "ok" if row[metric] >= floor else "REGRESSION"
        label = ",".join(str(k) for k in key_fn(row))
        print(f"gate,{label}: {row[metric]} vs baseline {base[metric]}{unit} "
              f"(floor {floor:.1f}) {verdict}")
        if row[metric] < floor:
            failures.append(row)
    if checked == 0:
        raise SystemExit(f"{gate_name} matched no rows in {baseline_path}")
    if failures:
        raise SystemExit(
            f"{gate_name}: {len(failures)}/{checked} rows regressed more than "
            f"{tolerance:.0%} vs {baseline_path} — if intentional, refresh it "
            "with --update-baseline"
        )
    print(f"{gate_name}: {checked} rows within {tolerance:.0%} of baseline")
    return checked
