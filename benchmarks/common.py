"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GeneratorConfig,
    generate_batch,
    gus_schedule,
    gus_schedule_batch,
    local_all,
    mean_us,
    offload_all,
    random_assignment,
    satisfied_mask,
    happy_computation,
    happy_communication,
)

MC_RUNS = 192          # paper uses 20 000; means stabilize far earlier
CHUNK = 64


def run_policy_mc(name: str, cfg: GeneratorConfig, seed: int = 0, mc: int = MC_RUNS) -> Dict[str, float]:
    """Monte-Carlo average of satisfied-% / mean-US / served mix for a policy."""
    sat, us, local_pct, cloud_pct, eo_pct, served = [], [], [], [], [], []
    n_servers = cfg.n_edge + cfg.n_cloud
    cloud_mask = jnp.arange(n_servers) >= cfg.n_edge

    for c0 in range(0, mc, CHUNK):
        n = min(CHUNK, mc - c0)
        batch = generate_batch(seed + c0, n, cfg)
        if name == "gus":
            a = gus_schedule_batch(batch)
        elif name == "happy_computation":
            a = gus_schedule_batch(batch, relax_compute=True)
        elif name == "happy_communication":
            a = gus_schedule_batch(batch, relax_comm=True)
        elif name == "local_all":
            a = jax.vmap(local_all)(batch)
        elif name == "offload_all":
            a = jax.vmap(lambda b: offload_all(b, cloud_mask))(batch)
        elif name == "random":
            keys = jax.random.split(jax.random.PRNGKey(seed + c0), n)
            a = jax.vmap(random_assignment)(batch, keys)
        else:
            raise ValueError(name)
        sm = satisfied_mask(batch, a.j, a.l)
        sat.append(np.asarray(sm.mean(-1)))
        us.append(np.asarray(mean_us(batch, a.j, a.l)))
        is_served = np.asarray(a.j) >= 0
        is_local = is_served & (np.asarray(a.j) == np.asarray(batch.cover))
        is_cloud = is_served & (np.asarray(a.j) >= cfg.n_edge)
        served.append(is_served.mean(-1))
        local_pct.append(is_local.mean(-1))
        cloud_pct.append(is_cloud.mean(-1))
        eo_pct.append((is_served & ~is_local & ~is_cloud).mean(-1))

    return {
        "satisfied_pct": 100 * float(np.mean(np.concatenate(sat))),
        "mean_us": float(np.mean(np.concatenate(us))),
        "served_pct": 100 * float(np.mean(np.concatenate(served))),
        "local_pct": 100 * float(np.mean(np.concatenate(local_pct))),
        "cloud_pct": 100 * float(np.mean(np.concatenate(cloud_pct))),
        "edge_offload_pct": 100 * float(np.mean(np.concatenate(eo_pct))),
    }


POLICIES = ("gus", "random", "offload_all", "local_all", "happy_computation", "happy_communication")


def csv_row(*cells) -> str:
    return ",".join(str(c) for c in cells)
