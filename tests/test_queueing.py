"""Congestion subsystem: inflation model, backlog conservation, the
PolicyCarry threading through both simulators, Happy-* collapse under load,
fleet-scan vs sequential parity, and the LP-relaxation bound."""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    CongestionConfig,
    GeneratorConfig,
    Policy,
    SimConfig,
    committed_loads,
    compute_inflation,
    demo_cluster_spec,
    effective_capacity,
    generate_instance,
    gus_schedule,
    init_policy_carry,
    lagrangian_bound,
    lagrangian_dual,
    mean_us,
    price_directed_greedy,
    register_policy,
    simulate,
    simulate_fleet,
    solve_bnb,
    step_backlog,
)
from repro.core.policies import POLICIES

CC = CongestionConfig(enabled=True)
TINY = GeneratorConfig(n_requests=6, n_edge=2, n_cloud=1, n_services=3, n_variants=2)


def overload_cfg(rate=8.0, **kw):
    return SimConfig(
        horizon_ms=kw.pop("horizon_ms", 24_000.0),
        arrival_rate_per_s=rate,
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=kw.pop("congestion", CC),
        **kw,
    )


# ---------------------------------------------------------------------------
# The inflation / backlog model
# ---------------------------------------------------------------------------


def test_inflation_is_one_at_or_below_budget():
    budget = jnp.asarray([100.0, 200.0, 50.0])
    for load in ([0.0, 0.0, 0.0], [100.0, 200.0, 50.0], [40.0, 199.0, 0.5]):
        phi = compute_inflation(jnp.asarray(load), budget, CC)
        np.testing.assert_array_equal(np.asarray(phi), 1.0)


def test_inflation_grows_monotonically_and_is_capped():
    budget = jnp.asarray([100.0])
    loads = [110.0, 150.0, 200.0, 400.0]
    phis = [float(compute_inflation(jnp.asarray([x]), budget, CC)[0]) for x in loads]
    assert all(a < b for a, b in zip(phis, phis[1:]))
    assert all(p > 1.0 for p in phis)
    huge = float(compute_inflation(jnp.asarray([1e9]), budget, CC)[0])
    assert huge == CC.max_inflation
    # a zero-budget (outage) server inflates to the cap, not to inf/NaN
    dead = float(compute_inflation(jnp.asarray([10.0]), jnp.asarray([0.0]), CC)[0])
    assert dead == CC.max_inflation


def test_backlog_step_conserves_work():
    """enqueued (backlog + committed) == drained + carried, frame by frame."""
    rng = np.random.default_rng(0)
    budget = jnp.asarray(rng.uniform(50.0, 150.0, 4), jnp.float32)
    backlog = jnp.zeros(4)
    total_committed = 0.0
    total_drained = 0.0
    for _ in range(25):
        committed = jnp.asarray(rng.uniform(0.0, 300.0, 4), jnp.float32)
        new = step_backlog(backlog, committed, budget, CC)
        drained = float(jnp.sum(backlog + committed - new))
        assert drained >= -1e-4  # never creates work
        assert drained <= float(jnp.sum(budget)) * CC.drain + 1e-3
        total_committed += float(jnp.sum(committed))
        total_drained += drained
        backlog = new
    carried = float(jnp.sum(backlog))
    np.testing.assert_allclose(total_committed, total_drained + carried, rtol=1e-5)


def test_effective_capacity_is_budget_minus_backlog_clipped():
    budget = jnp.asarray([100.0, 100.0])
    np.testing.assert_array_equal(
        np.asarray(effective_capacity(budget, jnp.asarray([30.0, 250.0]))),
        [70.0, 0.0],
    )
    # empty backlog passes the budget through bitwise (the disabled-path contract)
    np.testing.assert_array_equal(
        np.asarray(effective_capacity(budget, jnp.zeros(2))), np.asarray(budget)
    )


def test_committed_loads_match_manual_accounting():
    inst = generate_instance(0, TINY)
    a = gus_schedule(inst)
    w, c = committed_loads(inst, a.j, a.l)
    jv, lv = np.asarray(a.j), np.asarray(a.l)
    v, u = np.asarray(inst.v), np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    M = TINY.n_edge + TINY.n_cloud
    w_ref, c_ref = np.zeros(M), np.zeros(M)
    for i in range(TINY.n_requests):
        if jv[i] < 0:
            continue
        w_ref[jv[i]] += v[i, jv[i], lv[i]]
        if jv[i] != cover[i]:
            c_ref[cover[i]] += u[i, jv[i], lv[i]]
    np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c), c_ref, rtol=1e-5)


def test_simulate_congestion_work_conservation():
    """The sequential testbed's work accounting closes: enqueued work ==
    drained + carried, for both the compute and the comm backlog."""
    r = simulate(demo_cluster_spec(), overload_cfg(), policy="happy_computation", seed=0)
    s = r.congestion_stats
    assert s is not None
    np.testing.assert_allclose(
        s["work_enqueued_gamma"],
        s["work_drained_gamma"] + s["final_backlog_gamma"],
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        s["work_enqueued_eta"],
        s["work_drained_eta"] + s["final_backlog_eta"],
        rtol=1e-6,
    )
    assert s["mean_compute_inflation"] > 1.0  # happy_computation over-commits


# ---------------------------------------------------------------------------
# Disabled-path parity: congestion off == the pre-congestion simulator
# ---------------------------------------------------------------------------


def test_disabled_congestion_is_bitwise_inert():
    spec = demo_cluster_spec()
    cfg_off = overload_cfg(congestion=CongestionConfig(enabled=False))
    base = simulate(spec, overload_cfg(congestion=CongestionConfig()), policy="gus", seed=1)
    off = simulate(spec, cfg_off, policy="gus", seed=1)
    assert base.as_dict() == off.as_dict()
    assert off.congestion_stats is None
    fr_base = simulate_fleet(spec, overload_cfg(congestion=CongestionConfig()), policy="gus", n_rep=2, seed=1)
    fr_off = simulate_fleet(spec, cfg_off, policy="gus", n_rep=2, seed=1)
    np.testing.assert_array_equal(fr_base.satisfied_per_rep, fr_off.satisfied_per_rep)
    np.testing.assert_array_equal(fr_base.mean_us_per_rep, fr_off.mean_us_per_rep)
    assert fr_off.final_backlog_per_rep is None


# ---------------------------------------------------------------------------
# The paper's testbed behaviour: Happy-* collapse under congestion
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_happy_relaxations_collapse_below_gus_under_congestion():
    spec = demo_cluster_spec()
    cfg = overload_cfg()
    sat = {
        p: simulate_fleet(spec, cfg, policy=p, n_rep=2, seed=0).satisfied_pct
        for p in ("gus", "happy_computation", "happy_communication")
    }
    assert sat["happy_computation"] < sat["gus"], sat
    assert sat["happy_communication"] < sat["gus"], sat
    # without congestion they sit at/above GUS (upper bounds)
    cfg_off = overload_cfg(congestion=CongestionConfig())
    sat_off = {
        p: simulate_fleet(spec, cfg_off, policy=p, n_rep=2, seed=0).satisfied_pct
        for p in ("gus", "happy_computation", "happy_communication")
    }
    assert sat_off["happy_computation"] >= sat["happy_computation"]
    assert sat_off["happy_communication"] >= sat["happy_communication"]


def test_congestion_leaves_capacity_honoring_policies_unchanged():
    """GUS never over-commits, so enabling congestion must not change its
    fleet results (backlog stays empty, phi stays 1)."""
    spec = demo_cluster_spec()
    on = simulate_fleet(spec, overload_cfg(), policy="gus", n_rep=2, seed=0)
    off = simulate_fleet(
        spec, overload_cfg(congestion=CongestionConfig()), policy="gus", n_rep=2, seed=0
    )
    np.testing.assert_array_equal(on.satisfied_per_rep, off.satisfied_per_rep)
    assert np.all(np.asarray(on.final_backlog_per_rep) == 0.0)
    assert on.mean_compute_inflation == 1.0


# ---------------------------------------------------------------------------
# Fleet-scan vs sequential-simulate parity under congestion
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["gus", "happy_computation", "local_all"])
def test_fleet_scan_matches_sequential_under_congestion(policy):
    """Noise-free, frame-synchronous settings: the sequential testbed and the
    scan-based fleet must agree on served/satisfied counts exactly, with the
    congestion backlog evolving identically in both."""
    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=30_000.0, arrival_rate_per_s=6.0, delay_req_ms=6000.0,
        acc_req_mean=50.0, acc_req_std=10.0,
        channel_sigma=0.0, proc_sigma=0.0, queue_cap=10**9,
        bandwidth_init=spec.bandwidth_true, adapt_max_cs=False,
        congestion=CC,
    )
    r = simulate(spec, cfg, policy=policy, seed=0)
    fr = simulate_fleet(spec, cfg, policy=policy, n_rep=1, seed=0)
    assert fr.n_requests == r.n_requests
    assert fr.n_served == r.n_served
    fleet_sat = int(round(fr.satisfied_per_rep[0] * fr.n_requests / 100.0))
    assert fleet_sat == r.n_satisfied


# ---------------------------------------------------------------------------
# Stateful policies: the carry threads through frame loop and scan
# ---------------------------------------------------------------------------


def _make_adaptive(n_edge, n_servers):
    """EMA-load-aware GUS: shades each server's visible capacity by its
    estimated utilization and advances its own PRNG chain."""

    def fn(inst, carry):
        shade = jnp.maximum(1.0 - carry.ema_util, 0.1)
        a = gus_schedule(dataclasses.replace(inst, gamma=inst.gamma * shade))
        key, _ = jax.random.split(carry.key)
        return a, dataclasses.replace(carry, key=key)

    return fn


def test_stateful_policy_runs_both_paths_deterministically():
    name = "test-adaptive"
    register_policy(Policy(
        name=name, description="EMA-shaded GUS (stateful probe)",
        make=_make_adaptive, stateful=True, kind="greedy",
    ))
    try:
        spec = demo_cluster_spec()
        cfg = overload_cfg(rate=4.0, horizon_ms=12_000.0)
        a = simulate(spec, cfg, policy=name, seed=0)
        b = simulate(spec, cfg, policy=name, seed=0)
        assert a.as_dict() == b.as_dict()
        assert a.n_served + a.n_dropped == a.n_requests
        fa = simulate_fleet(spec, cfg, policy=name, n_rep=2, seed=0)
        fb = simulate_fleet(spec, cfg, policy=name, n_rep=2, seed=0)
        np.testing.assert_array_equal(fa.satisfied_per_rep, fb.satisfied_per_rep)
        assert np.isfinite(fa.satisfied_pct) and fa.n_served > 0
    finally:
        POLICIES.pop(name, None)


def test_stateful_policy_sees_growing_backlog_in_carry():
    """Under sustained over-commit the simulator-owned backlog (and the EMA
    load estimate) in the carry must be visible to a stateful policy and
    grow across frames — in the sequential path, like in the fleet's scan."""
    seen = []
    seen_ema = []

    def make(n_edge, n_servers):
        def fn(inst, carry):
            seen.append(float(jnp.sum(carry.backlog_gamma)))
            seen_ema.append(float(jnp.max(carry.ema_util)))
            a = gus_schedule(inst, relax_compute=True)  # over-commit on purpose
            return a, carry

        return fn

    name = "test-backlog-probe"
    register_policy(Policy(name=name, description="backlog probe", make=make,
                           stateful=True, vmappable=False, pad=False))
    try:
        simulate(demo_cluster_spec(), overload_cfg(horizon_ms=15_000.0),
                 policy=name, seed=0)
    finally:
        POLICIES.pop(name, None)
    assert len(seen) >= 3
    assert seen[0] == 0.0 and seen[-1] > 0.0
    assert max(seen) == pytest.approx(seen[-1])  # monotone growth under overload
    assert seen_ema[0] == 0.0 and seen_ema[-1] > 0.0  # EMA evolves here too


def test_init_policy_carry_shapes():
    c = init_policy_carry(5, seed=3, bandwidth_init=42.0)
    assert c.backlog_gamma.shape == (5,) and c.backlog_eta.shape == (5,)
    assert c.ema_util.shape == (5,)
    assert float(c.bw_cur) == 42.0
    # it is a pytree (scan-carry requirement)
    leaves = jax.tree_util.tree_leaves(c)
    assert len(leaves) == 8


# ---------------------------------------------------------------------------
# lp-bound: the LP-relaxation oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lagrangian_bound_dominates_exact_optimum(seed):
    inst = generate_instance(seed, TINY)
    _, opt = solve_bnb(inst)
    bound = lagrangian_bound(inst)
    assert bound >= opt - 1e-9
    # and it is tighter than (or equal to) the capacity-free naive bound
    from repro.core import best_us_per_request

    naive = float(jnp.maximum(best_us_per_request(inst), 0.0).sum()) / TINY.n_requests
    assert bound <= naive + 1e-6  # f32 (naive) vs f64 (dual) rounding slack


def test_price_directed_greedy_is_feasible():
    inst = generate_instance(0, GeneratorConfig(
        n_requests=40, n_edge=3, n_cloud=1, n_services=5, n_variants=3
    ))
    _, lam, mu = lagrangian_dual(inst)
    a = price_directed_greedy(inst, lam, mu)
    jv, lv = np.asarray(a.j), np.asarray(a.l)
    v, u = np.asarray(inst.v), np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma, np.float64).copy()
    eta = np.asarray(inst.eta, np.float64).copy()
    for i in range(40):
        if jv[i] < 0:
            continue
        gamma[jv[i]] -= v[i, jv[i], lv[i]]
        if jv[i] != cover[i]:
            eta[cover[i]] -= u[i, jv[i], lv[i]]
    assert (gamma >= -1e-6).all() and (eta >= -1e-6).all()


@pytest.mark.slow
def test_lp_bound_policy_scales_past_ilp_refusal():
    """The registered lp-bound policy schedules a 100-request frame (which
    the ilp policy refuses) and its bound dominates GUS's value there."""
    from repro.core import get_policy

    big = GeneratorConfig()  # 100 requests
    inst = generate_instance(0, big)
    pol = get_policy("lp-bound")
    assert pol.kind == "oracle" and not pol.vmappable and not pol.pad
    a = pol.bind(big.n_edge, big.n_edge + big.n_cloud)(inst)
    assert np.asarray(a.j).shape == (100,)
    bound = lagrangian_bound(inst)
    g = gus_schedule(inst)
    gus_val = float(mean_us(inst, g.j, g.l))
    assert bound >= gus_val - 1e-9
    assert gus_val / bound > 0.5  # the gap stays measurable, and sane


def test_lp_bound_runs_in_simulator_and_fleet():
    spec = demo_cluster_spec(n_edge=2, n_cloud=1, n_services=2, n_variants=2)
    cfg = SimConfig(horizon_ms=6000.0, arrival_rate_per_s=1.5,
                    delay_req_ms=6000.0, acc_req_mean=50.0, acc_req_std=10.0)
    r = simulate(spec, cfg, policy="lp-bound", seed=0)
    assert r.n_served + r.n_dropped == r.n_requests
    fr = simulate_fleet(spec, cfg, policy="lp-bound", n_rep=2, seed=0)
    assert np.isfinite(fr.satisfied_pct)
