"""Logical-axis sharding: divisibility fallback, rules, param spec trees,
and an actual 2-device pjit run of a sharded train step."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.sharding import DEFAULT_RULES, resolve_spec, shard


def mk_mesh(shape, names):
    devs = np.array(jax.devices()[: int(np.prod(shape))])
    if devs.size < np.prod(shape):
        pytest.skip("not enough devices")
    return Mesh(devs.reshape(shape), names)


def test_resolve_basic():
    mesh = mk_mesh((1, 1), ("data", "model"))
    spec = resolve_spec((128, 64), ("vocab", "embed"), mesh, DEFAULT_RULES)
    assert spec == P("model")  # embed unsharded -> trailing None trimmed


def test_resolve_divisibility_fallback():
    # model axis size 1 always divides; test the non-dividing case via a rules
    # table against a fake mesh of size 16 using jax's mesh abstraction
    devs = np.array(jax.devices() * 16)[:16]  # replicate the single CPU device
    mesh = Mesh(devs.reshape(4, 4), ("data", "model"))
    # kv_heads=4 divides 4 -> sharded
    assert resolve_spec((8, 4, 64), (None, "kv_heads", None), mesh) == P(None, "model")
    # kv_heads=3 does not divide 4 -> replicated
    assert resolve_spec((8, 3, 64), (None, "kv_heads", None), mesh) == P()


def test_resolve_no_double_axis_use():
    devs = np.array(jax.devices() * 16)[:16]
    mesh = Mesh(devs.reshape(4, 4), ("data", "model"))
    # batch takes data; embed mapped to data in train rules must be dropped
    rules = dict(DEFAULT_RULES, embed="data")
    spec = resolve_spec((16, 8, 64), ("batch", None, "embed"), mesh, rules)
    assert spec == P("data")


def test_resolve_composite_axes():
    devs = np.array(jax.devices() * 8)[:8]
    mesh = Mesh(devs.reshape(2, 2, 2), ("pod", "data", "model"))
    spec = resolve_spec((8, 16), ("batch", None), mesh)
    assert spec == P(("pod", "data"))


def test_param_specs_align_with_params():
    cfg = ModelConfig(family="moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=256, n_experts=4,
                      top_k=2, scan_layers=True)
    model = Model(cfg)
    ap = model.abstract_params()
    lg = model.param_logical_specs()
    # identical tree structure (tuples in lg are leaves wrt ap's structure);
    # rank of every logical spec matches its param's rank
    checked = jax.tree.map(
        lambda p, l: (len(p.shape) == len(l)) or pytest.fail(f"{p.shape} vs {l}"),
        ap,
        lg,
    )
    assert all(jax.tree.leaves(checked))


def test_shard_noop_outside_context():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x


def test_sharded_train_step_runs_two_devices():
    """End-to-end pjit train step on a 1x1 mesh (single CPU device) — the same
    builder path the 512-device dry-run uses."""
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import ShapeSpec
    from repro.launch.steps import build_train_step
    from repro.training import make_batch

    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=256, scan_layers=True)
    model = Model(cfg)
    mesh = make_test_mesh(1, 1)
    shape = ShapeSpec("tiny", seq_len=32, global_batch=4, kind="train")
    fn, (astate, aspecs) = build_train_step(model, mesh, shape)
    # materialize real inputs matching the abstract specs
    from repro.training import init_state
    state = init_state(model, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32, np.random.default_rng(0))
    with mesh:
        state2, metrics = fn(state, {k: batch[k] for k in aspecs})
    assert np.isfinite(float(metrics["loss"]))
