"""Streaming arrival engine: chunked-vs-materialized parity at fixed seed,
bounded-memory invariants, the streaming scenarios, and simulate/fleet runs
off the stream."""
import math

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    ArrivalStream,
    SimConfig,
    demo_cluster_spec,
    get_scenario,
    list_scenarios,
    simulate,
    simulate_fleet,
    stream_trace,
)


def cfg(**kw):
    return SimConfig(
        horizon_ms=kw.pop("horizon_ms", 20_000.0),
        arrival_rate_per_s=kw.pop("arrival_rate_per_s", 3.0),
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=50.0,
        acc_req_std=10.0,
        **kw,
    )


def _req_tuple(r):
    return (r.rid, r.arrival_ms, r.cover, r.service, r.A, r.C, r.size_bytes)


# ---------------------------------------------------------------------------
# Parity: frame-by-frame draining == one-shot materialization, fixed seed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", sorted(["paper-default", "diurnal", "flash-crowd",
                                             "hetero-tiers", "sustained-overload",
                                             "diurnal-week"]))
@pytest.mark.parametrize("chunk_ms", [250.0, 3000.0, 7777.0])
def test_streaming_vs_materialized_parity(scenario, chunk_ms):
    c = cfg()
    one_shot = stream_trace(scenario, 11, 4, 3, c)
    s = ArrivalStream(scenario, 11, 4, 3, c)
    chunked = []
    t = 0.0
    while not s.exhausted:
        t += chunk_ms
        chunked.extend(s.take_until(t))
    assert [_req_tuple(r) for r in chunked] == [_req_tuple(r) for r in one_shot]


def test_stream_is_deterministic_given_seed_and_seed_sensitive():
    c = cfg()
    a = [_req_tuple(r) for r in stream_trace("paper-default", 5, 4, 3, c)]
    b = [_req_tuple(r) for r in stream_trace("paper-default", 5, 4, 3, c)]
    other = [_req_tuple(r) for r in stream_trace("paper-default", 6, 4, 3, c)]
    assert a == b
    assert a != other


def test_stream_arrivals_sorted_with_sequential_rids():
    reqs = stream_trace("flash-crowd", 0, 4, 3, cfg())
    times = [r.arrival_ms for r in reqs]
    assert times == sorted(times)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    assert all(0.0 <= t < cfg().horizon_ms for t in times)


def test_stream_rate_matches_expectation():
    """Constant-rate scenario: emitted count ~ Poisson(rate * horizon * edges)."""
    c = cfg(horizon_ms=60_000.0, arrival_rate_per_s=2.0)
    n = len(stream_trace("paper-default", 0, 4, 3, c))
    expect = 2.0 * 60.0 * 4  # = 480
    assert abs(n - expect) < 5 * math.sqrt(expect)


def test_stream_bounded_lookahead():
    """The stream holds at most one pending arrival per edge."""
    s = ArrivalStream("paper-default", 0, 6, 3, cfg())
    assert len(s._heap) <= 6
    s.take_until(10_000.0)
    assert len(s._heap) <= 6


def test_take_until_respects_boundaries():
    s = ArrivalStream("paper-default", 3, 4, 3, cfg())
    first = s.take_until(5000.0)
    assert all(r.arrival_ms < 5000.0 for r in first)
    nxt = s.peek_ms()
    assert nxt >= 5000.0
    second = s.take_until(10_000.0)
    assert all(5000.0 <= r.arrival_ms < 10_000.0 for r in second)


# ---------------------------------------------------------------------------
# The streaming scenarios
# ---------------------------------------------------------------------------


def test_streaming_scenarios_registered():
    assert "sustained-overload" in list_scenarios()
    assert "diurnal-week" in list_scenarios()
    assert get_scenario("sustained-overload").streaming
    assert get_scenario("diurnal-week").streaming
    assert not get_scenario("paper-default").streaming


def test_sustained_overload_rate_is_multiplied():
    scn = get_scenario("sustained-overload")
    c = cfg()
    assert scn.rate(0, 1000.0, c) == pytest.approx(
        c.arrival_rate_per_s * scn.rate_mult
    )


def test_diurnal_week_has_seven_cycles():
    scn = get_scenario("diurnal-week")
    c = cfg(horizon_ms=70_000.0)
    # rate at t and t + horizon/7 are equal (one full period apart)
    assert scn.rate(0, 1234.0, c) == pytest.approx(
        scn.rate(0, 1234.0 + 10_000.0, c), rel=1e-9
    )
    # and the rate actually swings within a period
    rates = [scn.rate(0, t, c) for t in np.linspace(0, 10_000.0, 20)]
    assert max(rates) > 1.5 * min(rates)


# ---------------------------------------------------------------------------
# simulate / simulate_fleet off the stream
# ---------------------------------------------------------------------------


def test_simulate_streaming_deterministic_and_conserves_counts():
    spec = demo_cluster_spec()
    a = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0)
    b = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0)
    assert a.as_dict() == b.as_dict()
    assert a.n_served + a.n_dropped == a.n_requests
    assert a.n_requests > 0


def test_streaming_override_flag():
    """streaming=True forces the stream on a materialized scenario and
    streaming=False forces materialization on a streaming scenario."""
    spec = demo_cluster_spec()
    r_forced = simulate(spec, cfg(), policy="gus", scenario="paper-default",
                        seed=0, streaming=True)
    assert r_forced.n_served + r_forced.n_dropped == r_forced.n_requests
    r_mat = simulate(spec, cfg(), policy="gus", scenario="sustained-overload",
                     seed=0, streaming=False)
    assert r_mat.n_served + r_mat.n_dropped == r_mat.n_requests


def test_simulate_streaming_respects_n_requests_cap():
    spec = demo_cluster_spec()
    r = simulate(spec, cfg(), policy="gus", scenario="sustained-overload",
                 seed=0, n_requests=25)
    assert r.n_requests == 25


def test_fleet_runs_streaming_scenarios():
    spec = demo_cluster_spec()
    fr = simulate_fleet(spec, cfg(horizon_ms=12_000.0), policy="gus",
                        scenario="diurnal-week", n_rep=2, seed=0)
    assert np.isfinite(fr.satisfied_pct) and fr.n_requests > 0


def test_fleet_rep0_arrivals_match_sequential_stream():
    """Fleet replication r uses stream seed ``seed + r``, so rep 0's arrival
    trace equals the sequential simulate's at the same seed."""
    spec = demo_cluster_spec()
    c = cfg(horizon_ms=12_000.0)
    reqs = stream_trace("sustained-overload", 7, spec.n_edge, 3, c)
    fr = simulate_fleet(spec, c, policy="gus", scenario="sustained-overload",
                        n_rep=1, seed=7)
    assert fr.n_requests == len(reqs)


@pytest.mark.slow
def test_long_horizon_streaming_smoke():
    """10^3 frames through the sequential testbed off the stream — the
    long-horizon mode the materialized path would bloat on."""
    spec = demo_cluster_spec(n_edge=2, n_cloud=1, n_services=2, n_variants=2)
    c = SimConfig(horizon_ms=3_000_000.0, arrival_rate_per_s=0.05,
                  delay_req_ms=6000.0, acc_req_mean=50.0, acc_req_std=10.0)
    r = simulate(spec, c, policy="gus", scenario="diurnal-week", seed=0)
    assert r.n_served + r.n_dropped == r.n_requests
    assert r.n_requests > 100
