"""Streaming arrival engine: chunked-vs-materialized parity at fixed seed,
bounded-memory invariants, the streaming scenarios, and simulate/fleet runs
off the stream."""
import math
import threading

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    ArrivalStream,
    SimConfig,
    demo_cluster_spec,
    get_scenario,
    list_scenarios,
    simulate,
    simulate_fleet,
    stream_trace,
)


def cfg(**kw):
    return SimConfig(
        horizon_ms=kw.pop("horizon_ms", 20_000.0),
        arrival_rate_per_s=kw.pop("arrival_rate_per_s", 3.0),
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=50.0,
        acc_req_std=10.0,
        **kw,
    )


def _req_tuple(r):
    return (r.rid, r.arrival_ms, r.cover, r.service, r.A, r.C, r.size_bytes)


# ---------------------------------------------------------------------------
# Parity: frame-by-frame draining == one-shot materialization, fixed seed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", sorted(["paper-default", "diurnal", "flash-crowd",
                                             "hetero-tiers", "sustained-overload",
                                             "diurnal-week"]))
@pytest.mark.parametrize("chunk_ms", [250.0, 3000.0, 7777.0])
def test_streaming_vs_materialized_parity(scenario, chunk_ms):
    c = cfg()
    one_shot = stream_trace(scenario, 11, 4, 3, c)
    s = ArrivalStream(scenario, 11, 4, 3, c)
    chunked = []
    t = 0.0
    while not s.exhausted:
        t += chunk_ms
        chunked.extend(s.take_until(t))
    assert [_req_tuple(r) for r in chunked] == [_req_tuple(r) for r in one_shot]


def test_stream_is_deterministic_given_seed_and_seed_sensitive():
    c = cfg()
    a = [_req_tuple(r) for r in stream_trace("paper-default", 5, 4, 3, c)]
    b = [_req_tuple(r) for r in stream_trace("paper-default", 5, 4, 3, c)]
    other = [_req_tuple(r) for r in stream_trace("paper-default", 6, 4, 3, c)]
    assert a == b
    assert a != other


def test_stream_arrivals_sorted_with_sequential_rids():
    reqs = stream_trace("flash-crowd", 0, 4, 3, cfg())
    times = [r.arrival_ms for r in reqs]
    assert times == sorted(times)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    assert all(0.0 <= t < cfg().horizon_ms for t in times)


def test_stream_rate_matches_expectation():
    """Constant-rate scenario: emitted count ~ Poisson(rate * horizon * edges)."""
    c = cfg(horizon_ms=60_000.0, arrival_rate_per_s=2.0)
    n = len(stream_trace("paper-default", 0, 4, 3, c))
    expect = 2.0 * 60.0 * 4  # = 480
    assert abs(n - expect) < 5 * math.sqrt(expect)


def test_stream_bounded_lookahead():
    """The stream holds at most one pending arrival per edge."""
    s = ArrivalStream("paper-default", 0, 6, 3, cfg())
    assert len(s._heap) <= 6
    s.take_until(10_000.0)
    assert len(s._heap) <= 6


def test_take_until_respects_boundaries():
    s = ArrivalStream("paper-default", 3, 4, 3, cfg())
    first = s.take_until(5000.0)
    assert all(r.arrival_ms < 5000.0 for r in first)
    nxt = s.peek_ms()
    assert nxt >= 5000.0
    second = s.take_until(10_000.0)
    assert all(5000.0 <= r.arrival_ms < 10_000.0 for r in second)


# ---------------------------------------------------------------------------
# The streaming scenarios
# ---------------------------------------------------------------------------


def test_streaming_scenarios_registered():
    assert "sustained-overload" in list_scenarios()
    assert "diurnal-week" in list_scenarios()
    assert get_scenario("sustained-overload").streaming
    assert get_scenario("diurnal-week").streaming
    assert not get_scenario("paper-default").streaming


def test_sustained_overload_rate_is_multiplied():
    scn = get_scenario("sustained-overload")
    c = cfg()
    assert scn.rate(0, 1000.0, c) == pytest.approx(
        c.arrival_rate_per_s * scn.rate_mult
    )


def test_diurnal_week_has_seven_cycles():
    scn = get_scenario("diurnal-week")
    c = cfg(horizon_ms=70_000.0)
    # rate at t and t + horizon/7 are equal (one full period apart)
    assert scn.rate(0, 1234.0, c) == pytest.approx(
        scn.rate(0, 1234.0 + 10_000.0, c), rel=1e-9
    )
    # and the rate actually swings within a period
    rates = [scn.rate(0, t, c) for t in np.linspace(0, 10_000.0, 20)]
    assert max(rates) > 1.5 * min(rates)


# ---------------------------------------------------------------------------
# simulate / simulate_fleet off the stream
# ---------------------------------------------------------------------------


def test_simulate_streaming_deterministic_and_conserves_counts():
    spec = demo_cluster_spec()
    a = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0)
    b = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0)
    assert a.as_dict() == b.as_dict()
    assert a.n_served + a.n_dropped == a.n_requests
    assert a.n_requests > 0


def test_streaming_override_flag():
    """streaming=True forces the stream on a materialized scenario and
    streaming=False forces materialization on a streaming scenario."""
    spec = demo_cluster_spec()
    r_forced = simulate(spec, cfg(), policy="gus", scenario="paper-default",
                        seed=0, streaming=True)
    assert r_forced.n_served + r_forced.n_dropped == r_forced.n_requests
    r_mat = simulate(spec, cfg(), policy="gus", scenario="sustained-overload",
                     seed=0, streaming=False)
    assert r_mat.n_served + r_mat.n_dropped == r_mat.n_requests


def test_simulate_streaming_respects_n_requests_cap():
    spec = demo_cluster_spec()
    r = simulate(spec, cfg(), policy="gus", scenario="sustained-overload",
                 seed=0, n_requests=25)
    assert r.n_requests == 25


def test_fleet_runs_streaming_scenarios():
    spec = demo_cluster_spec()
    fr = simulate_fleet(spec, cfg(horizon_ms=12_000.0), policy="gus",
                        scenario="diurnal-week", n_rep=2, seed=0)
    assert np.isfinite(fr.satisfied_pct) and fr.n_requests > 0


def test_fleet_rep0_arrivals_match_sequential_stream():
    """Fleet replication r uses stream seed ``seed + r``, so rep 0's arrival
    trace equals the sequential simulate's at the same seed."""
    spec = demo_cluster_spec()
    c = cfg(horizon_ms=12_000.0)
    reqs = stream_trace("sustained-overload", 7, spec.n_edge, 3, c)
    fr = simulate_fleet(spec, c, policy="gus", scenario="sustained-overload",
                        n_rep=1, seed=7)
    assert fr.n_requests == len(reqs)


# ---------------------------------------------------------------------------
# Vectorized stream mode: chunking invariance + determinism off the stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", sorted(["paper-default", "diurnal", "flash-crowd",
                                             "hetero-tiers", "sustained-overload",
                                             "diurnal-week"]))
@pytest.mark.parametrize("chunk_ms", [250.0, 3000.0, 7777.0])
def test_vectorized_streaming_chunk_invariance(scenario, chunk_ms):
    """The vectorized stream buffers numpy chunks per edge, but the pull
    pattern still cannot change the draws — frame-by-frame == one-shot."""
    c = cfg()
    one_shot = stream_trace(scenario, 11, 4, 3, c, rng_mode="vectorized")
    s = ArrivalStream(scenario, 11, 4, 3, c, rng_mode="vectorized")
    chunked = []
    t = 0.0
    while not s.exhausted:
        t += chunk_ms
        chunked.extend(s.take_until(t))
    assert [_req_tuple(r) for r in chunked] == [_req_tuple(r) for r in one_shot]


def test_vectorized_stream_bounded_lookahead_and_order():
    s = ArrivalStream("paper-default", 0, 6, 3, cfg(), rng_mode="vectorized")
    assert len(s._heap) <= 6
    first = s.take_until(5000.0)
    assert all(r.arrival_ms < 5000.0 for r in first)
    assert len(s._heap) <= 6
    times = [r.arrival_ms for r in first]
    assert times == sorted(times)
    assert [r.rid for r in first] == list(range(len(first)))


def test_simulate_streaming_vectorized_deterministic():
    spec = demo_cluster_spec()
    a = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0,
                 rng_mode="vectorized")
    b = simulate(spec, cfg(), policy="gus", scenario="sustained-overload", seed=0,
                 rng_mode="vectorized")
    assert a.as_dict() == b.as_dict()
    assert a.n_served + a.n_dropped == a.n_requests
    assert a.n_requests > 0


# ---------------------------------------------------------------------------
# Overlapped window pipeline: thread safety, shutdown, long-horizon parity
# ---------------------------------------------------------------------------


def _producer_threads():
    return [
        t for t in threading.enumerate() if t.name == "fleet-window-producer"
    ]


def test_producer_exception_propagates_without_hang():
    """An exception inside the host-side window builder must surface to the
    caller (not deadlock the queue) and leave no producer thread behind."""
    import repro.core.simulator as sim_mod

    spec = demo_cluster_spec()
    real_build = sim_mod._build_frame_batch
    calls = {"n": 0}

    def exploding_build(*args, **kw):
        calls["n"] += 1
        if calls["n"] >= 2:  # let window 0 through, fail while overlapped
            raise RuntimeError("boom in host builder")
        return real_build(*args, **kw)

    sim_mod._build_frame_batch = exploding_build
    try:
        with pytest.raises(RuntimeError, match="boom in host builder"):
            simulate_fleet(spec, cfg(), policy="gus", n_rep=2, seed=0,
                           window=2, prefetch=2)
    finally:
        sim_mod._build_frame_batch = real_build
    for t in _producer_threads():
        t.join(timeout=5.0)
    assert not [t for t in _producer_threads() if t.is_alive()]


def test_consumer_error_drains_producer_and_joins():
    """If the *consumer* dies mid-run (device-side error), the early exit
    must drain the bounded queue so the producer unblocks and joins."""
    import repro.core.simulator as sim_mod

    spec = demo_cluster_spec()
    real_mask = sim_mod.satisfied_mask
    calls = {"n": 0}

    def exploding_mask(*args, **kw):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise RuntimeError("boom in consumer")
        return real_mask(*args, **kw)

    sim_mod.satisfied_mask = exploding_mask
    try:
        with pytest.raises(RuntimeError, match="boom in consumer"):
            # depth-1 queue + tiny windows: the producer is guaranteed to be
            # blocked in put() when the consumer raises
            simulate_fleet(spec, cfg(), policy="gus", n_rep=2, seed=0,
                           window=1, prefetch=1)
    finally:
        sim_mod.satisfied_mask = real_mask
    for t in _producer_threads():
        t.join(timeout=5.0)
    assert not [t for t in _producer_threads() if t.is_alive()]


def test_no_producer_thread_leak_on_success():
    spec = demo_cluster_spec()
    before = len([t for t in _producer_threads() if t.is_alive()])
    simulate_fleet(spec, cfg(), policy="gus", n_rep=2, seed=0, window=2, prefetch=2)
    assert len([t for t in _producer_threads() if t.is_alive()]) == before


@pytest.mark.slow
def test_sustained_overload_long_horizon_overlap_matches_serial():
    """A long-horizon streaming run under the overlapped pipeline (lazy
    per-window arrivals built in the producer thread) is bit-identical to
    the serial loop — the satellite case the ISSUE calls out."""
    spec = demo_cluster_spec()
    c = cfg(horizon_ms=240_000.0, arrival_rate_per_s=2.0)
    serial = simulate_fleet(spec, c, policy="gus", n_rep=2, seed=3,
                            scenario="sustained-overload", window=4, prefetch=0)
    overlapped = simulate_fleet(spec, c, policy="gus", n_rep=2, seed=3,
                                scenario="sustained-overload", window=4, prefetch=2)
    assert serial.n_requests == overlapped.n_requests
    assert serial.n_served == overlapped.n_served
    np.testing.assert_array_equal(
        serial.satisfied_per_rep, overlapped.satisfied_per_rep
    )
    np.testing.assert_array_equal(serial.mean_us_per_rep, overlapped.mean_us_per_rep)


@pytest.mark.slow
def test_long_horizon_streaming_smoke():
    """10^3 frames through the sequential testbed off the stream — the
    long-horizon mode the materialized path would bloat on."""
    spec = demo_cluster_spec(n_edge=2, n_cloud=1, n_services=2, n_variants=2)
    c = SimConfig(horizon_ms=3_000_000.0, arrival_rate_per_s=0.05,
                  delay_req_ms=6000.0, acc_req_mean=50.0, acc_req_std=10.0)
    r = simulate(spec, c, policy="gus", scenario="diurnal-week", seed=0)
    assert r.n_served + r.n_dropped == r.n_requests
    assert r.n_requests > 100
