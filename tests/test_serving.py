"""Serving substrate: caches, engine, zoo profiles, scheduler bridge."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs import get_config
from repro.models import Model
from repro.serving import (
    HW_CLASSES,
    ModelZoo,
    ServiceSpec,
    ServingEngine,
    accuracy_proxy,
    build_cluster_spec,
    request_latency_ms,
    step_costs,
    variant_ladder,
)
from repro.training import make_batch

DENSE = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=2, d_ff=128, vocab_size=256, scan_layers=False)


def test_generate_is_deterministic_and_consistent():
    model = Model(DENSE)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params)
    b = make_batch(DENSE, 2, 16, np.random.default_rng(0))
    r1 = eng.generate(b, max_new_tokens=6)
    r2 = eng.generate(b, max_new_tokens=6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 6)


def test_generate_matches_stepwise_forward():
    """Greedy generation must equal argmax decoding via full re-forward."""
    model = Model(DENSE)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServingEngine(model, params)
    b = make_batch(DENSE, 1, 12, np.random.default_rng(1))
    out = eng.generate(b, max_new_tokens=4)

    toks = np.asarray(b["tokens"])
    cur = toks.copy()
    for t in range(4):
        logits, _ = model.forward(params, {"tokens": jnp.asarray(cur)})
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))[:, None]
        assert (nxt[:, 0] == out.tokens[:, t]).all(), f"step {t}"
        cur = np.concatenate([cur, nxt], axis=1)


def test_sliding_window_ring_cache_wraps():
    cfg = dataclasses.replace(DENSE, sliding_window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = make_batch(cfg, 1, 24, np.random.default_rng(2))
    # decode 20 tokens past a 24-token prefill: cache wraps 5+ times
    cache = model.init_cache(1, 64)
    assert cache.attn["k"].shape[2] == 8  # ring limited to the window
    logits, cache = model.prefill(params, b, cache)
    for _ in range(20):
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)[:, 0:1]
        if tok.ndim == 3:
            tok = tok[..., 0]
        logits, cache = model.decode_step(params, tok, cache)
    assert int(cache.index) == 44
    assert np.isfinite(np.asarray(logits)).all()


def test_step_costs_monotone():
    big = step_costs(get_config("qwen2-72b"), 1, 4096, "decode")
    small = step_costs(get_config("yi-9b"), 1, 4096, "decode")
    assert big["flops"] > small["flops"]
    assert big["bytes"] > small["bytes"]
    # prefill flops scale linearly-to-quadratically with tokens (the tiny
    # DENSE config is attention-dominated, so the ratio approaches 4)
    a = step_costs(DENSE, 1, 1024, "prefill")["flops"]
    b2 = step_costs(DENSE, 1, 2048, "prefill")["flops"]
    assert 1.8 < b2 / a < 4.2
    # a param-dominated model is ~linear
    big = get_config("yi-9b")
    a = step_costs(big, 1, 1024, "prefill")["flops"]
    b2 = step_costs(big, 1, 2048, "prefill")["flops"]
    assert 1.8 < b2 / a < 2.3


def test_latency_decreases_with_chips():
    cfg = get_config("yi-9b")
    l1 = request_latency_ms(cfg, HW_CLASSES["edge-1"])
    l8 = request_latency_ms(cfg, HW_CLASSES["edge-8"])
    assert l8 < l1


def test_accuracy_proxy_monotone():
    xs = [1e6, 1e8, 1e10, 1e12]
    accs = [accuracy_proxy(x) for x in xs]
    assert accs == sorted(accs)
    assert 30 < accs[0] < accs[-1] <= 95


def test_variant_ladder_monotone_cost():
    lad = variant_ladder(get_config("yi-9b"), 4)
    params = [v.n_params() for v in lad]
    assert params == sorted(params)
    assert lad[-1].d_model == 4096  # top variant is the base config


def test_build_cluster_spec_shapes():
    zoo = ModelZoo([
        ServiceSpec("a", variant_ladder(get_config("mamba2-130m"), 3)),
        ServiceSpec("b", variant_ladder(get_config("yi-9b"), 3)),
    ])
    spec = build_cluster_spec(zoo, ["edge-1", "edge-4"], ["cloud-256"], seed=0)
    assert spec.proc_ms.shape == (3, 2, 3)
    assert spec.placed[2].all()  # cloud holds everything
    assert not spec.placed[:2].all()  # edges hold a subset
    # cloud is faster than the weakest edge wherever both host the variant
    both = spec.placed[0] & spec.placed[2]
    assert (spec.proc_ms[2][both] <= spec.proc_ms[0][both]).all()
