"""Regenerate the hierarchical member-jitter golden fixture.

``hier_member_golden.npz`` pins the impaired duplicate-class hierarchical
fleet run defined by ``tests/test_hier_parity.py::golden_run`` — the
regime where per-member realized link impairments are applied at
deaggregation.  Any change to the member expansion, the realized-channel
arithmetic, or the class-level admission accounting shows up as a fixture
diff instead of silent drift.

Regenerate (and commit the result) only when the accounting semantics are
*meant* to change:

    PYTHONPATH=src python tests/fixtures/make_hier_golden.py
"""
from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

import numpy as np

OUT = Path(__file__).parent / "hier_member_golden.npz"


def _load_golden_run():
    # the run config lives next to the test that consumes the fixture, so
    # the two can never diverge
    test_path = Path(__file__).parent.parent / "test_hier_parity.py"
    spec = importlib.util.spec_from_file_location("_hier_parity", test_path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod  # dataclass decorators resolve the module
    spec.loader.exec_module(mod)
    return mod.golden_run


def main():
    fr = _load_golden_run()()
    np.savez_compressed(
        OUT,
        n_requests=np.int64(fr.n_requests),
        n_served=np.int64(fr.n_served),
        satisfied_per_rep=np.asarray(fr.satisfied_per_rep),
        mean_us_per_rep=np.asarray(fr.mean_us_per_rep),
    )
    print(f"{OUT.name}: n_requests={fr.n_requests} n_served={fr.n_served} "
          f"satisfied={np.asarray(fr.satisfied_per_rep)}")


if __name__ == "__main__":
    main()
