"""Regenerate the golden GUS frame fixtures (``gus_golden_*.npz``).

Each fixture is one *real* scheduler input — a padded frame captured from a
short :func:`repro.core.simulate` run — plus the assignment the NumPy oracle
produced for it.  ``tests/test_gus_parity.py::test_golden_frame`` pins all
three GUS implementations (NumPy / XLA / Pallas) to these stored outputs, so
any behaviour change in utility computation, feasibility, tie-breaking or
the greedy loop shows up as a fixture diff instead of a silent drift.

Three regimes are pinned:

* ``paper-default``                  — the Sec. IV workload, light load;
* ``flash-crowd``                    — bursty overload (big, busy frames);
* ``sustained-overload-congested``   — the congestion model's
  backlog-reduced budgets (the frame's gamma is strictly below the
  cluster's per-frame budget).

Regenerate (and commit the result) only when the scheduling semantics are
*meant* to change:

    PYTHONPATH=src python tests/fixtures/make_golden_frames.py
"""
from __future__ import annotations

from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CongestionConfig,
    SimConfig,
    demo_cluster_spec,
    gus_schedule,
    gus_schedule_np,
    simulate,
)

OUT_DIR = Path(__file__).parent

LEAVES = ("cover", "A", "C", "w_a", "w_c", "acc", "ctime", "v", "u",
          "avail", "gamma", "eta", "max_as", "max_cs")

#: name -> (scenario, congestion, arrival rate/s, horizon s)
REGIMES = {
    "paper-default": ("paper-default", False, 3.0, 9.0),
    "flash-crowd": ("flash-crowd", False, 3.0, 9.0),
    "sustained-overload-congested": ("sustained-overload", True, 6.0, 12.0),
}


class _Capture:
    def __init__(self):
        self.frames = []

    def __call__(self, inst):
        self.frames.append(jax.tree.map(np.asarray, inst))
        return gus_schedule(inst)


def _pick_frame(frames, spec, congestion):
    """The most interesting captured frame: for the congested regime, the
    last one whose budget is strictly backlog-reduced; otherwise the busiest
    (most feasible rows) so the greedy loop actually contends for capacity."""
    if congestion:
        reduced = [
            f for f in frames
            if (np.asarray(f.gamma) < spec.gamma_frame - 1e-6).any()
        ]
        if not reduced:
            raise SystemExit("no backlog-reduced frame captured; raise the rate")
        return reduced[-1]
    return max(frames, key=lambda f: int(np.asarray(f.avail).any((1, 2)).sum()))


def main():
    spec = demo_cluster_spec()
    for name, (scenario, congestion, rate, horizon_s) in REGIMES.items():
        cap = _Capture()
        cfg = SimConfig(
            horizon_ms=horizon_s * 1000.0,
            arrival_rate_per_s=rate,
            delay_req_ms=6000.0,
            acc_req_mean=50.0,
            acc_req_std=10.0,
            congestion=CongestionConfig(enabled=congestion),
        )
        simulate(spec, cfg, scheduler=cap, scenario=scenario, seed=0)
        frame = _pick_frame(cap.frames, spec, congestion)
        ref = gus_schedule_np(frame)
        n_real = int(np.asarray(frame.avail).any((1, 2)).sum())
        path = OUT_DIR / f"gus_golden_{name}.npz"
        np.savez_compressed(
            path,
            **{f: np.asarray(getattr(frame, f)) for f in LEAVES},
            exp_j=np.asarray(ref.j),
            exp_l=np.asarray(ref.l),
            n_real=np.int64(n_real),
            congestion=np.bool_(congestion),
            gamma_frame=spec.gamma_frame,
            scenario=np.str_(scenario),
        )
        served = int((np.asarray(ref.j) >= 0).sum())
        print(f"{path.name}: N_pad={frame.A.shape[0]} n_real={n_real} "
              f"served={served} congestion={congestion}")


if __name__ == "__main__":
    main()
