"""Regenerate the golden GUS frame fixtures (``gus_golden_*.npz``).

Each fixture is one *real* scheduler input — a padded frame captured from a
short :func:`repro.core.simulate` run — plus the assignment the NumPy oracle
produced for it.  ``tests/test_gus_parity.py::test_golden_frame`` pins all
three GUS implementations (NumPy / XLA / Pallas) to these stored outputs, so
any behaviour change in utility computation, feasibility, tie-breaking or
the greedy loop shows up as a fixture diff instead of a silent drift.

Five regimes are pinned:

* ``paper-default``                  — the Sec. IV workload, light load;
* ``flash-crowd``                    — bursty overload (big, busy frames);
* ``sustained-overload-congested``   — the congestion model's
  backlog-reduced budgets (the frame's gamma is strictly below the
  cluster's per-frame budget);
* ``outage-masked``                  — a frame captured inside the
  ``outage`` scenario's window, where a down server's budget is masked to
  exactly zero;
* ``impairment-reduced``             — a frame whose completion times carry
  the resilience engine's link impairments (reduced bandwidth / extra
  latency); the unimpaired twin's ``ctime`` is stored alongside so the
  parity test can prove the frame really is impaired.

Regenerate (and commit the result) only when the scheduling semantics are
*meant* to change — and regenerate *only the fixture you mean to change*
(``--only NAME``): npz archives are not byte-stable across rebuilds, so a
blanket rerun dirties fixtures whose semantics did not move:

    PYTHONPATH=src python tests/fixtures/make_golden_frames.py --only outage-masked
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CongestionConfig,
    ImpairmentConfig,
    IntermittentLink,
    SatelliteLink,
    SimConfig,
    demo_cluster_spec,
    gus_schedule,
    gus_schedule_np,
    simulate,
)

OUT_DIR = Path(__file__).parent

LEAVES = ("cover", "A", "C", "w_a", "w_c", "acc", "ctime", "v", "u",
          "avail", "gamma", "eta", "max_as", "max_cs")

#: the impairment stream the ``impairment-reduced`` fixture runs under
IMPAIRED = ImpairmentConfig(
    enabled=True,
    link_profiles=(IntermittentLink(), SatelliteLink()),
    seed=3,
)

#: name -> dict(scenario, congestion, rate (req/s), horizon_s, impairments,
#: pick) — ``pick`` selects the captured frame to pin (see the pick rules)
REGIMES = {
    "paper-default": dict(
        scenario="paper-default", congestion=False, rate=3.0, horizon_s=9.0,
        impairments=None, pick="busiest",
    ),
    "flash-crowd": dict(
        scenario="flash-crowd", congestion=False, rate=3.0, horizon_s=9.0,
        impairments=None, pick="busiest",
    ),
    "sustained-overload-congested": dict(
        scenario="sustained-overload", congestion=True, rate=6.0, horizon_s=12.0,
        impairments=None, pick="backlog-reduced",
    ),
    "outage-masked": dict(
        scenario="outage", congestion=False, rate=4.0, horizon_s=12.0,
        impairments=None, pick="outage-masked",
    ),
    "impairment-reduced": dict(
        scenario="paper-default", congestion=False, rate=4.0, horizon_s=12.0,
        impairments=IMPAIRED, pick="impairment-reduced",
    ),
}


class _Capture:
    def __init__(self):
        self.frames = []

    def __call__(self, inst):
        self.frames.append(jax.tree.map(np.asarray, inst))
        return gus_schedule(inst)


def _pick_frame(frames, spec, pick, twin_frames=None):
    """Select the captured frame the fixture pins.

    * ``busiest``            — most feasible rows (greedy loop contends);
    * ``backlog-reduced``    — last frame whose budget is strictly below the
      cluster's per-frame budget (the congestion regime);
    * ``outage-masked``      — busiest frame with a zero-budget server;
    * ``impairment-reduced`` — first frame whose ``ctime`` differs from the
      amplitude-0 twin run's same-index frame (identical pending set, so
      the diff is purely the link impairment); returns ``(frame, twin)``.
    """
    if pick == "backlog-reduced":
        reduced = [
            f for f in frames
            if (np.asarray(f.gamma) < spec.gamma_frame - 1e-6).any()
        ]
        if not reduced:
            raise SystemExit("no backlog-reduced frame captured; raise the rate")
        return reduced[-1]
    if pick == "outage-masked":
        masked = [f for f in frames if (np.asarray(f.gamma) == 0.0).any()]
        if not masked:
            raise SystemExit("no outage-masked frame captured; raise the rate")
        return max(masked, key=lambda f: int(np.asarray(f.avail).any((1, 2)).sum()))
    if pick == "impairment-reduced":
        for f, g in zip(frames, twin_frames):
            if f.ctime.shape != g.ctime.shape:
                break  # pending sets diverged; earlier frames were identical
            same_inputs = (
                np.array_equal(f.cover, g.cover)
                and np.array_equal(f.A, g.A)
                and np.array_equal(f.C, g.C)
            )
            if same_inputs and not np.array_equal(f.ctime, g.ctime):
                assert (f.ctime >= g.ctime - 1e-6).all(), \
                    "impairments must only slow transfers down"
                return f, g
        raise SystemExit("no impairment-affected frame found; raise the horizon")
    return max(frames, key=lambda f: int(np.asarray(f.avail).any((1, 2)).sum()))


def _run(spec, regime, impairments):
    cap = _Capture()
    cfg = SimConfig(
        horizon_ms=regime["horizon_s"] * 1000.0,
        arrival_rate_per_s=regime["rate"],
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=regime["congestion"]),
        impairments=impairments or ImpairmentConfig(),
    )
    simulate(spec, cfg, scheduler=cap, scenario=regime["scenario"], seed=0)
    return cap.frames


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", action="append", choices=sorted(REGIMES),
                    help="regenerate a single fixture (repeatable); npz "
                         "archives are not byte-stable, so prefer this over "
                         "a blanket rerun")
    args = ap.parse_args(argv)
    names = args.only or list(REGIMES)

    spec = demo_cluster_spec()
    for name in names:
        regime = REGIMES[name]
        frames = _run(spec, regime, regime["impairments"])
        extra = {}
        if regime["pick"] == "impairment-reduced":
            # amplitude-0 twin: same engine, exact-identity values — frames
            # before the first divergence are bit-identical
            twin = _run(
                spec, regime,
                ImpairmentConfig(
                    enabled=True, amplitude=0.0,
                    link_profiles=regime["impairments"].link_profiles,
                    seed=regime["impairments"].seed,
                ),
            )
            frame, twin_frame = _pick_frame(frames, spec, regime["pick"], twin)
            extra["ctime_unimpaired"] = np.asarray(twin_frame.ctime)
        else:
            frame = _pick_frame(frames, spec, regime["pick"])
        ref = gus_schedule_np(frame)
        n_real = int(np.asarray(frame.avail).any((1, 2)).sum())
        path = OUT_DIR / f"gus_golden_{name}.npz"
        np.savez_compressed(
            path,
            **{f: np.asarray(getattr(frame, f)) for f in LEAVES},
            exp_j=np.asarray(ref.j),
            exp_l=np.asarray(ref.l),
            n_real=np.int64(n_real),
            congestion=np.bool_(regime["congestion"]),
            impaired=np.bool_(regime["impairments"] is not None),
            gamma_frame=spec.gamma_frame,
            scenario=np.str_(regime["scenario"]),
            **extra,
        )
        served = int((np.asarray(ref.j) >= 0).sum())
        print(f"{path.name}: N_pad={frame.A.shape[0]} n_real={n_real} "
              f"served={served} pick={regime['pick']}")


if __name__ == "__main__":
    main()
