"""Policy registry: every scheduler behind one interface, on the simulator's
padded hot path — registry contents, per-policy state threading, padded-batch
parity against NumPy references, and the paper's GUS-beats-baselines claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GeneratorConfig,
    Policy,
    SimConfig,
    demo_cluster_spec,
    generate_instance,
    get_policy,
    gus_schedule,
    gus_schedule_np,
    hard_feasible,
    list_policies,
    list_scenarios,
    mean_us,
    pad_instance,
    register_policy,
    simulate,
    simulate_fleet,
    solve_bnb,
    us_tensor,
)
from repro.core.policies import POLICIES

BUILTIN = (
    "gus", "gus-ordered", "random", "offload_all", "local_all",
    "happy_computation", "happy_communication", "ilp", "lp-bound",
)

TINY = GeneratorConfig(n_requests=6, n_edge=2, n_cloud=1, n_services=3, n_variants=2)


def small_spec():
    return demo_cluster_spec(n_edge=2, n_cloud=1, n_services=2, n_variants=2)


def small_cfg(**kw):
    return SimConfig(
        horizon_ms=kw.pop("horizon_ms", 6000.0),
        arrival_rate_per_s=kw.pop("arrival_rate_per_s", 1.5),
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=50.0,
        acc_req_std=10.0,
        **kw,
    )


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def test_registry_has_the_documented_policies():
    names = list_policies()
    for n in BUILTIN:
        assert n in names
    assert names[0] == "gus"  # registration order preserved, GUS first


def test_get_policy_resolves_and_rejects():
    p = get_policy("gus")
    assert p.name == "gus" and get_policy(p) is p
    with pytest.raises(KeyError, match="unknown policy"):
        get_policy("definitely-not-registered")


def test_policy_kinds_partition_the_registry():
    kinds = {n: get_policy(n).kind for n in BUILTIN}
    assert kinds["gus"] == kinds["gus-ordered"] == "greedy"
    assert kinds["ilp"] == kinds["lp-bound"] == "oracle"
    assert {kinds["random"], kinds["offload_all"], kinds["local_all"]} == {"baseline"}
    assert {kinds["happy_computation"], kinds["happy_communication"]} == {"relaxed"}


def test_register_custom_policy_runs_in_simulator():
    name = "test-cheapest-edge"
    register_policy(Policy(
        name=name,
        description="everything on the covering edge (custom-policy smoke)",
        make=lambda n_edge, n_servers: get_policy("local_all").bind(n_edge, n_servers),
    ))
    try:
        r = simulate(small_spec(), small_cfg(), policy=name, seed=0)
        assert r.n_cloud == 0 and r.n_edge_offload == 0
    finally:
        POLICIES.pop(name, None)


def test_pad_false_policy_sees_unpadded_frames_in_both_paths():
    """A policy that opts out of the padding contract must receive raw frame
    sizes from simulate() AND from the fleet (which host-loops it)."""
    name = "test-unpadded-probe"
    seen = []

    def make(n_edge, n_servers):
        gus_fn = get_policy("gus").bind(n_edge, n_servers)

        def fn(inst):
            seen.append(int(inst.n_requests))
            return gus_fn(inst)

        return fn

    register_policy(Policy(name=name, description="pad=False probe", make=make, pad=False))
    try:
        r = simulate(small_spec(), small_cfg(), policy=name, seed=0)
        assert sum(seen) == r.n_served + r.n_dropped
        seen.clear()
        fr = simulate_fleet(small_spec(), small_cfg(), policy=name, n_rep=2, seed=0)
        assert sum(seen) == fr.n_requests  # raw buckets, no pow2 padding
    finally:
        POLICIES.pop(name, None)


def test_host_side_needs_key_policy_gets_keys_in_the_fleet():
    """The fleet's host-loop fallback must thread PRNG keys exactly like the
    vmapped path does (custom non-vmappable policies can need them too)."""
    name = "test-host-random"
    register_policy(Policy(
        name=name,
        description="random, forced onto the host loop",
        make=lambda n_edge, n_servers: get_policy("random").bind(n_edge, n_servers),
        needs_key=True,
        vmappable=False,
    ))
    try:
        fa = simulate_fleet(small_spec(), small_cfg(), policy=name, n_rep=2, seed=4)
        fb = simulate_fleet(small_spec(), small_cfg(), policy=name, n_rep=2, seed=4)
        np.testing.assert_allclose(fa.satisfied_per_rep, fb.satisfied_per_rep)
        assert np.isfinite(fa.satisfied_pct) and fa.n_served > 0
    finally:
        POLICIES.pop(name, None)


def test_scheduler_and_policy_are_mutually_exclusive():
    with pytest.raises(ValueError, match="not both"):
        simulate(small_spec(), small_cfg(), gus_schedule_np, policy="gus")


def test_policy_name_accepted_positionally():
    a = simulate(small_spec(), small_cfg(), "gus", seed=0).as_dict()
    b = simulate(small_spec(), small_cfg(), policy="gus", seed=0).as_dict()
    assert a == b


# ---------------------------------------------------------------------------
# Every policy x every scenario: one short run, finite stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", BUILTIN)
@pytest.mark.parametrize("scenario", sorted(["paper-default", "diurnal", "flash-crowd",
                                             "mobility", "hetero-tiers", "outage"]))
def test_every_policy_runs_every_scenario_without_nans(policy, scenario):
    assert scenario in list_scenarios()
    r = simulate(small_spec(), small_cfg(), policy=policy, scenario=scenario, seed=0)
    d = r.as_dict()
    assert all(np.isfinite(v) for v in d.values()), d
    assert r.n_served + r.n_dropped == r.n_requests
    assert r.n_local + r.n_cloud + r.n_edge_offload == r.n_served
    assert 0.0 <= r.satisfied_pct <= 100.0


@pytest.mark.parametrize("policy", BUILTIN)
def test_every_policy_runs_the_fleet(policy):
    fr = simulate_fleet(small_spec(), small_cfg(), policy=policy, n_rep=2, seed=0)
    assert np.isfinite(fr.satisfied_pct) and np.isfinite(fr.mean_us)
    assert 0.0 <= fr.satisfied_pct <= 100.0
    assert fr.n_served <= fr.n_requests


def test_random_policy_deterministic_given_seed_and_seed_sensitive():
    a = simulate(small_spec(), small_cfg(), policy="random", seed=7).as_dict()
    b = simulate(small_spec(), small_cfg(), policy="random", seed=7).as_dict()
    assert a == b
    fa = simulate_fleet(small_spec(), small_cfg(), policy="random", n_rep=2, seed=3)
    fb = simulate_fleet(small_spec(), small_cfg(), policy="random", n_rep=2, seed=3)
    np.testing.assert_allclose(fa.satisfied_per_rep, fb.satisfied_per_rep)


# ---------------------------------------------------------------------------
# Padded-batch parity vs small NumPy references
# ---------------------------------------------------------------------------


def _restricted_greedy_np(inst, server_mask):
    """NumPy reference for the mask-restricted greedy the jitted baselines
    implement: per request, best-US feasible (server, variant) within the
    allowed servers, capacities updating sequentially as in GUS."""
    us = np.asarray(us_tensor(inst))
    feas = np.asarray(hard_feasible(inst)) & server_mask[:, :, None]
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma).copy()
    eta = np.asarray(inst.eta).copy()
    N, M, L = us.shape
    out_j = np.full(N, -1, np.int32)
    out_l = np.full(N, -1, np.int32)
    for i in range(N):
        s_i = int(cover[i])
        ok = (
            feas[i]
            & (v[i] <= gamma[:, None])
            & ((np.arange(M) == s_i)[:, None] | (u[i] <= eta[s_i]))
        )
        if not ok.any():
            continue
        score = np.where(ok, us[i], -np.inf)
        j, l = np.unravel_index(np.argmax(score), (M, L))
        out_j[i], out_l[i] = j, l
        gamma[j] -= v[i, j, l]
        if j != s_i:
            eta[s_i] -= u[i, j, l]
    return out_j, out_l


def _mask_for(policy, inst, picks=None):
    N, M, _ = np.asarray(inst.acc).shape
    cover = np.asarray(inst.cover)
    if policy == "local_all":
        return cover[:, None] == np.arange(M)[None, :]
    if policy == "offload_all":
        return np.broadcast_to(np.arange(M)[None, :] >= TINY.n_edge, (N, M)).copy()
    if policy == "random":
        return np.eye(M, dtype=bool)[picks]
    raise AssertionError(policy)


@pytest.mark.parametrize("policy", ["local_all", "offload_all", "random"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_restricted_baselines_padded_parity_vs_numpy_reference(policy, seed):
    inst = generate_instance(seed, TINY)
    n = TINY.n_requests
    padded = pad_instance(inst, n + 3)
    fn = get_policy(policy).bind(TINY.n_edge, TINY.n_edge + TINY.n_cloud)
    if policy == "random":
        key = jax.random.PRNGKey(seed)
        picks = np.asarray(jax.random.randint(key, (n + 3,), 0, TINY.n_edge + TINY.n_cloud))
        assign = fn(padded, key)
        ref_j, ref_l = _restricted_greedy_np(inst, _mask_for(policy, inst, picks[:n]))
    else:
        assign = fn(padded)
        ref_j, ref_l = _restricted_greedy_np(inst, _mask_for(policy, inst))
    np.testing.assert_array_equal(np.asarray(assign.j)[:n], ref_j)
    np.testing.assert_array_equal(np.asarray(assign.l)[:n], ref_l)
    # padded rows are always dropped
    assert (np.asarray(assign.j)[n:] == -1).all()
    assert (np.asarray(assign.l)[n:] == -1).all()


@pytest.mark.parametrize("policy,relax", [
    ("happy_computation", {"gamma": True}),
    ("happy_communication", {"eta": True}),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_relaxed_baselines_padded_parity_vs_numpy_oracle(policy, relax, seed):
    """Happy-* == plain GUS on an instance whose relaxed capacity is infinite,
    so the NumPy GUS oracle on that instance is their reference."""
    inst = generate_instance(seed, TINY)
    relaxed = dataclasses.replace(
        inst,
        gamma=jnp.full_like(inst.gamma, np.inf) if "gamma" in relax else inst.gamma,
        eta=jnp.full_like(inst.eta, np.inf) if "eta" in relax else inst.eta,
    )
    ref = gus_schedule_np(relaxed)
    n = TINY.n_requests
    fn = get_policy(policy).bind(TINY.n_edge, TINY.n_edge + TINY.n_cloud)
    assign = fn(pad_instance(inst, n + 2))
    np.testing.assert_array_equal(np.asarray(assign.j)[:n], np.asarray(ref.j))
    np.testing.assert_array_equal(np.asarray(assign.l)[:n], np.asarray(ref.l))
    assert (np.asarray(assign.j)[n:] == -1).all()


# ---------------------------------------------------------------------------
# ILP oracle policy
# ---------------------------------------------------------------------------


def test_ilp_policy_matches_solve_bnb_and_dominates_gus():
    inst = generate_instance(0, TINY)
    fn = get_policy("ilp").bind(TINY.n_edge, TINY.n_edge + TINY.n_cloud)
    a = fn(inst)
    _, opt = solve_bnb(inst)
    got = float(mean_us(inst, jnp.asarray(np.asarray(a.j)), jnp.asarray(np.asarray(a.l))))
    assert got == pytest.approx(opt, abs=1e-5)
    g = gus_schedule(inst)
    assert got >= float(mean_us(inst, g.j, g.l)) - 1e-6


def test_ilp_policy_refuses_oversized_frames():
    big = GeneratorConfig(n_requests=40, n_edge=2, n_cloud=1, n_services=3, n_variants=2)
    inst = generate_instance(0, big)
    fn = get_policy("ilp").bind(2, 3)
    with pytest.raises(ValueError, match="refuses"):
        fn(inst)


# ---------------------------------------------------------------------------
# The paper's headline ordering on the paper-default scenario
# ---------------------------------------------------------------------------


def test_gus_beats_every_restricted_baseline_on_paper_default():
    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=30_000.0, arrival_rate_per_s=3.0,
        delay_req_ms=6000.0, acc_req_mean=50.0, acc_req_std=10.0,
    )
    sat = {
        pol: simulate_fleet(spec, cfg, policy=pol, n_rep=4, seed=0).satisfied_pct
        for pol in ("gus", "random", "offload_all", "local_all")
    }
    for baseline in ("random", "offload_all", "local_all"):
        assert sat["gus"] >= sat[baseline], sat
