"""Unit + property tests for the paper's core: US metric, GUS, ILP, baselines.

The deterministic tests always run; only the Hypothesis property tests at
the bottom are gated on the optional dev dependency (requirements-dev.txt)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional dev dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    GeneratorConfig,
    generate_instance,
    generate_batch,
    gus_schedule,
    gus_schedule_batch,
    gus_schedule_np,
    hard_feasible,
    local_all,
    mean_us,
    offload_all,
    random_assignment,
    satisfied_mask,
    solve_bnb,
    solve_exhaustive,
    us_tensor,
    happy_computation,
    happy_communication,
)

TINY = GeneratorConfig(n_requests=5, n_edge=2, n_cloud=1, n_services=3, n_variants=2)
SMALL = GeneratorConfig(n_requests=30, n_edge=4, n_cloud=1, n_services=10, n_variants=4)


def _cap_ok(inst, assign):
    """Capacity constraints (2d)/(2e) hold for an assignment."""
    j = np.asarray(assign.j)
    l = np.asarray(assign.l)
    v = np.asarray(inst.v)
    u = np.asarray(inst.u)
    cover = np.asarray(inst.cover)
    gamma = np.asarray(inst.gamma).copy()
    eta = np.asarray(inst.eta).copy()
    for i in range(len(j)):
        if j[i] < 0:
            continue
        gamma[j[i]] -= v[i, j[i], l[i]]
        if j[i] != cover[i]:
            eta[cover[i]] -= u[i, j[i], l[i]]
    return (gamma >= -1e-4).all() and (eta >= -1e-4).all()


def _qos_ok(inst, assign):
    """(2b)/(2c): every served request meets its accuracy floor and deadline."""
    j = np.asarray(assign.j)
    l = np.asarray(assign.l)
    acc = np.asarray(inst.acc)
    ct = np.asarray(inst.ctime)
    A = np.asarray(inst.A)
    C = np.asarray(inst.C)
    avail = np.asarray(inst.avail)
    for i in range(len(j)):
        if j[i] < 0:
            continue
        if not avail[i, j[i], l[i]]:
            return False
        if acc[i, j[i], l[i]] < A[i] - 1e-5 or ct[i, j[i], l[i]] > C[i] + 1e-3:
            return False
    return True


# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_gus_jax_matches_numpy(seed):
    inst = generate_instance(seed)
    a = gus_schedule_np(inst)
    b = gus_schedule(inst)
    np.testing.assert_array_equal(np.asarray(a.j), np.asarray(b.j))
    np.testing.assert_array_equal(np.asarray(a.l), np.asarray(b.l))


@pytest.mark.parametrize("seed", range(8))
def test_gus_respects_constraints(seed):
    inst = generate_instance(seed, SMALL)
    a = gus_schedule(inst)
    assert _cap_ok(inst, a)
    assert _qos_ok(inst, a)
    # every served request is satisfied (hard-constraint form)
    sat = np.asarray(satisfied_mask(inst, a.j, a.l))
    served = np.asarray(a.j) >= 0
    assert (sat == served).all()


@pytest.mark.parametrize("seed", range(6))
def test_bnb_matches_exhaustive(seed):
    inst = generate_instance(seed, TINY)
    _, vb = solve_bnb(inst)
    _, ve = solve_exhaustive(inst)
    assert abs(vb - ve) < 1e-6


@pytest.mark.parametrize("seed", range(10))
def test_gus_near_optimal(seed):
    """Paper claim: GUS achieves ~90% of the CPLEX optimum on average."""
    cfg = GeneratorConfig(n_requests=8, n_edge=3, n_cloud=1, n_services=4, n_variants=3)
    inst = generate_instance(seed + 100, cfg)
    _, opt = solve_bnb(inst)
    a = gus_schedule(inst)
    g = float(mean_us(inst, a.j, a.l))
    assert g <= opt + 1e-6  # greedy can never beat the optimum
    if opt > 1e-6:
        assert g / opt > 0.6  # per-instance floor; the ~0.9 average is in benches


def test_gus_dominates_baselines_on_average():
    vals = {"gus": [], "local": [], "offload": [], "random": []}
    cloud_mask = None
    for seed in range(10):
        inst = generate_instance(seed)
        if cloud_mask is None:
            cloud_mask = jnp.arange(inst.n_servers) >= 9
        for name, a in [
            ("gus", gus_schedule(inst)),
            ("local", local_all(inst)),
            ("offload", offload_all(inst, cloud_mask)),
            ("random", random_assignment(inst, jax.random.PRNGKey(seed))),
        ]:
            vals[name].append(float(satisfied_mask(inst, a.j, a.l).sum()))
    gus = np.mean(vals["gus"])
    for name in ("local", "offload", "random"):
        assert gus >= np.mean(vals[name]), (name, vals)


def test_relaxed_variants_dominate():
    """Happy-* relax a constraint so can only serve more or equal requests."""
    for seed in range(5):
        inst = generate_instance(seed, SMALL)
        base = float(mean_us(inst, *_jl(gus_schedule(inst))))
        hc = float(mean_us(inst, *_jl(happy_computation(inst))))
        hm = float(mean_us(inst, *_jl(happy_communication(inst))))
        assert hc >= base - 1e-5
        assert hm >= base - 1e-5


def _jl(a):
    return a.j, a.l


def test_vmapped_batch_matches_loop():
    batch = generate_batch(0, 4, SMALL)
    out = gus_schedule_batch(batch)
    for i in range(4):
        inst = generate_instance(i, SMALL)
        single = gus_schedule(inst)
        np.testing.assert_array_equal(np.asarray(out.j[i]), np.asarray(single.j))


# ---------------------------------------------------------------------------
# property tests (hypothesis widens the seed space when installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_constraints_hold(seed):
        inst = generate_instance(seed, SMALL)
        a = gus_schedule(inst)
        assert _cap_ok(inst, a)
        assert _qos_ok(inst, a)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), scale=st.floats(0.2, 3.0))
    def test_property_more_capacity_never_hurts(seed, scale):
        """Scaling all capacities up can only increase total satisfaction."""
        import dataclasses as dc

        inst = generate_instance(seed, TINY)
        bigger = dc.replace(
            inst,
            gamma=inst.gamma * (1 + scale),
            eta=inst.eta * (1 + scale),
        )
        _, v1 = solve_bnb(inst)
        _, v2 = solve_bnb(bigger)
        assert v2 >= v1 - 1e-9

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_us_definition(seed):
        """US decomposes into the two normalized head-room terms (Eq. 1)."""
        inst = generate_instance(seed, TINY)
        us = np.asarray(us_tensor(inst))
        acc_term = (np.asarray(inst.acc) - np.asarray(inst.A)[:, None, None]) / float(inst.max_as)
        t_term = (np.asarray(inst.C)[:, None, None] - np.asarray(inst.ctime)) / float(inst.max_cs)
        np.testing.assert_allclose(us, acc_term + t_term, rtol=1e-5, atol=1e-5)
        # feasible assignments always have nonnegative US under hard constraints
        feas = np.asarray(hard_feasible(inst))
        assert (us[feas] >= -1e-6).all()
