"""Three-way parity for the hierarchical device allocator.

``repro.core.aggregation`` ships the analytic class allocator three ways —
a NumPy f32 oracle (:func:`hier_cells_np`), a jitted XLA scan, and a fused
Pallas kernel — and the contract is **bitwise integer equality** of the
``(take, start)`` cell tensors across all three, mirroring the dense GUS
harness in ``tests/test_gus_parity.py``:

* scenario-captured class instances (generated frames, tiled duplicate
  blocks) agree across every backend;
* every padding bucket agrees, and zero-count padding rows never allocate
  or touch the budgets;
* tie frames, all-infeasible frames, and exact-capacity chunk edges hit
  the same branch on every backend (first-occurrence argmax, f32 floor
  division);
* ``hier_assign(exact=False)`` is a faithful chunk-list view of the cell
  tensors (never over-allocates, allocation-ordered);
* backend dispatch (``hier_backend_fn``) returns stable identities and
  honors ``REPRO_GUS_BACKEND``;
* fleet level: the device hierarchical path composes with admission
  control and link impairments, matching the dense fleet *exactly* on
  singleton-class scenarios (continuous QoS draws) and on contiguous
  duplicate classes with lossless class means — and XLA vs Pallas fleet
  runs are bit-identical end to end;
* per-member realized impairment accounting is pinned by a golden fixture
  (``tests/fixtures/hier_member_golden.npz``).
"""
from __future__ import annotations

import dataclasses
import math
import pathlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    CongestionConfig,
    EngineOptions,
    Scenario,
    SimConfig,
    aggregate_instance,
    demo_cluster_spec,
    generate_instance,
    get_scenario,
    hier_assign,
    hier_backend_fn,
    hier_cells,
    hier_cells_np,
    simulate_fleet,
)
from repro.core.impairments import (  # noqa: E402
    AdmissionConfig,
    BurstyLossLink,
    ImpairmentConfig,
    IntermittentLink,
)
from repro.core.instance import FlatInstance, GeneratorConfig  # noqa: E402

SPEC = demo_cluster_spec()
FIXTURES = pathlib.Path(__file__).parent / "fixtures"

SMALL = GeneratorConfig(n_requests=24, n_edge=4, n_cloud=1, n_services=6,
                        n_variants=4)

#: every implementation of the analytic allocator, by dispatch name
HIER_IMPLS = ("np", "xla", "pallas")

#: padding buckets exercised by the fleet path (``_pad_bucket``)
BUCKETS = (4, 8, 16, 32, 64, 128)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _class_args(agg, gamma, eta, pad_to=None):
    """Sort class rows by ``first_idx`` (the order the fleet feeds the
    device allocator) and optionally pad with zero-count rows."""
    o = np.argsort(agg.first_idx, kind="stable")
    us, feas = agg.us[o], agg.feas[o]
    v, u = agg.v[o], agg.u[o]
    cover = agg.cover[o].astype(np.int32)
    count = agg.count[o].astype(np.int32)
    if pad_to is not None and pad_to > us.shape[0]:
        pad = pad_to - us.shape[0]
        zc = np.zeros((pad,) + us.shape[1:], us.dtype)
        us = np.concatenate([us, zc])
        feas = np.concatenate([feas, np.zeros_like(zc, bool)])
        v = np.concatenate([v, zc])
        u = np.concatenate([u, zc])
        cover = np.concatenate([cover, np.zeros(pad, np.int32)])
        count = np.concatenate([count, np.zeros(pad, np.int32)])
    return (us, feas, v, u, cover, count,
            np.asarray(gamma, np.float32), np.asarray(eta, np.float32))


def _run_impl(impl, args):
    if impl == "np":
        take, start = hier_cells_np(*args)
    else:
        take, start = hier_cells(*args, backend=impl)
    return np.asarray(take), np.asarray(start)


def three_way(args, label=""):
    """Assert bitwise (take, start) equality across all backends; return
    the oracle's tensors."""
    ref_take, ref_start = _run_impl("np", args)
    for impl in HIER_IMPLS[1:]:
        take, start = _run_impl(impl, args)
        np.testing.assert_array_equal(
            take, ref_take, err_msg=f"{label}: take np vs {impl}")
        np.testing.assert_array_equal(
            start, ref_start, err_msg=f"{label}: start np vs {impl}")
    return ref_take, ref_start


def tile_instance(inst: FlatInstance, k: int) -> FlatInstance:
    rep = lambda x: np.repeat(np.asarray(x), k, axis=0)  # noqa: E731
    return dataclasses.replace(
        inst,
        cover=rep(inst.cover), A=rep(inst.A), C=rep(inst.C),
        w_a=rep(inst.w_a), w_c=rep(inst.w_c),
        acc=rep(inst.acc), ctime=rep(inst.ctime), v=rep(inst.v),
        u=rep(inst.u), avail=rep(inst.avail),
    )


# ---------------------------------------------------------------------------
# allocator-level three-way parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_generated_instances_three_way(seed):
    inst = generate_instance(seed, as_numpy=True)
    agg = aggregate_instance(inst)
    args = _class_args(agg, np.asarray(inst.gamma), np.asarray(inst.eta))
    take, _ = three_way(args, f"seed={seed}")
    per_class = take.sum(axis=(1, 2))
    assert np.all(per_class <= args[5])  # never over-allocates a class


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("k", [2, 5])
def test_duplicate_class_instances_three_way(seed, k):
    inst = tile_instance(generate_instance(seed, SMALL, as_numpy=True), k)
    agg = aggregate_instance(inst)
    args = _class_args(agg, np.asarray(inst.gamma), np.asarray(inst.eta))
    three_way(args, f"dup seed={seed} k={k}")


@pytest.mark.parametrize("bucket", BUCKETS)
def test_padding_buckets_three_way(bucket):
    cfg = dataclasses.replace(SMALL, n_requests=max(2, (3 * bucket) // 4))
    inst = generate_instance(1, cfg, as_numpy=True)
    agg = aggregate_instance(inst)
    assert 0 < agg.n_classes <= bucket
    gamma, eta = np.asarray(inst.gamma), np.asarray(inst.eta)
    bare = _class_args(agg, gamma, eta)
    padded = _class_args(agg, gamma, eta, pad_to=bucket)
    take_b, start_b = three_way(bare, f"bucket={bucket} bare")
    take_p, start_p = three_way(padded, f"bucket={bucket} padded")
    n_c = agg.n_classes
    # padding rows never allocate, never shift the real rows' result
    np.testing.assert_array_equal(take_p[:n_c], take_b)
    np.testing.assert_array_equal(start_p[:n_c], start_b)
    assert take_p[n_c:].sum() == 0 and start_p[n_c:].sum() == 0


def _degenerate(us, feas, v, u, cover, count, gamma, eta):
    return (
        np.asarray(us, np.float32), np.asarray(feas, bool),
        np.asarray(v, np.float32), np.asarray(u, np.float32),
        np.asarray(cover, np.int32), np.asarray(count, np.int32),
        np.asarray(gamma, np.float32), np.asarray(eta, np.float32),
    )


def test_tie_frames_pick_first_flat_cell():
    # constant utility everywhere: every backend must break ties at the
    # first occurrence on the flat j*L + l axis
    C, M, L = 3, 4, 2
    args = _degenerate(
        np.ones((C, M, L)), np.ones((C, M, L), bool),
        np.ones((C, M, L)), np.ones((C, M, L)),
        np.zeros(C), np.full(C, 2),
        np.full(M, 1e6), np.full(M, 1e6),
    )
    take, start = three_way(args, "ties")
    assert np.all(take[:, 0, 0] == 2)       # cell (0, 0) wins every tie
    assert take.sum() == 3 * 2
    np.testing.assert_array_equal(start, np.zeros_like(start))


def test_all_infeasible_and_zero_count_rows():
    C, M, L = 4, 3, 2
    feas = np.ones((C, M, L), bool)
    feas[1] = False                          # class 1: nowhere to go
    count = np.array([3, 3, 0, 3])           # class 2: padding row
    args = _degenerate(
        np.random.default_rng(0).uniform(0, 1, (C, M, L)), feas,
        np.ones((C, M, L)), np.ones((C, M, L)),
        np.zeros(C), count, np.full(M, 1e6), np.full(M, 1e6),
    )
    take, _ = three_way(args, "infeasible/zero-count")
    assert take[1].sum() == 0 and take[2].sum() == 0
    assert take[0].sum() == 3 and take[3].sum() == 3


def test_exact_capacity_chunk_edges():
    # gamma fits exactly 2 of 3 members at the only feasible local cell
    M, L = 2, 1
    us = np.array([[[1.0], [0.5]]])
    feas = np.array([[[True], [False]]])
    args = _degenerate(
        us, feas, np.ones((1, M, L)), np.zeros((1, M, L)),
        [0], [3], [2.0, 0.0], [1e6, 1e6],
    )
    take, _ = three_way(args, "gamma-bound")
    assert int(take[0, 0, 0]) == 2 and take.sum() == 2

    # eta binds an offload cell: floor(2.5 / 1.0) = 2 of 3 members ship
    feas = np.array([[[False], [True]]])
    args = _degenerate(
        us, feas, np.ones((1, M, L)),
        np.ones((1, M, L)), [0], [3], [1e6, 1e6], [2.5, 1e6],
    )
    take, _ = three_way(args, "eta-bound")
    assert int(take[0, 1, 0]) == 2 and take.sum() == 2


def test_budget_carries_across_classes():
    # two identical classes compete for gamma[0] = 3: first (by order)
    # takes 3, second is pushed to the worse cell
    M, L = 2, 1
    us = np.tile(np.array([[[1.0], [0.4]]]), (2, 1, 1))
    args = _degenerate(
        us, np.ones((2, M, L), bool), np.ones((2, M, L)),
        np.zeros((2, M, L)), [0, 0], [3, 2], [3.0, 1e6], [1e6, 1e6],
    )
    take, _ = three_way(args, "carry")
    assert int(take[0, 0, 0]) == 3
    assert int(take[1, 0, 0]) == 0 and int(take[1, 1, 0]) == 2


def test_hier_assign_analytic_is_cell_view():
    """``hier_assign(exact=False)`` must be exactly the chunk-list view of
    the cell tensors: same totals per (class, cell), allocation-ordered,
    never over-allocating."""
    inst = tile_instance(generate_instance(2, SMALL, as_numpy=True), 3)
    agg = aggregate_instance(inst)
    gamma, eta = np.asarray(inst.gamma), np.asarray(inst.eta)
    chunks = hier_assign(agg, gamma, eta, exact=False)
    take, _ = hier_cells_np(*_class_args(agg, gamma, eta))
    o = np.argsort(agg.first_idx, kind="stable")
    totals = np.zeros_like(take)
    taken = np.zeros(agg.n_classes, np.int64)
    rank = np.empty(agg.n_classes, np.int64)
    rank[o] = np.arange(agg.n_classes)
    for c, j, l, t in chunks:
        totals[rank[c], j, l] += t
        taken[c] += t
    np.testing.assert_array_equal(totals, take)
    assert np.all(taken <= agg.count)


def test_backend_dispatch_plumbing(monkeypatch):
    from repro.core.aggregation import _hier_cells_xla

    # stable identities per resolved backend — the fleet runner's compile
    # cache keys on them
    assert hier_backend_fn() is hier_backend_fn("xla")
    assert hier_backend_fn() is _hier_cells_xla
    assert hier_backend_fn("pallas") is hier_backend_fn("pallas")
    assert hier_backend_fn("pallas") is not hier_backend_fn("xla")
    # env default steers the None resolution, explicit still wins
    monkeypatch.setenv("REPRO_GUS_BACKEND", "pallas")
    assert hier_backend_fn() is hier_backend_fn("pallas")
    assert hier_backend_fn("xla") is _hier_cells_xla
    with pytest.raises(ValueError):
        hier_backend_fn("cuda-graphs")


# ---------------------------------------------------------------------------
# fleet-level parity: admission, impairments, backends
# ---------------------------------------------------------------------------

def fleet_cfg(**kw) -> SimConfig:
    base = dict(
        horizon_ms=12_000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=False),
    )
    base.update(kw)
    return SimConfig(**base)


def _pair(cfg, *, scenario="paper-default", spec=SPEC, n_rep=2, seed=0,
          backend=None):
    """(dense, hier) fleet runs of the same trace with metrics on."""
    dense = simulate_fleet(
        spec, cfg, policy="gus", scenario=scenario, n_rep=n_rep, seed=seed,
        options=EngineOptions(metrics=True),
    )
    hier = simulate_fleet(
        spec, cfg, policy="gus", scenario=scenario, n_rep=n_rep, seed=seed,
        options=EngineOptions(scheduler="hierarchical", metrics=True,
                              backend=backend),
    )
    return dense, hier


def _assert_fleet_match(dense, hier, *, us_rtol=1e-6):
    assert hier.n_requests == dense.n_requests
    assert hier.n_served == dense.n_served
    np.testing.assert_array_equal(
        np.asarray(hier.satisfied_per_rep), np.asarray(dense.satisfied_per_rep))
    np.testing.assert_allclose(
        np.asarray(hier.mean_us_per_rep), np.asarray(dense.mean_us_per_rep),
        rtol=us_rtol)
    da, ha = dense.metrics.aggregate(), hier.metrics.aggregate()
    for key in ("n_arrivals", "n_served", "n_satisfied", "n_shed", "n_refused"):
        assert ha[key] == da[key], (key, ha[key], da[key])


def test_admission_shed_matches_dense_on_singletons():
    """delay_req < frame: early arrivals are provably late and must shed.
    Congestion off makes admission a pure deadline check, so the
    class-level shed on singleton classes is bit-identical to the dense
    per-request shed."""
    cfg = fleet_cfg(delay_req_ms=2500.0,
                    admission=AdmissionConfig(enabled=True, shed=True))
    dense, hier = _pair(cfg)
    _assert_fleet_match(dense, hier)
    agg = hier.metrics.aggregate()
    assert agg["n_shed"] > 0                       # the regime actually sheds
    assert agg["n_shed"] < agg["n_arrivals"]       # ... but not everything


def test_admission_queue_cap_matches_dense_on_singletons():
    """queue_cap_mult=0 refuses every assignment on both paths — the
    degenerate regime that exercises the post-allocation refusal lane."""
    cfg = fleet_cfg(admission=AdmissionConfig(enabled=True,
                                              queue_cap_mult=0.0))
    dense, hier = _pair(cfg)
    _assert_fleet_match(dense, hier)
    agg = hier.metrics.aggregate()
    assert agg["n_refused"] > 0
    assert agg["n_satisfied"] == 0                 # nothing survives a 0-cap


def test_plain_singleton_fleet_is_bitwise():
    dense, hier = _pair(fleet_cfg())
    _assert_fleet_match(dense, hier)


# -- duplicate classes: a trace whose class means are lossless --------------

@dataclasses.dataclass(frozen=True)
class _FrameSnappedDup(Scenario):
    """Paper workload with every arrival snapped to its frame start and
    duplicated ``dup`` times.

    With ``acc_req_std=0`` and ``req_size_lo == req_size_hi`` every request
    that lands in one frame with the same (cover, service) is *identical*,
    so the class-mean representatives equal every member exactly — the
    lossless-duplicate regime where the hierarchical fleet must match the
    dense fleet bit for bit (given ample capacity, so the greedy never
    binds mid-class).
    """

    name: str = "frame-snapped-dup"
    dup: int = 3

    def generate_arrivals(self, rng, n_edge, n_services, cfg, rng_mode=None):
        base = super().generate_arrivals(
            rng, n_edge, n_services, cfg, rng_mode=rng_mode)
        out = []
        for r in base:
            snap = float(math.floor(r.arrival_ms / cfg.frame_ms) * cfg.frame_ms)
            for _ in range(self.dup):
                out.append(dataclasses.replace(r, arrival_ms=snap))
        out.sort(key=lambda r: r.arrival_ms)
        for i, r in enumerate(out):
            r.rid = i
        return out


def _ample_spec():
    """demo cluster with budgets scaled far past the offered load, so the
    allocation order (per-request vs per-class) can never matter."""
    return dataclasses.replace(
        SPEC,
        gamma_frame=np.asarray(SPEC.gamma_frame) * 200.0,
        eta_frame=np.asarray(SPEC.eta_frame) * 200.0,
    )


def _dup_cfg(**kw) -> SimConfig:
    base = dict(
        horizon_ms=12_000.0,
        arrival_rate_per_s=3.0,
        delay_req_ms=6000.0,
        acc_req_std=0.0,                 # exact class means
        req_size_lo=65_536.0,
        req_size_hi=65_536.0,            # exact class means
        congestion=CongestionConfig(enabled=False),
    )
    base.update(kw)
    return SimConfig(**base)


_IMPAIRED = ImpairmentConfig(
    enabled=True,
    link_profiles=(IntermittentLink(), BurstyLossLink()),
    seed=7,
)


def test_duplicate_classes_match_dense_bitwise():
    dense, hier = _pair(_dup_cfg(), scenario=_FrameSnappedDup(),
                        spec=_ample_spec())
    assert dense.n_requests % 3 == 0 and dense.n_requests > 0
    _assert_fleet_match(dense, hier)


def test_duplicate_classes_impaired_match_dense_bitwise():
    """Per-member realized link impairments: the deaggregated member
    accounting must reproduce the dense impaired simulator exactly on
    contiguous-duplicate classes."""
    cfg = _dup_cfg(delay_req_ms=3300.0, impairments=_IMPAIRED)
    dense, hier = _pair(cfg, scenario=_FrameSnappedDup(), spec=_ample_spec())
    _assert_fleet_match(dense, hier)
    # the impairments must actually bite for this to mean anything
    plain, _ = _pair(_dup_cfg(delay_req_ms=3300.0),
                     scenario=_FrameSnappedDup(), spec=_ample_spec())
    moved = (
        (np.asarray(dense.satisfied_per_rep)
         != np.asarray(plain.satisfied_per_rep)).any()
        or not np.allclose(np.asarray(dense.mean_us_per_rep),
                           np.asarray(plain.mean_us_per_rep))
    )
    assert moved, "impairment stream left the run untouched"


def test_fleet_xla_vs_pallas_bitwise():
    """The two device backends must produce bit-identical fleet results —
    admission, impairments, and congestion all on."""
    cfg = fleet_cfg(
        delay_req_ms=4000.0,
        admission=AdmissionConfig(enabled=True, shed=True),
        impairments=_IMPAIRED,
        congestion=CongestionConfig(enabled=True),
    )
    runs = {}
    for backend in ("xla", "pallas"):
        runs[backend] = simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=2, seed=0,
            options=EngineOptions(scheduler="hierarchical", metrics=True,
                                  backend=backend),
        )
    x, p = runs["xla"], runs["pallas"]
    assert x.n_served == p.n_served
    np.testing.assert_array_equal(
        np.asarray(x.satisfied_per_rep), np.asarray(p.satisfied_per_rep))
    np.testing.assert_array_equal(
        np.asarray(x.mean_us_per_rep), np.asarray(p.mean_us_per_rep))
    np.testing.assert_array_equal(
        np.asarray(x.final_backlog_per_rep), np.asarray(p.final_backlog_per_rep))
    xa, pa = x.metrics.aggregate(), p.metrics.aggregate()
    for key in ("n_shed", "n_refused", "n_satisfied"):
        assert xa[key] == pa[key], key


def test_device_path_matches_host_loop_fallback(monkeypatch):
    """REPRO_HIER_HOST_LOOP=1 resurrects the PR-9 host loop; on a
    singleton-class scenario with everything off, the two pipelines
    agree on the integer accounting."""
    cfg = fleet_cfg()
    device = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=2, seed=0,
        options=EngineOptions(scheduler="hierarchical"),
    )
    monkeypatch.setenv("REPRO_HIER_HOST_LOOP", "1")
    host = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=2, seed=0,
        options=EngineOptions(scheduler="hierarchical"),
    )
    assert device.n_requests == host.n_requests
    assert device.n_served == host.n_served
    np.testing.assert_array_equal(
        np.asarray(device.satisfied_per_rep),
        np.asarray(host.satisfied_per_rep))


def test_mega_city_with_admission_and_impairments():
    """The previously-impossible composition: city-scale hierarchical
    fleet with admission and impairments both enabled."""
    spec = demo_cluster_spec(n_edge=6, n_cloud=1, n_services=5, n_variants=10)
    cfg = SimConfig(
        horizon_ms=9_000.0,
        admission=AdmissionConfig(enabled=True, shed=True),
        impairments=_IMPAIRED,
    )
    scn = dataclasses.replace(get_scenario("mega-city"),
                              rate_per_edge_per_s=60.0)
    fr = simulate_fleet(
        spec, cfg, policy="gus", scenario=scn, n_rep=1, seed=0,
        options=EngineOptions(scheduler="hierarchical", window=1,
                              metrics=True),
    )
    assert fr.n_requests > 0
    assert np.isfinite(np.asarray(fr.satisfied_per_rep)).all()
    for k, v in fr.metrics.aggregate().items():
        assert np.isfinite(np.asarray(v, np.float64)).all(), k


# ---------------------------------------------------------------------------
# golden fixture: per-member realized impairment accounting
# ---------------------------------------------------------------------------

def golden_run():
    """The pinned run: impaired duplicate-class hierarchical fleet.

    Shared with ``tests/fixtures/make_hier_golden.py`` (which loads this
    module by path), so the fixture and the test can never run different
    configurations.  The deadline sits close to the frame length, so the
    impairment stream's latency spikes actually decide satisfaction — the
    fixture pins a non-trivial per-member outcome profile.
    """
    return simulate_fleet(
        _ample_spec(), _dup_cfg(delay_req_ms=3300.0, impairments=_IMPAIRED),
        policy="gus", scenario=_FrameSnappedDup(), n_rep=2, seed=0,
        options=EngineOptions(scheduler="hierarchical"),
    )


def test_member_jitter_golden_fixture():
    """Pin the impaired duplicate-class hier fleet against a committed
    fixture (regenerate with ``PYTHONPATH=src python
    tests/fixtures/make_hier_golden.py``) so silent drift in the
    per-member deaggregation accounting fails loudly."""
    path = FIXTURES / "hier_member_golden.npz"
    if not path.exists():
        pytest.fail(f"missing fixture {path}; regenerate with "
                    "`PYTHONPATH=src python tests/fixtures/make_hier_golden.py`")
    fr = golden_run()
    g = np.load(path)
    assert int(g["n_requests"]) == fr.n_requests
    assert int(g["n_served"]) == fr.n_served
    np.testing.assert_array_equal(
        g["satisfied_per_rep"], np.asarray(fr.satisfied_per_rep))
    np.testing.assert_allclose(
        g["mean_us_per_rep"], np.asarray(fr.mean_us_per_rep), rtol=1e-6)
