"""Resilience-layer integration tests against the virtual testbed.

Three contracts are pinned here:

* **inertness** — with impairments / admission disabled (or enabled at
  identity settings) results are *bitwise identical* to a run that never
  heard of the resilience layer;
* **parity** — with impairments, outages and admission control all active,
  the windowed / prefetched / streaming / vectorized-rng / sharded fleet
  paths still agree bitwise with the materialized single-device run;
* **behaviour** — impairments hurt, outages are accounted, backlog
  conservation closes across outages and drains on recovery, shedding
  never drops a satisfiable request, and protection strictly helps an
  overcommitting policy on the composite overload regime while leaving
  capacity-honoring GUS untouched.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    AdmissionConfig,
    CongestionConfig,
    ImpairmentConfig,
    IntermittentLink,
    SatelliteLink,
    SimConfig,
    demo_cluster_spec,
    simulate,
    simulate_fleet,
)
from repro.core.scenarios import (  # noqa: E402
    FlashCrowdOutageScenario,
    OutageScenario,
    get_scenario,
)

SPEC = demo_cluster_spec()

IMPAIRED = ImpairmentConfig(
    enabled=True, link_profiles=(IntermittentLink(), SatelliteLink()), seed=3,
)
OUTAGES = ImpairmentConfig(
    enabled=True, outage_mtbf_frames=6.0, outage_mttr_frames=3.0,
    outage_servers=(1,), seed=3,
)
FULL = ImpairmentConfig(
    enabled=True, link_profiles=(IntermittentLink(),), seed=3,
    outage_mtbf_frames=6.0, outage_mttr_frames=3.0, outage_servers=(1,),
)
PROTECTED = AdmissionConfig(enabled=True, queue_cap_mult=1.0, shed=True)

#: the tuned composite overload regime (see benchmarks/paper_figures.py):
#: flash crowd + server outage in the same window, inflation in the range
#: where admission control actually changes outcomes
COMPOSITE = FlashCrowdOutageScenario(
    burst_mult=3.0, burst_start_frac=0.2, burst_end_frac=0.4,
    outage_start_frac=0.2, outage_end_frac=0.4,
)


def cfg(rate=2.0, horizon_ms=12_000.0, **kw):
    return SimConfig(
        horizon_ms=horizon_ms,
        arrival_rate_per_s=rate,
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=50.0,
        acc_req_std=10.0,
        **kw,
    )


def _serial(c, policy="gus", scenario="paper-default", **kw):
    return simulate(SPEC, c, policy=policy, scenario=scenario, seed=0, **kw)


def _fleet(c, policy="gus", scenario="paper-default", n_rep=2, **kw):
    return simulate_fleet(SPEC, c, policy=policy, scenario=scenario,
                          n_rep=n_rep, seed=0, **kw)


def _assert_fleet_equal(a, b):
    np.testing.assert_array_equal(a.satisfied_per_rep, b.satisfied_per_rep)
    np.testing.assert_array_equal(a.mean_us_per_rep, b.mean_us_per_rep)
    assert a.n_served == b.n_served and a.n_requests == b.n_requests


# ---------------------------------------------------------------------------
# inertness: disabled / identity-settings runs are bitwise clean
# ---------------------------------------------------------------------------


def test_disabled_resilience_is_bitwise_inert_serial():
    base = _serial(cfg())
    off = _serial(cfg(impairments=ImpairmentConfig(), admission=AdmissionConfig()))
    assert base.as_dict() == off.as_dict()
    assert off.resilience_stats is None


def test_disabled_resilience_is_bitwise_inert_fleet():
    _assert_fleet_equal(_fleet(cfg()), _fleet(cfg(
        impairments=ImpairmentConfig(), admission=AdmissionConfig())))


def test_amplitude_zero_is_bitwise_inert_serial():
    """Enabled engine at amplitude 0: every frame draws the trace, blends to
    exact identity values, and the run stays bit-identical."""
    zero = ImpairmentConfig(enabled=True, amplitude=0.0,
                            link_profiles=IMPAIRED.link_profiles, seed=3)
    base = _serial(cfg())
    amp0 = _serial(cfg(impairments=zero))
    assert base.as_dict() == amp0.as_dict()
    assert amp0.resilience_stats is not None  # engine ran, accounting exists


def test_amplitude_zero_is_bitwise_inert_fleet():
    zero = ImpairmentConfig(enabled=True, amplitude=0.0,
                            link_profiles=IMPAIRED.link_profiles, seed=3)
    _assert_fleet_equal(_fleet(cfg()), _fleet(cfg(impairments=zero)))


def test_admission_defaults_are_bitwise_inert():
    # enabled, but inf queue cap + no shedding == identity
    base = _serial(cfg())
    on = _serial(cfg(admission=AdmissionConfig(enabled=True)))
    assert base.as_dict() == on.as_dict()
    assert on.resilience_stats == {
        "n_shed": 0.0, "n_refused": 0.0, "frames_with_down_server": 0.0,
    }


def test_shed_without_congestion_is_noop_for_gus():
    """With congestion off the predicted inflation is 1, so shedding removes
    exactly the hard-infeasible requests — the ones GUS drops anyway."""
    base = _serial(cfg(rate=6.0))
    shed = _serial(cfg(rate=6.0, admission=AdmissionConfig(enabled=True, shed=True)))
    assert base.as_dict() == shed.as_dict()


def test_gus_adaptive_equals_gus_when_all_quiet():
    # with no impairments the carry's server_up/link_bw stay at ones and the
    # EMA shading is zero -> gus-adaptive must reproduce gus bit-for-bit
    a = _serial(cfg(), policy="gus")
    b = _serial(cfg(), policy="gus-adaptive")
    assert a.as_dict() == b.as_dict()
    _assert_fleet_equal(_fleet(cfg(), policy="gus"),
                        _fleet(cfg(), policy="gus-adaptive"))


# ---------------------------------------------------------------------------
# behaviour: impairments bite, outages are accounted, shedding is safe
# ---------------------------------------------------------------------------


def test_impairments_reduce_satisfaction_and_are_deterministic():
    # a tight deadline puts the transfer leg on the critical path, so the
    # degraded link actually costs satisfied requests
    tight = dict(horizon_ms=24_000.0, delay_req_ms=1500.0)
    base = _serial(cfg(**tight))
    a = _serial(cfg(**tight, impairments=IMPAIRED))
    b = _serial(cfg(**tight, impairments=IMPAIRED))
    assert a.as_dict() == b.as_dict()
    assert a.satisfied_pct < base.satisfied_pct
    assert a.n_requests == base.n_requests  # impairments never change arrivals


def test_outage_stream_is_accounted():
    r = _serial(cfg(horizon_ms=24_000.0, impairments=OUTAGES))
    assert r.resilience_stats["frames_with_down_server"] > 0
    base = _serial(cfg(horizon_ms=24_000.0))
    assert r.satisfied_pct <= base.satisfied_pct


def test_fleet_impairment_weather_is_rep_prefix_stable():
    """The link/outage streams are seeded independently of the replication
    index — every rep sees the same network weather — so growing the fleet
    leaves the existing replications' results bitwise unchanged."""
    c = cfg(horizon_ms=9_000.0, impairments=FULL)
    f1 = _fleet(c, n_rep=1)
    f3 = _fleet(c, n_rep=3)
    assert f1.satisfied_per_rep[0] == f3.satisfied_per_rep[0]
    assert f1.mean_us_per_rep[0] == f3.mean_us_per_rep[0]


def test_backlog_conservation_closes_across_outages():
    c = cfg(rate=4.0, horizon_ms=18_000.0,
            congestion=CongestionConfig(enabled=True), impairments=FULL)
    s = _serial(c, scenario=COMPOSITE).congestion_stats
    for kind in ("gamma", "eta"):
        enq = s[f"work_enqueued_{kind}"]
        drained = s[f"work_drained_{kind}"]
        carried = s[f"final_backlog_{kind}"]
        np.testing.assert_allclose(drained + carried, enq, rtol=1e-6)


def test_backlog_drains_after_recovery():
    """Same absolute outage window, longer tail: the carried backlog built
    during the outage drains once capacity comes back."""
    # outage occupies [3 s, 9 s) in both runs; only the recovery tail grows
    sc_short = OutageScenario(outage_start_frac=0.25, outage_end_frac=0.75)
    sc_long = OutageScenario(outage_start_frac=0.125, outage_end_frac=0.375)
    cc = CongestionConfig(enabled=True)
    short = _serial(cfg(rate=4.0, horizon_ms=12_000.0, congestion=cc),
                    scenario=sc_short).congestion_stats
    long = _serial(cfg(rate=4.0, horizon_ms=24_000.0, congestion=cc),
                   scenario=sc_long).congestion_stats
    assert long["final_backlog_gamma"] <= short["final_backlog_gamma"]


def test_protection_rescues_overcommitting_policy_on_composite():
    c_none = cfg(rate=4.0, horizon_ms=18_000.0,
                 congestion=CongestionConfig(enabled=True), impairments=FULL)
    c_prot = cfg(rate=4.0, horizon_ms=18_000.0,
                 congestion=CongestionConfig(enabled=True), impairments=FULL,
                 admission=PROTECTED)
    plain = _fleet(c_none, policy="happy_computation", scenario=COMPOSITE)
    prot = _fleet(c_prot, policy="happy_computation", scenario=COMPOSITE)
    assert prot.satisfied_pct > plain.satisfied_pct


def test_protection_leaves_gus_untouched_on_composite():
    """GUS honors per-frame capacity, so its backlog never crosses the cap
    and its pre-frame inflation estimate never sheds a request it would
    have served: protection is exactly inert."""
    c_none = cfg(rate=4.0, horizon_ms=18_000.0,
                 congestion=CongestionConfig(enabled=True), impairments=FULL)
    c_prot = cfg(rate=4.0, horizon_ms=18_000.0,
                 congestion=CongestionConfig(enabled=True), impairments=FULL,
                 admission=PROTECTED)
    _assert_fleet_equal(_fleet(c_none, scenario=COMPOSITE),
                        _fleet(c_prot, scenario=COMPOSITE))


def test_flash_crowd_outage_scenario_registered():
    sc = get_scenario("flash-crowd-outage")
    assert isinstance(sc, FlashCrowdOutageScenario)
    c = cfg(horizon_ms=10_000.0)
    inside = sc.capacity_scale(0.5 * c.horizon_ms, c, SPEC.n_edge, SPEC.n_servers)
    outside = sc.capacity_scale(0.9 * c.horizon_ms, c, SPEC.n_edge, SPEC.n_servers)
    assert inside is not None and inside[sc.down_servers[0]] == 0.0
    assert outside is None or np.all(np.asarray(outside) == 1.0)


# ---------------------------------------------------------------------------
# parity: every fleet execution path agrees under active impairments
# ---------------------------------------------------------------------------

ACTIVE = dict(rate=3.0, horizon_ms=12_000.0,
              congestion=CongestionConfig(enabled=True), impairments=FULL,
              admission=PROTECTED)


@pytest.mark.parametrize("policy", ["gus", "gus-adaptive"])
def test_windowed_fleet_parity_under_impairments(policy):
    c = cfg(**ACTIVE)
    full = _fleet(c, policy=policy, n_rep=2, scenario=COMPOSITE)
    win = _fleet(c, policy=policy, n_rep=2, scenario=COMPOSITE, window=4)
    _assert_fleet_equal(full, win)


def test_prefetched_fleet_parity_under_impairments():
    c = cfg(**ACTIVE)
    p0 = _fleet(c, n_rep=2, scenario=COMPOSITE, window=4, prefetch=0)
    p2 = _fleet(c, n_rep=2, scenario=COMPOSITE, window=4, prefetch=2)
    _assert_fleet_equal(p0, p2)


def test_streaming_fleet_parity_under_impairments():
    c = cfg(**ACTIVE)
    w4 = _fleet(c, n_rep=2, scenario=COMPOSITE, streaming=True, window=4)
    w9 = _fleet(c, n_rep=2, scenario=COMPOSITE, streaming=True, window=9)
    _assert_fleet_equal(w4, w9)


def test_vectorized_rng_fleet_parity_under_impairments():
    c = cfg(**ACTIVE)
    full = _fleet(c, n_rep=2, scenario=COMPOSITE, rng_mode="vectorized")
    win = _fleet(c, n_rep=2, scenario=COMPOSITE, rng_mode="vectorized", window=4)
    _assert_fleet_equal(full, win)


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices")
def test_sharded_fleet_parity_under_impairments():
    c = cfg(**ACTIVE)
    one = _fleet(c, n_rep=4, scenario=COMPOSITE, devices=1, rep_group=2)
    two = _fleet(c, n_rep=4, scenario=COMPOSITE, devices=2, rep_group=2)
    _assert_fleet_equal(one, two)
