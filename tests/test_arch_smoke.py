"""Per-architecture smoke tests (spec requirement (f)).

Each assigned architecture is instantiated as a REDUCED same-family variant
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train
step on CPU, asserting output shapes and absence of NaNs.  Decode-capable
archs also run a one-token serve step against a fresh cache."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models import Model
from repro.training import AdamWConfig, init_state, make_batch, make_train_step


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def _smoke_cfg(arch_id):
    return reduce_for_smoke(get_config(arch_id))


def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, 2, 32, rng)
    params = model.init(jax.random.PRNGKey(0))
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"
    assert np.isfinite(float(aux["router_aux"]))


def test_one_train_step(arch):
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, 2, 32, rng)
    state = init_state(model, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, AdamWConfig(total_steps=10, warmup_steps=2)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss not finite"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_serve_step(arch):
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, 2, 32, rng)
    params = model.init(jax.random.PRNGKey(2))
    cache = model.init_cache(2, 48)
    last, cache = jax.jit(model.prefill)(params, batch, cache)
    assert last.shape == (2, 1, cfg.vocab_size)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    logits, cache = jax.jit(model.decode_step)(params, tok, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite decode"
    assert int(cache.index) == 33


def test_decode_matches_forward(arch):
    """Teacher-forcing forward and prefill+decode must agree.

    MoE note: capacity dropping differs between full-sequence forward (tokens
    compete for expert slots) and one-token decode (no competition), so for
    parity we use a dropless capacity factor — drop semantics are covered by
    test_moe.py."""
    import dataclasses

    cfg = _smoke_cfg(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = Model(cfg)
    rng = np.random.default_rng(3)
    S = 32
    batch = make_batch(cfg, 2, S, rng)
    params = model.init(jax.random.PRNGKey(3))
    full, _ = model.forward(params, batch)
    P = S - 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]
    cache = model.init_cache(2, S)
    last, cache = model.prefill(params, pre, cache)
    errs = [float(jnp.abs(last[:, 0] - full[:, P - 1]).max())]
    for t in range(P, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, t : t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-3, f"{arch}: decode/forward divergence {errs}"
