"""Arrival-generation RNG modes: the frozen ``paper-default`` draw order
(golden trace), the vectorized generator's determinism and distributional
parity with the per-request loop, the columnar trace's equivalence to the
object trace, and the ``max_frame_arrivals`` envelope in both modes.

The vectorized mode is *opt-in* precisely because it consumes the RNG in a
different order — these tests pin (a) that the default mode's traces can
never drift (any RNG refactor that changes them fails the golden test) and
(b) that the vectorized mode draws the same thinned-Poisson process and the
same QoS/size laws, just batched."""
import math

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    RNG_MODES,
    RequestColumns,
    SimConfig,
    bucket_arrivals,
    bucket_columns,
    demo_cluster_spec,
    get_scenario,
    list_scenarios,
    max_frame_arrivals,
    simulate_fleet,
    stream_trace,
    stream_trace_columns,
)
from repro.core.scenarios import VEC_CHUNK, iter_edge_arrival_chunks  # noqa: E402


def cfg(**kw):
    base = dict(
        horizon_ms=12_000.0,
        arrival_rate_per_s=3.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
    )
    base.update(kw)
    return SimConfig(**base)


def req_tuple(r):
    return (r.rid, r.arrival_ms, r.cover, r.service, r.A, r.C, r.size_bytes)


SCENARIO_NAMES = sorted(
    ["paper-default", "diurnal", "flash-crowd", "hetero-tiers", "sustained-overload"]
)


# ---------------------------------------------------------------------------
# Golden trace: the paper-default per-request draw order is frozen
# ---------------------------------------------------------------------------

# generate_arrivals(default_rng(123), n_edge=3, n_services=2, cfg()) — any
# refactor that changes the default mode's RNG consumption breaks these
# literals and must NOT ship as the default (that is the whole point of
# rng_mode being opt-in)
GOLDEN_N = 125
GOLDEN_FIRST3 = [
    (0, 198.9908317075506, 0, 1, 62.879252612892486, 6000.0, 38437.18106986697),
    (1, 229.59171846192464, 0, 0, 55.77103791257251, 6000.0, 112334.49980270564),
    (2, 281.13602104387934, 0, 1, 46.7761088384104, 6000.0, 71297.04552295318),
]
GOLDEN_SUM_ARRIVAL = 692928.7122563681
GOLDEN_SUM_A = 6440.5145223247655
GOLDEN_SUM_SIZE = 8635273.808705235
GOLDEN_COVER_PREFIX = [0, 0, 0, 0, 2, 2, 0, 1, 0, 2, 2, 0]

# stream_trace("paper-default", seed=123, ...) — the streaming engine's
# spawned-generator draw order, equally frozen
GOLDEN_STREAM_N = 105
GOLDEN_STREAM_SUM_ARRIVAL = 605829.1700185866
GOLDEN_STREAM_FIRST = (
    0, 88.68756074937487, 0, 0, 48.885479413246465, 6000.0, 35585.81796957245,
)


def test_paper_default_trace_is_bit_frozen():
    reqs = get_scenario("paper-default").generate_arrivals(
        np.random.default_rng(123), 3, 2, cfg()
    )
    assert len(reqs) == GOLDEN_N
    assert [req_tuple(r) for r in reqs[:3]] == GOLDEN_FIRST3
    assert [r.cover for r in reqs[:12]] == GOLDEN_COVER_PREFIX
    assert float(np.sum([r.arrival_ms for r in reqs])) == GOLDEN_SUM_ARRIVAL
    assert float(np.sum([r.A for r in reqs])) == GOLDEN_SUM_A
    assert float(np.sum([r.size_bytes for r in reqs])) == GOLDEN_SUM_SIZE


def test_streaming_trace_is_bit_frozen():
    s = stream_trace("paper-default", 123, 3, 2, cfg())
    assert len(s) == GOLDEN_STREAM_N
    assert req_tuple(s[0]) == GOLDEN_STREAM_FIRST
    assert float(np.sum([r.arrival_ms for r in s])) == GOLDEN_STREAM_SUM_ARRIVAL


def test_default_rng_mode_is_paper_default_everywhere():
    # mega-city is the one deliberate exception: at 10^5+ users per frame a
    # materialized per-Request trace is exactly what that scenario avoids,
    # so it declares the vectorized columnar generator as its default
    for name in list_scenarios():
        expected = "vectorized" if name == "mega-city" else "paper-default"
        assert get_scenario(name).rng_mode == expected, name
    assert RNG_MODES == ("paper-default", "vectorized")


def test_unknown_rng_mode_raises():
    scn = get_scenario("paper-default")
    with pytest.raises(ValueError, match="rng_mode"):
        scn.generate_arrivals(np.random.default_rng(0), 2, 2, cfg(), rng_mode="turbo")
    with pytest.raises(ValueError, match="rng_mode"):
        simulate_fleet(
            demo_cluster_spec(), cfg(), policy="gus", n_rep=1, rng_mode="turbo"
        )


# ---------------------------------------------------------------------------
# Vectorized mode: determinism, well-formedness, columnar equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_vectorized_deterministic_given_seed_and_seed_sensitive(scenario):
    scn = get_scenario(scenario)
    c = cfg()
    a = scn.generate_arrivals(np.random.default_rng(5), 4, 3, c, rng_mode="vectorized")
    b = scn.generate_arrivals(np.random.default_rng(5), 4, 3, c, rng_mode="vectorized")
    other = scn.generate_arrivals(np.random.default_rng(6), 4, 3, c, rng_mode="vectorized")
    assert [req_tuple(r) for r in a] == [req_tuple(r) for r in b]
    assert [req_tuple(r) for r in a] != [req_tuple(r) for r in other]


@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_vectorized_trace_well_formed(scenario):
    scn = get_scenario(scenario)
    c = cfg()
    reqs = scn.generate_arrivals(np.random.default_rng(7), 4, 3, c, rng_mode="vectorized")
    times = [r.arrival_ms for r in reqs]
    assert times == sorted(times)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    assert all(0.0 <= t < c.horizon_ms for t in times)
    assert all(0 <= r.cover < 4 and 0 <= r.service < 3 for r in reqs)
    assert all(1.0 <= r.A <= 99.0 for r in reqs)
    assert all(c.req_size_lo <= r.size_bytes <= c.req_size_hi for r in reqs)
    assert all(r.C > 0 for r in reqs)


@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_columns_and_requests_are_one_trace(scenario):
    """generate_arrivals(vectorized) is exactly generate_arrivals_columns
    wrapped into Request objects — same seed, same values, same order."""
    scn = get_scenario(scenario)
    c = cfg()
    reqs = scn.generate_arrivals(np.random.default_rng(9), 4, 3, c, rng_mode="vectorized")
    cols = scn.generate_arrivals_columns(np.random.default_rng(9), 4, 3, c)
    assert len(cols) == len(reqs)
    assert [req_tuple(r) for r in cols.to_requests()] == [req_tuple(r) for r in reqs]


def test_bucket_columns_matches_bucket_arrivals():
    scn = get_scenario("flash-crowd")
    c = cfg()
    cols = scn.generate_arrivals_columns(np.random.default_rng(3), 4, 3, c)
    n_frames = int(np.ceil(c.horizon_ms / c.frame_ms))
    by_req = bucket_arrivals(cols.to_requests(), c.frame_ms, n_frames)
    by_col = bucket_columns(cols, c.frame_ms, n_frames)
    assert [len(b) for b in by_req] == [len(b) for b in by_col]
    for br, bc in zip(by_req, by_col):
        assert [r.arrival_ms for r in br] == list(bc.arrival_ms)
        assert [r.cover for r in br] == list(bc.cover)
    # empty columnar buckets are falsy, like empty lists
    empty = RequestColumns.concatenate([])
    assert not empty and len(empty) == 0


def test_stream_trace_columns_matches_vectorized_stream():
    c = cfg()
    for scenario in SCENARIO_NAMES:
        via_stream = stream_trace(scenario, 21, 4, 3, c, rng_mode="vectorized")
        via_cols = stream_trace_columns(scenario, 21, 4, 3, c).to_requests()
        assert [req_tuple(r) for r in via_stream] == [req_tuple(r) for r in via_cols]


# ---------------------------------------------------------------------------
# Distributional parity: vectorized vs per-request, same law
# ---------------------------------------------------------------------------


def _counts_over_seeds(scn, c, mode, n_seeds, n_edge=2, n_services=2):
    return np.array(
        [
            len(scn.generate_arrivals(
                np.random.default_rng(s), n_edge, n_services, c, rng_mode=mode
            ))
            for s in range(n_seeds)
        ],
        np.float64,
    )


@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_vectorized_counts_match_per_request_in_expectation(scenario):
    """Both modes draw the same thinned Poisson process, so total counts over
    seeds must agree within Monte-Carlo error (5 sigma of the pooled mean)."""
    scn = get_scenario(scenario)
    c = cfg(horizon_ms=20_000.0)
    n_seeds = 24
    a = _counts_over_seeds(scn, c, "paper-default", n_seeds)
    b = _counts_over_seeds(scn, c, "vectorized", n_seeds)
    # Poisson totals: var == mean; compare seed-means with a 5-sigma band
    pooled = 0.5 * (a.mean() + b.mean())
    sigma = math.sqrt(2.0 * pooled / n_seeds)
    assert abs(a.mean() - b.mean()) < 5.0 * sigma, (a.mean(), b.mean(), sigma)


def test_vectorized_respects_time_varying_rate():
    """flash-crowd's hot edges must see ~burst_mult the traffic inside the
    burst window in *both* modes (the thinning is what's being vectorized)."""
    scn = get_scenario("flash-crowd")
    c = cfg(horizon_ms=50_000.0, arrival_rate_per_s=2.0)
    t_lo, t_hi = scn.burst_start_frac * c.horizon_ms, scn.burst_end_frac * c.horizon_ms
    for mode in RNG_MODES:
        in_burst = out_burst = 0
        for s in range(8):
            for r in scn.generate_arrivals(
                np.random.default_rng(s), 2, 2, c, rng_mode=mode
            ):
                if r.cover != 0:
                    continue  # edge 0 is hot (stride 2)
                if t_lo <= r.arrival_ms < t_hi:
                    in_burst += 1
                else:
                    out_burst += 1
        # burst window is 20% of the horizon at 10x rate -> in/out ~ 10 * (0.2/0.8)
        ratio = in_burst / max(out_burst, 1)
        assert 1.5 < ratio < 4.0, (mode, ratio)


def test_vectorized_qos_law_matches():
    """hetero-tiers' two-tier QoS mix must survive vectorization: deadlines
    take exactly the two tier values, accuracy means sit near the mix mean."""
    scn = get_scenario("hetero-tiers")
    c = cfg(horizon_ms=40_000.0)
    strict_c = c.delay_req_ms * scn.strict_deadline_mult
    lenient_c = c.delay_req_ms * scn.lenient_deadline_mult
    stats = {}
    for mode in RNG_MODES:
        reqs = [
            r
            for s in range(6)
            for r in scn.generate_arrivals(
                np.random.default_rng(s), 3, 2, c, rng_mode=mode
            )
        ]
        cs = {r.C for r in reqs}
        assert cs == {strict_c, lenient_c}, (mode, cs)
        frac_strict = np.mean([r.C == strict_c for r in reqs])
        assert abs(frac_strict - scn.strict_frac) < 0.05, (mode, frac_strict)
        stats[mode] = np.mean([r.A for r in reqs])
    assert abs(stats["vectorized"] - stats["paper-default"]) < 1.5, stats


# ---------------------------------------------------------------------------
# max_frame_arrivals envelope, both modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", RNG_MODES)
@pytest.mark.parametrize("scenario", ["sustained-overload", "flash-crowd"])
def test_max_frame_arrivals_bounds_realized_buckets(scenario, mode):
    c = cfg()
    n_frames = int(np.ceil(c.horizon_ms / c.frame_ms))
    mx = max_frame_arrivals(scenario, 13, 4, 3, c, n_frames, rng_mode=mode)
    reqs = stream_trace(scenario, 13, 4, 3, c, rng_mode=mode)
    buckets = bucket_arrivals(reqs, c.frame_ms, n_frames)
    realized = max((len(b) for b in buckets), default=0)
    assert mx >= realized
    # the count-only pass must be exact, not just an upper bound — that is
    # what pins windowed == materialized padding
    assert mx == realized


# ---------------------------------------------------------------------------
# Chunk engine internals
# ---------------------------------------------------------------------------


def test_chunk_iterator_consumption_is_pull_independent():
    """Draining the chunk iterator all at once vs chunk-by-chunk with
    interruptions yields the same chunks (the RNG advance is internal)."""
    scn = get_scenario("diurnal")
    c = cfg()
    a = list(iter_edge_arrival_chunks(scn, np.random.default_rng(1), 0, 3, c, c.horizon_ms))
    it = iter_edge_arrival_chunks(scn, np.random.default_rng(1), 0, 3, c, c.horizon_ms)
    b = []
    while True:
        nxt = next(it, None)
        if nxt is None:
            break
        b.append(nxt)
    assert len(a) == len(b)
    for ca, cb in zip(a, b):
        for xa, xb in zip(ca, cb):
            np.testing.assert_array_equal(xa, xb)


def test_zero_rate_edge_yields_nothing():
    scn = get_scenario("paper-default")
    c = cfg(arrival_rate_per_s=0.0)
    assert scn.generate_arrivals(np.random.default_rng(0), 3, 2, c,
                                 rng_mode="vectorized") == []
    assert list(iter_edge_arrival_chunks(scn, np.random.default_rng(0), 0, 2, c,
                                         c.horizon_ms)) == []


def test_deep_subclass_scalar_override_is_honored_in_vectorized_mode():
    """A subclass of a *registered* scenario that overrides only the scalar
    hooks must not silently inherit the parent's batched law: the vectorized
    engine detects the deeper scalar override (MRO depth, not a one-level
    `is` check) and loops the scalar hook instead."""
    import dataclasses as dc

    from repro.core.scenarios import FlashCrowdScenario, HeteroTiersScenario

    @dc.dataclass(frozen=True)
    class FixedQosTiers(HeteroTiersScenario):
        # new scalar QoS law, no draw_qos_batch twin
        def draw_qos(self, rng, cfg):
            rng.random()  # consume like a tier draw would
            return 42.0, 4242.0

    c = cfg()
    reqs = FixedQosTiers().generate_arrivals(
        np.random.default_rng(0), 3, 2, c, rng_mode="vectorized"
    )
    assert reqs, "subclass scenario generated nothing"
    assert {r.A for r in reqs} == {42.0}
    assert {r.C for r in reqs} == {4242.0}

    @dc.dataclass(frozen=True)
    class NoBurstFlash(FlashCrowdScenario):
        # new scalar rate law (burst removed), no rate_batch twin
        def rate(self, edge, t_ms, cfg):
            return cfg.arrival_rate_per_s

    c = cfg(horizon_ms=30_000.0, arrival_rate_per_s=2.0)
    scn = NoBurstFlash()
    n = np.mean([
        len(scn.generate_arrivals(np.random.default_rng(s), 2, 2, c,
                                  rng_mode="vectorized"))
        for s in range(10)
    ])
    # the thinned process must follow the constant scalar rate (~120 total),
    # not the inherited 10x-burst batch law (~175)
    expect = 2.0 * 30.0 * 2
    assert abs(n - expect) < 4 * math.sqrt(expect), n


def test_vec_chunk_constant_is_frozen():
    """VEC_CHUNK is part of the vectorized trace's definition — changing it
    changes every vectorized trace, so treat it like a file format."""
    assert VEC_CHUNK == 512


# ---------------------------------------------------------------------------
# Property tests (hypothesis — optional in minimal environments; the guard
# keeps the rest of the module running where it is not installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        rate=st.floats(min_value=0.2, max_value=12.0),
        n_edge=st.integers(min_value=1, max_value=5),
    )
    def test_prop_vectorized_deterministic_and_in_horizon(seed, rate, n_edge):
        scn = get_scenario("paper-default")
        c = cfg(horizon_ms=6000.0, arrival_rate_per_s=rate)
        a = scn.generate_arrivals(np.random.default_rng(seed), n_edge, 2, c,
                                  rng_mode="vectorized")
        b = scn.generate_arrivals(np.random.default_rng(seed), n_edge, 2, c,
                                  rng_mode="vectorized")
        assert [req_tuple(r) for r in a] == [req_tuple(r) for r in b]
        assert all(0.0 <= r.arrival_ms < c.horizon_ms for r in a)
        assert all(1.0 <= r.A <= 99.0 for r in a)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        rate=st.floats(min_value=0.5, max_value=8.0),
    )
    def test_prop_max_frame_arrivals_is_exact_envelope(seed, rate):
        c = cfg(horizon_ms=9000.0, arrival_rate_per_s=rate)
        n_frames = int(np.ceil(c.horizon_ms / c.frame_ms))
        for mode in RNG_MODES:
            mx = max_frame_arrivals(
                "paper-default", seed, 3, 2, c, n_frames, rng_mode=mode
            )
            buckets = bucket_arrivals(
                stream_trace("paper-default", seed, 3, 2, c, rng_mode=mode),
                c.frame_ms, n_frames,
            )
            assert mx == max((len(b) for b in buckets), default=0)
else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_vectorized_deterministic_and_in_horizon():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_max_frame_arrivals_is_exact_envelope():
        pass
