"""Chunked (flash-style) attention path vs the reference implementation,
plus hypothesis sweeps over odd sequence lengths / windows / GQA shapes."""
import dataclasses

import numpy as np
import jax
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.training import make_batch


def _cfg(**kw):
    base = dict(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, scan_layers=False,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("block", [8, 32, 1024])
def test_chunked_matches_reference(window, block):
    cfg = _cfg(sliding_window=window, attn_block=block)
    m_ref = Model(cfg)
    m_chk = Model(dataclasses.replace(cfg, attn_impl="chunked"))
    params = m_ref.init(jax.random.PRNGKey(0))
    b = make_batch(cfg, 2, 40, np.random.default_rng(0))
    lr, _ = m_ref.forward(params, b)
    lc, _ = m_chk.forward(params, b)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lc), rtol=1e-4, atol=1e-4)


def test_chunked_encdec_bidir():
    cfg = _cfg(family="encdec", num_enc_layers=2, num_kv_heads=4, enc_seq_len=24)
    m_ref = Model(cfg)
    m_chk = Model(dataclasses.replace(cfg, attn_impl="chunked", attn_block=8))
    params = m_ref.init(jax.random.PRNGKey(1))
    b = make_batch(cfg, 2, 24, np.random.default_rng(1))
    lr, _ = m_ref.forward(params, b)
    lc, _ = m_chk.forward(params, b)
    # 4 layers of f32 accumulation-order noise: slightly looser tolerance
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lc), rtol=5e-4, atol=5e-4)


def test_chunked_grads_match():
    """Backward pass parity (the chunked path is used for training)."""
    from repro.training import make_loss_fn

    cfg = _cfg()
    m_ref = Model(cfg)
    m_chk = Model(dataclasses.replace(cfg, attn_impl="chunked", attn_block=16))
    params = m_ref.init(jax.random.PRNGKey(2))
    b = make_batch(cfg, 2, 32, np.random.default_rng(2))
    g_ref = jax.grad(lambda p: make_loss_fn(m_ref)(p, b)[0])(params)
    g_chk = jax.grad(lambda p: make_loss_fn(m_chk)(p, b)[0])(params)
    for a, c in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_chk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=5e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(3, 70),
    block=st.sampled_from([4, 16, 64]),
    window=st.sampled_from([None, 5, 16]),
    kv=st.sampled_from([1, 2, 4]),
)
def test_property_chunked_any_shape(s, block, window, kv):
    cfg = _cfg(num_kv_heads=kv, sliding_window=window, attn_block=block)
    m_ref = Model(cfg)
    m_chk = Model(dataclasses.replace(cfg, attn_impl="chunked"))
    params = m_ref.init(jax.random.PRNGKey(3))
    b = make_batch(cfg, 1, s, np.random.default_rng(3))
    lr, _ = m_ref.forward(params, b)
    lc, _ = m_chk.forward(params, b)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lc), rtol=2e-4, atol=2e-4)


def test_remat_policy_dots_same_loss():
    from repro.training import make_loss_fn

    cfg = _cfg(scan_layers=True, remat=True)
    m_full = Model(cfg)
    m_dots = Model(dataclasses.replace(cfg, remat_policy="dots"))
    params = m_full.init(jax.random.PRNGKey(4))
    b = make_batch(cfg, 2, 32, np.random.default_rng(4))
    l1 = float(make_loss_fn(m_full)(params, b)[0])
    l2 = float(make_loss_fn(m_dots)(params, b)[0])
    assert l1 == pytest.approx(l2, rel=1e-6)
    g1 = jax.grad(lambda p: make_loss_fn(m_full)(p, b)[0])(params)
    g2 = jax.grad(lambda p: make_loss_fn(m_dots)(p, b)[0])(params)
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-6)
