"""INT8 KV-cache quantization: roundtrip error bounds, decode parity within
int8 tolerance, greedy-token agreement, property tests."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.models.quant import dequantize_kv, quantize_kv
from repro.training import make_batch


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8, 2, 64)) * 3, jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    assert s.shape == (4, 8, 2, 1)
    back = dequantize_kv(q, s, jnp.float32)
    rel = np.abs(np.asarray(back - x)) / (np.abs(np.asarray(x)).max(-1, keepdims=True) + 1e-9)
    assert rel.max() < 1.0 / 127 + 1e-6  # symmetric int8 bound


def test_quantize_zeros_safe():
    q, s = quantize_kv(jnp.zeros((2, 3, 1, 8)))
    assert np.asarray(q).sum() == 0
    assert np.isfinite(np.asarray(s)).all()
    assert (np.asarray(dequantize_kv(q, s, jnp.float32)) == 0).all()


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), hd=st.sampled_from([8, 64, 128]))
def test_property_quant_bounded(scale, hd):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((2, 5, 1, hd)) * scale, jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert (np.abs(np.asarray(back - x)) <= amax / 127 + 1e-6).all()


FAMS = [
    ("dense", False, dict(num_heads=4, num_kv_heads=2, d_ff=128)),
    ("dense", True, dict(num_heads=4, num_kv_heads=2, d_ff=128)),
    ("hybrid", False, dict(num_heads=4, num_kv_heads=4, d_ff=128, ssm_state=16,
                           ssm_headdim=32, ssd_chunk=8, attn_every=2)),
    ("encdec", False, dict(num_heads=4, num_kv_heads=4, d_ff=128,
                           num_enc_layers=2, enc_seq_len=24)),
]


@pytest.mark.parametrize("fam,scan,kw", FAMS)
def test_int8_decode_close_and_tokens_agree(fam, scan, kw):
    cfg = ModelConfig(family=fam, num_layers=4 if fam == "hybrid" else 2,
                      d_model=64, vocab_size=256, scan_layers=scan, **kw)
    m = Model(cfg)
    m8 = Model(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    params = m.init(jax.random.PRNGKey(0))
    S = 32
    batch = make_batch(cfg, 2, S, np.random.default_rng(0))
    P = S - 6
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]

    cache_f = m.init_cache(2, S)
    cache_q = m8.init_cache(2, S)
    assert cache_q.attn["k"].dtype == jnp.int8
    lf, cache_f = m.prefill(params, pre, cache_f)
    lq, cache_q = m8.prefill(params, pre, cache_q)
    agree, close = [], []
    for t in range(P, S):
        tok = batch["tokens"][:, t : t + 1]
        lf, cache_f = m.decode_step(params, tok, cache_f)
        lq, cache_q = m8.decode_step(params, tok, cache_q)
        close.append(float(jnp.abs(lf - lq).max()))
        agree.append(bool((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).all()))
    # logits close in absolute terms and greedy tokens agree on ~every step
    # (hybrid compounds int8 error through the recurrent state -> looser)
    assert max(close) < (1.0 if fam == "hybrid" else 0.5), close
    assert np.mean(agree) >= 0.8, agree


def test_int8_cache_memory_is_quarter():
    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=256)
    m = Model(dataclasses.replace(cfg, kv_cache_dtype="int8", dtype="float32"))
    mf = Model(cfg)
    cq = m.init_cache(2, 128)
    cf = mf.init_cache(2, 128)
    bytes_q = sum(x.nbytes for x in jax.tree.leaves(cq.attn))
    bytes_f = sum(x.nbytes for x in jax.tree.leaves(cf.attn))
    # int8 payload + f32 scales (4/head_dim overhead; head_dim=16 here) vs f32
    assert bytes_q < 0.35 * bytes_f
