"""Config registry: exact assigned hyperparameters, registry integrity."""
import pytest

from repro.configs import ARCH_IDS, REGISTRY, get_config, reduce_for_smoke

# the assignment table, verbatim
ASSIGNED = {
    "pixtral-12b": dict(family="vlm", num_layers=40, d_model=5120, num_heads=32,
                        num_kv_heads=8, d_ff=14336, vocab_size=131072),
    "qwen2-moe-a2.7b": dict(family="moe", num_layers=24, d_model=2048, num_heads=16,
                            num_kv_heads=16, d_ff=1408, vocab_size=151936,
                            n_experts=60, top_k=4),
    "stablelm-12b": dict(family="dense", num_layers=40, d_model=5120, num_heads=32,
                         num_kv_heads=8, d_ff=13824, vocab_size=100352),
    "qwen2-72b": dict(family="dense", num_layers=80, d_model=8192, num_heads=64,
                      num_kv_heads=8, d_ff=29568, vocab_size=152064, qkv_bias=True),
    "yi-9b": dict(family="dense", num_layers=48, d_model=4096, num_heads=32,
                  num_kv_heads=4, d_ff=11008, vocab_size=64000),
    "seamless-m4t-medium": dict(family="encdec", num_layers=12, d_model=1024,
                                num_heads=16, num_kv_heads=16, d_ff=4096,
                                vocab_size=256206),
    "starcoder2-15b": dict(family="dense", num_layers=40, d_model=6144, num_heads=48,
                           num_kv_heads=4, d_ff=24576, vocab_size=49152),
    "arctic-480b": dict(family="moe", num_layers=35, d_model=7168, num_heads=56,
                        num_kv_heads=8, d_ff=4864, vocab_size=32000,
                        n_experts=128, top_k=2, dense_residual=True),
    "zamba2-1.2b": dict(family="hybrid", num_layers=38, d_model=2048, num_heads=32,
                        num_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64),
    "mamba2-130m": dict(family="ssm", num_layers=24, d_model=768, vocab_size=50280,
                        ssm_state=128),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_hparams_exact(arch):
    cfg = get_config(arch)
    for k, v in ASSIGNED[arch].items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    assert cfg.source, f"{arch}: missing citation"


def test_all_ten_assigned_present():
    assert len(ARCH_IDS) == 10
    assert set(ARCH_IDS) <= set(REGISTRY)
    families = {get_config(a).family for a in ARCH_IDS}
    assert families == {"vlm", "moe", "dense", "encdec", "hybrid", "ssm"}


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("gpt-17")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_bounds(arch):
    r = reduce_for_smoke(get_config(arch))
    assert r.num_layers == 2
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_sane(arch):
    """n_params within a sane band of the name-plate size."""
    nameplate = {
        "pixtral-12b": 12e9, "qwen2-moe-a2.7b": 14.3e9, "stablelm-12b": 12e9,
        "qwen2-72b": 72e9, "yi-9b": 8.8e9, "seamless-m4t-medium": 1.2e9,
        "starcoder2-15b": 16e9, "arctic-480b": 480e9, "zamba2-1.2b": 1.2e9,
        "mamba2-130m": 0.13e9,
    }[arch]
    n = get_config(arch).n_params()
    assert 0.6 * nameplate <= n <= 1.35 * nameplate, (arch, n)
