"""Roofline machinery: HLO shape parsing, collective-bytes accounting, terms."""

from repro.roofline import V5E, collective_bytes, roofline_terms, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[4,4]{1,0}") == 64
    assert _shape_bytes("(bf16[8], f32[8])") == 16 + 32
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("token[]") == 0  # unknown dtypes ignored


HLO = """
ENTRY main {
  %p0 = bf16[32,64]{1,0} parameter(0)
  %ag = bf16[32,1024]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[8,8]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[32,64]{1,0}, bf16[32,1024]{1,0}) all-gather-start(%p0), dimensions={1}
  %agd = bf16[32,1024]{1,0} all-gather-done(%ags)
  %not = bf16[99]{0} add(%a, %b)
}
"""


def test_collective_bytes_parses_all_kinds():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 32 * 1024 * 2 + 32 * 1024 * 2  # sync + start(max member)
    assert got["all-reduce"] == 128 * 4
    assert got["reduce-scatter"] == 16 * 4
    assert got["all-to-all"] == 64 * 2
    assert got["collective-permute"] == 4 * 2


def test_roofline_terms_bottleneck():
    rep = roofline_terms(
        arch="x", shape="s", mesh_name="16x16", n_devices=256,
        cost_analysis={"flops": 1e15, "bytes accessed": 1e9},
        hlo_text=HLO,
        model_flops_total=2.56e17,
    )
    assert rep.compute_s == 1e15 / V5E.peak_flops
    assert rep.memory_s == 1e9 / V5E.hbm_bw
    assert rep.bottleneck == "compute"
    assert rep.useful_ratio == (2.56e17 / 256) / 1e15
    assert not rep.loop_corrected


def test_roofline_corrected_counts():
    rep = roofline_terms(
        arch="x", shape="s", mesh_name="16x16", n_devices=256,
        cost_analysis={"flops": 1e12, "bytes accessed": 1e8},
        hlo_text=HLO,
        model_flops_total=2.56e17,
        corrected_counts={"flops": 4e13, "bytes": 4e9, "coll": 123.0,
                          "coll_breakdown": {"all-gather": 123}},
    )
    assert rep.loop_corrected
    assert rep.flops_per_device == 4e13
    assert rep.raw_flops_per_device == 1e12
    assert rep.coll_bytes_per_device == 123.0
