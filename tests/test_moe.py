"""MoE layer: routing semantics, capacity dropping, shared/dense branches,
load-balance loss — including hypothesis property tests."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models.layers import init_from_decl
from repro.models.moe import apply_moe, capacity, moe_decl, router_aux_loss

BASE = ModelConfig(
    family="moe", num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
    d_ff=64, vocab_size=64, n_experts=4, top_k=2, moe_d_ff=48,
    capacity_factor=8.0,  # dropless unless a test lowers it
)


def init_moe(cfg, seed=0):
    return init_from_decl(jax.random.PRNGKey(seed), moe_decl(cfg))


def test_output_shape_and_finite():
    p = init_moe(BASE)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 32)), jnp.float32)
    y, aux = apply_moe(p, x, BASE)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_matches_dense_expert_loop():
    """Capacity-dispatch output == naive per-token top-k expert loop."""
    cfg = BASE
    p = init_moe(cfg, seed=1)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 6, 32)), jnp.float32)
    y, _ = apply_moe(p, x, cfg)

    xf = np.asarray(x).reshape(-1, 32)
    logits = xf @ np.asarray(p["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        g = probs[t, top] / probs[t, top].sum()
        for e, gv in zip(top, g):
            act = xf[t] @ np.asarray(p["w_gate"][e])
            act = act / (1 + np.exp(-act))  # silu
            hid = act * (xf[t] @ np.asarray(p["w_up"][e]))
            want[t] += gv * (hid @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 32), want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens():
    """With capacity_factor ~0, (almost) everything is dropped -> tiny output."""
    cfg = dataclasses.replace(BASE, capacity_factor=0.01)
    p = init_moe(cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 64, 32)), jnp.float32)
    y_drop, _ = apply_moe(p, x, cfg)
    y_full, _ = apply_moe(p, x, BASE)
    # dropped-token rows are exactly zero (routed branch, no shared experts)
    zero_rows = (np.abs(np.asarray(y_drop)).max(-1) < 1e-7).sum()
    assert zero_rows > 0
    assert float(jnp.abs(y_drop).sum()) < float(jnp.abs(y_full).sum())


def test_capacity_formula():
    assert capacity(128, BASE) == max(8, -(-int(8.0 * 128 * 2 / 4) // 8) * 8)
    assert capacity(1, BASE) >= 8


def test_shared_expert_branch():
    cfg = dataclasses.replace(BASE, n_shared_experts=1, shared_expert_d_ff=16)
    p = init_moe(cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 4, 32)), jnp.float32)
    y, _ = apply_moe(p, x, cfg)
    # shared branch contributes even when router weights are zeroed
    p0 = dict(p)
    p0["router"] = jnp.zeros_like(p["router"])
    y0, _ = apply_moe(p0, x, cfg)
    assert float(jnp.abs(y0).sum()) > 0


def test_dense_residual_branch():
    cfg = dataclasses.replace(BASE, dense_residual=True)
    p = init_moe(cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 4, 32)), jnp.float32)
    y_with, _ = apply_moe(p, x, cfg)
    y_moe_only, _ = apply_moe({k: v for k, v in p.items() if k != "dense"}, x, BASE_48(cfg))
    assert not np.allclose(np.asarray(y_with), np.asarray(y_moe_only))


def BASE_48(cfg):
    return dataclasses.replace(cfg, dense_residual=False)


def test_aux_loss_balanced_vs_skewed():
    """Uniform routing minimizes the Switch load-balance loss (=1)."""
    T, E = 1024, 8
    rng = np.random.default_rng(0)
    uniform = jnp.full((T, E), 1.0 / E)
    idx_uniform = jnp.asarray(rng.integers(0, E, size=(T, 2)))
    skew = jnp.zeros((T, E)).at[:, 0].set(1.0)
    idx_skew = jnp.zeros((T, 2), jnp.int32)
    l_u = float(router_aux_loss(uniform, idx_uniform, E))
    l_s = float(router_aux_loss(skew, idx_skew, E))
    assert l_u == pytest.approx(1.0, rel=0.1)
    assert l_s > 4 * l_u


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 40),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
)
def test_property_dropless_preserves_token_mass(t, e, k):
    """With huge capacity, every token is processed by exactly k experts:
    sum of combine gates per token == 1."""
    cfg = dataclasses.replace(BASE, n_experts=e, top_k=min(k, e), capacity_factor=64.0)
    p = init_moe(cfg, seed=t)
    x = jnp.asarray(np.random.default_rng(t).standard_normal((1, t, 32)), jnp.float32)
    # identity experts: w_gate big -> silu ~ linear? instead verify via gates:
    y, _ = apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # no token row should be exactly zero in a dropless regime
    assert (np.abs(np.asarray(y)).max(-1) > 0).all()
