"""Continuous batching: parity with sequential generation, slot reuse,
admission under a full pool, and multi-family support."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.configs.paper_zoo import SQUEEZE_LM
from repro.models import Model
from repro.serving import ServingEngine
from repro.serving.continuous import ContinuousBatcher, Request


def _ref_outputs(model, params, prompts, gen, max_len=64):
    eng = ServingEngine(model, params)
    out = {}
    for i, p in enumerate(prompts):
        r = eng.generate({"tokens": jnp.asarray(p)[None]}, max_new_tokens=gen, max_len=max_len)
        out[i] = list(r.tokens[0])
    return out


@pytest.mark.parametrize("n_slots", [1, 3])
def test_parity_with_sequential(n_slots):
    model = Model(SQUEEZE_LM)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, SQUEEZE_LM.vocab_size, size=12).astype(np.int32) for _ in range(5)]
    ref = _ref_outputs(model, params, prompts, 8)
    cb = ContinuousBatcher(model, params, n_slots=n_slots, max_len=64)
    out = cb.run([Request(i, p, 8) for i, p in enumerate(prompts)])
    assert out == ref


def test_slot_reuse_and_admission():
    model = Model(SQUEEZE_LM)
    params = model.init(jax.random.PRNGKey(1))
    cb = ContinuousBatcher(model, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, 512, size=8).astype(np.int32), 4) for i in range(5)]
    assert cb.admit(reqs[0]) and cb.admit(reqs[1])
    assert not cb.admit(reqs[2])  # pool full
    for _ in range(4):
        cb.step()
    assert len(cb.free_slots()) == 2  # both finished and vacated
    assert cb.admit(reqs[2])  # reused slot
    out = cb.run(reqs[3:])
    assert set(out) >= {3, 4}


def test_ssm_family_continuous():
    cfg = ModelConfig(family="ssm", num_layers=2, d_model=64, vocab_size=128,
                      num_heads=1, num_kv_heads=1, d_ff=0, ssm_state=16,
                      ssm_headdim=32, ssd_chunk=8, scan_layers=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 128, size=8).astype(np.int32) for _ in range(3)]
    ref = _ref_outputs(model, params, prompts, 6)
    cb = ContinuousBatcher(model, params, n_slots=2, max_len=32)
    out = cb.run([Request(i, p, 6) for i, p in enumerate(prompts)])
    assert out == ref
