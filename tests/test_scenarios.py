"""Scenario engine + vectorized simulation hot path.

Covers the padding/masking contract (`pad_instance`), jitted-vs-NumPy GUS
parity on padded random frames, the registry, end-to-end smoke of every
registered scenario through both `simulate` and `simulate_fleet`, and the
scenario-specific behaviours (outage masking, diurnal/burst rates,
hetero QoS tiers, mobility override).
"""
import numpy as np
import pytest

from repro.core import (
    GeneratorConfig,
    Scenario,
    SimConfig,
    demo_cluster_spec,
    generate_instance,
    get_scenario,
    gus_schedule,
    gus_schedule_batch,
    gus_schedule_np,
    list_scenarios,
    pad_instance,
    register_scenario,
    simulate,
    simulate_fleet,
    stack_instances,
)

SPEC = demo_cluster_spec()
CFG = SimConfig(
    horizon_ms=24_000.0,
    arrival_rate_per_s=1.5,
    delay_req_ms=6000.0,
    acc_req_mean=50.0,
    acc_req_std=10.0,
)


# ---------------------------------------------------------------------------
# padding / masking contract
# ---------------------------------------------------------------------------


def test_pad_instance_rows_are_dropped_and_assignments_unchanged():
    cfg = GeneratorConfig(n_requests=13, n_edge=3, n_cloud=1, n_services=4, n_variants=3)
    inst = generate_instance(7, cfg)
    padded = pad_instance(inst, 16)
    a0 = gus_schedule(inst)
    a1 = gus_schedule(padded)
    np.testing.assert_array_equal(np.asarray(a0.j), np.asarray(a1.j)[:13])
    np.testing.assert_array_equal(np.asarray(a0.l), np.asarray(a1.l)[:13])
    assert (np.asarray(a1.j)[13:] == -1).all()
    assert (np.asarray(a1.l)[13:] == -1).all()


def test_pad_instance_validates():
    inst = generate_instance(0, GeneratorConfig(n_requests=5, n_edge=2, n_cloud=1,
                                                n_services=2, n_variants=2))
    assert pad_instance(inst, 5) is inst
    with pytest.raises(ValueError):
        pad_instance(inst, 4)


def test_batch_parity_padded_jitted_vs_numpy_oracle():
    """The acceptance-criterion test: gus_schedule on padded, stacked random
    frames matches the unpadded NumPy oracle row-for-row."""
    sizes = [3, 7, 12, 16]
    cfgs = [
        GeneratorConfig(n_requests=n, n_edge=3, n_cloud=1, n_services=5, n_variants=3)
        for n in sizes
    ]
    insts = [generate_instance(100 + i, c) for i, c in enumerate(cfgs)]
    batch = stack_instances([pad_instance(x, 16) for x in insts])
    ab = gus_schedule_batch(batch)
    for i, (inst, n) in enumerate(zip(insts, sizes)):
        ref = gus_schedule_np(inst)
        np.testing.assert_array_equal(
            np.asarray(ab.j)[i, :n], np.asarray(ref.j), err_msg=f"frame {i} j"
        )
        np.testing.assert_array_equal(
            np.asarray(ab.l)[i, :n], np.asarray(ref.l), err_msg=f"frame {i} l"
        )
        assert (np.asarray(ab.j)[i, n:] == -1).all()


def test_simulate_jitted_default_matches_numpy_oracle_end_to_end():
    a = simulate(SPEC, CFG, seed=0).as_dict()            # default: jitted gus
    b = simulate(SPEC, CFG, gus_schedule_np, seed=0).as_dict()
    assert a == b


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_has_the_documented_scenarios():
    names = list_scenarios()
    for expected in ("paper-default", "diurnal", "flash-crowd", "mobility",
                     "hetero-tiers", "outage"):
        assert expected in names
    assert len(names) >= 5


def test_get_scenario_resolves_and_rejects():
    scn = get_scenario("diurnal")
    assert scn.name == "diurnal"
    assert get_scenario(scn) is scn
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_register_scenario_instance_and_class():
    class Custom(Scenario):
        pass

    original = get_scenario("paper-default")
    try:
        register_scenario(Custom())
        assert isinstance(get_scenario("paper-default"), Custom)
    finally:
        register_scenario(original)
    assert get_scenario("paper-default") is original


# ---------------------------------------------------------------------------
# every scenario runs end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(["paper-default", "diurnal", "flash-crowd",
                                         "mobility", "hetero-tiers", "outage"]))
def test_scenario_smoke_simulate(name):
    r = simulate(SPEC, CFG, scenario=name, seed=2)
    assert r.n_requests > 0
    assert 0.0 <= r.satisfied_pct <= 100.0
    assert r.n_served + r.n_dropped == r.n_requests
    assert r.n_local + r.n_cloud + r.n_edge_offload == r.n_served


@pytest.mark.parametrize("name", sorted(["paper-default", "flash-crowd", "outage"]))
def test_scenario_smoke_fleet(name):
    fr = simulate_fleet(SPEC, CFG, scenario=name, n_rep=3, seed=0)
    assert fr.n_rep == 3
    assert fr.n_requests > 0
    assert fr.satisfied_per_rep.shape == (3,)
    assert 0.0 <= fr.satisfied_pct <= 100.0
    assert fr.n_served <= fr.n_requests


def test_fleet_tracks_per_frame_simulator_on_default_scenario():
    """Frame-synchronous fleet semantics should land near the sequential
    testbed's satisfied-% under light load (no queue-cap early closes)."""
    light = SimConfig(horizon_ms=30_000.0, arrival_rate_per_s=1.0,
                      delay_req_ms=8000.0, channel_sigma=0.0, proc_sigma=0.0)
    seq = np.mean([
        simulate(SPEC, light, seed=s).satisfied_pct for s in range(3)
    ])
    fleet = simulate_fleet(SPEC, light, n_rep=3, seed=0).satisfied_pct
    assert abs(seq - fleet) < 15.0, (seq, fleet)


# ---------------------------------------------------------------------------
# scenario-specific behaviour
# ---------------------------------------------------------------------------


def test_outage_masks_capacity_only_inside_window():
    scn = get_scenario("outage")
    m = SPEC.n_servers
    mid = 0.5 * CFG.horizon_ms
    scale = scn.capacity_scale(mid, CFG, SPEC.n_edge, m)
    assert scale is not None and scale[0] == 0.0 and scale[1:].min() == 1.0
    assert scn.capacity_scale(0.0, CFG, SPEC.n_edge, m) is None
    # the dead server serves nothing while it is down
    r_out = simulate(SPEC, CFG, scenario="outage", seed=3)
    r_base = simulate(SPEC, CFG, scenario="paper-default", seed=3)
    assert r_out.satisfied_pct <= r_base.satisfied_pct + 1e-9


def test_diurnal_and_flash_crowd_rates_vary_in_time():
    d = get_scenario("diurnal")
    peak = d.rate(0, 0.25 * CFG.horizon_ms, CFG)
    trough = d.rate(0, 0.75 * CFG.horizon_ms, CFG)
    assert peak > CFG.arrival_rate_per_s > trough
    assert d.rate_bound(0, CFG) >= peak

    f = get_scenario("flash-crowd")
    assert f.rate(0, 0.5 * CFG.horizon_ms, CFG) == pytest.approx(
        CFG.arrival_rate_per_s * f.burst_mult
    )
    assert f.rate(1, 0.5 * CFG.horizon_ms, CFG) == CFG.arrival_rate_per_s
    assert f.rate(0, 0.0, CFG) == CFG.arrival_rate_per_s


def test_hetero_tiers_qos_mixture():
    scn = get_scenario("hetero-tiers")
    rng = np.random.default_rng(0)
    draws = [scn.draw_qos(rng, CFG) for _ in range(400)]
    deadlines = {c for _, c in draws}
    assert deadlines == {
        CFG.delay_req_ms * scn.strict_deadline_mult,
        CFG.delay_req_ms * scn.lenient_deadline_mult,
    }
    strict_acc = [a for a, c in draws if c == CFG.delay_req_ms * scn.strict_deadline_mult]
    assert np.mean(strict_acc) > CFG.acc_req_mean + 10


def test_mobility_scenario_overrides_config():
    assert get_scenario("mobility").move_prob == 0.3
    assert get_scenario("paper-default").move_prob is None


def test_paper_default_arrivals_are_bit_identical_to_legacy_generator():
    """The base generator must consume RNG draws in the legacy inline order."""
    cfg = CFG
    rng = np.random.default_rng(11)
    reqs = get_scenario("paper-default").generate_arrivals(rng, SPEC.n_edge, 3, cfg)

    rng2 = np.random.default_rng(11)
    legacy = []
    for e in range(SPEC.n_edge):
        t = 0.0
        while t < cfg.horizon_ms:
            t += rng2.exponential(1000.0 / cfg.arrival_rate_per_s)
            if t >= cfg.horizon_ms:
                break
            legacy.append((
                t, e, int(rng2.integers(0, 3)),
                float(np.clip(rng2.normal(cfg.acc_req_mean, cfg.acc_req_std), 1, 99)),
                float(rng2.uniform(cfg.req_size_lo, cfg.req_size_hi)),
            ))
    legacy.sort(key=lambda x: x[0])
    assert len(reqs) == len(legacy)
    for r, (t, e, k, a, s) in zip(reqs, legacy):
        assert (r.arrival_ms, r.cover, r.service, r.A, r.size_bytes) == (t, e, k, a, s)
