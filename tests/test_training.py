"""Training substrate: optimizer math, loss, data determinism, checkpointing."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.training import (
    AdamWConfig,
    SyntheticLM,
    adamw_init,
    adamw_update,
    batch_iterator,
    cosine_schedule,
    cross_entropy,
    init_state,
    make_batch,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)

CFG = ModelConfig(
    family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, scan_layers=False,
)


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.1,
                      grad_clip=1e9)
    st = adamw_init(p)
    new_p, st2, m = adamw_update(g, st, p, cfg)

    lr = float(cosine_schedule(cfg)(jnp.int32(1)))
    gw = np.asarray(g["w"])
    mw = 0.1 * gw
    vw = 0.05 * gw ** 2
    mhat = mw / (1 - 0.9)
    vhat = vw / (1 - 0.95)
    want = np.asarray(p["w"]) - lr * (mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5, atol=1e-6)
    assert int(st2.step) == 1


def test_grad_clip_scales():
    p = {"w": jnp.ones((2, 2), jnp.float32)}
    g = {"w": jnp.full((2, 2), 100.0, jnp.float32)}
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, weight_decay=0.0)
    _, _, metrics = adamw_update(g, adamw_init(p), p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    s = cosine_schedule(cfg)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(s(jnp.int32(55))) < 1.0


def test_cross_entropy_uniform():
    V = 16
    logits = jnp.zeros((2, 3, V))
    labels = jnp.zeros((2, 3), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(V), rel=1e-5)


def test_loss_decreases_over_steps():
    model = Model(CFG)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3)))
    state = init_state(model, jax.random.PRNGKey(0))
    it = batch_iterator(CFG, 8, 32, seed=0)
    losses = []
    for _ in range(30):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_data_deterministic():
    a = SyntheticLM(256, seed=7).sample(np.random.default_rng(1), 2, 16)
    b = SyntheticLM(256, seed=7).sample(np.random.default_rng(1), 2, 16)
    np.testing.assert_array_equal(a, b)
    batch = make_batch(CFG, 2, 16, np.random.default_rng(0))
    np.testing.assert_array_equal(
        np.asarray(batch["tokens"][:, 1:]), np.asarray(batch["labels"][:, :-1])
    )


def test_checkpoint_roundtrip_trainstate():
    model = Model(CFG)
    state = init_state(model, jax.random.PRNGKey(0))
    tree = {"params": state.params, "m": state.opt.m}
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(os.path.join(d, "ck.npz"), tree, step=5)
        restored, step = restore_checkpoint(path, tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(os.path.join(d, "ck.npz"), {"a": jnp.ones(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"a": jnp.ones(3), "b": jnp.ones(2)})
