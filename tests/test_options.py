"""The consolidated ``EngineOptions`` API (``options=``).

The contract under test (see ``docs/architecture.md``):

* old-style per-call keywords and ``options=EngineOptions(...)`` resolve to
  the same configuration, so the two spellings produce **bit-identical**
  results — checked for every vmappable policy, congestion on and off;
* any deprecated per-call keyword emits one ``DeprecationWarning``; mixing
  them with an explicit ``options=`` raises (never a silent merge);
* ``resolve_options`` / ``resolve_backend`` enforce one precedence order:
  explicit argument > environment variable > scenario default > built-in;
* fleet sizing knobs validate loudly: ``rep_group < 1`` raises,
  ``rep_group > n_rep`` clamps (bit-identical to ``rep_group=n_rep``), and
  an unsatisfiable ``devices=`` request names both the requested and the
  visible device count.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    CongestionConfig,
    EngineOptions,
    SimConfig,
    demo_cluster_spec,
    get_policy,
    get_scenario,
    list_policies,
    resolve_backend,
    resolve_options,
    simulate,
    simulate_fleet,
)
from repro.core.options import (  # noqa: E402
    ENV_BACKEND,
    ENV_RNG_MODE,
    ENV_SCHEDULER,
)

VMAPPABLE = [p for p in list_policies() if get_policy(p).vmappable]
SPEC = demo_cluster_spec()
N_DEV = jax.local_device_count()


def fleet_cfg(congestion: bool = False, **kw) -> SimConfig:
    base = dict(
        horizon_ms=12_000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=congestion),
    )
    base.update(kw)
    return SimConfig(**base)


def assert_fleet_identical(a, b):
    """Bitwise equality of every result array two fleet runs produce."""
    assert a.n_rep == b.n_rep
    assert a.n_frames == b.n_frames
    assert a.n_requests == b.n_requests
    assert a.n_served == b.n_served
    np.testing.assert_array_equal(a.satisfied_per_rep, b.satisfied_per_rep)
    np.testing.assert_array_equal(a.mean_us_per_rep, b.mean_us_per_rep)
    assert (a.final_backlog_per_rep is None) == (b.final_backlog_per_rep is None)
    if a.final_backlog_per_rep is not None:
        np.testing.assert_array_equal(
            a.final_backlog_per_rep, b.final_backlog_per_rep
        )
    assert a.mean_compute_inflation == b.mean_compute_inflation


# ---------------------------------------------------------------------------
# old-style keywords vs options= : bit-identical results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
@pytest.mark.parametrize("policy", VMAPPABLE)
def test_fleet_kwargs_vs_options_bitwise(policy, congestion):
    cfg = fleet_cfg(congestion)
    with pytest.warns(DeprecationWarning):
        old = simulate_fleet(
            SPEC, cfg, policy=policy, n_rep=4, seed=0,
            rng_mode="paper-default", window=2,
        )
    new = simulate_fleet(
        SPEC, cfg, policy=policy, n_rep=4, seed=0,
        options=EngineOptions(rng_mode="paper-default", window=2),
    )
    assert_fleet_identical(old, new)


def test_simulate_kwargs_vs_options_bitwise():
    cfg = fleet_cfg()
    with pytest.warns(DeprecationWarning):
        old = simulate(SPEC, cfg, policy="gus", seed=0, rng_mode="vectorized")
    new = simulate(
        SPEC, cfg, policy="gus", seed=0,
        options=EngineOptions(rng_mode="vectorized"),
    )
    assert old.as_dict() == new.as_dict()


def test_options_only_emits_no_deprecation_warning():
    cfg = fleet_cfg()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        simulate(SPEC, cfg, policy="gus", seed=0, options=EngineOptions())
        simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=2, seed=0, options=EngineOptions()
        )


# ---------------------------------------------------------------------------
# deprecation warnings and the conflict error
# ---------------------------------------------------------------------------

def test_deprecated_kwarg_warns_with_name():
    cfg = fleet_cfg()
    with pytest.warns(DeprecationWarning, match="streaming"):
        simulate(SPEC, cfg, policy="gus", seed=0, streaming=False)
    with pytest.warns(DeprecationWarning, match="prefetch"):
        simulate_fleet(SPEC, cfg, policy="gus", n_rep=2, seed=0, prefetch=0)


def test_options_plus_deprecated_kwarg_conflicts():
    cfg = fleet_cfg()
    with pytest.raises(ValueError, match="rng_mode"):
        simulate(
            SPEC, cfg, policy="gus", seed=0,
            options=EngineOptions(), rng_mode="vectorized",
        )
    with pytest.raises(ValueError, match="window"):
        simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=2, seed=0,
            options=EngineOptions(), window=2,
        )


# ---------------------------------------------------------------------------
# resolution precedence: explicit > env > scenario > built-in
# ---------------------------------------------------------------------------

def test_builtin_defaults():
    r = resolve_options(None, env={})
    assert r.rng_mode == "paper-default"
    assert r.streaming is False
    assert r.scheduler == "dense"
    assert r.backend is None
    assert r.prefetch == 1


def test_env_beats_builtin_default():
    r = resolve_options(
        None,
        env={ENV_RNG_MODE: "vectorized", ENV_SCHEDULER: "hierarchical"},
    )
    assert r.rng_mode == "vectorized"
    assert r.scheduler == "hierarchical"


def test_explicit_beats_env():
    r = resolve_options(
        EngineOptions(rng_mode="paper-default", scheduler="dense"),
        env={ENV_RNG_MODE: "vectorized", ENV_SCHEDULER: "hierarchical"},
    )
    assert r.rng_mode == "paper-default"
    assert r.scheduler == "dense"


def test_scenario_default_fills_unset_fields():
    scn = get_scenario("mega-city")  # streaming=True, rng_mode="vectorized"
    r = resolve_options(None, scenario=scn, env={})
    assert r.streaming is True
    assert r.rng_mode == "vectorized"


def test_env_beats_scenario_default():
    scn = get_scenario("mega-city")
    r = resolve_options(None, scenario=scn, env={ENV_RNG_MODE: "paper-default"})
    assert r.rng_mode == "paper-default"
    assert r.streaming is True  # no env var for streaming: scenario wins


def test_explicit_beats_scenario_default():
    scn = get_scenario("mega-city")
    r = resolve_options(
        EngineOptions(streaming=False, rng_mode="paper-default"),
        scenario=scn,
        env={},
    )
    assert r.streaming is False
    assert r.rng_mode == "paper-default"


def test_invalid_env_value_raises():
    with pytest.raises(ValueError, match=ENV_RNG_MODE):
        resolve_options(None, env={ENV_RNG_MODE: "bogus"})
    with pytest.raises(ValueError, match=ENV_SCHEDULER):
        resolve_options(None, env={ENV_SCHEDULER: "bogus"})
    with pytest.raises(ValueError, match=ENV_BACKEND):
        resolve_backend(None, env={ENV_BACKEND: "bogus"})


def test_resolve_backend_precedence():
    assert resolve_backend(None, env={}) == "xla"
    assert resolve_backend(None, env={ENV_BACKEND: "pallas"}) == "pallas"
    assert resolve_backend("xla", env={ENV_BACKEND: "pallas"}) == "xla"
    with pytest.raises(ValueError, match="bogus"):
        resolve_backend("bogus", env={})


def test_invalid_backend_in_options_raises_early():
    with pytest.raises(ValueError, match="bogus"):
        resolve_options(EngineOptions(backend="bogus"), env={})


def test_env_read_from_process_environment(monkeypatch):
    monkeypatch.setenv(ENV_RNG_MODE, "vectorized")
    monkeypatch.setenv(ENV_SCHEDULER, "hierarchical")
    r = resolve_options(None)
    assert r.rng_mode == "vectorized"
    assert r.scheduler == "hierarchical"


def test_resolve_is_idempotent():
    scn = get_scenario("mega-city")
    once = resolve_options(EngineOptions(window=3), scenario=scn, env={})
    twice = resolve_options(once, scenario=get_scenario("paper-default"), env={})
    assert once == twice  # resolved fields never re-defer


def test_prefetch_clamps_and_sizes_validate():
    assert resolve_options(EngineOptions(prefetch=-3), env={}).prefetch == 0
    for field in ("window", "devices", "rep_group"):
        with pytest.raises(ValueError, match=field):
            resolve_options(EngineOptions(**{field: 0}), env={})


def test_options_type_checked():
    with pytest.raises(TypeError, match="EngineOptions"):
        resolve_options({"rng_mode": "vectorized"}, env={})


# ---------------------------------------------------------------------------
# fleet sizing knobs: rep_group edge cases, devices error message
# ---------------------------------------------------------------------------

def test_rep_group_below_one_raises():
    cfg = fleet_cfg()
    with pytest.raises(ValueError, match="rep_group"):
        simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=4, seed=0,
            options=EngineOptions(rep_group=0),
        )


def test_rep_group_above_n_rep_clamps_bitwise():
    cfg = fleet_cfg()
    big = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=4, seed=0,
        options=EngineOptions(rep_group=64),
    )
    exact = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=4, seed=0,
        options=EngineOptions(rep_group=4),
    )
    assert_fleet_identical(big, exact)


def test_devices_error_names_requested_and_available():
    cfg = fleet_cfg()
    want = N_DEV + 3
    with pytest.raises(ValueError) as ei:
        simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=4, seed=0,
            options=EngineOptions(devices=want),
        )
    msg = str(ei.value)
    assert str(want) in msg and str(N_DEV) in msg


def test_engine_options_is_frozen_and_replaceable():
    opts = EngineOptions(window=2)
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.window = 3
    assert dataclasses.replace(opts, prefetch=0).window == 2
