"""Virtual-testbed simulator: frame protocol, capacity budgets, EMA estimator."""
import numpy as np
import jax.numpy as jnp

from repro.core import ClusterSpec, SimConfig, gus_schedule_np, local_all, offload_all, simulate


def tiny_spec(edge_gamma=3900.0, cloud_gamma=3000.0, eta=350.0):
    M, K, L = 3, 2, 2
    proc = np.zeros((M, K, L), np.float32)
    proc[0] = proc[1] = [[650.0, 1300.0], [650.0, 1300.0]]
    proc[2] = [[150.0, 300.0], [150.0, 300.0]]
    placed = np.ones((M, K, L), bool)
    acc = np.array([[55.0, 80.0], [55.0, 80.0]], np.float32)
    return ClusterSpec(
        n_edge=2,
        n_cloud=1,
        gamma_frame=np.array([edge_gamma, edge_gamma, cloud_gamma], np.float32),
        eta_frame=np.array([eta, eta, 10 * eta], np.float32),
        proc_ms=proc,
        placed=placed,
        acc=acc,
    )


def cfg(rate=1.0, **kw):
    return SimConfig(
        horizon_ms=kw.pop("horizon_ms", 30_000.0),
        arrival_rate_per_s=rate,
        delay_req_ms=kw.pop("delay_req_ms", 6000.0),
        acc_req_mean=kw.pop("acc_req_mean", 50.0),
        **kw,
    )


def test_counts_add_up():
    r = simulate(tiny_spec(), cfg(), gus_schedule_np, seed=0)
    assert r.n_served + r.n_dropped == r.n_requests
    assert r.n_local + r.n_cloud + r.n_edge_offload == r.n_served
    assert 0 <= r.satisfied_pct <= 100


def test_deterministic_given_seed():
    a = simulate(tiny_spec(), cfg(), gus_schedule_np, seed=3).as_dict()
    b = simulate(tiny_spec(), cfg(), gus_schedule_np, seed=3).as_dict()
    assert a == b


def test_overload_causes_drops():
    light = simulate(tiny_spec(), cfg(rate=0.5), gus_schedule_np, seed=0)
    heavy = simulate(tiny_spec(), cfg(rate=12.0), gus_schedule_np, seed=0)
    assert heavy.satisfied_pct < light.satisfied_pct
    assert heavy.n_dropped > 0


def test_capacity_budget_not_refreshed_by_early_decisions():
    """Queue-cap-triggered early decisions must share the frame budget: with
    per-frame cloud capacity for ~2 requests, a 10x overload cannot satisfy
    much more than capacity even though decisions fire many times per frame."""
    spec = tiny_spec(edge_gamma=1300.0, cloud_gamma=600.0)
    r = simulate(spec, cfg(rate=10.0, queue_cap=2), gus_schedule_np, seed=0)
    # capacity: per frame, 2 edges x 1 (1300/1300) + cloud 2 (600/300) = ~4
    frames = 30_000.0 / 3000.0
    assert r.n_served <= 4.5 * frames + 8, (r.n_served, frames)


def test_accuracy_floor_respected():
    spec = tiny_spec()
    r = simulate(spec, cfg(acc_req_mean=90.0), gus_schedule_np, seed=0)
    assert r.n_served == 0  # no variant reaches 90%
    r2 = simulate(spec, cfg(acc_req_mean=70.0), gus_schedule_np, seed=0)
    # only the 80%-accurate (big) variants qualify
    assert r2.n_served > 0


def test_local_all_never_offloads():
    r = simulate(tiny_spec(), cfg(), lambda i: local_all(i), seed=0)
    assert r.n_cloud == 0 and r.n_edge_offload == 0


def test_offload_all_never_local():
    r = simulate(
        tiny_spec(), cfg(),
        lambda i: offload_all(i, jnp.arange(3) >= 2), seed=0,
    )
    assert r.n_local == 0 and r.n_edge_offload == 0


def test_bandwidth_ema_tracks_channel():
    """E[B_{t+1}] = (B_t + B_{t-1})/2 should converge near the true bandwidth
    even from a bad initial estimate."""
    spec = tiny_spec()
    spec.bandwidth_true = 900.0
    c = cfg(rate=2.0, horizon_ms=60_000.0, bandwidth_init=100.0, channel_sigma=0.05)
    r = simulate(spec, c, lambda i: offload_all(i, jnp.arange(3) >= 2), seed=0)
    est = r.bandwidth_estimates
    assert len(est) > 3
    assert abs(est[-1] - 900.0) / 900.0 < 0.35, est[-5:]
    assert abs(est[-1] - 900.0) < abs(est[0] - 900.0)
