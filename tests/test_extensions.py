"""Beyond-paper extensions: ordered GUS, priorities, mobility."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (
    GeneratorConfig,
    apply_mobility,
    generate_instance,
    gus_schedule,
    gus_schedule_ordered,
    mean_us,
    satisfied_mask,
    simulate,
    solve_bnb,
    gus_schedule_np,
)

CONTENDED = GeneratorConfig(
    n_requests=10, n_edge=3, n_cloud=1, n_services=5, n_variants=3,
    edge_compute_classes=(400.0, 600.0, 800.0),
    edge_comm_classes=(60.0, 90.0, 120.0),
    cloud_compute=1600.0, cloud_comm=300.0,
)


def _cap_qos_ok(inst, a):
    j = np.asarray(a.j); l = np.asarray(a.l)
    gamma = np.asarray(inst.gamma).copy(); eta = np.asarray(inst.eta).copy()
    cover = np.asarray(inst.cover)
    for i in range(len(j)):
        if j[i] < 0:
            continue
        if not inst.avail[i, j[i], l[i]]:
            return False
        gamma[j[i]] -= inst.v[i, j[i], l[i]]
        if j[i] != cover[i]:
            eta[cover[i]] -= inst.u[i, j[i], l[i]]
    return (gamma >= -1e-4).all() and (eta >= -1e-4).all()


@pytest.mark.parametrize("seed", range(8))
def test_ordered_respects_constraints(seed):
    inst = generate_instance(seed, CONTENDED)
    a = gus_schedule_ordered(inst)
    assert _cap_qos_ok(inst, a)
    sat = np.asarray(satisfied_mask(inst, a.j, a.l))
    assert (sat == (np.asarray(a.j) >= 0)).all()


def test_ordered_improves_on_average():
    base, ordered = [], []
    for seed in range(20):
        inst = generate_instance(seed, CONTENDED)
        _, opt = solve_bnb(inst)
        if opt < 1e-9:
            continue
        base.append(float(mean_us(inst, *_jl(gus_schedule(inst)))) / opt)
        ordered.append(float(mean_us(inst, *_jl(gus_schedule_ordered(inst)))) / opt)
    assert np.mean(ordered) >= np.mean(base)
    assert np.mean(ordered) > 0.95  # near-optimal in the contended regime


def _jl(a):
    return a.j, a.l


def test_priority_shifts_allocation():
    """With priority, a high-priority request wins the contested slot."""
    inst = generate_instance(3, CONTENDED)
    N = inst.n_requests
    pri = jnp.ones(N)
    a0 = gus_schedule_ordered(inst, priority=pri)
    # give max priority to requests dropped under uniform priority
    dropped = np.asarray(a0.j) < 0
    if dropped.any():
        pri = jnp.where(jnp.asarray(dropped), 100.0, 0.1)
        a1 = gus_schedule_ordered(inst, priority=pri)
        served_now = (np.asarray(a1.j) >= 0) & dropped
        # a previously-dropped request is served iff it was serveable at all
        # under FRESH capacity (QoS-feasible AND fits some server's gamma)
        from repro.core import hard_feasible

        feas = np.asarray(hard_feasible(inst))
        fits = feas & (np.asarray(inst.v) <= np.asarray(inst.gamma)[None, :, None])
        # offloading also needs comm capacity at the covering edge (2e)
        cover = np.asarray(inst.cover)
        is_local = cover[:, None] == np.arange(inst.n_servers)[None, :]
        eta_ok = is_local[:, :, None] | (
            np.asarray(inst.u) <= np.asarray(inst.eta)[cover][:, None, None]
        )
        fits &= eta_ok
        serveable_dropped = fits.any(axis=(1, 2)) & dropped
        if serveable_dropped.any():
            assert served_now.any()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_ordered_constraints(seed):
    inst = generate_instance(seed, CONTENDED)
    assert _cap_qos_ok(inst, gus_schedule_ordered(inst))


def test_mobility_reattaches_users():
    rng = np.random.default_rng(0)
    cover = np.zeros(1000, np.int32)
    moved = apply_mobility(cover, n_edge=4, move_prob=0.3, rng=rng)
    frac = (moved != 0).mean()  # ~0.3 * 3/4
    assert 0.1 < frac < 0.35


def test_simulator_with_mobility_runs():
    from tests.test_simulator import cfg, tiny_spec

    r0 = simulate(tiny_spec(), cfg(), gus_schedule_np, seed=0)
    r1 = simulate(tiny_spec(), cfg(move_prob=0.5), gus_schedule_np, seed=0)
    assert r1.n_requests == r0.n_requests
    assert r1.n_served > 0
