"""End-to-end behaviour tests for the paper's system: zoo -> profiles ->
GUS scheduling -> serving, plus the launch/dry-run machinery on a test mesh."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_zoo import SQUEEZE_LM
from repro.core import (
    SimConfig,
    gus_schedule_np,
    local_all,
    offload_all,
    simulate,
)
from repro.models import Model
from repro.serving import ModelZoo, ServiceSpec, ServingEngine, build_cluster_spec, variant_ladder
from repro.training import make_batch


def test_zoo_to_schedule_to_serve_end_to_end():
    """The full paper pipeline at test scale: profiles from real configs feed
    GUS; GUS beats local-all/offload-all under load; served mix is sane."""
    zoo = ModelZoo(
        [
            ServiceSpec("svc-a", variant_ladder(get_config("mamba2-130m"), 3)),
            ServiceSpec("svc-b", variant_ladder(get_config("yi-9b"), 3)),
        ]
    )
    spec = build_cluster_spec(zoo, ["edge-1", "edge-1"], ["cloud-256"],
                              edge_variants=2, edge_service_frac=1.0, seed=0)
    # normalize to testbed-like latencies and tight capacity
    for j in range(2):
        m = spec.proc_ms[j][spec.placed[j]].max()
        spec.proc_ms[j] *= 1300.0 / m
    m = spec.proc_ms[2][spec.placed[2]].max()
    spec.proc_ms[2] *= 300.0 / m
    spec.gamma_frame = np.array([3900.0, 3900.0, 1500.0], np.float32)
    spec.eta_frame = np.array([250.0, 250.0, 2500.0], np.float32)

    cfg = SimConfig(horizon_ms=60_000.0, arrival_rate_per_s=5.0,
                    delay_req_ms=5000.0, acc_req_mean=50.0)
    gus = simulate(spec, cfg, gus_schedule_np, seed=0)
    loc = simulate(spec, cfg, lambda i: local_all(i), seed=0)
    off = simulate(spec, cfg, lambda i: offload_all(i, jnp.arange(3) >= 2), seed=0)
    assert gus.satisfied_pct >= loc.satisfied_pct
    assert gus.satisfied_pct >= off.satisfied_pct
    assert gus.n_local + gus.n_cloud > 0  # actually mixes tiers


def test_engine_latency_feeds_scheduler():
    """Measured engine latencies can be injected as T^proc overrides."""
    model = Model(SQUEEZE_LM)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params)
    r = eng.generate(make_batch(SQUEEZE_LM, 1, 16, np.random.default_rng(0)), 4)
    measured = {(0, 0, 0): r.total_ms}
    zoo = ModelZoo([ServiceSpec("svc", [SQUEEZE_LM])])
    spec = build_cluster_spec(
        zoo, ["edge-1"], ["cloud-256"], edge_service_frac=1.0,
        edge_variants=1, measured_proc=measured, seed=0,
    )
    assert spec.proc_ms[0, 0, 0] == pytest.approx(r.total_ms)


def test_dryrun_pipeline_on_test_mesh():
    """The exact dry-run path (specs -> sharded step -> lower -> compile ->
    roofline terms) on a 1-device mesh with a reduced config."""
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import ShapeSpec, model_flops
    from repro.launch.steps import build_serve_step, build_train_step
    from repro.roofline import roofline_terms

    cfg = reduce_for_smoke(get_config("yi-9b"))
    model = Model(cfg)
    mesh = make_test_mesh(1, 1)
    shape = ShapeSpec("tiny_train", seq_len=32, global_batch=4, kind="train")
    fn, args = build_train_step(model, mesh, shape)
    with mesh:
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    rep = roofline_terms(
        arch=cfg.arch_id, shape=shape.name, mesh_name="1x1", n_devices=1,
        cost_analysis=cost, hlo_text=compiled.as_text(),
        model_flops_total=model_flops(cfg, shape),
    )
    assert rep.flops_per_device > 0
    assert rep.bottleneck in ("compute", "memory", "collective")

    dshape = ShapeSpec("tiny_dec", seq_len=64, global_batch=4, kind="decode")
    fn, args = build_serve_step(model, mesh, dshape)
    with mesh:
        compiled = fn.lower(*args).compile()
    assert compiled is not None


def test_input_specs_cover_all_archs_and_shapes():
    from repro.configs import ARCH_IDS
    from repro.launch.specs import SHAPES, input_specs, shape_config

    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            cfg = shape_config(get_config(arch), shape)
            spec = input_specs(cfg, shape)
            assert spec["tokens"].shape == (shape.global_batch, shape.seq_len)
            if cfg.family == "vlm":
                assert "vision_embeds" in spec
            if cfg.family == "encdec":
                assert "enc_embeds" in spec
            if shape.name == "long_500k" and cfg.family != "ssm":
                # sub-quadratic carve-out: attention archs get a window
                assert cfg.sliding_window is not None
                assert cfg.sliding_window <= 8192
