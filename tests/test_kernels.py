"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all interpret=True against the ref.py pure-jnp oracles (spec requirement).

The deterministic sweeps always run — default CPU CI must exercise every
kernel's interpret path, so only the Hypothesis property tests (at the
bottom) are gated on the optional dev dependency (requirements-dev.txt)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional dev dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import generate_batch, gus_schedule_np
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gus_pallas import gus_assign_pallas
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,hd,win,bq,bk",
    [
        (2, 4, 2, 64, 32, None, 32, 32),
        (1, 8, 2, 128, 64, None, 64, 32),
        (2, 4, 4, 96, 32, 24, 32, 32),
        (1, 2, 1, 256, 128, 128, 128, 128),
        (1, 4, 1, 80, 16, None, 32, 32),  # ragged q blocks
    ],
)
def test_flash_vs_ref(B, H, KV, S, hd, win, bq, bk, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, S, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, KV, S, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, KV, S, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, window=win, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------- decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,KV,rep,T,hd,bk",
    [
        (2, 2, 2, 100, 32, 32),
        (1, 8, 1, 256, 64, 64),
        (3, 2, 3, 33, 16, 16),
        (2, 4, 2, 500, 128, 128),
    ],
)
def test_decode_vs_ref(B, KV, rep, T, hd, bk, dtype):
    H = KV * rep
    q = jnp.asarray(RNG.standard_normal((B, KV, rep, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, KV, T, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, KV, T, hd)), dtype)
    valid = jnp.asarray(RNG.random((B, T)) < 0.8).at[:, 0].set(True)
    out = decode_attention(q, k, v, valid, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q.reshape(B, H, hd), k, v, valid)
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, H, hd), np.float32),
        np.asarray(want, np.float32),
        **_tol(dtype),
    )


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "B,H,S,P,N,Q",
    [
        (2, 3, 64, 16, 8, 16),
        (1, 4, 128, 32, 16, 32),
        (2, 2, 256, 64, 128, 64),
        (1, 2, 128, 64, 128, 128),
    ],
)
def test_ssd_vs_ref(B, H, S, P, N, Q, dtype):
    x = jnp.asarray(RNG.standard_normal((B, H, S, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, H, S)), dtype)
    A = jnp.asarray(-RNG.uniform(0.5, 4, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, H, S, N)), dtype)
    Cm = jnp.asarray(RNG.standard_normal((B, H, S, N)), dtype)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------- gus
@pytest.mark.parametrize("B,cfg_kw", [
    (1, dict(n_requests=10, n_edge=3, n_cloud=1, n_services=5, n_variants=3)),
    (4, dict(n_requests=16, n_edge=4, n_cloud=1, n_services=8, n_variants=4)),
])
def test_gus_kernel_vs_oracle(B, cfg_kw):
    """The raw fused kernel (one grid program per frame) reproduces the
    NumPy oracle's assignments bit-for-bit — integer outputs, exact bar.
    The full dispatch/padding/relaxation surface is covered by
    tests/test_gus_parity.py; this pins the kernel entry point itself."""
    from repro.core import GeneratorConfig

    batch = generate_batch(0, B, GeneratorConfig(**cfg_kw))
    j, l = gus_assign_pallas(
        batch.cover, batch.A, batch.C, batch.w_a, batch.w_c,
        batch.acc, batch.ctime, batch.v, batch.u, batch.avail,
        batch.gamma, batch.eta, batch.max_as, batch.max_cs,
        interpret=True,
    )
    assert j.dtype == jnp.int32 and l.dtype == jnp.int32
    for b in range(B):
        want = gus_schedule_np(jax.tree.map(lambda x: np.asarray(x)[b], batch))
        np.testing.assert_array_equal(np.asarray(j[b]), np.asarray(want.j))
        np.testing.assert_array_equal(np.asarray(l[b]), np.asarray(want.l))


def test_gus_kernel_vmap_matches_grid():
    """vmap-of-kernel (the fleet runner's lifting) equals the native grid."""
    from repro.core import GeneratorConfig

    batch = generate_batch(3, 3, GeneratorConfig(
        n_requests=12, n_edge=3, n_cloud=1, n_services=6, n_variants=3))

    def one(inst_leaves):
        add = lambda x: x[None]  # noqa: E731
        j, l = gus_assign_pallas(*[add(x) for x in inst_leaves], interpret=True)
        return j[0], l[0]

    leaves = (batch.cover, batch.A, batch.C, batch.w_a, batch.w_c,
              batch.acc, batch.ctime, batch.v, batch.u, batch.avail,
              batch.gamma, batch.eta, batch.max_as, batch.max_cs)
    jv, lv = jax.vmap(one)(leaves)
    jg, lg = gus_assign_pallas(*leaves, interpret=True)
    np.testing.assert_array_equal(np.asarray(jv), np.asarray(jg))
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lg))


# ----------------------------------------------------- model-integration
def test_model_uses_kernels():
    """use_pallas=True routes attention/SSD through the kernels and matches
    the pure-jnp model to within bf16-free f32 tolerance."""
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models import Model
    from repro.training import make_batch

    for family, kw in [
        ("dense", dict(num_heads=4, num_kv_heads=2, d_ff=128)),
        ("ssm", dict(num_heads=1, num_kv_heads=1, d_ff=0, ssm_state=16, ssm_headdim=32, ssd_chunk=32)),
    ]:
        cfg = ModelConfig(family=family, num_layers=2, d_model=64, vocab_size=128,
                          scan_layers=False, **kw)
        m_ref = Model(cfg)
        m_ker = Model(dataclasses.replace(cfg, use_pallas=True))
        params = m_ref.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 2, 64, np.random.default_rng(0))
        lr, _ = m_ref.forward(params, batch)
        lk, _ = m_ker.forward(params, batch)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lk), rtol=5e-3, atol=5e-3)


# ------------------------------------------------- hypothesis properties
if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        s_blocks=st.integers(1, 6),
        hd_pow=st.integers(4, 7),
        kv=st.sampled_from([1, 2, 4]),
        rep=st.sampled_from([1, 2, 4]),
    )
    def test_flash_property(s_blocks, hd_pow, kv, rep):
        S = 32 * s_blocks
        hd = 2 ** hd_pow
        H = kv * rep
        q = jnp.asarray(RNG.standard_normal((1, H, S, hd)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((1, kv, S, hd)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, kv, S, hd)), jnp.float32)
        out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(t=st.integers(9, 300), kv=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2]))
    def test_decode_property(t, kv, rep):
        q = jnp.asarray(RNG.standard_normal((1, kv, rep, 32)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((1, kv, t, 32)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, kv, t, 32)), jnp.float32)
        valid = jnp.ones((1, t), bool)
        out = decode_attention(q, k, v, valid, block_k=64, interpret=True)
        want = ref.decode_attention_ref(q.reshape(1, kv * rep, 32), k, v, valid)
        np.testing.assert_allclose(
            np.asarray(out.reshape(1, -1, 32)), np.asarray(want), rtol=2e-4, atol=2e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(nc=st.integers(1, 5), p=st.sampled_from([16, 32, 64]), n=st.sampled_from([8, 16, 64]))
    def test_ssd_property(nc, p, n):
        S = 32 * nc
        x = jnp.asarray(RNG.standard_normal((1, 2, S, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (1, 2, S)), jnp.float32)
        A = jnp.asarray(-RNG.uniform(0.5, 4, (2,)), jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((1, 2, S, n)), jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((1, 2, S, n)), jnp.float32)
        out = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
        want = ref.ssd_ref(x, dt, A, Bm, Cm, 32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 20))
    def test_gus_kernel_property(seed, n):
        from repro.core import GeneratorConfig, generate_instance

        inst = generate_instance(
            seed, GeneratorConfig(n_requests=n, n_edge=3, n_cloud=1,
                                  n_services=6, n_variants=3))
        add = lambda x: jnp.asarray(x)[None]  # noqa: E731
        j, l = gus_assign_pallas(
            add(inst.cover), add(inst.A), add(inst.C), add(inst.w_a), add(inst.w_c),
            add(inst.acc), add(inst.ctime), add(inst.v), add(inst.u), add(inst.avail),
            add(inst.gamma), add(inst.eta), add(inst.max_as), add(inst.max_cs),
            interpret=True,
        )
        want = gus_schedule_np(inst)
        np.testing.assert_array_equal(np.asarray(j[0]), np.asarray(want.j))
        np.testing.assert_array_equal(np.asarray(l[0]), np.asarray(want.l))
