"""Sharded multi-device fleet: bitwise parity and the devices/window API.

The contract under test (see ``docs/architecture.md`` section 6):

* ``simulate_fleet(devices=d)`` is **bit-identical** to the single-device
  run for every vmappable policy, congestion on or off — replications are
  dispatched as fixed-width groups, and every group runs the same compiled
  program no matter how many devices are in play;
* ``simulate_fleet(window=W)`` (bounded-memory windowed scan) is
  bit-identical to the fully materialized run, on materialized and
  streaming scenarios alike, with and without sharding;
* asking for more devices than ``jax.local_device_count()`` raises a clear
  error — never a silent fallback.

The multi-device cases need >= 2 devices; CI runs them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the
``multi-device`` job).  On a single-device host they skip.
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    CongestionConfig,
    SimConfig,
    demo_cluster_spec,
    get_policy,
    list_policies,
    simulate,
    simulate_fleet,
)

N_DEV = jax.local_device_count()
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 devices; run with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

VMAPPABLE = [p for p in list_policies() if get_policy(p).vmappable]

SPEC = demo_cluster_spec()


def fleet_cfg(congestion: bool = False, **kw) -> SimConfig:
    base = dict(
        horizon_ms=18_000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=congestion),
    )
    base.update(kw)
    return SimConfig(**base)


def assert_fleet_identical(a, b, msg=""):
    """Every numeric field of two FleetResults must match bit for bit."""
    assert a.n_requests == b.n_requests, msg
    assert a.n_served == b.n_served, msg
    np.testing.assert_array_equal(a.satisfied_per_rep, b.satisfied_per_rep, err_msg=msg)
    np.testing.assert_array_equal(a.mean_us_per_rep, b.mean_us_per_rep, err_msg=msg)
    if a.final_backlog_per_rep is None:
        assert b.final_backlog_per_rep is None, msg
    else:
        np.testing.assert_array_equal(
            a.final_backlog_per_rep, b.final_backlog_per_rep, err_msg=msg
        )
        assert a.mean_compute_inflation == b.mean_compute_inflation, msg


# ---------------------------------------------------------------------------
# Sharded vs single-device bitwise parity
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("policy", VMAPPABLE)
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_sharded_bitwise_parity_every_policy(policy, congestion):
    cfg = fleet_cfg(congestion)
    single = simulate_fleet(SPEC, cfg, policy=policy, n_rep=12, seed=0, devices=1)
    sharded = simulate_fleet(SPEC, cfg, policy=policy, n_rep=12, seed=0, devices=N_DEV)
    assert single.n_devices == 1 and sharded.n_devices == N_DEV
    assert_fleet_identical(single, sharded, f"{policy} congestion={congestion}")


@multi_device
def test_sharded_parity_on_uneven_and_padded_replication_counts():
    """n_rep that divides neither the group width nor the mesh still matches
    (throwaway padding replications are sliced back out)."""
    cfg = fleet_cfg(congestion=True)
    for n_rep in (1, 3, 5, 11):
        single = simulate_fleet(SPEC, cfg, policy="gus", n_rep=n_rep, seed=1, devices=1)
        sharded = simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=n_rep, seed=1, devices=min(N_DEV, 4)
        )
        assert_fleet_identical(single, sharded, f"n_rep={n_rep}")


@multi_device
def test_default_devices_uses_every_local_device_and_stays_bitwise():
    cfg = fleet_cfg()
    auto = simulate_fleet(SPEC, cfg, policy="gus", n_rep=2 * N_DEV, seed=0)
    assert auto.n_devices == N_DEV
    single = simulate_fleet(SPEC, cfg, policy="gus", n_rep=2 * N_DEV, seed=0, devices=1)
    assert_fleet_identical(single, auto)


def test_requesting_too_many_devices_raises_not_falls_back():
    with pytest.raises(ValueError, match="local device"):
        simulate_fleet(
            SPEC, fleet_cfg(), policy="gus", n_rep=2, seed=0, devices=N_DEV + 1
        )
    with pytest.raises(ValueError, match="devices"):
        simulate_fleet(SPEC, fleet_cfg(), policy="gus", n_rep=2, seed=0, devices=0)


def test_single_device_request_always_works():
    fr = simulate_fleet(SPEC, fleet_cfg(), policy="gus", n_rep=2, seed=0, devices=1)
    assert fr.n_devices == 1 and fr.n_requests > 0


# ---------------------------------------------------------------------------
# Windowed (bounded-memory) vs materialized bitwise parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["paper-default", "diurnal-week", "flash-crowd"])
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_windowed_fleet_matches_materialized(scenario, congestion):
    """The windowed scan (including lazy per-window streaming arrivals on
    diurnal-week) reproduces the one-shot fleet bit for bit."""
    cfg = fleet_cfg(congestion)
    full = simulate_fleet(SPEC, cfg, policy="gus", n_rep=2, seed=0, scenario=scenario)
    for window in (1, 2, 5):
        windowed = simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=2, seed=0, scenario=scenario, window=window
        )
        assert windowed.window == window
        assert_fleet_identical(full, windowed, f"{scenario} window={window}")


def test_windowed_fleet_with_keyed_policy_keeps_the_key_chain():
    """`random` draws one key per (rep, frame) from a chain precomputed up
    front, so windowing must not change what it schedules."""
    cfg = fleet_cfg()
    full = simulate_fleet(SPEC, cfg, policy="random", n_rep=3, seed=7)
    windowed = simulate_fleet(SPEC, cfg, policy="random", n_rep=3, seed=7, window=2)
    assert_fleet_identical(full, windowed)


@multi_device
def test_windowed_and_sharded_compose():
    cfg = fleet_cfg(congestion=True)
    full = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=4, seed=0, scenario="diurnal-week", devices=1
    )
    both = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=4, seed=0, scenario="diurnal-week",
        devices=min(N_DEV, 4), window=3,
    )
    assert_fleet_identical(full, both)


def test_window_bounds_memory_not_results_on_long_horizon():
    """A longer streaming horizon through small windows still matches the
    materialized run (the count pre-pass pins one shared padding bucket)."""
    cfg = fleet_cfg(horizon_ms=90_000.0)
    full = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=2, seed=3, scenario="sustained-overload"
    )
    windowed = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=2, seed=3, scenario="sustained-overload",
        window=4,
    )
    assert_fleet_identical(full, windowed)


# ---------------------------------------------------------------------------
# Overlapped dispatch pipeline (prefetch) — bit-identical to the serial loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", VMAPPABLE)
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_prefetch_bitwise_parity_every_policy(policy, congestion):
    """The producer thread does the same host work in the same order as the
    serial loop, so prefetch>0 == prefetch=0 (the pre-overlap pipeline) for
    every vmappable policy, congestion on or off."""
    cfg = fleet_cfg(congestion)
    serial = simulate_fleet(SPEC, cfg, policy=policy, n_rep=6, seed=0, prefetch=0)
    assert serial.prefetch == 0
    for pf in (1, 2):
        overlapped = simulate_fleet(SPEC, cfg, policy=policy, n_rep=6, seed=0, prefetch=pf)
        assert overlapped.prefetch == pf
        msg = f"{policy} congestion={congestion} prefetch={pf}"
        assert_fleet_identical(serial, overlapped, msg)


@pytest.mark.parametrize("scenario", ["paper-default", "diurnal-week", "sustained-overload"])
def test_prefetch_parity_windowed_and_materialized(scenario):
    """prefetch composes with window= (where the overlap actually bites) on
    materialized and streaming scenarios alike."""
    cfg = fleet_cfg(congestion=True)
    serial = simulate_fleet(SPEC, cfg, policy="gus", n_rep=3, seed=0, scenario=scenario, prefetch=0)
    for window in (None, 2, 5):
        overlapped = simulate_fleet(
            SPEC, cfg, policy="gus", n_rep=3, seed=0, scenario=scenario, window=window, prefetch=2
        )
        assert_fleet_identical(serial, overlapped, f"{scenario} window={window}")


def test_prefetch_parity_with_keyed_policy():
    cfg = fleet_cfg()
    serial = simulate_fleet(SPEC, cfg, policy="random", n_rep=3, seed=7, window=2, prefetch=0)
    overlapped = simulate_fleet(SPEC, cfg, policy="random", n_rep=3, seed=7, window=2, prefetch=2)
    assert_fleet_identical(serial, overlapped)


@multi_device
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_prefetch_parity_on_multi_device_mesh(congestion):
    """prefetch>0 stays bit-identical when the replication axis is sharded:
    the producer feeds the same groups to the same compiled program."""
    cfg = fleet_cfg(congestion)
    serial = simulate_fleet(SPEC, cfg, policy="gus", n_rep=12, seed=0, devices=1, prefetch=0)
    both = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=12, seed=0, devices=N_DEV, window=3, prefetch=2
    )
    assert both.n_devices == N_DEV
    assert_fleet_identical(serial, both, f"congestion={congestion}")


def test_gen_s_is_reported_and_bounded_by_wall():
    import time

    t0 = time.perf_counter()
    fr = simulate_fleet(SPEC, fleet_cfg(), policy="gus", n_rep=4, seed=0, prefetch=1)
    wall = time.perf_counter() - t0
    assert fr.gen_s > 0.0
    assert fr.gen_s <= wall + 1e-6


# ---------------------------------------------------------------------------
# Vectorized rng mode on the fleet: same invariants, different (opt-in) trace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["paper-default", "diurnal-week", "flash-crowd"])
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_vectorized_windowed_matches_materialized(scenario, congestion):
    """In rng_mode='vectorized' the materialized grid is columnar and the
    windowed/lazy path streams Request objects — they must still agree bit
    for bit (one chunk engine underneath)."""
    cfg = fleet_cfg(congestion)
    kw = dict(policy="gus", n_rep=2, seed=0, scenario=scenario, rng_mode="vectorized")
    full = simulate_fleet(SPEC, cfg, prefetch=0, **kw)
    for window in (1, 2, 5):
        windowed = simulate_fleet(SPEC, cfg, window=window, prefetch=2, **kw)
        assert_fleet_identical(full, windowed, f"{scenario} window={window}")


def test_vectorized_fleet_deterministic_and_close_to_default():
    """Different RNG order, same law: satisfied-% from the two modes must
    agree within Monte-Carlo noise at moderate replication counts."""
    cfg = fleet_cfg()
    v1 = simulate_fleet(SPEC, cfg, policy="gus", n_rep=16, seed=0, rng_mode="vectorized")
    v2 = simulate_fleet(SPEC, cfg, policy="gus", n_rep=16, seed=0, rng_mode="vectorized")
    assert_fleet_identical(v1, v2, "vectorized determinism")
    d = simulate_fleet(SPEC, cfg, policy="gus", n_rep=16, seed=0)
    assert abs(v1.satisfied_pct - d.satisfied_pct) < 6.0


@multi_device
@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_vectorized_sharded_bitwise_parity(congestion):
    cfg = fleet_cfg(congestion)
    kw = dict(policy="gus", n_rep=12, seed=0, rng_mode="vectorized")
    single = simulate_fleet(SPEC, cfg, devices=1, **kw)
    sharded = simulate_fleet(SPEC, cfg, devices=N_DEV, window=3, prefetch=2, **kw)
    assert_fleet_identical(single, sharded, f"vectorized congestion={congestion}")


# ---------------------------------------------------------------------------
# The sequential testbed stays the parity anchor
# ---------------------------------------------------------------------------


@multi_device
def test_sharded_fleet_still_matches_sequential_simulator():
    """Noise-free frame-synchronous settings: the sharded fleet must agree
    with the sequential testbed exactly, like the single-device fleet does
    (tests/test_queueing.py pins that one)."""
    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=30_000.0, arrival_rate_per_s=6.0, delay_req_ms=6000.0,
        acc_req_mean=50.0, acc_req_std=10.0,
        channel_sigma=0.0, proc_sigma=0.0, queue_cap=10**9,
        bandwidth_init=spec.bandwidth_true, adapt_max_cs=False,
        congestion=CongestionConfig(enabled=True),
    )
    r = simulate(spec, cfg, policy="gus", seed=0)
    fr = simulate_fleet(
        spec, cfg, policy="gus", n_rep=1, seed=0, devices=min(N_DEV, 2), window=3
    )
    assert fr.n_requests == r.n_requests
    assert fr.n_served == r.n_served
    assert int(round(fr.satisfied_per_rep[0] * fr.n_requests / 100.0)) == r.n_satisfied
