"""Telemetry subsystem: inertness, metric-stream correctness, trace schema.

The contracts under test (see ``docs/architecture.md`` section 10):

* **Inertness** — ``metrics=True`` never changes simulation results: for
  every vmappable policy, congestion on/off, impairments on/off, the
  fleet's result fields are bit-identical with the metric stream on and
  off (the disabled path traces the exact pre-telemetry program, so
  equality with the enabled run pins both).  Same for ``simulate`` and
  the host-side (ILP) fleet path.
* **Stream correctness** — per-frame rows satisfy the counting
  invariants (shed <= arrivals, tier histogram sums to served, QoS class
  counts sum to arrivals, utilizations/backlogs finite and >= 0) and
  aggregate EXACTLY to the ``SimResult`` / ``FleetResult`` totals.
* **Tracing** — spans record only while a recorder is installed, the
  emitted JSON passes :func:`validate_chrome_trace`, producer-thread
  spans land on their own tid, and the JSONL exporter's io spans ride
  the "telemetry-writer" thread.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    AdmissionConfig,
    CongestionConfig,
    ImpairmentConfig,
    IntermittentLink,
    SimConfig,
    demo_cluster_spec,
    get_policy,
    list_policies,
    simulate,
    simulate_fleet,
)
from repro.obs import (  # noqa: E402
    QOS_ACC_EDGES,
    AsyncJsonlWriter,
    MetricsFrame,
    MetricsResult,
    Stopwatch,
    active_recorder,
    instant,
    recording,
    span,
    validate_chrome_trace,
)

VMAPPABLE = [p for p in list_policies() if get_policy(p).vmappable]

SPEC = demo_cluster_spec()

IMPAIRED = ImpairmentConfig(
    enabled=True, link_profiles=(IntermittentLink(),), seed=3,
    outage_mtbf_frames=6.0, outage_mttr_frames=3.0, outage_servers=(1,),
)


def cfg(congestion: bool = False, impaired: bool = False, **kw) -> SimConfig:
    base = dict(
        horizon_ms=4000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=3000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=congestion),
        admission=AdmissionConfig(enabled=True, shed=True, queue_cap_mult=2.0),
        impairments=IMPAIRED if impaired else ImpairmentConfig(),
    )
    base.update(kw)
    return SimConfig(**base)


def _assert_fleet_equal(a, b):
    assert a.n_requests == b.n_requests
    assert a.n_served == b.n_served
    np.testing.assert_array_equal(a.satisfied_per_rep, b.satisfied_per_rep)
    np.testing.assert_array_equal(a.mean_us_per_rep, b.mean_us_per_rep)
    assert a.mean_compute_inflation == b.mean_compute_inflation


# ---------------------------------------------------------------------------
# inertness: metrics on/off bitwise parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", VMAPPABLE)
@pytest.mark.parametrize("congestion", [False, True])
@pytest.mark.parametrize("impaired", [False, True])
def test_fleet_metrics_bitwise_inert(policy, congestion, impaired):
    c = cfg(congestion, impaired)
    off = simulate_fleet(SPEC, c, policy=policy, n_rep=2, seed=7)
    on = simulate_fleet(SPEC, c, policy=policy, n_rep=2, seed=7, metrics=True)
    _assert_fleet_equal(off, on)
    assert off.metrics is None
    assert on.metrics is not None


@pytest.mark.parametrize("congestion", [False, True])
def test_simulate_metrics_bitwise_inert(congestion):
    c = cfg(congestion)
    off = simulate(SPEC, c, seed=5)
    on = simulate(SPEC, c, seed=5, metrics=True)
    assert off.n_satisfied == on.n_satisfied
    assert off.n_served == on.n_served
    assert off.mean_us == on.mean_us
    assert off.mean_completion_ms == on.mean_completion_ms
    assert off.bandwidth_estimates == on.bandwidth_estimates
    assert off.metrics is None and on.metrics is not None


def test_host_fleet_metrics_inert():
    # low rate: the exact ILP refuses frames above its variable budget
    c = cfg(congestion=True, arrival_rate_per_s=1.0)
    off = simulate_fleet(SPEC, c, policy="ilp", n_rep=2, seed=1)
    on = simulate_fleet(SPEC, c, policy="ilp", n_rep=2, seed=1, metrics=True)
    _assert_fleet_equal(off, on)
    assert on.metrics is not None


# ---------------------------------------------------------------------------
# metric-stream correctness
# ---------------------------------------------------------------------------


def _check_invariants(m: MetricsResult, n_servers: int):
    d = m.data
    assert d["n_shed"].sum() >= 0
    assert np.all(d["n_shed"] <= d["n_arrivals"])
    assert np.all(d["n_served"] <= d["n_arrivals"])
    assert np.all(d["n_satisfied"] <= d["n_served"])
    assert np.all(d["tier_hist"].sum(-1) == d["n_served"])
    assert np.all(d["qos_count"].sum(-1) == d["n_arrivals"])
    assert np.all(d["qos_sat"] <= d["qos_count"])
    for f in ("util_gamma", "util_eta", "backlog_gamma", "backlog_eta"):
        assert d[f].shape[-1] == n_servers
        assert np.all(np.isfinite(d[f]))
        assert np.all(d[f] >= 0.0)
    assert d["qos_count"].shape[-1] == len(QOS_ACC_EDGES) + 1


def test_fleet_metrics_invariants_and_totals():
    c = cfg(congestion=True, impaired=True)
    fr = simulate_fleet(SPEC, c, n_rep=3, seed=2, metrics=True)
    m = fr.metrics
    assert m.fleet and m.n_rep == 3 and m.n_frames == fr.n_frames
    _check_invariants(m, SPEC.n_servers)
    agg = m.aggregate()
    assert agg["n_arrivals"] == fr.n_requests
    assert agg["n_served"] == fr.n_served
    sat_per_rep = m.data["n_satisfied"].sum(1)
    reqs_per_rep = m.data["n_arrivals"].sum(1)
    np.testing.assert_allclose(
        100.0 * sat_per_rep / np.maximum(reqs_per_rep, 1),
        fr.satisfied_per_rep,
    )
    # congestion on: some backlog must actually appear in the stream
    assert m.data["backlog_gamma"].max() >= 0.0


def test_simulate_metrics_aggregate_matches_exactly():
    c = cfg(congestion=True)
    r = simulate(SPEC, c, seed=4, metrics=True)
    m = r.metrics
    assert not m.fleet
    _check_invariants(m, SPEC.n_servers)
    agg = m.aggregate()
    assert agg["n_arrivals"] == r.n_requests
    assert agg["n_served"] == r.n_served
    assert agg["n_satisfied"] == r.n_satisfied
    assert agg["n_local"] == r.n_local
    assert agg["n_cloud"] == r.n_cloud
    assert agg["n_edge_offload"] == r.n_edge_offload
    # decision times are monotone and frame-aligned or early-closed
    assert np.all(np.diff(m.t_ms) > 0)


def test_windowed_fleet_metrics_match_materialized():
    c = cfg(congestion=True)
    full = simulate_fleet(SPEC, c, n_rep=3, seed=0, metrics=True)
    windowed = simulate_fleet(SPEC, c, n_rep=3, seed=0, metrics=True, window=1)
    for f in MetricsFrame._fields:
        np.testing.assert_array_equal(
            full.metrics.data[f], windowed.metrics.data[f], err_msg=f
        )


def test_metrics_rollups_and_jsonl(tmp_path):
    fr = simulate_fleet(SPEC, cfg(congestion=True), n_rep=2, seed=0, metrics=True)
    m = fr.metrics
    pct = m.percentiles("backlog_gamma")
    assert set(pct) == {"p50", "p90", "p99"} and pct["p50"] <= pct["p99"]
    roll = m.per_edge_rollup()
    assert len(roll["util_gamma"]) == SPEC.n_edge
    assert len(roll["util_gamma_cloud"]) == SPEC.n_servers - SPEC.n_edge

    path = tmp_path / "m.jsonl"
    n = m.to_jsonl(path)
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert n == len(rows) == m.n_rep * m.n_frames
    assert sum(r["n_satisfied"] for r in rows) == m.aggregate()["n_satisfied"]
    assert {"frame", "t_ms", "rep", "tier", "qos_sat", "util_gamma"} <= set(rows[0])


def test_async_jsonl_writer(tmp_path):
    path = tmp_path / "w.jsonl"
    with recording() as rec:
        with AsyncJsonlWriter(path) as w:
            for i in range(100):
                w.write({"i": i})
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["i"] for r in rows] == list(range(100))
    # the writer thread's io spans were recorded under its own name
    io = [e for e in rec.events() if e.get("cat") == "io"]
    assert io and rec.to_chrome_trace()
    names = [
        e["args"]["name"] for e in rec.to_chrome_trace()["traceEvents"]
        if e["ph"] == "M"
    ]
    assert "telemetry-writer" in names


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------


def test_span_inert_without_recorder():
    assert active_recorder() is None
    with span("unit/x") as s:
        pass
    assert s.elapsed_s >= 0.0
    instant("unit/i")  # no-op, must not raise
    assert active_recorder() is None


def test_stopwatch_accumulates_with_tracing_off():
    sw = Stopwatch()
    with sw.span("a"):
        pass
    with sw.span("a"):
        pass
    with sw.span("b"):
        pass
    assert sw.total("a") > 0.0
    assert sw.total("a", "b") == pytest.approx(sw.total("a") + sw.total("b"))
    assert set(sw.as_dict()) == {"a", "b"}


def test_recording_scopes_and_schema(tmp_path):
    with recording() as rec:
        simulate_fleet(SPEC, cfg(), n_rep=2, seed=0, metrics=True)
    assert active_recorder() is None
    assert {"gen", "build", "dispatch", "metrics"} <= rec.categories()
    assert "fleet/dispatch" in rec.span_names()
    path = tmp_path / "trace.json"
    rec.save(path)
    obj = json.loads(path.read_text())
    assert validate_chrome_trace(obj) == []
    assert any(e["ph"] == "M" for e in obj["traceEvents"])
    # after the recorder is gone, new spans don't grow it
    n = len(rec)
    with span("unit/after"):
        pass
    assert len(rec) == n


def test_validate_chrome_trace_rejects_garbage():
    assert validate_chrome_trace(42)
    assert validate_chrome_trace({"nope": []})
    assert validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})
    bad_dur = {"traceEvents": [
        {"ph": "X", "name": "a", "cat": "c", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": -1.0}
    ]}
    assert validate_chrome_trace(bad_dur)


def test_producer_thread_spans_on_own_tid():
    with recording() as rec:
        simulate_fleet(SPEC, cfg(), n_rep=2, seed=0, window=1, prefetch=1)
    trace = rec.to_chrome_trace()
    names = {
        e["tid"]: e["args"]["name"]
        for e in trace["traceEvents"] if e["ph"] == "M"
    }
    assert "fleet-window-producer" in names.values()
    prod_tid = next(t for t, n in names.items() if n == "fleet-window-producer")
    prod_spans = [
        e for e in trace["traceEvents"]
        if e["ph"] == "X" and e["tid"] == prod_tid
    ]
    assert {e["name"] for e in prod_spans} >= {"fleet/arrivals", "fleet/grid_build"}
    assert len(rec.thread_ids()) >= 2


def test_timings_fields_derive_from_spans():
    r = simulate(SPEC, cfg(), seed=0)
    assert set(r.timings) >= {"gen_s", "build_s", "sched_s", "realize_s", "total_s"}
    assert all(v >= 0.0 for v in r.timings.values())
    fr = simulate_fleet(SPEC, cfg(), n_rep=2, seed=0)
    assert fr.timings["total_s"] > 0.0
    assert fr.gen_s == pytest.approx(
        fr.timings.get("fleet/generate_traces", 0.0)
        + fr.timings.get("fleet/window_wait", 0.0)
    )
    assert fr.dispatch_s == pytest.approx(fr.timings.get("fleet/dispatch", 0.0))


def test_golden_trace_is_valid():
    with open("results/telemetry/golden_trace.json") as f:
        obj = json.load(f)
    assert validate_chrome_trace(obj) == []
    cats = {e["cat"] for e in obj["traceEvents"] if e["ph"] not in ("M",)}
    assert len(cats) >= 4
    tids = {e["tid"] for e in obj["traceEvents"]}
    assert len(tids) >= 2


# ---------------------------------------------------------------------------
# end-to-end: the documented CLI invocation
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_run_scenario_metrics_and_trace(tmp_path, monkeypatch):
    import sys
    sys.path.insert(0, "examples")
    try:
        import run_scenario
    finally:
        sys.path.pop(0)
    monkeypatch.chdir(tmp_path)
    trace_path = tmp_path / "trace.json"
    r, _ = run_scenario.main([
        "--scenario", "sustained-overload", "--congestion", "--metrics",
        "--trace", str(trace_path), "--horizon-s", "6",
    ])
    obj = json.loads(trace_path.read_text())
    assert validate_chrome_trace(obj) == []
    events = obj["traceEvents"]
    cats = {e["cat"] for e in events if e["ph"] != "M"}
    assert len(cats) >= 4
    assert len({e["tid"] for e in events}) >= 2
    out = tmp_path / "results" / "telemetry" / "sustained-overload-gus.metrics.jsonl"
    rows = [json.loads(line) for line in out.read_text().splitlines()]
    assert sum(row["n_satisfied"] for row in rows) == r.n_satisfied
    assert sum(row["n_arrivals"] for row in rows) == r.n_requests
