"""Renderer smoke: draws every panel kind from a minimal synthetic figures
JSON.  Skips cleanly when matplotlib is absent (it is optional everywhere)."""
import json

import pytest

pytest.importorskip("matplotlib")


def _fake_json(tmp_path):
    rows_sweep = [
        {"x": x, "policy": p, "satisfied_pct": 50.0 + 10 * i + x}
        for x in (1.0, 2.0)
        for i, p in enumerate(("gus", "random"))
    ]
    data = {
        "meta": {"tiny": True, "policies": ["gus", "random"]},
        "figures": {
            "arrival-rate": {"x_label": "rate", "rows": rows_sweep},
            "scenarios": {"x_label": "scenario", "rows": [
                {"scenario": s, "policy": p, "satisfied_pct": 60.0 + i}
                for s in ("paper-default", "outage")
                for i, p in enumerate(("gus", "random", "ilp"))
            ]},
            "optimality-gap": {"x_label": "seed", "rows": [
                {"regime": r, "seed": s, "certified": r != "large-lp",
                 "opt": 0.5, "gus": 0.45, "gus_ordered": 0.46,
                 "ratio": 0.9, "ratio_ordered": 0.92}
                for r in ("ample", "large-lp") for s in (0, 1)
            ]},
            "congestion": {"x_label": "rate", "rows": [
                {"scenario": "paper-default", "x": 8.0, "policy": p,
                 "satisfied_pct": 40.0 - 10 * i}
                for i, p in enumerate(("gus", "happy_computation"))
            ]},
        },
        "claims": {},
    }
    path = tmp_path / "paper_figures.json"
    path.write_text(json.dumps(data))
    return path


def test_renderer_draws_every_panel(tmp_path):
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
    try:
        import render_figures
    finally:
        sys.path.pop(0)

    json_path = _fake_json(tmp_path)
    written = render_figures.render(json_path, tmp_path)
    names = {p.name for p in written}
    assert names == {"arrival-rate.png", "scenarios.png",
                     "optimality-gap.png", "congestion.png"}
    assert all(p.stat().st_size > 0 for p in written)
