"""Hierarchical class-aggregate scheduling (``repro.core.aggregation``).

The contract under test (see ``docs/architecture.md`` section 11):

* ``aggregate_instance`` partitions a frame's requests into QoS classes:
  counts sum to N, ``members`` is a permutation grouped by class and
  ascending within each class, and each representative is the class's
  lowest-index member;
* ``gus-hier`` (exact mode) is **bit-identical** to dense GUS whenever
  classes are lossless — every singleton-class frame (the paper generator:
  continuous QoS draws) and frames with index-contiguous duplicate blocks;
* de-aggregation is deterministic: chunks consume members in ascending
  request index, never over-allocate a class, and replaying the same
  chunks reproduces the same per-request assignment;
* the fleet path (``EngineOptions(scheduler="hierarchical")``) stays
  within the 2% satisfaction band of the dense fleet on paper-scale
  scenarios, congestion on and off;
* composition errors are loud: hierarchical + non-GUS policy, + raw
  callable, + ``backend=`` under :func:`simulate` (which has no device
  hier path) all raise — while admission control, which used to raise,
  now composes (class-level shedding; parity in ``test_hier_parity.py``);
* the ``mega-city`` scenario delivers 10^5+ users per frame to the
  hierarchical fleet within bounded memory and all-finite statistics
  (reduced-scale fast, full scale marked slow).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (  # noqa: E402
    CongestionConfig,
    EngineOptions,
    SimConfig,
    aggregate_instance,
    aggregate_requests,
    demo_cluster_spec,
    generate_instance,
    get_scenario,
    gus_schedule_np,
    hier_assign,
    hier_schedule_np,
    deaggregate,
    simulate,
    simulate_fleet,
)
from repro.core.impairments import AdmissionConfig  # noqa: E402
from repro.core.instance import FlatInstance, GeneratorConfig  # noqa: E402

SPEC = demo_cluster_spec()

SMALL = GeneratorConfig(n_requests=24, n_edge=4, n_cloud=1, n_services=6,
                        n_variants=4)


def fleet_cfg(congestion: bool = False, **kw) -> SimConfig:
    base = dict(
        horizon_ms=12_000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=6000.0,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=congestion),
    )
    base.update(kw)
    return SimConfig(**base)


def tile_instance(inst: FlatInstance, k: int) -> FlatInstance:
    """Repeat every request row ``k`` times (duplicates index-contiguous)."""
    rep = lambda x: np.repeat(np.asarray(x), k, axis=0)  # noqa: E731
    return dataclasses.replace(
        inst,
        cover=rep(inst.cover), A=rep(inst.A), C=rep(inst.C),
        w_a=rep(inst.w_a), w_c=rep(inst.w_c),
        acc=rep(inst.acc), ctime=rep(inst.ctime), v=rep(inst.v),
        u=rep(inst.u), avail=rep(inst.avail),
    )


# ---------------------------------------------------------------------------
# aggregation invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_aggregate_instance_partitions(seed):
    inst = generate_instance(seed, SMALL, as_numpy=True)
    agg = aggregate_instance(inst)
    n = np.asarray(inst.A).shape[0]
    assert int(agg.count.sum()) == n
    assert sorted(agg.members.tolist()) == list(range(n))
    for c in range(agg.n_classes):
        mem = agg.members[agg.offsets[c]:agg.offsets[c + 1]]
        assert mem.shape[0] == agg.count[c]
        assert np.all(np.diff(mem) > 0)  # ascending within the class
        assert agg.first_idx[c] == mem[0]
        assert agg.cover[c] == np.asarray(inst.cover)[mem[0]]


def test_duplicates_collapse_into_one_class():
    inst = tile_instance(generate_instance(0, SMALL, as_numpy=True), 5)
    agg = aggregate_instance(inst)
    assert agg.n_classes == SMALL.n_requests
    assert np.all(agg.count == 5)


# ---------------------------------------------------------------------------
# exact-mode parity with dense GUS
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_singleton_classes_match_dense_gus(seed):
    inst = generate_instance(seed)  # continuous QoS draws: all singletons
    dense = gus_schedule_np(inst)
    hier = hier_schedule_np(inst)
    np.testing.assert_array_equal(np.asarray(dense.j), np.asarray(hier.j))
    np.testing.assert_array_equal(np.asarray(dense.l), np.asarray(hier.l))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("k", [2, 7])
def test_contiguous_duplicate_classes_match_dense_gus(seed, k):
    inst = tile_instance(generate_instance(seed, SMALL, as_numpy=True), k)
    dense = gus_schedule_np(inst)
    hier = hier_schedule_np(inst)
    np.testing.assert_array_equal(np.asarray(dense.j), np.asarray(hier.j))
    np.testing.assert_array_equal(np.asarray(dense.l), np.asarray(hier.l))


def test_deaggregate_is_deterministic_and_bounded():
    inst = tile_instance(generate_instance(1, SMALL, as_numpy=True), 4)
    agg = aggregate_instance(inst)
    chunks = hier_assign(
        agg, np.asarray(inst.gamma), np.asarray(inst.eta), exact=True
    )
    taken = np.zeros(agg.n_classes, np.int64)
    for c, _, _, take in chunks:
        taken[c] += take
    assert np.all(taken <= agg.count)  # never over-allocates a class
    n = np.asarray(inst.A).shape[0]
    j1, l1 = deaggregate(agg, chunks, n)
    j2, l2 = deaggregate(agg, chunks, n)
    np.testing.assert_array_equal(j1, j2)
    np.testing.assert_array_equal(l1, l2)
    # allocated members are exactly the first `take` (lowest-index) members
    for c, j, l, take in chunks:
        mem = agg.members[agg.offsets[c]:agg.offsets[c] + take]
        assert np.all(j1[mem] == j) and np.all(l1[mem] == l)


def test_aggregate_requests_groups_discrete_tiers():
    n = 300
    rng = np.random.default_rng(0)
    cover = rng.integers(0, 4, n)
    service = rng.integers(0, 3, n)
    A = np.choose(rng.integers(0, 2, n), [45.0, 65.0])
    C = np.full(n, 6000.0)
    size = np.full(n, 512.0)
    tq = np.zeros(n)
    count, first_idx, members, offsets, rep = aggregate_requests(
        cover, service, A, C, size, tq
    )
    assert int(count.sum()) == n
    assert count.shape[0] <= 4 * 3 * 2  # bounded by the tier product
    np.testing.assert_array_equal(rep["cover"], cover[first_idx])
    np.testing.assert_array_equal(rep["service"], service[first_idx])
    for v in rep.values():
        assert np.isfinite(np.asarray(v, dtype=np.float64)).all()


# ---------------------------------------------------------------------------
# engine composition: gus-hier policy and the scheduler switch
# ---------------------------------------------------------------------------

def test_simulate_hier_matches_dense_gus_bitwise():
    cfg = fleet_cfg()
    dense = simulate(SPEC, cfg, policy="gus", seed=0)
    hier = simulate(SPEC, cfg, policy="gus-hier", seed=0)
    assert dense.as_dict() == hier.as_dict()
    via_opts = simulate(
        SPEC, cfg, policy="gus", seed=0,
        options=EngineOptions(scheduler="hierarchical"),
    )
    assert dense.as_dict() == via_opts.as_dict()


@pytest.mark.parametrize("congestion", [False, True], ids=["plain", "congestion"])
def test_fleet_hier_within_two_percent_of_dense(congestion):
    cfg = fleet_cfg(congestion)
    dense = simulate_fleet(SPEC, cfg, policy="gus", n_rep=3, seed=0)
    hier = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=3, seed=0,
        options=EngineOptions(scheduler="hierarchical", window=2),
    )
    assert hier.n_requests == dense.n_requests
    gap = np.abs(
        np.asarray(hier.satisfied_per_rep) - np.asarray(dense.satisfied_per_rep)
    )
    assert gap.max() <= 2.0, f"per-rep satisfaction gap {gap} exceeds 2%"


def test_fleet_hier_metrics_stream_is_finite():
    cfg = fleet_cfg(congestion=True)
    fr = simulate_fleet(
        SPEC, cfg, policy="gus", n_rep=2, seed=0,
        options=EngineOptions(scheduler="hierarchical", metrics=True),
    )
    assert fr.metrics is not None
    agg = fr.metrics.aggregate()
    assert agg  # non-empty aggregate
    for k, v in agg.items():
        assert np.isfinite(np.asarray(v, dtype=np.float64)).all(), k


def test_hier_scheduler_composition_errors():
    cfg = fleet_cfg()
    hier = EngineOptions(scheduler="hierarchical")
    with pytest.raises(ValueError, match="does not compose"):
        simulate(SPEC, cfg, policy="random", seed=0, options=hier)
    with pytest.raises(ValueError, match="callable"):
        simulate(SPEC, cfg, gus_schedule_np, seed=0, options=hier)
    with pytest.raises(ValueError, match="backend"):
        simulate(
            SPEC, cfg, policy="gus", seed=0,
            options=EngineOptions(scheduler="hierarchical", backend="pallas"),
        )

def test_hier_fleet_admission_no_longer_raises():
    """Regression: ``scheduler="hierarchical"`` + admission used to raise."""
    fr = simulate_fleet(
        SPEC, fleet_cfg(admission=AdmissionConfig(enabled=True)),
        policy="gus", n_rep=2, seed=0,
        options=EngineOptions(scheduler="hierarchical"),
    )
    assert fr.n_requests > 0
    assert np.isfinite(np.asarray(fr.satisfied_per_rep)).all()
    assert np.isfinite(np.asarray(fr.mean_us_per_rep)).all()


def test_class_keys_are_chunk_invariant_on_mega_city():
    """Quantization bins must not depend on how the trace is chunked or on
    the arrival-RNG mode: ``class_keys`` is anchored (fixed-width bins), so
    keys for any slice of a trace equal the same rows of the full trace's
    keys, and a columnar trace and its object-mode round trip key
    identically."""
    from repro.core.aggregation import class_keys

    scn = get_scenario("mega-city")
    cfg = SimConfig(horizon_ms=6_000.0)
    cols = scn.generate_arrivals_columns(
        np.random.default_rng(0), 6, 5, cfg
    )
    n = len(cols)
    assert n > 100
    tq = cfg.frame_ms - np.mod(cols.arrival_ms, cfg.frame_ms)
    full = class_keys(cols.cover, cols.service, cols.A, cols.C,
                      cols.size_bytes, tq)
    # chunk invariance: keys of a slice == the slice of the keys
    for lo, hi in ((0, n // 3), (n // 3, n), (n // 2, n // 2 + 7)):
        part = class_keys(
            cols.cover[lo:hi], cols.service[lo:hi], cols.A[lo:hi],
            cols.C[lo:hi], cols.size_bytes[lo:hi], tq[lo:hi],
        )
        np.testing.assert_array_equal(part, full[lo:hi])
    # mode stability: the object-mode view of the same trace keys identically
    reqs = cols.to_requests()
    obj = class_keys(
        np.array([r.cover for r in reqs]),
        np.array([r.service for r in reqs]),
        np.array([r.A for r in reqs]),
        np.array([r.C for r in reqs]),
        np.array([r.size_bytes for r in reqs]),
        tq,
    )
    np.testing.assert_array_equal(obj, full)


# ---------------------------------------------------------------------------
# mega-city: the 10^5-users-per-frame workload
# ---------------------------------------------------------------------------

def _mega_city_run(rate_per_edge_per_s: float, n_edge: int):
    spec = demo_cluster_spec(n_edge=n_edge, n_cloud=1, n_services=5,
                             n_variants=10)
    cfg = SimConfig(horizon_ms=9_000.0)
    scn = dataclasses.replace(
        get_scenario("mega-city"), rate_per_edge_per_s=rate_per_edge_per_s
    )
    return simulate_fleet(
        spec, cfg, policy="gus", scenario=scn, n_rep=1, seed=0,
        options=EngineOptions(scheduler="hierarchical", window=1),
    )


def test_mega_city_smoke_reduced_scale():
    fr = _mega_city_run(rate_per_edge_per_s=60.0, n_edge=6)
    assert fr.n_requests > 0
    assert np.isfinite(np.asarray(fr.satisfied_per_rep)).all()
    assert np.isfinite(np.asarray(fr.mean_us_per_rep)).all()
    assert fr.window == 1


@pytest.mark.slow
def test_mega_city_full_scale_bounded():
    fr = _mega_city_run(rate_per_edge_per_s=2400.0, n_edge=20)
    per_frame = fr.n_requests / fr.n_frames
    assert per_frame >= 1e5, f"only {per_frame:,.0f} users/frame"
    assert np.isfinite(np.asarray(fr.satisfied_per_rep)).all()
    assert np.isfinite(np.asarray(fr.mean_us_per_rep)).all()
