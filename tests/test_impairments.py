"""Resilience-layer unit tests: link-quality trace generators, outage
streams, the deterministic fault-injection engine, and the admission-control
primitives (deadline shedding + queue caps).  Integration with the
simulators lives in ``tests/test_resilience.py``."""
import dataclasses
import math

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    AdmissionConfig,
    BurstyLossLink,
    ComposedLink,
    CongestionConfig,
    GeneratorConfig,
    HandoffLink,
    IdealLink,
    ImpairmentConfig,
    IntermittentLink,
    LinkTrace,
    OutageTrace,
    ResilienceEngine,
    SatelliteLink,
    admission_keep,
    apply_queue_cap,
    generate_instance,
    gus_schedule,
    predicted_inflation,
)
from repro.core.impairments import MIN_BW_SCALE  # noqa: E402

try:  # optional dev dep: see requirements-dev.txt
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ALL_PROFILES = (
    IdealLink(),
    IntermittentLink(),
    BurstyLossLink(),
    HandoffLink(),
    SatelliteLink(),
    ComposedLink(parts=(IntermittentLink(), SatelliteLink())),
)

TINY = GeneratorConfig(n_requests=8, n_edge=3, n_cloud=1, n_services=3, n_variants=2)
CC = CongestionConfig(enabled=True)


def _trace_arrays(profile, seed=0, n=200):
    return LinkTrace(profile, seed=seed).values(0, n)


# ---------------------------------------------------------------------------
# Link profiles
# ---------------------------------------------------------------------------


def test_ideal_link_is_identity():
    bw, lat = _trace_arrays(IdealLink())
    np.testing.assert_array_equal(bw, 1.0)
    np.testing.assert_array_equal(lat, 0.0)


def test_intermittent_link_two_states():
    p = IntermittentLink()
    bw, lat = _trace_arrays(p, seed=1)
    up = bw == 1.0
    np.testing.assert_array_equal(lat[up], 0.0)
    np.testing.assert_array_equal(bw[~up], p.down_bw)
    np.testing.assert_array_equal(lat[~up], p.down_lat)
    assert (~up).any() and up.any()  # both states visited in 200 frames


def test_bursty_link_two_states():
    p = BurstyLossLink()
    bw, lat = _trace_arrays(p, seed=1)
    bad = bw < 1.0
    np.testing.assert_array_equal(bw[bad], p.bad_bw)
    np.testing.assert_array_equal(lat[bad], p.bad_lat)
    assert bad.any() and (~bad).any()


def _gap_runs(bw, gap_value):
    """(start, length) of each maximal run of gap frames."""
    runs, start = [], None
    for i, v in enumerate(bw):
        if v == gap_value and start is None:
            start = i
        elif v != gap_value and start is not None:
            runs.append((start, i - start))
            start = None
    if start is not None:
        runs.append((start, len(bw) - start))
    return runs


@pytest.mark.parametrize("gap_frames", [1, 2, 3])
def test_handoff_gaps_are_well_formed(gap_frames):
    p = HandoffLink(period_frames=6, period_jitter=2, gap_frames=gap_frames)
    bw, lat = _trace_arrays(p, seed=2, n=400)
    runs = _gap_runs(bw, p.gap_bw)
    assert runs, "no handoff gap in 400 frames"
    # every interior gap is exactly gap_frames long (the last may be clipped)
    for _, length in runs[:-1]:
        assert length == gap_frames
    # connected stretches between gaps stay within the jittered period
    for (s0, l0), (s1, _) in zip(runs, runs[1:]):
        connected = s1 - (s0 + l0)
        assert p.period_frames - p.period_jitter <= connected <= p.period_frames + p.period_jitter
    np.testing.assert_array_equal(lat[bw == p.gap_bw], p.gap_lat)
    np.testing.assert_array_equal(lat[bw == 1.0], 0.0)


def test_satellite_link_always_impaired():
    p = SatelliteLink()
    bw, lat = _trace_arrays(p, seed=3)
    np.testing.assert_array_equal(bw, p.bw)
    assert (lat >= 0.0).all()
    assert lat.std() > 0.0  # jitter actually moves
    assert abs(lat.mean() - p.lat) < 5 * p.lat_jitter


def test_composed_link_multiplies_bw_and_adds_latency():
    # two jitter-free satellite parts: fully deterministic composition
    part = SatelliteLink(bw=0.8, lat=550.0, lat_jitter=0.0)
    bw, lat = _trace_arrays(ComposedLink(parts=(part, part)), seed=0, n=10)
    np.testing.assert_allclose(bw, 0.8 * 0.8)
    np.testing.assert_allclose(lat, 550.0 + 550.0)


def test_composed_link_empty_is_identity():
    bw, lat = _trace_arrays(ComposedLink(parts=()), seed=0, n=10)
    np.testing.assert_array_equal(bw, 1.0)
    np.testing.assert_array_equal(lat, 0.0)


# ---------------------------------------------------------------------------
# LinkTrace: determinism, bounds, prefix stability
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("profile", ALL_PROFILES, ids=lambda p: type(p).__name__)
def test_trace_values_bounded(profile):
    bw, lat = _trace_arrays(profile, seed=7)
    assert np.isfinite(bw).all() and np.isfinite(lat).all()
    assert (bw >= MIN_BW_SCALE).all() and (bw <= 1.0).all()
    assert (lat >= 0.0).all()


@pytest.mark.parametrize("profile", ALL_PROFILES, ids=lambda p: type(p).__name__)
def test_trace_chunked_equals_oneshot(profile):
    """The pull pattern never changes the sequence — the property that keeps
    windowed / prefetched fleet runs bitwise identical to the serial one."""
    bw_ref, lat_ref = LinkTrace(profile, seed=11).values(0, 120)
    chunked = LinkTrace(profile, seed=11)
    bw_parts, lat_parts = [], []
    for t0, t1 in ((0, 7), (7, 40), (40, 41), (41, 120)):
        b, t = chunked.values(t0, t1)
        bw_parts.append(b)
        lat_parts.append(t)
    np.testing.assert_array_equal(np.concatenate(bw_parts), bw_ref)
    np.testing.assert_array_equal(np.concatenate(lat_parts), lat_ref)
    # scalar pulls agree too, including re-reads of already-drawn frames
    scalar = LinkTrace(profile, seed=11)
    assert scalar.value(100) == (bw_ref[100], lat_ref[100])
    assert scalar.value(5) == (bw_ref[5], lat_ref[5])


def test_trace_seed_determinism():
    a = LinkTrace(IntermittentLink(), seed=5).values(0, 100)
    b = LinkTrace(IntermittentLink(), seed=5).values(0, 100)
    c = LinkTrace(IntermittentLink(), seed=6).values(0, 100)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    assert not np.array_equal(a[0], c[0])


def test_trace_empty_range():
    bw, lat = LinkTrace(IntermittentLink(), seed=0).values(5, 5)
    assert bw.size == 0 and lat.size == 0


# ---------------------------------------------------------------------------
# OutageTrace
# ---------------------------------------------------------------------------


def test_outage_trace_deterministic_and_prefix_stable():
    a = OutageTrace(5.0, 2.0, seed=9)
    b = OutageTrace(5.0, 2.0, seed=9)
    seq_a = [a.up(t) for t in range(100)]
    # out-of-order queries on b must agree with a's in-order draws
    assert b.up(99) == seq_a[99]
    assert b.up(3) == seq_a[3]
    assert [b.up(t) for t in range(100)] == seq_a


def test_outage_trace_mtbf_one_fails_immediately():
    # p_fail = 1: down at frame 0; p_repair = 1: straight back up — the
    # chain alternates deterministically
    tr = OutageTrace(1.0, 1.0, seed=0)
    assert [tr.up(t) for t in range(6)] == [False, True, False, True, False, True]


def test_outage_trace_huge_mtbf_stays_up():
    tr = OutageTrace(1e12, 3.0, seed=0)
    assert all(tr.up(t) for t in range(200))


def test_outage_trace_visits_both_states():
    tr = OutageTrace(4.0, 2.0, seed=1)
    ups = [tr.up(t) for t in range(200)]
    assert any(ups) and not all(ups)


# ---------------------------------------------------------------------------
# ResilienceEngine
# ---------------------------------------------------------------------------


def _engine(**kw):
    defaults = dict(enabled=True, link_profiles=(IntermittentLink(),), seed=2)
    defaults.update(kw)
    return ResilienceEngine(ImpairmentConfig(**defaults), n_edge=3, n_servers=5)


def test_engine_cloud_entries_stay_identity():
    eng = _engine()
    for t in range(50):
        scale, lat = eng.link_frame(t)
        assert scale.shape == (5,) and lat.shape == (5,)
        np.testing.assert_array_equal(scale[3:], 1.0)  # cloud tier untouched
        np.testing.assert_array_equal(lat[3:], 0.0)


def test_engine_amplitude_zero_is_exact_identity():
    eng = _engine(amplitude=0.0)
    for t in range(20):
        scale, lat = eng.link_frame(t)
        np.testing.assert_array_equal(scale, 1.0)
        np.testing.assert_array_equal(lat, 0.0)


def test_engine_amplitude_blends_linearly():
    full = _engine(amplitude=1.0)
    half = _engine(amplitude=0.5)
    s1, l1 = full.link_frame(7)
    sh, lh = half.link_frame(7)
    np.testing.assert_allclose(sh, np.clip(1.0 + 0.5 * (s1 - 1.0), MIN_BW_SCALE, None))
    np.testing.assert_allclose(lh, 0.5 * l1)


def test_engine_profiles_cycle_across_edges():
    profiles = (IntermittentLink(), SatelliteLink())
    eng = ResilienceEngine(
        ImpairmentConfig(enabled=True, link_profiles=profiles, seed=0),
        n_edge=3, n_servers=4,
    )
    assert [type(tr.profile) for tr in eng._traces] == [
        IntermittentLink, SatelliteLink, IntermittentLink
    ]


def test_engine_per_edge_seeds_differ():
    eng = _engine()
    a = np.array([eng.link_frame(t)[0][0] for t in range(100)])
    b = np.array([eng.link_frame(t)[0][1] for t in range(100)])
    assert not np.array_equal(a, b)  # same profile, distinct per-edge streams


def test_engine_capacity_scale_none_without_outages():
    eng = _engine()
    assert eng.capacity_scale(0) is None
    np.testing.assert_array_equal(eng.server_up(0), 1.0)


def test_engine_outage_masks_only_configured_servers():
    eng = _engine(outage_mtbf_frames=1.0, outage_mttr_frames=1e12,
                  outage_servers=(1, 3))
    up = eng.server_up(0)  # mtbf 1 -> down at frame 0; mttr huge -> stays down
    np.testing.assert_array_equal(up, [1.0, 0.0, 1.0, 0.0, 1.0])
    cap = eng.capacity_scale(0)
    np.testing.assert_array_equal(cap, up.astype(np.float64))


def test_engine_out_of_range_outage_servers_ignored():
    eng = _engine(outage_mtbf_frames=1.0, outage_servers=(7, -1))
    assert eng._outages == {}
    assert eng.capacity_scale(0) is None


def test_engine_deterministic_across_instances():
    a, b = _engine(), _engine()
    for t in (0, 3, 17):
        np.testing.assert_array_equal(a.link_frame(t)[0], b.link_frame(t)[0])
        np.testing.assert_array_equal(a.link_frame(t)[1], b.link_frame(t)[1])


# ---------------------------------------------------------------------------
# Admission-control primitives
# ---------------------------------------------------------------------------


def test_predicted_inflation_disabled_is_ones():
    g = jnp.asarray([100.0, 50.0])
    phi_c, phi_e = predicted_inflation(
        jnp.asarray([500.0, 0.0]), jnp.asarray([0.0, 900.0]), g, g,
        CongestionConfig(enabled=False),
    )
    np.testing.assert_array_equal(np.asarray(phi_c), 1.0)
    np.testing.assert_array_equal(np.asarray(phi_e), 1.0)


def test_predicted_inflation_is_lower_bound_of_realized():
    """phi(backlog) <= phi(backlog + committed): the monotonicity that makes
    shedding provably safe."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.uniform(50.0, 150.0, 6), jnp.float32)
    backlog = jnp.asarray(rng.uniform(0.0, 400.0, 6), jnp.float32)
    committed = jnp.asarray(rng.uniform(0.0, 300.0, 6), jnp.float32)
    pred, _ = predicted_inflation(backlog, backlog, g, g, CC)
    from repro.core import compute_inflation
    real = compute_inflation(backlog + committed, g, CC)
    assert (np.asarray(pred) <= np.asarray(real) + 1e-6).all()


def test_admission_keep_matches_feasibility_when_uninflated():
    inst = generate_instance(0, TINY)
    ones = jnp.ones(TINY.n_edge + TINY.n_cloud)
    tq = jnp.zeros(TINY.n_requests)
    keep = admission_keep(inst, tq, ones, ones)
    expect = np.asarray(
        (inst.avail
         & (inst.acc >= inst.A[:, None, None])
         & (inst.ctime <= inst.C[:, None, None])).any((-1, -2))
    )
    np.testing.assert_array_equal(np.asarray(keep), expect)


def test_admission_keep_is_monotone_in_inflation():
    """A request kept under higher inflation is kept under lower inflation —
    so shedding on the pre-frame (lower-bound) estimate never drops anything
    the realized (higher) inflation would have allowed through."""
    M = TINY.n_edge + TINY.n_cloud
    rng = np.random.default_rng(1)
    for seed in range(5):
        inst = generate_instance(seed, TINY)
        tq = jnp.zeros(TINY.n_requests)
        lo = jnp.asarray(1.0 + rng.uniform(0.0, 2.0, M), jnp.float32)
        hi = lo * jnp.asarray(1.0 + rng.uniform(0.0, 2.0, M), jnp.float32)
        keep_lo = np.asarray(admission_keep(inst, tq, lo, lo))
        keep_hi = np.asarray(admission_keep(inst, tq, hi, hi))
        assert (keep_lo | ~keep_hi).all()  # keep_hi implies keep_lo


def test_admission_keep_sheds_only_hopeless_requests():
    """Under uniform inflation, a request GUS actually satisfies is never
    shed by the pre-frame estimate with inflation at/below realized."""
    inst = generate_instance(3, TINY)
    a = gus_schedule(inst)
    served = np.asarray(a.j) >= 0
    ones = jnp.ones(TINY.n_edge + TINY.n_cloud)
    keep = np.asarray(admission_keep(inst, jnp.zeros(TINY.n_requests), ones, ones))
    assert (keep | ~served).all()  # served implies kept


def test_queue_cap_inert_at_inf():
    inst = generate_instance(0, TINY)
    a = gus_schedule(inst)
    backlog = jnp.asarray([1e9, 0.0, 5.0, 0.0], jnp.float32)
    out = apply_queue_cap(a.j, inst, backlog, backlog, AdmissionConfig(enabled=True))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a.j))


def test_queue_cap_inert_at_inf_even_for_dead_servers():
    # inf * 0 = nan, and comparisons with nan are False -> no refusal
    inst = generate_instance(0, TINY)
    inst = dataclasses.replace(inst, gamma=jnp.zeros_like(inst.gamma))
    a_j = jnp.zeros(TINY.n_requests, jnp.int32)  # everything on server 0
    out = apply_queue_cap(
        a_j, inst, jnp.zeros_like(inst.gamma), jnp.zeros_like(inst.eta),
        AdmissionConfig(enabled=True),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a_j))


def test_queue_cap_refuses_over_backlog_server():
    inst = generate_instance(0, TINY)
    a = gus_schedule(inst)
    jv = np.asarray(a.j)
    target = int(jv[jv >= 0][0])
    backlog_g = np.zeros(TINY.n_edge + TINY.n_cloud, np.float32)
    backlog_g[target] = 10.0 * float(np.asarray(inst.gamma)[target])
    out = np.asarray(apply_queue_cap(
        a.j, inst, jnp.asarray(backlog_g), jnp.zeros_like(inst.eta),
        AdmissionConfig(enabled=True, queue_cap_mult=2.0),
    ))
    assert (out[jv == target] == -1).all()          # over-cap server refused
    mask = (jv != target)
    np.testing.assert_array_equal(out[mask], jv[mask])  # everyone else kept


def test_queue_cap_comm_side_spares_local_requests():
    """Comm-side cap binds the covering edge of *offloaded* requests only —
    a local assignment on the same edge sails through."""
    inst = generate_instance(2, TINY)
    cover = np.asarray(inst.cover)
    edge = int(cover[0])
    n = TINY.n_requests
    jv = np.where(cover == edge, edge, cover).astype(np.int32)  # all local
    backlog_e = np.zeros(TINY.n_edge + TINY.n_cloud, np.float32)
    backlog_e[edge] = 10.0 * float(np.asarray(inst.eta)[edge])
    acfg = AdmissionConfig(enabled=True, queue_cap_mult=1.0)
    out_local = np.asarray(apply_queue_cap(
        jnp.asarray(jv), inst, jnp.zeros_like(inst.gamma),
        jnp.asarray(backlog_e), acfg,
    ))
    np.testing.assert_array_equal(out_local, jv)  # local: comm cap irrelevant
    # the same requests offloaded to a cloud server get refused
    cloud = TINY.n_edge
    jv_off = np.full(n, cloud, np.int32)
    out_off = np.asarray(apply_queue_cap(
        jnp.asarray(jv_off), inst, jnp.zeros_like(inst.gamma),
        jnp.asarray(backlog_e), acfg,
    ))
    assert (out_off[cover == edge] == -1).all()
    np.testing.assert_array_equal(out_off[cover != edge], jv_off[cover != edge])


def test_queue_cap_finite_refuses_dead_server():
    # backlog 0 >= cap * budget 0 -> a zero-budget (outage) server is
    # refused by any finite cap
    inst = generate_instance(0, TINY)
    inst = dataclasses.replace(inst, gamma=inst.gamma.at[0].set(0.0))
    jv = jnp.zeros(TINY.n_requests, jnp.int32)
    out = np.asarray(apply_queue_cap(
        jv, inst, jnp.zeros_like(inst.gamma), jnp.zeros_like(inst.eta),
        AdmissionConfig(enabled=True, queue_cap_mult=3.0),
    ))
    np.testing.assert_array_equal(out, -1)


def test_queue_cap_leaves_dropped_rows_alone():
    inst = generate_instance(0, TINY)
    jv = jnp.full(TINY.n_requests, -1, jnp.int32)
    big = jnp.full_like(inst.gamma, 1e9)
    out = apply_queue_cap(jv, inst, big, big,
                          AdmissionConfig(enabled=True, queue_cap_mult=0.5))
    np.testing.assert_array_equal(np.asarray(out), -1)


def test_admission_config_defaults_are_inert():
    acfg = AdmissionConfig()
    assert not acfg.enabled and not acfg.shed
    assert math.isinf(acfg.queue_cap_mult)
    assert not ImpairmentConfig().enabled
    assert not ImpairmentConfig().has_outages
    # outages need both a positive MTBF and a non-empty server set
    assert not ImpairmentConfig(outage_mtbf_frames=5.0).has_outages
    assert not ImpairmentConfig(outage_servers=(0,)).has_outages
    assert ImpairmentConfig(outage_mtbf_frames=5.0, outage_servers=(0,)).has_outages


# ---------------------------------------------------------------------------
# property tests (hypothesis widens the space when installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    PROFILE_STRATEGY = st.sampled_from(ALL_PROFILES)

    @settings(max_examples=30, deadline=None)
    @given(profile=PROFILE_STRATEGY, seed=st.integers(0, 10_000))
    def test_property_trace_values_bounded(profile, seed):
        bw, lat = LinkTrace(profile, seed=seed).values(0, 60)
        assert np.isfinite(bw).all() and np.isfinite(lat).all()
        assert (bw >= MIN_BW_SCALE).all() and (bw <= 1.0).all()
        assert (lat >= 0.0).all()

    @settings(max_examples=30, deadline=None)
    @given(
        profile=PROFILE_STRATEGY,
        seed=st.integers(0, 10_000),
        cuts=st.lists(st.integers(1, 79), min_size=0, max_size=6),
    )
    def test_property_chunked_equals_oneshot(profile, seed, cuts):
        ref_bw, ref_lat = LinkTrace(profile, seed=seed).values(0, 80)
        tr = LinkTrace(profile, seed=seed)
        bounds = [0] + sorted(set(cuts)) + [80]
        bw = np.concatenate([tr.values(a, b)[0] for a, b in zip(bounds, bounds[1:])])
        lat = np.concatenate([tr.values(a, b)[1] for a, b in zip(bounds, bounds[1:])])
        np.testing.assert_array_equal(bw, ref_bw)
        np.testing.assert_array_equal(lat, ref_lat)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        period=st.integers(2, 12),
        jitter=st.integers(0, 3),
        gap=st.integers(1, 4),
    )
    def test_property_handoff_transitions_well_formed(seed, period, jitter, gap):
        jitter = min(jitter, period - 1)
        p = HandoffLink(period_frames=period, period_jitter=jitter, gap_frames=gap)
        bw, _ = LinkTrace(p, seed=seed).values(0, 300)
        runs = _gap_runs(bw, p.gap_bw) if p.gap_bw != 1.0 else []
        for _, length in runs[:-1]:
            assert length == gap
        for (s0, l0), (s1, _) in zip(runs, runs[1:]):
            assert period - jitter <= s1 - (s0 + l0) <= period + jitter

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), mtbf=st.floats(1.0, 50.0),
           mttr=st.floats(1.0, 50.0))
    def test_property_outage_prefix_stable(seed, mtbf, mttr):
        a = OutageTrace(mtbf, mttr, seed=seed)
        b = OutageTrace(mtbf, mttr, seed=seed)
        _ = b.up(59)  # draw everything in one go
        assert [a.up(t) for t in range(60)] == [b.up(t) for t in range(60)]
