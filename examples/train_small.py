"""Train a ~100M-parameter model for a few hundred steps (deliverable (b)).

Uses mamba2-130m — the one assigned architecture that actually fits a CPU
training run at full d_model (we shorten depth/vocab for wall-clock, keeping
~tens of millions of params; pass --full for the real 130M config if you have
the patience or a TPU).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.training import (
    AdamWConfig,
    batch_iterator,
    init_state,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def main(steps=200, full=False, batch=4, seq=128, ckpt="/tmp/repro_train_small.npz"):
    cfg = get_config("mamba2-130m")
    if not full:
        cfg = dataclasses.replace(
            cfg,
            num_layers=6,
            vocab_size=2048,
            ssd_chunk=64,
            dtype="float32",
            param_dtype="float32",
            scan_layers=True,
        )
    else:
        cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    model = Model(cfg)
    n = cfg.n_params()
    print(f"training {cfg.arch_id} ({n/1e6:.1f}M params) for {steps} steps, "
          f"batch={batch} seq={seq}")

    opt = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=steps // 10)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_state(model, jax.random.PRNGKey(0))
    it = batch_iterator(cfg, batch, seq, seed=0)

    losses = []
    t0 = time.time()
    for i in range(steps):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
        if (i + 1) % 25 == 0:
            rate = batch * seq * (i + 1) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}  ({rate:,.0f} tok/s)", flush=True)

    # loss must actually fall (the synthetic stream has learnable structure)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "training should reduce loss substantially"

    save_checkpoint(ckpt, {"params": state.params}, step=steps)
    restored, at = restore_checkpoint(ckpt, {"params": state.params})
    leaves0 = jax.tree.leaves(state.params)
    leaves1 = jax.tree.leaves(restored["params"])
    assert all(np.allclose(a, b) for a, b in zip(leaves0, leaves1))
    print(f"checkpoint round-trip OK ({ckpt}, step {at})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(args.steps, args.full, args.batch, args.seq)
